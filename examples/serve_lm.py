"""Serve a small model with batched requests (prefill + decode engine).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.serve import Engine

mcfg = get_arch("llama3.2-1b").smoke(num_layers=4, d_model=256, d_ff=1024,
                                     vocab_size=8192, name="serve-demo")
shape = ShapeConfig("serve", seq_len=64, global_batch=8, kind="prefill")
cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1))

engine = Engine(cfg, max_len=128)
engine.init_params()

B, S = 8, 32
prompts = np.random.default_rng(0).integers(0, mcfg.vocab_size, (B, S),
                                            dtype=np.int32)
t0 = time.perf_counter()
out = engine.generate(prompts, max_new_tokens=16, greedy=True)
dt = time.perf_counter() - t0
print(f"batch={B} prompt={S} new=16 tokens in {dt:.2f}s "
      f"({B*out.steps/dt:.1f} tok/s)")
print("first row:", out.tokens[0])

# temperature sampling path
out2 = engine.generate(prompts, max_new_tokens=8, greedy=False,
                       temperature=0.8, seed=1)
print("sampled :", out2.tokens[0])
print("OK")
