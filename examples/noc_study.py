"""NoC design study: reproduce the paper's evaluation interactively.

Declares the paper's two network configurations as NocSpecs, sweeps the
cycle-level simulator over the Fig. 5 operating points with vmapped
``simulate_batch`` calls (one jit per topology instead of a Python loop
per point), and prints the latency/bandwidth tables plus the analytic
Table-I/Fig-6 quantities.

    PYTHONPATH=src python examples/noc_study.py
"""
import numpy as np

from repro.core.noc_sim import PAPER, PAPER_CLAIMS
from repro.noc import NocSpec, Workload, simulate, simulate_batch

print("=== Table I / bandwidth (analytic) ===")
print(f"wide link: {PAPER.wide_link_gbps():.0f} Gbps "
      f"(paper {PAPER_CLAIMS['wide_link_gbps']:.0f})")
print(f"duplex   : {PAPER.wide_link_duplex_tbps():.2f} Tbps "
      f"(paper {PAPER_CLAIMS['wide_link_duplex_tbps']})")
print(f"7x7 mesh boundary: {PAPER.mesh_boundary_bandwidth_tbs(7, 7):.1f} TB/s "
      f"(paper {PAPER_CLAIMS['mesh7x7_boundary_tbs']})")

print("\n=== zero-load latency ===")
spec = NocSpec.narrow_wide(2, 1, cycles=200)
m = simulate(spec, Workload.make("fig5", rates={"narrow": 0.01},
                                 counts={"narrow": 1}, src=0, dst=1))
print(f"adjacent-tile round trip: {m.classes['narrow'].avg_lat[0]:.0f} cycles "
      f"(paper {PAPER_CLAIMS['zero_load_round_trip_cycles']})")

print("\n=== Fig 5a: narrow latency vs wide interference ===")
wide_rates = (0.0, 0.25, 0.5, 0.75, 1.0)
for preset, label in ((NocSpec.narrow_wide, "narrow-wide"),
                      (NocSpec.wide_only, "wide-only  ")):
    spec = preset(4, 4, cycles=8000)
    wls = [Workload.make("fig5",
                         rates={"narrow": 0.05, "wide": rate},
                         counts={"narrow": 100, "wide": 200 if rate else 0},
                         src=0, dst=15, bidir=True)
           for rate in wide_rates]
    m = simulate_batch(spec, wls)              # one vmapped jit call
    row = m.classes["narrow"].avg_lat[:, 0]
    print(f"{label}: "
          + "  ".join(f"{r/row[0]:4.2f}x" for r in row))

print("\n=== Fig 5b: wide effective bandwidth vs narrow interference ===")
narrow_rates = (0.0, 0.25, 1.0)
for preset, label in ((NocSpec.narrow_wide, "narrow-wide"),
                      (NocSpec.wide_only, "wide-only  ")):
    spec = preset(4, 4, cycles=6000)
    wls = [Workload.make("fig5",
                         rates={"narrow": nrate, "wide": 1.0},
                         counts={"narrow": 3000 if nrate else 0,
                                 "wide": 256},
                         src=0, dst=5)
           for nrate in narrow_rates]
    m = simulate_batch(spec, wls)
    row = m.classes["wide"].eff_bw[:, 0]
    print(f"{label}: util " + "  ".join(f"{u:.2f}" for u in row)
          + f"  (relative: {row[-1]/max(row[0],1e-9):.2f})")

print("\n=== per-channel link energy (Fig 6 model) ===")
spec = NocSpec.narrow_wide(4, 4, cycles=6000)
m = simulate(spec, Workload.make("fig5",
                                 rates={"narrow": 0.05, "wide": 1.0},
                                 counts={"narrow": 100, "wide": 64},
                                 src=0, dst=15))
for name, ch in m.channels.items():
    print(f"  {name:6s}: {int(ch.link_moves):6d} link moves, "
          f"{float(ch.energy_pj)/1e3:8.1f} nJ")
print(f"1 kB x 1 hop: {PAPER.energy_pj(1024, 1):.0f} pJ "
      f"({PAPER.pj_per_byte_hop} pJ/B/hop)")
print("OK")
