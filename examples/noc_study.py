"""NoC design study: reproduce the paper's evaluation interactively.

Sweeps the cycle-level simulator over the Fig. 5 operating points and
prints the latency/bandwidth tables plus the analytic Table-I/Fig-6
quantities.

    PYTHONPATH=src python examples/noc_study.py
"""
import numpy as np

from repro.core.noc_sim import (PAPER, PAPER_CLAIMS, SimConfig, fig5_traffic,
                                run_sim)

print("=== Table I / bandwidth (analytic) ===")
print(f"wide link: {PAPER.wide_link_gbps():.0f} Gbps "
      f"(paper {PAPER_CLAIMS['wide_link_gbps']:.0f})")
print(f"duplex   : {PAPER.wide_link_duplex_tbps():.2f} Tbps "
      f"(paper {PAPER_CLAIMS['wide_link_duplex_tbps']})")
print(f"7x7 mesh boundary: {PAPER.mesh_boundary_bandwidth_tbs(7, 7):.1f} TB/s "
      f"(paper {PAPER_CLAIMS['mesh7x7_boundary_tbs']})")

print("\n=== zero-load latency ===")
cfg = SimConfig(nx=2, ny=1, cycles=200, service_lat=10)
m = run_sim(cfg, fig5_traffic(cfg, num_narrow=1, num_wide=0,
                              narrow_rate=0.01, src=0, dst=1))
print(f"adjacent-tile round trip: {m['narrow_avg_lat'][0]:.0f} cycles "
      f"(paper {PAPER_CLAIMS['zero_load_round_trip_cycles']})")

print("\n=== Fig 5a: narrow latency vs wide interference ===")
for nw in (True, False):
    row = []
    for rate in (0.0, 0.25, 0.5, 0.75, 1.0):
        cfg = SimConfig(nx=4, ny=4, cycles=8000, narrow_wide=nw,
                        service_lat=10)
        tr = fig5_traffic(cfg, num_narrow=100,
                          num_wide=200 if rate else 0, wide_rate=rate,
                          narrow_rate=0.05, src=0, dst=15, bidir=True)
        m = run_sim(cfg, tr)
        row.append(float(m["narrow_avg_lat"][0]))
    base = row[0]
    label = "narrow-wide" if nw else "wide-only  "
    print(f"{label}: " + "  ".join(f"{r/base:4.2f}x" for r in row))

print("\n=== Fig 5b: wide effective bandwidth vs narrow interference ===")
for nw in (True, False):
    row = []
    for nrate in (0.0, 0.25, 1.0):
        cfg = SimConfig(nx=4, ny=4, cycles=6000, narrow_wide=nw,
                        service_lat=10)
        tr = fig5_traffic(cfg, num_narrow=3000 if nrate else 0, num_wide=256,
                          wide_rate=1.0, narrow_rate=nrate, src=0, dst=5)
        m = run_sim(cfg, tr)
        row.append(float(m["wide_eff_bw"][0]))
    label = "narrow-wide" if nw else "wide-only  "
    print(f"{label}: util " + "  ".join(f"{u:.2f}" for u in row)
          + f"  (relative: {row[-1]/max(row[0],1e-9):.2f})")

print("\n=== energy (Fig 6) ===")
print(f"1 kB x 1 hop: {PAPER.energy_pj(1024, 1):.0f} pJ "
      f"({PAPER.pj_per_byte_hop} pJ/B/hop)")
print("OK")
