"""Replay the repo's OWN ML workloads on the simulated NoC.

The trace bridge (`repro.noc.traces`) end to end: trace real
train/prefill/decode steps on a 2x2 device mesh, capture their
collective byte ledgers, and replay them as AXI4 traffic on a 7x7
narrow/wide NoC — then compare MoE all-to-all dispatch against the
classic hotspot archetype, and show what per-stream AXI IDs
(`TrafficClass(n_streams=)`) buy on a real decode trace.

    PYTHONPATH=src python examples/noc_ml_traffic_study.py
"""
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs import ShapeConfig, get_arch              # noqa: E402
from repro.configs.base import MeshConfig, RunConfig         # noqa: E402
from repro.core.channels import Ledger                       # noqa: E402
from repro.dist import step as step_lib                      # noqa: E402
from repro.models import build_model                         # noqa: E402
from repro.noc import NocSpec, Workload, simulate            # noqa: E402

MESH_CFG = MeshConfig(data=2, model=2, pod=1)


def trace_ledger(arch: str, phase: str) -> Ledger:
    """Build one step and trace it (no compute) — the ledger records
    every collective the step would run on real devices."""
    mcfg = get_arch(arch).smoke()
    cfg = RunConfig(model=mcfg, shape=ShapeConfig("p", 32, 4, "prefill"),
                    mesh=MESH_CFG)
    mesh = jax.make_mesh(MESH_CFG.shape, MESH_CFG.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    model = build_model(mcfg, cfg)
    if phase == "train":
        art = step_lib.build_train_step(
            model, ShapeConfig("t", 32, 4, "train"), mesh)
    elif phase == "prefill":
        art = step_lib.build_prefill_step(
            model, ShapeConfig("p", 32, 4, "prefill"), mesh)
    else:
        art = step_lib.build_decode_step(
            model, ShapeConfig("d", 64, 4, "decode"), mesh)
    art.fn.lower(*art.in_sds)          # trace time populates the ledger
    return art.ledger


def streamed(spec: NocSpec, n: int) -> NocSpec:
    return spec.with_(classes=tuple(
        dataclasses.replace(c, n_streams=min(n, c.max_outstanding))
        for c in spec.classes))


print("=== train vs prefill vs decode on a 7x7 narrow/wide NoC ===")
# the traced job ran on a 2x2 device mesh: map its 4 ranks onto a 2x2
# corner of the 7x7 fabric (the rest of the mesh carries no traffic)
MAP = {"data": 2, "model": 2}
spec = NocSpec.narrow_wide(7, 7, cycles=6000)
ledgers = {ph: trace_ledger("llama3.2-1b", ph)
           for ph in ("train", "prefill", "decode")}
print("phase     entries    wide KB  narrow KB   done  w_lat avg/max"
      "  makespan  drained")
for ph, led in ledgers.items():
    by_cls = {"wide": 0, "narrow": 0}
    for e in led.entries:
        by_cls[e.traffic_class] += e.nbytes
    # scale production-sized tensors down to a simulable burst count
    r = simulate(spec, Workload.from_ledger(led, spec, mapping=MAP,
                                            scale=0.25))
    w = r.classes["wide"]
    lat = w.w_avg_lat[w.w_done > 0]
    done = sum(int(c.done.sum() + c.w_done.sum())
               for c in r.classes.values())
    mk = max(int(c.stream_w_last_t.max()) for c in r.classes.values())
    print(f"{ph:8s}  {len(led.entries):5d}  {by_cls['wide'] / 2**10:9.1f} "
          f" {by_cls['narrow'] / 2**10:9.2f}  {done:5d}"
          f"  {float(lat.mean()) if lat.size else float('nan'):6.1f}/"
          f"{int(w.w_max_lat.max()):4d}  {mk:8d}  {bool(r.drained)}")

print("\n=== MoE all-to-all dispatch vs hotspot archetype ===")
moe = trace_ledger("grok-1-314b", "prefill")
a2a = Ledger(entries=[e for e in moe.entries if e.op == "all_to_all"])
a2a_bytes = sum(e.nbytes for e in a2a.entries)
print(f"grok-1 prefill logs {len(a2a.entries)} all_to_all entries, "
      f"{a2a_bytes / 2**10:.0f} KiB")
spec_a2a = NocSpec.narrow_wide(7, 7, cycles=20000)
r_a2a = simulate(spec_a2a, Workload.from_ledger(a2a, spec_a2a, scale=0.25))
# a hotspot pattern pushing a comparable wide write volume at one tile
burst_bytes = 16 * 512 // 8
txns = max(1, int(a2a_bytes * 0.25 / burst_bytes) // spec.n_routers)
r_hot = simulate(spec_a2a, Workload.make(
    "hotspot", rates={"wide": 1.0}, counts={"wide": txns},
    hot=spec.n_routers // 2, hot_frac=1.0, write_frac=1.0, seed=0))
for tag, r in (("all_to_all", r_a2a), ("hotspot", r_hot)):
    w = r.classes["wide"]
    lat = w.w_avg_lat[w.w_done > 0]
    moves = int(r.channels["wide"].link_moves)
    print(f"  {tag:10s}: {int(w.w_done.sum()):4d} writes  "
          f"avg lat {float(lat.mean()):6.1f}  max {int(w.w_max_lat.max()):4d}"
          f"  wide-link moves {moves:6d}  drained {bool(r.drained)}")
print("  (the exchange spreads load across every link; the hotspot "
      "serializes at one ejection port)")

print("\n=== per-stream AXI IDs on the decode trace ===")
led = ledgers["decode"]
print("n_streams  wide w_avg_lat  per-stream last W beat")
for n in (1, 2, 4):
    sp = streamed(NocSpec.narrow_wide(7, 7, cycles=6000), n)
    r = simulate(sp, Workload.from_ledger(led, sp, mapping=MAP, scale=0.25))
    w = r.classes["wide"]
    lat = float(w.w_avg_lat[w.w_done > 0].mean())
    per = np.asarray(w.stream_w_last_t).max(axis=-1).astype(int)
    print(f"    {n}        {lat:8.1f}     {per.tolist()}")
print("(consecutive collectives round-robin across AXI IDs: with more "
      "streams, a bulk transfer in flight no longer holds the next "
      "collective's transactions in the shared in-order ROB, so the "
      "mean write latency of the SAME trace drops)")
