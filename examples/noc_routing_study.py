"""Routing & virtual-channel study: deadlock freedom and path diversity.

The journal FlooNoC evaluation pairs the wide physical channels with a
routing layer: dimension-ordered XY by default, an escape virtual
channel with a dateline discipline to make torus wrap links
deadlock-free, and optional multi-plane policies (O1TURN, Valiant) for
path diversity under adversarial traffic.  This study reproduces that
story on the cycle-level simulator:

1. the wedge: a minimal-wrap torus under saturating wormhole bursts
   deadlocks with a single VC — visible as ``drained=False``, a stall
   streak the length of the remaining horizon, and VC0 occupancy pinned
   at its peak,
2. the fix: the identical spec with ``RoutingPolicy.xy(n_vcs=2)``
   (dateline escape VC) drains, and at equal load completes at least as
   many transactions as the mesh — wrap links now pay off instead of
   wedging,
3. path diversity: O1TURN splits flows across XY and YX planes
   (both VC groups show occupancy), Valiant trades hops for balance.

    PYTHONPATH=src python examples/noc_routing_study.py
"""
import numpy as np

from repro.noc import (Mesh, NocSpec, RoutingPolicy, Torus, Workload,
                       simulate)

CYCLES = 3500
wl = Workload.make("all_to_all", rates={"wide": 1.0}, rounds={"wide": 4},
                   write_frac=0.5)


def run(topo, pol):
    spec = NocSpec.wide_only(4, 4, topology=topo, burstlen=32,
                             cycles=CYCLES, max_wide_outstanding=16,
                             routing=pol)
    return simulate(spec, wl)


def report(tag, m):
    st = m.classes["wide"]
    done = int(st.done.sum()) + int(st.w_done.sum())
    occ = np.round(m.channels["wide"].vc_occupancy, 1)
    print(f"  {tag:22s} done={done:4d} drained={str(bool(m.drained)):5s} "
          f"max_stall={int(m.max_stall_cycles):4d} vc_occ={occ.tolist()}")
    return done


print("=== 1. the wedge: saturating bursts on a VC-less torus ===")
wedged = run(Torus(4, 4), RoutingPolicy.xy(1))
report("torus xy 1vc (wedged)", wedged)
assert not bool(wedged.drained)

print("\n=== 2. the fix: escape-VC dateline routing ===")
mesh_done = report("mesh  xy 1vc", run(Mesh(4, 4), RoutingPolicy.xy(1)))
torus_done = report("torus xy 2vc (fixed)",
                    run(Torus(4, 4), RoutingPolicy.xy(2)))
print(f"  -> torus with escape VC completes {torus_done} >= mesh "
      f"{mesh_done} at equal load (wrap links now help)")
assert torus_done >= mesh_done

print("\n=== 3. path diversity: multi-plane policies ===")
report("mesh  o1turn 2vc", run(Mesh(4, 4), RoutingPolicy.o1turn(2)))
report("torus o1turn 4vc", run(Torus(4, 4), RoutingPolicy.o1turn(4)))
report("mesh  valiant 4vc", run(Mesh(4, 4), RoutingPolicy.valiant(4)))
print("  (o1turn: both VC planes occupied -> flows split XY/YX; "
      "valiant pays detour hops for load balance)")
