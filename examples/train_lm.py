"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
with checkpointing, resume, and the narrow/wide (floo) collective backend.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--small]

The --small flag (used by CI) shrinks to ~10M params / 50 steps.
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    base = get_arch("llama3.2-1b")
    if args.small:
        mcfg = base.smoke(num_layers=4, d_model=256, d_ff=1024,
                          vocab_size=4096, name="lm-10m")
        shape = ShapeConfig("small", seq_len=128, global_batch=8, kind="train")
        steps = min(args.steps, 50)
    else:
        # ~100M params: 12L x d=640, GQA 10/2 heads, 50k vocab
        mcfg = dataclasses.replace(
            base, name="lm-100m", num_layers=12, d_model=640, num_heads=10,
            num_kv_heads=2, head_dim=64, d_ff=2560, vocab_size=50_304,
            tie_embeddings=True)
        shape = ShapeConfig("lm100m", seq_len=256, global_batch=8,
                            kind="train")
        steps = args.steps

    cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1),
                    backend="floo", learning_rate=6e-4, microbatches=2)
    print(f"params={mcfg.param_count()/1e6:.1f}M steps={steps} "
          f"tokens/step={shape.tokens}")
    res = train(cfg, num_steps=steps, ckpt_dir=args.ckpt, ckpt_every=50,
                log_every=10)
    w = max(len(res.losses) // 10, 1)
    print(f"loss first10={np.mean(res.losses[:w]):.3f} "
          f"last10={np.mean(res.losses[-w:]):.3f}")
    assert np.mean(res.losses[-w:]) < np.mean(res.losses[:w])
    print("OK")


if __name__ == "__main__":
    main()
