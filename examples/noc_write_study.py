"""AXI4 write-traffic study: the full AW/W/B flow model end to end.

Sweeps the read/write mix of the Fig. 5 workload through one vmapped
``simulate_batch`` call, showing (1) per-direction latency/bandwidth,
(2) how write data shifts the per-channel link-energy ledger (W bursts
ride the wide channel, B acks load the narrow rsp channel — the
paper's AW/AR/B-narrow, W/R-wide mapping), (3) per-class
service-latency *distributions* (mean + seeded jitter), and (4) the
liveness fields on a saturating VC-less torus, where minimal-wrap
wormhole bursts can wedge (see ROADMAP).

    PYTHONPATH=src python examples/noc_write_study.py
"""
import numpy as np

from repro.noc import NocSpec, Torus, Workload, simulate, simulate_batch

print("=== read/write mix sweep (one vmapped jit) ===")
spec = NocSpec.narrow_wide(4, 4, cycles=6000)
mixes = (0.0, 0.25, 0.5, 0.75, 1.0)
wls = [Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                     counts={"narrow": 60, "wide": 48},
                     src=0, dst=15, bidir=True, write_frac=mix)
       for mix in mixes]
m = simulate_batch(spec, wls)
print("write_frac   reads  writes  rd_lat  wr_lat  rsp_moves  wide_moves")
for i, mix in enumerate(mixes):
    wide = m.classes["wide"]
    reads = int(wide.done[i].sum())
    writes = int(wide.w_done[i].sum())
    rd_lat = float(np.max(wide.avg_lat[i])) if reads else float("nan")
    wr_lat = float(np.max(wide.w_avg_lat[i])) if writes else float("nan")
    print(f"  {mix:4.2f}      {reads:4d}   {writes:4d}   "
          f"{rd_lat:6.1f}  {wr_lat:6.1f}  "
          f"{int(m.channels['rsp'].link_moves[i]):8d}  "
          f"{int(m.channels['wide'].link_moves[i]):9d}")

print("\n=== per-channel energy at 50/50 (B acks on rsp, W on wide) ===")
r = simulate(spec, wls[2])
for name, ch in r.classes.items():
    print(f"  {name:6s}: rd {int(ch.done.sum()):3d} done "
          f"/ {int(ch.beats_rx.sum()):4d} R beats | "
          f"wr {int(ch.w_done.sum()):3d} done "
          f"/ {int(ch.w_beats_rx.sum()):4d} W beats")
for name, ch in r.channels.items():
    print(f"  {name:6s}: {int(ch.link_moves):6d} moves "
          f"{float(ch.energy_pj) / 1e3:8.1f} nJ")

print("\n=== per-class service-latency distributions ===")
wl = Workload.make("uniform_random", rates={"narrow": 0.4, "wide": 0.8},
                   counts={"narrow": 40, "wide": 10}, seed=3,
                   write_frac=0.5)
flat = simulate(spec, wl, service_lat=[8, 24], service_jitter=0)
jit = simulate(spec, wl, service_lat=[8, 24], service_jitter=[6, 0])
for tag, res in (("jitter=0", flat), ("narrow +/-6", jit)):
    st = res.classes["narrow"]
    print(f"  {tag:12s}: narrow avg {float(np.mean(st.avg_lat)):6.1f} "
          f"max {int(np.max(st.max_lat)):3d} cycles")

print("\n=== liveness: saturating bursts, mesh vs VC-less torus ===")
burst_wl = Workload.make("all_to_all", rates={"wide": 1.0},
                         rounds={"wide": 4}, write_frac=0.5)
for tag, topo in (("mesh ", None), ("torus", Torus(4, 4))):
    s = NocSpec.wide_only(4, 4, topology=topo, burstlen=32, cycles=2500,
                          max_wide_outstanding=16)
    res = simulate(s, burst_wl)
    print(f"  {tag}: drained={str(bool(res.drained)):5s} "
          f"max_stall={int(res.max_stall_cycles):4d} cycles "
          f"completed={int(res.classes['wide'].done.sum()) + int(res.classes['wide'].w_done.sum()):3d}")
print("OK")
