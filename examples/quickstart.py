"""Quickstart: train a tiny llama-family model for 20 steps, then generate.

Runs on a single CPU device in ~a minute:
    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.serve import Engine
from repro.train.loop import train

mcfg = get_arch("llama3.2-1b").smoke()           # reduced same-family config
shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")
cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1),
                learning_rate=1e-3)

print(f"arch={mcfg.name} params={mcfg.param_count()/1e6:.1f}M")
res = train(cfg, num_steps=20, log_every=5)
print(f"loss: {res.losses[0]:.3f} -> {res.final_loss:.3f} "
      f"({res.steps} steps, {np.mean(res.step_times)*1e3:.0f} ms/step)")
assert res.final_loss < res.losses[0], "loss should decrease"

engine = Engine(cfg, max_len=96)
engine.init_params()
out = engine.generate(np.ones((2, 8), np.int32), max_new_tokens=8)
print("generated:", out.tokens)
print("OK")
