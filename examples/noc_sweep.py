"""Design-space exploration with the declarative repro.noc API.

Demonstrates what the NocSpec/Workload/simulate redesign buys beyond
the paper's two fixed configurations:

1. a vmapped injection-rate sweep (one jitted call for the whole
   curve — the Python-loop-free path for Fig.-5-style studies),
2. arbitrary channel counts: 1 (wide-only), 3 (paper narrow-wide), and
   journal-style 2/4-stream parallel wide channels, compared under an
   all-to-all DNN-phase workload,
3. workload patterns beyond paired tiles: hotspot and transpose,
4. first-class fabric topologies (mesh / torus / express-link mesh)
   and the pluggable Pallas router backend behind the same simulate().

    PYTHONPATH=src python examples/noc_sweep.py
"""
import numpy as np

from repro.noc import (Mesh, NocSpec, Torus, Workload, hop_table, simulate,
                       simulate_batch)

# ------------------------------------------------------------------ #
# 1. one-jit rate sweep
# ------------------------------------------------------------------ #
print("=== vmapped rate sweep (one jit call) ===")
spec = NocSpec.narrow_wide(4, 4, cycles=4000)
rates = [0.2, 0.4, 0.6, 0.8, 1.0]
wls = [Workload.make("fig5", rates={"narrow": 0.05, "wide": r},
                     counts={"narrow": 50, "wide": 48}, src=0, dst=15)
       for r in rates]
res = simulate_batch(spec, wls)          # arrays carry a leading sweep axis
for i, r in enumerate(rates):
    pt = res.point(i)
    print(f"  wide_rate={r:.1f}: narrow avg "
          f"{pt.classes['narrow'].avg_lat[0]:5.1f} cyc, wide eff bw "
          f"{pt.classes['wide'].eff_bw[0]:.2f} beats/cyc")

# ------------------------------------------------------------------ #
# 2. channel-count exploration under an all-to-all phase
# ------------------------------------------------------------------ #
print("\n=== channel topologies under all-to-all (DNN exchange phase) ===")


def all_to_all_wl(spec, per_wide_rate):
    wide_classes = [c.name for c in spec.classes if c.burst_beats > 1]
    rates = {"narrow": 0.1}
    rounds = {"narrow": 4}
    for w in wide_classes:
        rates[w] = per_wide_rate / len(wide_classes)
        rounds[w] = max(1, 4 // len(wide_classes))
    return Workload.make("all_to_all", rates=rates, rounds=rounds)


topologies = [
    ("wide-only (1 ch) ", NocSpec.wide_only(4, 4, cycles=6000)),
    ("narrow-wide (3 ch)", NocSpec.narrow_wide(4, 4, cycles=6000)),
    ("2-stream (4 ch)   ", NocSpec.multi_stream(4, 4, n_wide=2,
                                                cycles=6000)),
    ("4-stream (6 ch)   ", NocSpec.multi_stream(4, 4, n_wide=4,
                                                cycles=6000)),
]
for label, topo in topologies:
    r = simulate(topo, all_to_all_wl(topo, per_wide_rate=1.0))
    s = r.summary()
    wide_done = sum(int(np.sum(st.done)) for name, st in r.classes.items()
                    if name != "narrow")
    print(f"  {label}: narrow avg {float(s['narrow_avg_lat']):6.1f} cyc, "
          f"wide txns {wide_done:4d}, link energy "
          f"{float(s['total_energy_pj'])/1e6:7.2f} uJ "
          f"({len(topo.channels)} nets)")

# ------------------------------------------------------------------ #
# 3. beyond paired tiles: hotspot and transpose
# ------------------------------------------------------------------ #
print("\n=== hotspot vs transpose (narrow-wide, 4x4) ===")
spec = NocSpec.narrow_wide(4, 4, cycles=6000)
patterns = [
    Workload.make("hotspot", rates={"narrow": 0.1, "wide": 0.5},
                  counts={"narrow": 20, "wide": 8}, hot_frac=0.7),
    Workload.make("transpose", rates={"narrow": 0.1, "wide": 0.5},
                  counts={"narrow": 20, "wide": 8}),
]
res = simulate_batch(spec, patterns)     # different patterns, one jit
for name, i in (("hotspot  ", 0), ("transpose", 1)):
    pt = res.point(i)
    nl = pt.classes["narrow"]
    active = nl.done > 0
    avg = float(np.sum(nl.avg_lat * active) / max(np.sum(active), 1))
    print(f"  {name}: narrow avg {avg:6.1f} cyc "
          f"(worst NI {float(np.max(nl.max_lat)):5.0f}), wide beats "
          f"{int(np.sum(pt.classes['wide'].beats_rx)):5d}")

# ------------------------------------------------------------------ #
# 4. fabric topologies + pluggable backends
# ------------------------------------------------------------------ #
print("\n=== fabric topologies (corner-to-corner, narrow-wide) ===")
wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                   counts={"narrow": 30, "wide": 16}, src=0, dst=15)
for label, fabric in (("mesh 4x4        ", Mesh(4, 4)),
                      ("torus 4x4       ", Torus(4, 4)),
                      ("mesh + express-2", Mesh(4, 4, express=(2,)))):
    spec = NocSpec.narrow_wide(4, 4, topology=fabric, cycles=4000)
    r = simulate(spec, wl)
    print(f"  {label}: max hops {int(hop_table(fabric).max())}, "
          f"narrow avg {float(r.classes['narrow'].avg_lat[0]):5.1f} cyc, "
          f"link moves {int(r.total_link_moves):6d} "
          f"({fabric.n_ports}-port routers)")

print("\n=== backend equivalence (jnp reference vs Pallas arbiter) ===")
spec = NocSpec.narrow_wide(4, 4, cycles=2000)
ref = simulate(spec, wl)
pal = simulate(spec, wl, backend="pallas")
same = np.array_equal(ref.classes["narrow"].done, pal.classes["narrow"].done)
print(f"  flit-for-flit identical: {same and int(ref.total_link_moves) == int(pal.total_link_moves)}")
print("OK")
