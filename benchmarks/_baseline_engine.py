"""PINNED pre-fusion engine — the perf baseline, not a product path.

This is a frozen, self-contained copy of the cycle engine as it stood
before the fused-hot-loop PR: Python-unrolled per-class/per-queue NI
updates (6 scatters per ``_q_push``, per-class ``col``-masked metric
updates), a per-output-port scatter loop in the fabric step, one
separate ``lax.scan`` body per physical channel, and a static FIFO
depth baked into the compilation.  ``bench_engine_throughput`` in
``run.py`` times it against the live engine in the same process so
BENCH_noc.json records a real before/after speedup instead of numbers
measured on different machines.

Do not "fix" or modernize this file — its whole value is staying
identical to commit d5128ae's hot path.  It shares only the flit-field
constants and NocSpec surface with the live code; everything hot is
local.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc_sim.router import (F_BEAT, F_DEST, F_KIND, F_SRC, F_TIME,
                                       F_TXN, N_FIELDS)
from repro.noc.engine import (build_channel_plan, req_kind, rsp_kind,
                              ChannelPlan)
from repro.noc.spec import NocSpec

RESP_Q_CAP = 256
BIG = 1 << 30
NO_PORT = 99


class NetState(NamedTuple):
    fifo: jax.Array     # (R, P, D, F)
    count: jax.Array    # (R, P)
    rr_ptr: jax.Array   # (R, P)
    oreg: jax.Array     # (R, P, F)
    oreg_v: jax.Array   # (R, P)
    lock_in: jax.Array  # (R, P)


def init_fabric_state(R: int, P: int, depth: int = 2) -> NetState:
    return NetState(
        fifo=jnp.zeros((R, P, depth, N_FIELDS), jnp.int32),
        count=jnp.zeros((R, P), jnp.int32),
        rr_ptr=jnp.zeros((R, P), jnp.int32),
        oreg=jnp.zeros((R, P, N_FIELDS), jnp.int32),
        oreg_v=jnp.zeros((R, P), jnp.bool_),
        lock_in=jnp.full((R, P), -1, jnp.int32),
    )


def arbiter_jnp(out_port, beat, rr_ptr, oreg_free, lock_in):
    R, P = out_port.shape
    o_ids = jnp.arange(P)[None, None, :]
    i_ids = jnp.arange(P)[None, :, None]
    req = (out_port[:, :, None] == o_ids) & oreg_free.astype(bool)[:, None, :]
    locked = lock_in[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock_in[:, None, :])

    prio = (i_ids - rr_ptr[:, None, :]) % P
    score = jnp.where(req, prio, NO_PORT)
    best = jnp.min(score, axis=1)
    granted = best < NO_PORT
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)

    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    new_ptr = jnp.where(granted & (lock_in < 0), (winner + 1) % P, rr_ptr)

    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :], beat[:, :, None], 0),
                     axis=1)
    new_lock = jnp.where(granted & (w_beat > 1), winner,
                         jnp.where(granted, -1, lock_in))
    return winner, pop, new_ptr, new_lock


def make_fabric_step(nbr: np.ndarray, opp: np.ndarray, route: np.ndarray):
    """Pre-PR fabric step: per-output-port scatter loop, static depth."""
    R, P = nbr.shape
    PORT_L = P - 1
    nbr_j = jnp.asarray(nbr, jnp.int32)
    opp_j = jnp.asarray(opp, jnp.int32)
    route_j = jnp.asarray(route, jnp.int32)
    r_idx = jnp.arange(R)

    def step(state: NetState, inject_valid, inject_flit):
        D = state.fifo.shape[2]
        heads = state.fifo[:, :, 0, :]
        head_valid = state.count > 0

        ds_count = state.count[jnp.clip(nbr_j, 0, R - 1), opp_j]
        can_drain = jnp.where(jnp.arange(P)[None, :] == PORT_L,
                              True,
                              (nbr_j >= 0) & (ds_count < D))
        drain = state.oreg_v & can_drain

        deliver_valid = drain[:, PORT_L]
        deliver_flit = state.oreg[:, PORT_L, :]

        recv_valid = jnp.zeros((R, P), jnp.bool_)
        recv_flit = jnp.zeros((R, P, N_FIELDS), jnp.int32)
        tgt_r = jnp.where(nbr_j >= 0, nbr_j, 0)
        for o in range(P - 1):
            v = drain[:, o]
            recv_valid = recv_valid.at[tgt_r[:, o], opp_j[:, o]].max(v)
            recv_flit = recv_flit.at[tgt_r[:, o], opp_j[:, o]].add(
                jnp.where(v[:, None], state.oreg[:, o, :], 0))

        local_ready = state.count[:, PORT_L] < D
        inj_ok = inject_valid & local_ready
        recv_valid = recv_valid.at[:, PORT_L].set(inj_ok)
        recv_flit = recv_flit.at[:, PORT_L].set(
            jnp.where(inj_ok[:, None], inject_flit, 0))

        oreg_free = (~state.oreg_v) | drain
        out_port = route_j[r_idx[:, None], heads[:, :, F_DEST]]
        out_port = jnp.where(head_valid, out_port, NO_PORT)
        winner, pop, new_ptr, new_lock = arbiter_jnp(
            out_port, heads[:, :, F_BEAT], state.rr_ptr, oreg_free,
            state.lock_in)

        any_grant = winner >= 0
        flit_to_oreg = heads[r_idx[:, None], jnp.clip(winner, 0)]
        new_oreg_v = (state.oreg_v & ~drain) | any_grant
        new_oreg = jnp.where(any_grant[:, :, None], flit_to_oreg, state.oreg)

        shifted = jnp.concatenate(
            [state.fifo[:, :, 1:, :],
             jnp.zeros_like(state.fifo[:, :, :1, :])], axis=2)
        fifo = jnp.where(pop[:, :, None, None], shifted, state.fifo)
        count = state.count - pop.astype(jnp.int32)

        slot = jnp.clip(count, 0, D - 1)
        write = recv_valid & (count < D)
        onehot_slot = jax.nn.one_hot(slot, D, dtype=jnp.bool_)
        sel = write[:, :, None] & onehot_slot
        fifo = jnp.where(sel[..., None], recv_flit[:, :, None, :], fifo)
        count = count + write.astype(jnp.int32)

        new_state = NetState(fifo=fifo, count=count, rr_ptr=new_ptr,
                             oreg=new_oreg, oreg_v=new_oreg_v,
                             lock_in=new_lock)
        link_moves = jnp.sum(drain.astype(jnp.int32)
                             * (jnp.arange(P)[None, :] != PORT_L))
        return new_state, inj_ok, deliver_valid, deliver_flit, link_moves

    return step


class NIState(NamedTuple):
    ptr: jax.Array
    out: jax.Array
    rq_head: jax.Array
    rq_tail: jax.Array
    rq_ready: jax.Array
    rq_dest: jax.Array
    rq_beats: jax.Array
    rq_time0: jax.Array
    rq_txn: jax.Array
    rq_kind: jax.Array
    w_started: jax.Array
    inj_rr: jax.Array
    lat_sum: jax.Array
    lat_max: jax.Array
    done: jax.Array
    beats_rx: jax.Array
    first_t: jax.Array
    last_t: jax.Array


class SimState(NamedTuple):
    nets: tuple
    ni: NIState
    cycle: jax.Array
    moves: jax.Array


def init_ni(R: int, topo: ChannelPlan) -> NIState:
    zc = jnp.zeros((R, topo.n_cls), jnp.int32)
    zq = jnp.zeros((R, topo.n_q), jnp.int32)
    zqc = jnp.zeros((R, topo.n_q, RESP_Q_CAP), jnp.int32)
    return NIState(
        ptr=zc, out=zc, rq_head=zq, rq_tail=zq, rq_ready=zqc, rq_dest=zqc,
        rq_beats=zqc, rq_time0=zqc, rq_txn=zqc, rq_kind=zqc,
        w_started=jnp.zeros((R, topo.n_q), jnp.bool_),
        inj_rr=jnp.zeros((R, topo.n_ch), jnp.int32),
        lat_sum=zc, lat_max=zc, done=zc, beats_rx=zc,
        first_t=jnp.full((R, topo.n_cls), BIG, jnp.int32), last_t=zc)


def _q_push(ni, q, valid, dest, beats, time0, txn, ready_at, kind):
    rows = jnp.arange(valid.shape[0])
    slot = ni.rq_tail[:, q] % RESP_Q_CAP

    def upd(arr, val):
        return arr.at[rows, q, slot].set(
            jnp.where(valid, val, arr[rows, q, slot]))

    return ni._replace(
        rq_ready=upd(ni.rq_ready, ready_at),
        rq_dest=upd(ni.rq_dest, dest),
        rq_beats=upd(ni.rq_beats, beats),
        rq_time0=upd(ni.rq_time0, time0),
        rq_txn=upd(ni.rq_txn, txn),
        rq_kind=upd(ni.rq_kind, kind),
        rq_tail=ni.rq_tail.at[:, q].add(valid.astype(jnp.int32)),
    )


def _q_head(ni, q, now):
    rows = jnp.arange(ni.rq_head.shape[0])
    have = ni.rq_head[:, q] < ni.rq_tail[:, q]
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    ready = have & (ni.rq_ready[rows, q, slot] <= now)
    return {
        "ready": ready,
        "dest": ni.rq_dest[rows, q, slot],
        "beats": ni.rq_beats[rows, q, slot],
        "time0": ni.rq_time0[rows, q, slot],
        "txn": ni.rq_txn[rows, q, slot],
        "kind": ni.rq_kind[rows, q, slot],
    }


def _q_sent(ni, q, sent):
    rows = jnp.arange(sent.shape[0])
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    left = ni.rq_beats[rows, q, slot] - sent.astype(jnp.int32)
    return ni._replace(
        rq_beats=ni.rq_beats.at[rows, q, slot].set(
            jnp.where(sent, left, ni.rq_beats[rows, q, slot])),
        rq_head=ni.rq_head.at[:, q].add(
            (sent & (left <= 0)).astype(jnp.int32)),
        w_started=ni.w_started.at[:, q].set(
            jnp.where(sent, left > 0, ni.w_started[:, q])),
    )


def make_step(spec: NocSpec, topo: ChannelPlan, T: int, net_step):
    R = spec.n_routers
    rows = jnp.arange(R)

    def mk_flit(valid, dest, src, time, kind, txn, beat):
        f = jnp.zeros((R, N_FIELDS), jnp.int32)
        z = jnp.int32(0)
        for idx, val in ((F_DEST, dest), (F_SRC, src), (F_TIME, time),
                         (F_KIND, kind), (F_TXN, txn), (F_BEAT, beat)):
            f = f.at[:, idx].set(jnp.where(valid, val, z))
        return f

    def step(dyn, state: SimState, _):
        times, dests = dyn["times"], dyn["dests"]
        service_lat = dyn["service_lat"]
        max_out, burst_beats = dyn["max_out"], dyn["burst_beats"]
        ni = state.ni
        now = state.cycle

        want, req_d = [], []
        for i in range(topo.n_cls):
            p = jnp.clip(ni.ptr[:, i], 0, T - 1)
            want.append((ni.ptr[:, i] < T) & (times[i, rows, p] <= now)
                        & (ni.out[:, i] < max_out[i]))
            req_d.append(dests[i, rows, p])

        heads = [_q_head(ni, q, now) for q in range(topo.n_q)]

        injected = [jnp.zeros((R,), jnp.bool_) for _ in range(topo.n_cls)]
        sent = [jnp.zeros((R,), jnp.bool_) for _ in range(topo.n_q)]
        new_nets, deliveries, moves = [], [], []

        for c in range(topo.n_ch):
            reqs, qs = topo.reqs_on[c], topo.queues_on[c]
            if not reqs and not qs:
                net, _, dv, df, lm = net_step(
                    state.nets[c], jnp.zeros((R,), jnp.bool_),
                    jnp.zeros((R, N_FIELDS), jnp.int32))
            elif not reqs and len(qs) == 1:
                q = qs[0]
                h = heads[q]
                f = mk_flit(h["ready"], h["dest"], rows, h["time0"],
                            h["kind"], h["txn"], h["beats"])
                net, ok, dv, df, lm = net_step(state.nets[c], h["ready"], f)
                sent[q] = ok & h["ready"]
            elif reqs and not qs:
                taken = jnp.zeros((R,), jnp.bool_)
                sel = []
                for i in reqs:
                    s = want[i] & ~taken
                    sel.append((i, s))
                    taken = taken | s
                dest = kind = txn = jnp.zeros((R,), jnp.int32)
                for i, s in sel:
                    dest = jnp.where(s, req_d[i], dest)
                    kind = jnp.where(s, req_kind(i), kind)
                    txn = jnp.where(s, ni.ptr[:, i], txn)
                f = mk_flit(taken, dest, rows, now, kind, txn, 1)
                net, ok, dv, df, lm = net_step(state.nets[c], taken, f)
                for i, s in sel:
                    injected[i] = ok & s
            else:
                cand = ([("rsp", q) for q in qs]
                        + [("req", i) for i in reqs])
                n_cand = len(cand)
                cand_valid = jnp.stack(
                    [heads[q]["ready"] for q in qs]
                    + [want[i] for i in reqs], axis=1)
                rr = ni.inj_rr[:, c] % n_cand
                order = (jnp.arange(n_cand)[None, :] + rr[:, None]) % n_cand
                ordered = jnp.take_along_axis(cand_valid, order, axis=1)
                first = jnp.argmax(ordered, axis=1)
                has_any = jnp.any(cand_valid, axis=1)
                choice = jnp.take_along_axis(order, first[:, None],
                                             axis=1)[:, 0]
                hold = jnp.zeros((R,), jnp.bool_)
                for k, q in enumerate(qs):
                    hq = ni.w_started[:, q] & (heads[q]["beats"] > 0)
                    choice = jnp.where(hq & ~hold, k, choice)
                    hold = hold | hq
                valid0 = has_any | hold

                sel_masks = []
                for k, (tag, idx) in enumerate(cand):
                    gate = heads[idx]["ready"] if tag == "rsp" else want[idx]
                    sel_masks.append(valid0 & (choice == k) & gate)
                valid = functools.reduce(jnp.logical_or, sel_masks)

                dest = kind = txn = beat = jnp.zeros((R,), jnp.int32)
                time = jnp.broadcast_to(now, (R,)).astype(jnp.int32)
                for (tag, idx), s in zip(cand, sel_masks):
                    if tag == "rsp":
                        h = heads[idx]
                        dest = jnp.where(s, h["dest"], dest)
                        kind = jnp.where(s, h["kind"], kind)
                        txn = jnp.where(s, h["txn"], txn)
                        time = jnp.where(s, h["time0"], time)
                        beat = jnp.where(s, h["beats"], beat)
                    else:
                        dest = jnp.where(s, req_d[idx], dest)
                        kind = jnp.where(s, req_kind(idx), kind)
                        txn = jnp.where(s, ni.ptr[:, idx], txn)
                        beat = jnp.where(s, 1, beat)
                f = mk_flit(valid, dest, rows, time, kind, txn, beat)
                net, ok, dv, df, lm = net_step(state.nets[c], valid, f)
                for (tag, idx), s in zip(cand, sel_masks):
                    if tag == "rsp":
                        sent[idx] = sent[idx] | (ok & s)
                    else:
                        injected[idx] = ok & s
                ni = ni._replace(inj_rr=ni.inj_rr.at[:, c].add(
                    (ok & ~hold).astype(jnp.int32)))
            new_nets.append(net)
            deliveries.append((dv, df))
            moves.append(lm)

        inj = jnp.stack(injected, axis=1).astype(jnp.int32)
        ni = ni._replace(ptr=ni.ptr + inj, out=ni.out + inj)
        for q in range(topo.n_q):
            ni = _q_sent(ni, q, sent[q])

        for c, (dv, df) in enumerate(deliveries):
            kind = df[:, F_KIND]
            src = df[:, F_SRC]
            lat = now - df[:, F_TIME]
            for i in topo.reqs_on[c]:
                is_req = dv & (kind == req_kind(i))
                ni = _q_push(
                    ni, topo.queue_of_class[i], is_req, src,
                    jnp.broadcast_to(burst_beats[i], (R,)).astype(jnp.int32),
                    df[:, F_TIME], df[:, F_TXN], now + service_lat,
                    jnp.full((R,), rsp_kind(i), jnp.int32))
            rsp_classes = [i for i in range(topo.n_cls)
                           if topo.queue_of_class[i] in topo.queues_on[c]]
            for i in rsp_classes:
                is_rsp = dv & (kind == rsp_kind(i))
                last = is_rsp & (df[:, F_BEAT] <= 1)
                li = last.astype(jnp.int32)
                col = (jnp.arange(topo.n_cls) == i)
                ni = ni._replace(
                    beats_rx=ni.beats_rx + jnp.where(
                        col, is_rsp.astype(jnp.int32)[:, None], 0),
                    first_t=jnp.where(
                        col & is_rsp[:, None],
                        jnp.minimum(ni.first_t, now), ni.first_t),
                    last_t=jnp.where(
                        col & is_rsp[:, None],
                        jnp.maximum(ni.last_t, now), ni.last_t),
                    done=ni.done + jnp.where(col, li[:, None], 0),
                    lat_sum=ni.lat_sum + jnp.where(
                        col, jnp.where(last, lat, 0)[:, None], 0),
                    lat_max=jnp.maximum(ni.lat_max, jnp.where(
                        col, jnp.where(last, lat, 0)[:, None], 0)),
                    out=ni.out - jnp.where(col, li[:, None], 0),
                )

        new_moves = state.moves + jnp.stack(moves).astype(jnp.int32)
        return SimState(tuple(new_nets), ni, now + 1, new_moves), None

    return step


@functools.lru_cache(maxsize=16)
def compiled_sim_baseline(spec: NocSpec, T: int):
    """The pre-PR ``compiled_sim``: separate per-channel scan bodies,
    Python-unrolled NI, scatter-loop fabric, static FIFO depth."""
    topo = build_channel_plan(spec)
    nbr, opp, route = spec.topology.tables()
    fstep = make_fabric_step(nbr, opp, route)
    step = make_step(spec, topo, T, fstep)
    R, P = nbr.shape

    @jax.jit
    def run(times, dests, service_lat, max_out, burst_beats):
        nets = tuple(init_fabric_state(R, P, ch.depth)
                     for ch in spec.channels)
        state = SimState(nets, init_ni(spec.n_routers, topo), jnp.int32(0),
                         jnp.zeros((topo.n_ch,), jnp.int32))
        dyn = {"times": times, "dests": dests,
               "service_lat": service_lat, "max_out": max_out,
               "burst_beats": burst_beats}
        final, _ = jax.lax.scan(functools.partial(step, dyn), state, None,
                                length=spec.cycles)
        ni = final.ni
        return {
            "done": ni.done, "lat_sum": ni.lat_sum, "lat_max": ni.lat_max,
            "beats_rx": ni.beats_rx, "first_t": ni.first_t,
            "last_t": ni.last_t, "link_moves": final.moves,
        }

    return run
