"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper reports, e.g. latency cycles, bandwidth utilization, pJ/B/hop).

All cycle-level benches run through the declarative ``repro.noc`` API
(NocSpec presets + Workload patterns + vmapped ``simulate_batch``).

    PYTHONPATH=src python benchmarks/run.py [--smoke] [--json PATH]

``--smoke`` shrinks horizons for CI and ``--json`` (default
``BENCH_noc.json`` under --smoke) records every derived metric plus
wall time so the performance trajectory accumulates across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

RESULTS: dict[str, dict] = {}


def _record(name: str, us: float, compile_us: float | None = None,
            **derived):
    def _jsonable(v):
        if isinstance(v, (bool, np.bool_)):
            return bool(v)
        if isinstance(v, (int, float, np.integer, np.floating)):
            return float(v)
        return v
    row = {"us_per_call": round(us, 1)}
    if compile_us is not None:
        row["compile_us"] = round(compile_us, 1)
    RESULTS[name] = {**row,
                     **{k: _jsonable(v) for k, v in derived.items()}}


def _timed(fn, *args, repeat=1, **kw):
    """(out, run_us, compile_us): the first call carries tracing + XLA
    compilation, steady-state calls don't — report them separately
    instead of conflating them in one number."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    first_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    run_us = (time.perf_counter() - t0) / repeat * 1e6
    return out, run_us, max(first_us - run_us, 0.0)


def bench_zero_load_latency(smoke: bool = False):
    """Paper section VI-A: 18-cycle tile-to-tile round trip."""
    from repro.noc import NocSpec, Workload, simulate
    spec = NocSpec.narrow_wide(2, 1, cycles=200)
    wl = Workload.make("fig5", rates={"narrow": 0.01},
                       counts={"narrow": 1}, src=0, dst=1)
    m, us, cus = _timed(simulate, spec, wl)
    lat = float(m.classes["narrow"].avg_lat[0])
    print(f"zero_load_latency,{us:.0f},round_trip_cycles={lat:.0f} (paper=18)")
    _record("zero_load_latency", us, cus, round_trip_cycles=lat,
            paper=18)
    return lat


def bench_fig5a_latency(smoke: bool = False):
    """Fig. 5a: narrow latency under wide burst interference.

    One vmapped ``simulate_batch`` per topology covers the interference
    and no-interference points together."""
    from repro.noc import NocSpec, Workload, simulate_batch
    cycles = 3000 if smoke else 8000
    n_wide = 64 if smoke else 200
    rows = []
    for preset, tag in ((NocSpec.narrow_wide, "nw"),
                        (NocSpec.wide_only, "wideonly")):
        spec = preset(4, 4, cycles=cycles)
        for bidir in (False, True):
            # point 0: interference at `bidir`; point 1: the seed bench's
            # baseline — no wide traffic, always unidirectional
            wls = [Workload.make("fig5",
                                 rates={"narrow": 0.05, "wide": 1.0},
                                 counts={"narrow": 100, "wide": n_wide},
                                 src=0, dst=15, bidir=bidir),
                   Workload.make("fig5", rates={"narrow": 0.05},
                                 counts={"narrow": 100}, src=0, dst=15)]
            m, us, cus = _timed(simulate_batch, spec, wls)
            lat = float(m.classes["narrow"].avg_lat[0, 0])
            lat0 = float(m.classes["narrow"].avg_lat[1, 0])
            mx = float(m.classes["narrow"].max_lat[0, 0])
            name = f"fig5a_{tag}_{'bidir' if bidir else 'unidir'}"
            print(f"{name},{us:.0f},avg={lat:.0f}cyc({lat/lat0:.2f}x)"
                  f" max={mx:.0f}cyc({mx/lat0:.2f}x)")
            _record(name, us, cus, avg_cycles=lat, avg_x=lat / lat0,
                    max_x=mx / lat0)
            rows.append((tag, bidir, lat / lat0, mx / lat0))
    return rows


def bench_fig5b_bandwidth(smoke: bool = False):
    """Fig. 5b: wide effective bandwidth under narrow interference."""
    from repro.noc import NocSpec, Workload, simulate_batch
    cycles = 3000 if smoke else 6000
    n_wide = 128 if smoke else 256
    rows = []
    for preset, tag in ((NocSpec.narrow_wide, "nw"),
                        (NocSpec.wide_only, "wideonly")):
        spec = preset(4, 4, cycles=cycles)
        wls = [Workload.make("fig5",
                             rates={"narrow": nrate, "wide": 1.0},
                             counts={"narrow": 3000 if nrate else 0,
                                     "wide": n_wide},
                             src=0, dst=5)
               for nrate in (0.0, 1.0)]
        m, us, cus = _timed(simulate_batch, spec, wls)
        utils = [float(m.classes["wide"].eff_bw[i, 0]) for i in (0, 1)]
        rel = utils[1] / max(utils[0], 1e-9)
        name = f"fig5b_{tag}"
        print(f"{name},{us:.0f},util={utils[1]:.2f} rel={rel:.2f}"
              f" (paper nw>=0.85)")
        _record(name, us, cus, util=utils[1], rel=rel)
        rows.append((tag, utils))
    return rows


def bench_rate_sweep(smoke: bool = False):
    """API showcase: a vmapped injection-rate sweep in ONE jit call."""
    from repro.noc import NocSpec, Workload, simulate_batch
    spec = NocSpec.narrow_wide(4, 4, cycles=2000 if smoke else 4000)
    rates = [0.25, 0.5, 0.75, 1.0]
    wls = [Workload.make("fig5", rates={"narrow": 0.05, "wide": r},
                         counts={"narrow": 50, "wide": 32},
                         src=0, dst=15) for r in rates]
    m, us, cus = _timed(simulate_batch, spec, wls)
    bw = [float(m.classes["wide"].eff_bw[i, 0]) for i in range(len(rates))]
    print(f"rate_sweep_vmap,{us:.0f},"
          + " ".join(f"r{r}={b:.2f}" for r, b in zip(rates, bw)))
    _record("rate_sweep_vmap", us, cus,
            **{f"bw_at_{r}": b for r, b in zip(rates, bw)})
    return bw


def bench_backend_channels(smoke: bool = False):
    """Backend x channel-count comparison behind one simulate() surface.

    Times the jnp reference against the Pallas arbiter kernel and the
    fused full-cycle kernel on 1-channel (wide-only), 3-channel (paper
    narrow-wide) and 4-channel (2-stream) specs, checks them
    flit-for-flit equivalent, and records everything into
    BENCH_noc.json.  Off-TPU the Pallas backends run interpreted, so
    their timings measure correctness cost, not kernel speed."""
    from repro.noc import NocSpec, Workload, simulate
    cycles = 1000 if smoke else 3000
    n_wide = 12 if smoke else 48
    specs = [
        ("1ch", NocSpec.wide_only(4, 4, cycles=cycles),
         {"narrow": 0.05, "wide": 1.0}, {"narrow": 30, "wide": n_wide}),
        ("3ch", NocSpec.narrow_wide(4, 4, cycles=cycles),
         {"narrow": 0.05, "wide": 1.0}, {"narrow": 30, "wide": n_wide}),
        ("4ch", NocSpec.multi_stream(4, 4, n_wide=2, cycles=cycles),
         {"narrow": 0.05, "wide0": 1.0, "wide1": 1.0},
         {"narrow": 30, "wide0": n_wide // 2, "wide1": n_wide // 2}),
    ]
    backends = ("jnp", "pallas", "pallas_fused")
    rows = []
    for tag, spec, rates, counts in specs:
        wl = Workload.make("fig5", rates=rates, counts=counts,
                           src=0, dst=15)
        results = {}
        for backend in backends:
            m, us, cus = _timed(simulate, spec, wl, backend=backend)
            results[backend] = (m, us, cus)
        mj, usj, cusj = results["jnp"]
        equal = all(
            np.array_equal(getattr(mj.classes[c], f),
                           getattr(results[b][0].classes[c], f))
            for b in backends[1:]
            for c in mj.classes
            for f in ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw")
        ) and all(
            np.array_equal(mj.channels[ch].link_moves,
                           results[b][0].channels[ch].link_moves)
            for b in backends[1:] for ch in mj.channels)
        lat = float(mj.classes["narrow"].avg_lat[0])
        name = f"backend_{tag}"
        print(f"{name},{usj:.0f},jnp={usj:.0f}us "
              f"pallas={results['pallas'][1]:.0f}us "
              f"fused={results['pallas_fused'][1]:.0f}us "
              f"equal={equal} narrow_avg={lat:.0f}cyc")
        _record(name, usj, cusj, pallas_us=results["pallas"][1],
                pallas_fused_us=results["pallas_fused"][1],
                backends_equal=equal,
                narrow_avg_cycles=lat, n_channels=len(spec.channels))
        rows.append((tag, usj, equal))
    assert all(eq for *_, eq in rows), "backend mismatch!"
    return rows


def bench_write_mix(smoke: bool = False):
    """AXI4 write-path bench: read-only vs 50/50 vs write-heavy traffic
    through the full AW/W/B flow model, across ALL THREE backends.

    For each mix, every backend must agree flit-for-flit (asserted);
    the derived metrics record per-direction completions/latency and
    the per-channel link-move shift as W bursts move to the wide
    channel and B acks load the rsp channel.  Off-TPU the Pallas
    backends run interpreted (correctness cost, not kernel speed)."""
    from repro.noc import NocSpec, Workload, simulate
    cycles = 1500 if smoke else 4000
    n_wide = 12 if smoke else 48
    spec = NocSpec.narrow_wide(4, 4, cycles=cycles)
    backends = ("jnp", "pallas", "pallas_fused")
    fields = ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw",
              "w_done", "w_avg_lat", "w_max_lat", "w_beats_rx", "w_eff_bw")
    rows = []
    for tag, wf in (("read_only", 0.0), ("mix50", 0.5),
                    ("write_heavy", 0.9)):
        wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                           counts={"narrow": 30, "wide": n_wide},
                           src=0, dst=15, bidir=True, write_frac=wf)
        results = {}
        for backend in backends:
            m, us, cus = _timed(simulate, spec, wl, backend=backend)
            results[backend] = (m, us, cus)
        mj, usj, cusj = results["jnp"]
        equal = all(
            np.array_equal(getattr(mj.classes[c], f),
                           getattr(results[b][0].classes[c], f))
            for b in backends[1:] for c in mj.classes for f in fields
        ) and all(
            np.array_equal(mj.channels[ch].link_moves,
                           results[b][0].channels[ch].link_moves)
            for b in backends[1:] for ch in mj.channels)
        assert equal, f"backend mismatch on write mix {tag}!"
        r_done = sum(int(c.done.sum()) for c in mj.classes.values())
        w_done = sum(int(c.w_done.sum()) for c in mj.classes.values())
        w_lat = float(np.max(mj.classes["wide"].w_avg_lat)) if w_done \
            else 0.0
        name = f"write_mix_{tag}"
        print(f"{name},{usj:.0f},reads={r_done} writes={w_done} "
              f"wide_w_avg_lat={w_lat:.0f}cyc "
              f"rsp_moves={int(mj.channels['rsp'].link_moves)} "
              f"drained={bool(mj.drained)} equal={equal}")
        _record(name, usj, cusj, reads_done=r_done, writes_done=w_done,
                wide_write_avg_lat=w_lat,
                rsp_link_moves=int(mj.channels["rsp"].link_moves),
                wide_link_moves=int(mj.channels["wide"].link_moves),
                drained=bool(mj.drained), backends_equal=equal,
                pallas_us=results["pallas"][1],
                pallas_fused_us=results["pallas_fused"][1])
        rows.append((tag, r_done, w_done))
    # the mix conserves transactions while shifting direction
    totals = {tag: r + w for tag, r, w in rows}
    assert len(set(totals.values())) == 1, totals
    return rows


def bench_routing(smoke: bool = False):
    """Routing-policy x VC-count study: torus vs mesh throughput at
    EQUAL saturating all-to-all load (paper-adjacent: the journal
    FlooNoC routing evaluation + escape-VC deadlock freedom).

    The VC-less minimal-wrap torus wedges under this load (drained
    False, stall ~ horizon) — recorded as the contrast point.  With the
    2-VC escape/dateline policy the torus drains and completes at least
    as many transactions as the mesh in the same horizon (asserted:
    that is the PR acceptance).  The escape-VC jnp/pallas_fused results
    are also equivalence-asserted so the folded-table VC fabric stays
    backend-exact inside the bench, not just the test suite."""
    from repro.noc import Mesh, NocSpec, RoutingPolicy, Torus, Workload, \
        simulate
    cycles = 2000 if smoke else 3500
    wl = Workload.make("all_to_all", rates={"wide": 1.0},
                       rounds={"wide": 4}, write_frac=0.5)

    def mk(topo, pol):
        return NocSpec.wide_only(4, 4, topology=topo, burstlen=32,
                                 cycles=cycles, max_wide_outstanding=16,
                                 routing=pol)

    configs = [
        ("mesh_xy_1vc", Mesh(4, 4), RoutingPolicy.xy(1)),
        ("torus_xy_1vc", Torus(4, 4), RoutingPolicy.xy(1)),
        ("torus_xy_2vc", Torus(4, 4), RoutingPolicy.xy(2)),
        ("mesh_o1turn_2vc", Mesh(4, 4), RoutingPolicy.o1turn(2)),
        ("torus_o1turn_4vc", Torus(4, 4), RoutingPolicy.o1turn(4)),
        ("mesh_valiant_4vc", Mesh(4, 4), RoutingPolicy.valiant(4)),
    ]
    done = {}
    for tag, topo, pol in configs:
        spec = mk(topo, pol)
        m, us, cus = _timed(simulate, spec, wl)
        st = m.classes["wide"]
        n_done = int(st.done.sum()) + int(st.w_done.sum())
        done[tag] = n_done
        thpt = n_done / cycles
        occ = m.channels["wide"].vc_occupancy
        name = f"routing_{tag}"
        print(f"{name},{us:.0f},done={n_done} thpt={thpt:.3f}/cyc "
              f"drained={bool(m.drained)} "
              f"max_stall={int(m.max_stall_cycles)} "
              f"vc_occ={np.round(occ, 1).tolist()}")
        _record(name, us, cus, txns_done=n_done, txns_per_cycle=thpt,
                drained=bool(m.drained),
                max_stall_cycles=int(m.max_stall_cycles),
                n_vcs=pol.n_vcs, algorithm=pol.algorithm,
                vc_peak_occupancy=[
                    int(v) for v in m.channels["wide"].vc_peak_occupancy])

    # escape-VC torus: backend-exact (jnp vs fused kernel, VC tables)
    spec = mk(Torus(4, 4), RoutingPolicy.xy(2))
    mj = simulate(spec, wl, backend="jnp")
    mf = simulate(spec, wl, backend="pallas_fused")
    equal = all(
        np.array_equal(getattr(mj.classes[c], f),
                       getattr(mf.classes[c], f))
        for c in mj.classes
        for f in ("done", "avg_lat", "beats_rx", "w_done", "w_beats_rx")
    ) and np.array_equal(mj.channels["wide"].link_moves,
                         mf.channels["wide"].link_moves)
    assert equal, "VC fabric backend mismatch in bench_routing!"

    torus_ge_mesh = done["torus_xy_2vc"] >= done["mesh_xy_1vc"]
    print(f"routing_summary,0,torus2vc={done['torus_xy_2vc']} "
          f"mesh={done['mesh_xy_1vc']} torus_ge_mesh={torus_ge_mesh} "
          f"backends_equal={equal}")
    _record("routing_summary", 0.0, torus_done=done["torus_xy_2vc"],
            mesh_done=done["mesh_xy_1vc"], torus_ge_mesh=torus_ge_mesh,
            vcless_torus_done=done["torus_xy_1vc"], backends_equal=equal)
    assert torus_ge_mesh, (
        f"escape-VC torus completed {done['torus_xy_2vc']} < mesh "
        f"{done['mesh_xy_1vc']} at equal load")
    return done


def _count_eqns(jaxpr) -> int:
    """Total jaxpr equations, recursing into scan/jit sub-jaxprs — the
    trace-size metric the fusion work optimizes."""
    n = 0
    for eq in jaxpr.eqns:
        n += 1
        for v in eq.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    n += _count_eqns(inner)
                elif hasattr(x, "eqns"):
                    n += _count_eqns(x)
    return n


def _scan_body_eqns(jaxpr) -> int:
    """Equation count of the innermost scan body — per-cycle HLO ops."""
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                inner = inner if inner is not None and hasattr(
                    inner, "eqns") else (x if hasattr(x, "eqns") else None)
                if inner is None:
                    continue
                if eq.primitive.name == "scan":
                    return len(inner.eqns)
                found = _scan_body_eqns(inner)
                if found:
                    return found
    return 0


def bench_ledger_replay(smoke: bool = False):
    """Replay a REAL decode step's collective ledger through all three
    backends.  The trace (``benchmarks/decode_ledger.json``) was
    captured once from ``build_decode_step`` on a 2x2 device mesh
    (llama3.2-1b smoke) and committed via ``Ledger.to_json`` — the
    bench replays it with ``Workload.from_ledger`` on the job's own
    rank mapping, times each backend, and asserts the replayed traffic
    is flit-for-flit identical across them (including the per-stream
    completion stats)."""
    from pathlib import Path

    from repro.core.channels import Ledger
    from repro.noc import NocSpec, Workload, simulate

    led = Ledger.from_json(
        (Path(__file__).parent / "decode_ledger.json").read_text())
    spec = NocSpec.narrow_wide(4, 4, cycles=2500 if smoke else 4000)
    wl = Workload.from_ledger(led, spec, mapping={"data": 2, "model": 2},
                              scale=0.25)
    results = {}
    for backend in ("jnp", "pallas", "pallas_fused"):
        r, us, compile_us = _timed(simulate, spec, wl, backend=backend,
                                   repeat=1 if smoke else 3)
        results[backend] = (r, us, compile_us)
    ref = results["jnp"][0]
    for backend in ("pallas", "pallas_fused"):
        r = results[backend][0]
        for cname, c in ref.classes.items():
            other = r.classes[cname]
            for f in ("done", "avg_lat", "w_done", "w_avg_lat",
                      "stream_done", "stream_last_t", "stream_w_done",
                      "stream_w_last_t"):
                np.testing.assert_array_equal(
                    getattr(c, f), getattr(other, f),
                    err_msg=f"{backend}:{cname}.{f}")
        for ch in ref.channels:
            np.testing.assert_array_equal(
                ref.channels[ch].link_moves, r.channels[ch].link_moves,
                err_msg=f"{backend}:{ch}.link_moves")
    txns = sum(int(c.done.sum() + c.w_done.sum())
               for c in ref.classes.values())
    makespan = max(int(c.stream_w_last_t.max())
                   for c in ref.classes.values())
    for backend in ("jnp", "pallas", "pallas_fused"):
        _, us, compile_us = results[backend]
        print(f"ledger_replay_{backend},{us:.0f},txns={txns} "
              f"makespan={makespan} drained={bool(ref.drained)} "
              f"equal=True")
        _record(f"ledger_replay_{backend}", us, compile_us,
                txns=txns, makespan=makespan,
                drained=bool(ref.drained), backends_equal=True,
                entries=len(led.entries))


def bench_engine_throughput(smoke: bool = False):
    """Perf tentpole bench: the fused hot loop vs the PINNED pre-PR
    engine (``_baseline_engine.py``), measured in the same process on
    bit-identical workloads.

    Records router steps/sec, run vs compile wall time, per-cycle HLO
    op count (scan-body jaxpr equations), the >=3x speedup target on
    the fig5 preset, a backend x mesh x channel-count steps/sec grid,
    and the one-compilation depth-sweep cost per point."""
    import jax
    from repro.noc import NocSpec, Workload, sim_cache_clear, \
        sim_cache_stats, simulate, sweep
    from repro.noc.api import _depths, _dyn_scalars, jitter_table, \
        stack_schedules
    from repro.noc.engine import compiled_sim
    import _baseline_engine as baseline

    cycles = 1500 if smoke else 4000
    spec = NocSpec.narrow_wide(4, 4, cycles=cycles)
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 100, "wide": 64},
                       src=0, dst=15, bidir=True)
    times, dests, writes = stack_schedules(spec, wl.schedules(spec))
    sl, mo, bb = _dyn_scalars(spec, None, None, None)
    T = times.shape[-1]

    new_fn = compiled_sim(spec, T)
    old_fn = baseline.compiled_sim_baseline(spec, T)
    new_args = (times, dests, writes, sl, mo, bb, jitter_table(spec),
                _depths(spec))
    # the pinned baseline predates the AXI4 flow model: scalar service
    # latency, no write mask/jitter operands
    old_args = (times, dests, np.int32(spec.service_lat), mo, bb)
    block = jax.block_until_ready
    out_new, run_new, comp_new = _timed(
        lambda: block(new_fn(*new_args)), repeat=3)
    out_old, run_old, comp_old = _timed(
        lambda: block(old_fn(*old_args)), repeat=3)
    # compare the read metrics the baseline knows about (the live
    # engine additionally reports write metrics + liveness)
    equal = all(np.array_equal(np.asarray(out_new[k]),
                               np.asarray(out_old[k])) for k in out_old)
    assert equal, "AXI4 engine diverged from the pinned baseline!"

    sps_new = cycles / (run_new / 1e6)
    sps_old = cycles / (run_old / 1e6)
    speedup = run_old / run_new
    jp_new = jax.make_jaxpr(new_fn)(*new_args).jaxpr
    jp_old = jax.make_jaxpr(old_fn)(*old_args).jaxpr
    eq_new, cyc_new = _count_eqns(jp_new), _scan_body_eqns(jp_new)
    eq_old, cyc_old = _count_eqns(jp_old), _scan_body_eqns(jp_old)
    print(f"engine_throughput,{run_new:.0f},steps/s={sps_new:,.0f} "
          f"(baseline {sps_old:,.0f}) speedup={speedup:.2f}x "
          f"scan_body_eqns={cyc_new} (baseline {cyc_old}) "
          f"compile={comp_new/1e3:.0f}ms (baseline {comp_old/1e3:.0f}ms)")
    # the live engine now also models the AXI4 write path (five flow
    # gathers, W rings, per-direction metrics) the read-only baseline
    # doesn't, so the historical 3x-over-baseline target became ~2x;
    # warn only on a real regression below that level
    if speedup < 1.5:
        print(f"# WARNING: fig5 speedup {speedup:.2f}x below the 1.5x "
              f"floor — engine regression?")
    _record("bench_engine_throughput", run_new, comp_new,
            steps_per_sec=sps_new, baseline_steps_per_sec=sps_old,
            speedup_x=speedup, baseline_us_per_call=run_old,
            baseline_compile_us=comp_old, results_equal=equal,
            scan_body_eqns=cyc_new, baseline_scan_body_eqns=cyc_old,
            total_trace_eqns=eq_new, baseline_total_trace_eqns=eq_old,
            cycles=cycles)

    # backend x mesh x channel-count steps/sec grid (interpret-mode
    # Pallas off-TPU: correctness cost, not kernel speed)
    grid_cycles = 300 if smoke else 1000
    grid = [("jnp", 4, NocSpec.narrow_wide, "3ch"),
            ("jnp", 8, NocSpec.narrow_wide, "3ch"),
            ("jnp", 4, NocSpec.wide_only, "1ch"),
            ("pallas", 4, NocSpec.narrow_wide, "3ch"),
            ("pallas_fused", 4, NocSpec.narrow_wide, "3ch"),
            ("pallas_fused", 4, NocSpec.wide_only, "1ch")]
    for backend, n, preset, tag in grid:
        gspec = preset(n, n, cycles=grid_cycles)
        gwl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                            counts={"narrow": 30, "wide": 12},
                            src=0, dst=n * n - 1)
        _, us, cus = _timed(simulate, gspec, gwl, backend=backend)
        sps = grid_cycles / (us / 1e6)
        name = f"engine_grid_{backend}_{n}x{n}_{tag}"
        print(f"{name},{us:.0f},steps/s={sps:,.0f}")
        _record(name, us, cus, steps_per_sec=sps, mesh=n,
                n_channels=len(gspec.channels))

    # one-compilation FIFO-depth sweep: wall per point, compiles counted
    depths = (2, 3, 4, 6)
    dwl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                        counts={"narrow": 20, "wide": 8}, src=0, dst=15)
    pts = [(NocSpec.narrow_wide(4, 4, depth=d, cycles=grid_cycles), dwl)
           for d in depths]
    sim_cache_clear()
    _, us, cus = _timed(sweep, pts)
    compiles = sim_cache_stats()["misses"]
    print(f"depth_sweep,{us / len(pts):.0f},points={len(pts)} "
          f"compiles={compiles} wall_per_point_us={us / len(pts):.0f}")
    _record("depth_sweep", us / len(pts), cus,
            points=len(pts), compiles=compiles)
    assert compiles == 1, f"depth sweep compiled {compiles}x, expected 1"
    return speedup


def _sweep_scaling_points(smoke: bool):
    """The shared sweep campaign: >=64 spec points (16 under smoke
    workers would undershoot the acceptance floor, so both modes keep
    64 and shrink the horizon instead), one depth-compatible group so
    the whole campaign rides a single farm-compiled executable."""
    from repro.noc import NocSpec, Workload
    n_specs = 64
    cycles = 400 if smoke else 1200
    depths = (2, 3, 4, 6)
    pts = []
    for i in range(n_specs):
        spec = NocSpec.narrow_wide(4, 4, depth=depths[i % len(depths)],
                                   cycles=cycles)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.1, "wide": 0.6},
                           counts={"narrow": 4, "wide": 3}, seed=i)
        pts.append((spec, wl))
    return pts


def _sweep_scaling_worker(devices: int, smoke: bool) -> None:
    """Child-process body for one device count: XLA_FLAGS (set by the
    parent BEFORE this process imported jax) provides the fake host
    devices; prints one JSON line the parent parses."""
    import hashlib

    import jax
    from repro.noc import sim_cache_clear, sim_cache_stats, sweep

    if jax.device_count() < devices:
        raise SystemExit(
            f"worker wanted {devices} devices, jax sees "
            f"{jax.device_count()} — XLA_FLAGS not applied before import?")
    pts = _sweep_scaling_points(smoke)
    sim_cache_clear()
    t0 = time.perf_counter()
    out = sweep(pts, devices=devices)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = sweep(pts, devices=devices)
    run_s = time.perf_counter() - t0
    misses = sim_cache_stats()["misses"]
    # one inner engine build (shared "jnp" partition) + one farm
    # shard_map wrapper serve the whole campaign, and the second call
    # reuses both — the farm partition must not recompile per call
    assert misses == 2, f"farm sweep built {misses} fns, expected 2"

    h = hashlib.sha256()
    for m in out:
        for cname in sorted(m.classes):
            c = m.classes[cname]
            for f in ("done", "avg_lat", "max_lat", "beats_rx", "w_done",
                      "w_avg_lat", "w_beats_rx"):
                h.update(np.ascontiguousarray(getattr(c, f)).tobytes())
        for ch in sorted(m.channels):
            h.update(np.ascontiguousarray(
                m.channels[ch].link_moves).tobytes())
    print(json.dumps({
        "devices": devices, "n_specs": len(pts),
        "specs_per_sec": len(pts) / run_s,
        "run_s": round(run_s, 4), "compile_s": round(compile_s, 2),
        "compiles": misses, "digest": h.hexdigest()}))


def bench_sweep_scaling(smoke: bool = False):
    """Tentpole bench: the device-parallel sweep farm at 1/2/4/8 (host)
    devices over the same >=64-spec campaign, each count in its own
    subprocess so ``XLA_FLAGS=--xla_force_host_platform_device_count``
    lands before jax import.

    Records specs/sec and parallel efficiency per device count plus the
    result digest — asserted identical across counts (sharding must be
    bit-invisible).  Host 'devices' share this machine's physical
    cores, so real speedup needs real cores: the >=5x floor at 8
    devices is asserted only when the host has >= 8 cores, and the
    honest per-count numbers + core count are recorded either way."""
    devices_list = (1, 2, 4, 8)
    cores = os.cpu_count() or 1
    stats = {}
    for n in devices_list:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        flags = " ".join(f for f in flags.split()
                         if not f.startswith(
                             "--xla_force_host_platform_device_count"))
        env["XLA_FLAGS"] = (flags + " "
                            f"--xla_force_host_platform_device_count={n}"
                            ).strip()
        cmd = [sys.executable, os.path.abspath(__file__),
               "--sweep-worker", str(n)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sweep worker (devices={n}) failed:\n{proc.stdout}\n"
                f"{proc.stderr}")
        stats[n] = json.loads(proc.stdout.strip().splitlines()[-1])

    digests = {s["digest"] for s in stats.values()}
    assert len(digests) == 1, \
        f"sweep results differ across device counts: {stats}"
    sps1 = stats[1]["specs_per_sec"]
    for n in devices_list:
        s = stats[n]
        eff = s["specs_per_sec"] / (n * sps1)
        speedup = s["specs_per_sec"] / sps1
        name = f"sweep_scaling_d{n}"
        print(f"{name},{1e6 / s['specs_per_sec']:.0f},"
              f"specs/s={s['specs_per_sec']:.1f} speedup={speedup:.2f}x "
              f"efficiency={eff:.2f} n_specs={s['n_specs']} "
              f"compiles={s['compiles']} cores={cores}")
        _record(name, 1e6 / s["specs_per_sec"],
                s["compile_s"] * 1e6,
                specs_per_sec=s["specs_per_sec"], speedup_x=speedup,
                efficiency=eff, n_specs=s["n_specs"],
                compiles=s["compiles"], cores=cores,
                bit_identical=True)
    if cores >= 8:
        assert stats[8]["specs_per_sec"] >= 5 * sps1, (
            f"sweep(devices=8) reached only "
            f"{stats[8]['specs_per_sec'] / sps1:.2f}x over devices=1 "
            f"on a {cores}-core host (need >= 5x)")
    else:
        print(f"# sweep_scaling: {cores} core(s) < 8 — host devices "
              f"share cores, >=5x floor not asserted (numbers above "
              f"are the honest single-core serialization)")
    return stats


def bench_table1_links(smoke: bool = False):
    """Table I / section VI-B: link sizing and peak bandwidth."""
    from repro.core.noc_sim import PAPER
    _, us, _ = _timed(lambda: None)
    gbps = PAPER.wide_link_gbps()
    tbps = PAPER.wide_link_duplex_tbps()
    agg = PAPER.mesh_boundary_bandwidth_tbs(7, 7)
    wires = PAPER.duplex_channel_wires()
    um = PAPER.routing_channel_um()
    print(f"table1_wide_link,{us:.0f},{gbps:.0f}Gbps (paper 629)")
    print(f"table1_duplex,{us:.0f},{tbps:.2f}Tbps (paper 1.26)")
    print(f"table1_mesh7x7_boundary,{us:.0f},{agg:.1f}TB/s (paper 4.4)")
    print(f"table1_channel_wires,{us:.0f},{wires} wires (~1600)")
    print(f"table1_channel_width,{us:.0f},{um:.0f}um (paper ~120)")
    _record("table1", us, wide_link_gbps=gbps, duplex_tbps=tbps,
            mesh7x7_boundary_tbs=agg, channel_wires=wires,
            channel_width_um=um)
    return gbps, tbps, agg


def bench_fig6_area_energy(smoke: bool = False):
    """Fig. 6: area/power breakdown + 0.19 pJ/B/hop."""
    from repro.core.noc_sim import PAPER
    _, us, _ = _timed(lambda: None)
    frac = PAPER.noc_area_fraction()
    e = PAPER.energy_pj(1024, 1)
    print(f"fig6_noc_area_fraction,{us:.0f},{frac:.2f} (paper 0.10)")
    print(f"fig6_energy_1kB_hop,{us:.0f},{e:.0f}pJ (paper 198)")
    print(f"fig6_pJ_per_B_hop,{us:.0f},{PAPER.pj_per_byte_hop} (paper 0.19)")
    _record("fig6", us, noc_area_fraction=frac, energy_1kB_hop_pj=e,
            pj_per_byte_hop=PAPER.pj_per_byte_hop)
    return frac, e


def bench_straggler_sim(smoke: bool = False):
    """Straggler mitigation at 1024 hosts (DESIGN section 7)."""
    from repro.train.straggler import SimulatedCluster
    sim = SimulatedCluster(n_hosts=128 if smoke else 1024)
    rep, us, cus = _timed(sim.report)
    for pol, r in rep.items():
        print(f"straggler_{pol},{us:.0f},p50={r['p50']:.3f} p99={r['p99']:.3f}")
        _record(f"straggler_{pol}", us, cus, p50=r["p50"],
                p99=r["p99"])
    return rep


def bench_train_step(smoke: bool = False):
    """End-to-end smoke train step through repro.dist (wide grad bulk +
    narrow flit-packed metrics riding the dual-channel policy)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, ShapeConfig
    from repro.configs.base import MeshConfig, RunConfig
    from repro.dist import params as params_lib, step as step_lib
    from repro.models import build_model

    mcfg = get_arch("llama3.2-1b").smoke(num_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256)
    shape = ShapeConfig("bench", 64, 4, "train")
    cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(mcfg, cfg)
    art = step_lib.build_train_step(model, shape, mesh)
    key = jax.random.key(0)
    params = params_lib.materialize_sharded(art.param_specs, key, mesh)
    opt = params_lib.materialize_sharded(art.opt_specs, key, mesh)
    toks = jax.random.randint(key, (4, 64), 0, mcfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    t0 = time.perf_counter()
    params, opt, m = art.fn(params, opt, jnp.int32(0), batch)   # compile
    first_us = (time.perf_counter() - t0) * 1e6
    (_, _, m), us, _ = _timed(art.fn, params, opt, jnp.int32(1), batch,
                              repeat=2 if smoke else 5)
    loss = float(m["loss"])
    gnorm = float(m["grad_norm"])
    print(f"train_step,{us:.0f},loss={loss:.3f} grad_norm={gnorm:.3f}")
    _record("train_step", us, max(first_us - us, 0.0), loss=loss,
            grad_norm=gnorm)
    return loss


def bench_faults(smoke: bool = False):
    """Graceful-degradation study: the same workload on a healthy
    torus, with one statically dead X-link (rerouted around via the
    dedicated detour VC), and under flapping links with NI
    timeout/retry.  Reports completed transactions, worst-case latency
    inflation over healthy, and goodput while links are down.  The
    dead-link case is equivalence-asserted across all three backends —
    the fault machinery must stay backend-exact, not just the healthy
    path."""
    from repro.noc import (FaultModel, NocSpec, RoutingPolicy, Torus,
                           Workload, simulate)
    cycles = 4000 if smoke else 8000
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.3, "wide": 0.8},
                       counts={"narrow": 12, "wide": 5}, seed=7)

    def mk(faults=None):
        return NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                                   cycles=cycles,
                                   routing=RoutingPolicy.xy(3),
                                   faults=faults)

    flap = FaultModel(link_events=((1, 2, 100, 260), (5, 6, 300, 420)),
                      timeout_cycles=2000, max_retries=2)
    configs = [
        ("healthy", None),
        ("dead_link", FaultModel(dead_links=((1, 2),))),
        ("flapping", flap),
    ]
    base_lat = None
    stats = {}
    for tag, fm in configs:
        spec = mk(fm)
        m, us, cus = _timed(simulate, spec, wl)
        n_done = sum(int(s.done.sum()) + int(s.w_done.sum())
                     for s in m.classes.values())
        worst = max(int(s.max_lat.max()) for s in m.classes.values())
        if base_lat is None:
            base_lat = worst
        row = {"txns_done": n_done, "max_lat": worst,
               "lat_x_healthy": worst / max(base_lat, 1),
               "drained": bool(m.drained)}
        if m.faults is not None:
            row["fault_cycles"] = int(m.faults.fault_cycles)
            row["retries"] = sum(int(np.sum(v))
                                 for v in m.faults.retries.values())
            row["goodput_under_fault"] = sum(
                float(v) for v in m.faults.goodput_under_fault.values())
        name = f"faults_{tag}"
        print(f"{name},{us:.0f}," + " ".join(
            f"{k}={v if not isinstance(v, float) else round(v, 3)}"
            for k, v in row.items()))
        _record(name, us, cus, **row)
        stats[tag] = (n_done, worst, bool(m.drained))

    # every case must drain, and the cut's latency hit stays under 2x
    assert all(d for _, _, d in stats.values()), stats
    assert stats["dead_link"][1] < 2 * stats["healthy"][1], stats

    # dead-link cut: backend-exact fault path
    spec = mk(FaultModel(dead_links=((1, 2),)))
    runs = {b: simulate(spec, wl, backend=b)
            for b in ("jnp", "pallas", "pallas_fused")}
    ref = runs["jnp"]
    for b, m in runs.items():
        for cname, s in ref.classes.items():
            got = m.classes[cname]
            assert int(got.done.sum()) == int(s.done.sum()), (b, cname)
            assert int(got.max_lat.max()) == int(s.max_lat.max()), b
        assert int(m.faults.fault_cycles) == int(ref.faults.fault_cycles)
    print("faults_backend_equiv,0,jnp==pallas==pallas_fused on the cut")
    _record("faults_backend_equiv", 0.0, equivalent=True)


def bench_channels_ablation(smoke: bool = False):
    """Software Fig. 5 analogue: the collectives schedule under the
    dual- vs single-channel policies derived from the same NocSpecs that
    drive the cycle simulator (one shared vocabulary)."""
    from repro.core import channels
    from repro.noc import NocSpec

    class Fake:
        def __init__(self, shape):
            self.shape = shape
            self.dtype = np.dtype(np.float32)

    leaves = [Fake((1024, 1024)), Fake((4096, 512))] + \
             [Fake((256,)) for _ in range(20)]
    t0 = time.perf_counter()
    dual = channels.ChannelPolicy.from_spec(NocSpec.narrow_wide())
    single = channels.ChannelPolicy.from_spec(NocSpec.wide_only())
    cls = [dual.classify(int(np.prod(l.shape)) * 4) for l in leaves]
    n_narrow = sum(c.transport == "psum" for c in cls)
    wide = [l for l, c in zip(leaves, cls) if c.transport == "ring"]
    buckets = channels.bucketize(wide, dual.bucket_bytes)
    us = (time.perf_counter() - t0) * 1e6
    narrow_bytes = sum(int(np.prod(l.shape)) * 4 for l, c in
                       zip(leaves, cls) if c.transport == "psum")
    single_shared = len({c.channel for c in single.classes}) == 1
    print(f"channels_dual,{us:.0f},smalls={n_narrow}->1 flit-packed psum"
          f" ({narrow_bytes}B) + {len(buckets)} wide ring bucket(s)"
          f" | single-channel policy shares 1 link: {single_shared}"
          f" ({len(leaves)} tensors serialized on one ring)")
    _record("channels_dual", us, n_narrow=n_narrow,
            narrow_bytes=narrow_bytes, wide_buckets=len(buckets),
            single_policy_shared=single_shared)
    return cls, buckets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced horizons for CI")
    ap.add_argument("--json", default=None,
                    help="write derived metrics to this JSON file "
                         "(default BENCH_noc.json under --smoke)")
    ap.add_argument("--tpu", action="store_true",
                    help="require a real TPU backend: the Pallas benches "
                         "then compile through Mosaic (and hit the VMEM "
                         "budget check) instead of interpreting")
    ap.add_argument("--sweep-worker", type=int, default=None,
                    metavar="N", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.sweep_worker is not None:
        _sweep_scaling_worker(args.sweep_worker, args.smoke)
        return
    if args.tpu:
        import jax
        if jax.default_backend() != "tpu":
            raise SystemExit(
                f"--tpu passed but jax.default_backend() is "
                f"{jax.default_backend()!r}; the Pallas kernels would "
                f"silently fall back to interpret mode, which is not "
                f"the measurement you asked for")
    json_path = args.json or ("BENCH_noc.json" if args.smoke else None)

    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    bench_table1_links(args.smoke)
    bench_fig6_area_energy(args.smoke)
    bench_zero_load_latency(args.smoke)
    bench_fig5a_latency(args.smoke)
    bench_fig5b_bandwidth(args.smoke)
    bench_rate_sweep(args.smoke)
    bench_backend_channels(args.smoke)
    bench_write_mix(args.smoke)
    bench_routing(args.smoke)
    bench_engine_throughput(args.smoke)
    bench_sweep_scaling(args.smoke)
    bench_ledger_replay(args.smoke)
    bench_straggler_sim(args.smoke)
    bench_train_step(args.smoke)
    bench_channels_ablation(args.smoke)
    bench_faults(args.smoke)
    wall_s = time.perf_counter() - t0

    if json_path:
        import jax
        payload = {"smoke": args.smoke, "wall_s": round(wall_s, 2),
                   "accelerator": jax.default_backend(),
                   "benches": RESULTS}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path} ({len(RESULTS)} benches, "
              f"{wall_s:.1f}s wall)")


if __name__ == "__main__":
    main()
