"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper reports, e.g. latency cycles, bandwidth utilization, pJ/B/hop).
"""
from __future__ import annotations

import time

import numpy as np


def _timed(fn, *args, repeat=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def bench_zero_load_latency():
    """Paper section VI-A: 18-cycle tile-to-tile round trip."""
    from repro.core.noc_sim import SimConfig, fig5_traffic, run_sim
    cfg = SimConfig(nx=2, ny=1, cycles=200, narrow_wide=True, service_lat=10)
    tr = fig5_traffic(cfg, num_narrow=1, num_wide=0, narrow_rate=0.01,
                      src=0, dst=1)
    m, us = _timed(run_sim, cfg, tr)
    lat = float(m["narrow_avg_lat"][0])
    print(f"zero_load_latency,{us:.0f},round_trip_cycles={lat:.0f} (paper=18)")
    return lat


def bench_fig5a_latency():
    """Fig. 5a: narrow latency under wide burst interference."""
    from repro.core.noc_sim import SimConfig, fig5_traffic, run_sim
    rows = []
    for nw in (True, False):
        for bidir in (False, True):
            cfg = SimConfig(nx=4, ny=4, cycles=8000, narrow_wide=nw,
                            service_lat=10)
            tr = fig5_traffic(cfg, num_narrow=100, num_wide=200,
                              wide_rate=1.0, narrow_rate=0.05, src=0,
                              dst=15, bidir=bidir)
            m, us = _timed(run_sim, cfg, tr)
            tr0 = fig5_traffic(cfg, num_narrow=100, num_wide=0,
                               narrow_rate=0.05, src=0, dst=15)
            m0, _ = _timed(run_sim, cfg, tr0)
            lat = float(m["narrow_avg_lat"][0])
            lat0 = float(m0["narrow_avg_lat"][0])
            mx = float(m["narrow_max_lat"][0])
            name = (f"fig5a_{'nw' if nw else 'wideonly'}_"
                    f"{'bidir' if bidir else 'unidir'}")
            print(f"{name},{us:.0f},avg={lat:.0f}cyc({lat/lat0:.2f}x)"
                  f" max={mx:.0f}cyc({mx/lat0:.2f}x)")
            rows.append((nw, bidir, lat / lat0, mx / lat0))
    return rows


def bench_fig5b_bandwidth():
    """Fig. 5b: wide effective bandwidth under narrow interference."""
    from repro.core.noc_sim import SimConfig, fig5_traffic, run_sim
    rows = []
    for nw in (True, False):
        utils = []
        for nrate in (0.0, 1.0):
            cfg = SimConfig(nx=4, ny=4, cycles=6000, narrow_wide=nw,
                            service_lat=10)
            tr = fig5_traffic(cfg, num_narrow=3000 if nrate else 0,
                              num_wide=256, wide_rate=1.0, narrow_rate=nrate,
                              src=0, dst=5)
            m, us = _timed(run_sim, cfg, tr)
            utils.append(float(m["wide_eff_bw"][0]))
        rel = utils[1] / max(utils[0], 1e-9)
        name = f"fig5b_{'nw' if nw else 'wideonly'}"
        print(f"{name},{us:.0f},util={utils[1]:.2f} rel={rel:.2f}"
              f" (paper nw>=0.85)")
        rows.append((nw, utils))
    return rows


def bench_table1_links():
    """Table I / section VI-B: link sizing and peak bandwidth."""
    from repro.core.noc_sim import PAPER
    _, us = _timed(lambda: None)
    gbps = PAPER.wide_link_gbps()
    tbps = PAPER.wide_link_duplex_tbps()
    agg = PAPER.mesh_boundary_bandwidth_tbs(7, 7)
    wires = PAPER.duplex_channel_wires()
    um = PAPER.routing_channel_um()
    print(f"table1_wide_link,{us:.0f},{gbps:.0f}Gbps (paper 629)")
    print(f"table1_duplex,{us:.0f},{tbps:.2f}Tbps (paper 1.26)")
    print(f"table1_mesh7x7_boundary,{us:.0f},{agg:.1f}TB/s (paper 4.4)")
    print(f"table1_channel_wires,{us:.0f},{wires} wires (~1600)")
    print(f"table1_channel_width,{us:.0f},{um:.0f}um (paper ~120)")
    return gbps, tbps, agg


def bench_fig6_area_energy():
    """Fig. 6: area/power breakdown + 0.19 pJ/B/hop."""
    from repro.core.noc_sim import PAPER
    _, us = _timed(lambda: None)
    frac = PAPER.noc_area_fraction()
    e = PAPER.energy_pj(1024, 1)
    print(f"fig6_noc_area_fraction,{us:.0f},{frac:.2f} (paper 0.10)")
    print(f"fig6_energy_1kB_hop,{us:.0f},{e:.0f}pJ (paper 198)")
    print(f"fig6_pJ_per_B_hop,{us:.0f},{PAPER.pj_per_byte_hop} (paper 0.19)")
    return frac, e


def bench_straggler_sim():
    """Straggler mitigation at 1024 hosts (DESIGN section 7)."""
    from repro.train.straggler import SimulatedCluster
    sim = SimulatedCluster(n_hosts=1024)
    rep, us = _timed(sim.report)
    for pol, r in rep.items():
        print(f"straggler_{pol},{us:.0f},p50={r['p50']:.3f} p99={r['p99']:.3f}")
    return rep


def bench_channels_ablation():
    """Software Fig. 5 analogue: dual- vs single-channel grad-sync schedule
    (static schedule planning: op counts, bytes, and latency-op model)."""
    import numpy as np
    from repro.core import channels

    class Fake:
        def __init__(self, shape):
            self.shape = shape
            self.dtype = np.dtype(np.float32)

    leaves = [Fake((1024, 1024)), Fake((4096, 512))] + \
             [Fake((256,)) for _ in range(20)]
    t0 = time.perf_counter()
    classes = channels.classify(leaves, 65536)
    n_narrow = classes.count(channels.NARROW)
    wide = [l for l, c in zip(leaves, classes) if c == channels.WIDE]
    buckets = channels.bucketize(wide, 4 << 20)
    us = (time.perf_counter() - t0) * 1e6
    narrow_bytes = sum(int(np.prod(l.shape)) * 4 for l, c in
                       zip(leaves, classes) if c == channels.NARROW)
    # dual: smalls -> ONE fused psum; wide -> len(buckets) ring transactions
    # single: every leaf serialized through the wide ring schedule
    print(f"channels_dual,{us:.0f},smalls={n_narrow}->1 flit-packed psum"
          f" ({narrow_bytes}B) + {len(buckets)} wide ring bucket(s)"
          f" | single-channel: {len(leaves)} tensors serialized on one ring")
    return classes, buckets


def main() -> None:
    print("name,us_per_call,derived")
    bench_table1_links()
    bench_fig6_area_energy()
    bench_zero_load_latency()
    bench_fig5a_latency()
    bench_fig5b_bandwidth()
    bench_straggler_sim()
    bench_channels_ablation()


if __name__ == "__main__":
    main()
