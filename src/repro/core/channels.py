"""Narrow/wide traffic separation for collectives (FlooNoC principle C3).

Heterogeneous gradient/parameter traffic is split by message size:

* **wide**  — latency-tolerant bulk (attention/FFN grads, expert tokens).
  Scheduled as *bucketed, dimension-ordered ring* collectives so every hop
  moves a full wide flit (bandwidth-bound, ≥``wide_flit_bytes``).
* **narrow** — latency-critical smalls (norm/bias/router params, scalars).
  Flit-packed (``core/flit.py``) into ONE fused latency-optimal ``psum`` per
  dtype; they never ride (and never stall behind) the wide channel.

The paper shows (Fig. 5a/5b) that mixing the classes on one physical link
costs up to 5x latency for the smalls and ~15%+ effective bandwidth for the
bulk; `benchmarks/channels_ablation.py` reproduces the software analogue.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import flit, routing

WIDE = "wide"
NARROW = "narrow"


@dataclass
class LedgerEntry:
    phase: str
    op: str
    axes: tuple[str, ...]
    nbytes: int
    traffic_class: str
    note: str = ""


@dataclass
class Ledger:
    """Static per-trace record of the collective schedule (for EXPERIMENTS)."""
    entries: list[LedgerEntry] = field(default_factory=list)
    phase: str = "fwd"

    def log(self, op: str, axes: Sequence[str], nbytes: int, cls: str,
            note: str = "") -> None:
        self.entries.append(LedgerEntry(self.phase, op, tuple(axes), int(nbytes), cls, note))

    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for e in self.entries:
            key = (e.traffic_class, e.op)
            agg = out.setdefault(key, {"count": 0, "bytes": 0})
            agg["count"] += 1
            agg["bytes"] += e.nbytes
        return {f"{c}/{o}": v for (c, o), v in out.items()}


def _nbytes(x: jax.Array) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


def classify(leaves: Sequence[jax.Array], threshold: int) -> list[str]:
    return [WIDE if _nbytes(l) >= threshold else NARROW for l in leaves]


def bucketize(leaves: Sequence[Any], bucket_bytes: int) -> list[list[int]]:
    """Greedy size-ordered bucketing of leaf indices into ~bucket_bytes groups."""
    order = sorted(range(len(leaves)), key=lambda i: -_nbytes(leaves[i]))
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in order:
        b = _nbytes(leaves[i])
        if cur and cur_bytes + b > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(cur)
    return buckets


def dual_channel_all_reduce(
    tree: Any,
    axes: Sequence[tuple[str, int]],
    *,
    wide_flit_bytes: int = 65536,
    bucket_bytes: int = 4 << 20,
    bidir: bool = False,
    ledger: Ledger | None = None,
    narrow_dtype=None,
) -> Any:
    """All-reduce a gradient pytree with narrow/wide channel separation.

    axes: [(axis_name, size), ...] in dimension (XY) order.
    """
    total = 1
    for _, s in axes:
        total *= s
    if total == 1:
        return tree

    leaves, treedef = jax.tree.flatten(tree)
    classes = classify(leaves, wide_flit_bytes)
    axis_names = tuple(n for n, _ in axes)

    out: list[Any] = [None] * len(leaves)

    # --- narrow channel: one flit-packed latency-optimal psum ---------------
    narrow_idx = [i for i, c in enumerate(classes) if c == NARROW]
    if narrow_idx:
        payload, header = flit.pack([leaves[i] for i in narrow_idx])
        reduced = {k: lax.psum(v, axis_names) for k, v in payload.items()}
        if ledger is not None:
            for k, v in payload.items():
                ledger.log("psum", axis_names, _nbytes(v), NARROW,
                           f"flit-packed x{len(narrow_idx)}")
        restored = flit.unpack(reduced, header)
        for j, i in enumerate(narrow_idx):
            out[i] = restored[j]

    # --- wide channel: bucketed dimension-ordered ring RS+AG ----------------
    wide_idx = [i for i, c in enumerate(classes) if c == WIDE]
    if wide_idx:
        for bucket in bucketize([leaves[i] for i in wide_idx], bucket_bytes):
            idxs = [wide_idx[j] for j in bucket]
            payload, header = flit.pack([leaves[i] for i in idxs])
            reduced = {}
            for k, v in payload.items():
                vp, n = flit.pad_to(v, total * (2 if bidir else 1))
                r = routing.dim_ordered_all_reduce(vp, axes, dim=0, bidir=bidir)
                reduced[k] = r[:n]
                if ledger is not None:
                    ledger.log("ring_rs_ag", axis_names, _nbytes(vp), WIDE,
                               f"bucket x{len(idxs)} bidir={bidir}")
            restored = flit.unpack(reduced, header)
            for j, i in enumerate(idxs):
                out[i] = restored[j]

    return jax.tree.unflatten(treedef, out)


def single_channel_all_reduce(tree: Any, axes: Sequence[tuple[str, int]],
                              *, bidir: bool = False,
                              ledger: Ledger | None = None) -> Any:
    """Ablation baseline: everything rides one wide channel (paper's
    'wide-only' configuration in Fig. 5) — smalls are bucketed together with
    bulk and serialized through the same ring schedule."""
    leaves, treedef = jax.tree.flatten(tree)
    total = 1
    for _, s in axes:
        total *= s
    if total == 1:
        return tree
    payload, header = flit.pack(leaves)
    reduced = {}
    for k, v in payload.items():
        vp, n = flit.pad_to(v, total * (2 if bidir else 1))
        r = routing.dim_ordered_all_reduce(vp, axes, dim=0, bidir=bidir)
        reduced[k] = r[:n]
        if ledger is not None:
            ledger.log("ring_rs_ag", tuple(n_ for n_, _ in axes), _nbytes(vp),
                       WIDE, "single-channel (ablation)")
    return jax.tree.unflatten(treedef, flit.unpack(reduced, header))
