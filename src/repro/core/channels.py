"""Narrow/wide traffic separation for collectives (FlooNoC principle C3).

Heterogeneous gradient/parameter traffic is split by message size:

* **wide**  — latency-tolerant bulk (attention/FFN grads, expert tokens).
  Scheduled as *bucketed, dimension-ordered ring* collectives so every hop
  moves a full wide flit (bandwidth-bound, ≥``wide_flit_bytes``).
* **narrow** — latency-critical smalls (norm/bias/router params, scalars).
  Flit-packed (``core/flit.py``) into ONE fused latency-optimal ``psum`` per
  dtype; they never ride (and never stall behind) the wide channel.

The paper shows (Fig. 5a/5b) that mixing the classes on one physical link
costs up to 5x latency for the smalls and ~15%+ effective bandwidth for the
bulk; `benchmarks/channels_ablation.py` reproduces the software analogue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import collectives, flit

WIDE = "wide"
NARROW = "narrow"


@dataclass
class LedgerEntry:
    phase: str
    op: str
    axes: tuple[str, ...]
    nbytes: int
    traffic_class: str
    note: str = ""


@dataclass
class Ledger:
    """Static per-trace record of the collective schedule (for EXPERIMENTS).

    Serializable: ``to_json()`` / ``from_json()`` round-trip exactly, so
    a trace captured once (e.g. in a multi-device subprocess or a 512-
    chip dry-run) can be committed and replayed on the NoC simulator
    (``repro.noc.Workload.from_ledger``) without re-tracing the step.
    """
    entries: list[LedgerEntry] = field(default_factory=list)
    phase: str = "fwd"

    def log(self, op: str, axes: Sequence[str], nbytes: int, cls: str,
            note: str = "") -> None:
        self.entries.append(LedgerEntry(self.phase, op, tuple(axes), int(nbytes), cls, note))

    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for e in self.entries:
            key = (e.traffic_class, e.op)
            agg = out.setdefault(key, {"count": 0, "bytes": 0})
            agg["count"] += 1
            agg["bytes"] += e.nbytes
        return {f"{c}/{o}": v for (c, o), v in out.items()}

    def to_json(self) -> str:
        import json
        return json.dumps({
            "phase": self.phase,
            "entries": [{"phase": e.phase, "op": e.op,
                         "axes": list(e.axes), "nbytes": e.nbytes,
                         "traffic_class": e.traffic_class,
                         "note": e.note} for e in self.entries]})

    @classmethod
    def from_json(cls, s: str) -> "Ledger":
        import json
        d = json.loads(s)
        return cls(entries=[
            LedgerEntry(e["phase"], e["op"], tuple(e["axes"]),
                        int(e["nbytes"]), e["traffic_class"],
                        e.get("note", ""))
            for e in d["entries"]], phase=d.get("phase", "fwd"))


def _nbytes(x: jax.Array) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


def classify(leaves: Sequence[jax.Array], threshold: int) -> list[str]:
    return [WIDE if _nbytes(l) >= threshold else NARROW for l in leaves]


def bucketize(leaves: Sequence[Any], bucket_bytes: int) -> list[list[int]]:
    """Greedy size-ordered bucketing of leaf indices into ~bucket_bytes groups."""
    order = sorted(range(len(leaves)), key=lambda i: -_nbytes(leaves[i]))
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in order:
        b = _nbytes(leaves[i])
        if cur and cur_bytes + b > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(cur)
    return buckets


@dataclass(frozen=True)
class PolicyClass:
    """One software traffic class: which leaves it owns (size threshold),
    how it moves them (transport), and which physical channel it rides."""
    name: str
    min_bytes: int          # leaf belongs to the largest matching class
    transport: str          # "psum" (latency-optimal fused) | "ring" (RS+AG)
    channel: str            # physical channel name (from the NocSpec)


@dataclass(frozen=True)
class ChannelPolicy:
    """Class->channel assignment for collectives, the software twin of
    :class:`repro.noc.NocSpec`'s ``class_map``.

    Classes whose ``channel`` hosts a ring-transport class SHARE that
    ring: their leaves serialize through the same bucketed schedule (the
    paper's wide-only ablation). A psum class with a channel of its own
    gets the fused latency-optimal reduction (the dedicated narrow
    network). ``ChannelPolicy.from_spec`` derives this mechanically from
    a NocSpec, so the cycle simulator and the collectives analogue are
    driven by one declaration.
    """
    classes: tuple[PolicyClass, ...]      # ascending min_bytes
    bucket_bytes: int | None = 4 << 20    # None = single bucket per ring

    def __post_init__(self):
        cs = tuple(sorted(self.classes, key=lambda c: c.min_bytes))
        object.__setattr__(self, "classes", cs)
        if not cs or cs[0].min_bytes != 0:
            raise ValueError("policy needs a base class with min_bytes=0")
        for c in cs:
            if c.transport not in ("psum", "ring"):
                raise ValueError(f"unknown transport {c.transport!r}")

    def classify(self, nbytes: int) -> PolicyClass:
        chosen = self.classes[0]
        for c in self.classes:
            if nbytes >= c.min_bytes:
                chosen = c
        return chosen

    @classmethod
    def from_spec(cls, spec, *, wide_flit_bytes: int = 65536,
                  thresholds: dict[str, int] | None = None,
                  bucket_bytes: int | None | str = "auto"
                  ) -> "ChannelPolicy":
        """Derive the collectives policy from a NocSpec (duck-typed):
        single-beat classes become fused-psum classes, burst classes
        become ring classes, each riding the channel its responses are
        mapped to. ``thresholds`` overrides per-class ``min_bytes``
        (default: 0 for the smallest class, ``wide_flit_bytes`` scaled
        4x per further burst class). ``bucket_bytes="auto"`` picks
        4 MiB buckets for separated topologies but a single serialized
        schedule when every class shares one channel — the paper's
        wide-only ablation, where smalls stall behind bulk."""
        thresholds = dict(thresholds or {})
        if bucket_bytes == "auto":
            shared = len({spec.channels[spec.rsp_channel(c.name)].name
                          for c in spec.classes}) == 1
            bucket_bytes = None if shared else 4 << 20
        ordered = sorted(spec.classes, key=lambda c: (c.burst_beats > 1,
                                                      c.payload_bits))
        out, k = [], 0
        for i, tc in enumerate(ordered):
            if tc.name in thresholds:
                mb = thresholds[tc.name]
            elif i == 0:
                mb = 0
            else:
                mb = wide_flit_bytes * (4 ** k)
                k += 1
            out.append(PolicyClass(
                name=tc.name, min_bytes=mb,
                transport="ring" if tc.burst_beats > 1 else "psum",
                channel=spec.channels[spec.rsp_channel(tc.name)].name))
        return cls(tuple(out), bucket_bytes)


def dual_policy(wide_flit_bytes: int = 65536,
                bucket_bytes: int | None = 4 << 20) -> ChannelPolicy:
    """The paper's narrow/wide separation with a custom size threshold."""
    return ChannelPolicy((
        PolicyClass(NARROW, 0, "psum", "rsp"),
        PolicyClass(WIDE, wide_flit_bytes, "ring", "wide"),
    ), bucket_bytes)


# default two-class policies mirroring the paper's configurations
DUAL_POLICY = dual_policy()
SINGLE_POLICY = ChannelPolicy((
    PolicyClass(NARROW, 0, "psum", "wide"),
    PolicyClass(WIDE, 65536, "ring", "wide"),
), bucket_bytes=None)


def multi_channel_all_reduce(
    tree: Any,
    axes: Sequence[tuple[str, int]],
    *,
    policy: ChannelPolicy = DUAL_POLICY,
    bidir: bool = False,
    ledger: Ledger | None = None,
) -> Any:
    """All-reduce a gradient pytree under a declarative channel policy.

    axes: [(axis_name, size), ...] in dimension (XY) order.  Leaves are
    classified by size into the policy's classes; per physical channel,
    psum classes get one fused flit-packed latency-optimal ``psum``
    each, ring classes get bucketed dimension-ordered ring RS+AG — and
    any class sharing a channel with a ring class is serialized into
    that ring (the wide-only ablation falls out of the policy instead of
    being a separate code path).
    """
    total = 1
    for _, s in axes:
        total *= s
    if total == 1:
        return tree

    leaves, treedef = jax.tree.flatten(tree)
    axis_names = tuple(n for n, _ in axes)
    leaf_cls = [policy.classify(_nbytes(l)) for l in leaves]
    out: list[Any] = [None] * len(leaves)

    def fused_psum(idxs: list[int], cls_name: str) -> None:
        payload, header = flit.pack([leaves[i] for i in idxs])
        reduced = {k: lax.psum(v, axis_names) for k, v in payload.items()}
        if ledger is not None:
            for v in payload.values():
                ledger.log("psum", axis_names, _nbytes(v), cls_name,
                           f"flit-packed x{len(idxs)}")
        restored = flit.unpack(reduced, header)
        for j, i in enumerate(idxs):
            out[i] = restored[j]

    def ring_group(idxs: list[int], cls_name: str) -> None:
        cap = policy.bucket_bytes
        buckets = (bucketize([leaves[i] for i in idxs], cap)
                   if cap else [list(range(len(idxs)))])
        for bucket in buckets:
            bidx = [idxs[j] for j in bucket]
            payload, header = flit.pack([leaves[i] for i in bidx])
            reduced = {}
            for k, v in payload.items():
                vp, n = flit.pad_to(v, total * (2 if bidir else 1))
                r = collectives.dim_ordered_all_reduce(vp, axes, dim=0,
                                                   bidir=bidir)
                reduced[k] = r[:n]
                if ledger is not None:
                    ledger.log("ring_rs_ag", axis_names, _nbytes(vp),
                               cls_name,
                               f"bucket x{len(bidx)} bidir={bidir}")
            restored = flit.unpack(reduced, header)
            for j, i in enumerate(bidx):
                out[i] = restored[j]

    # group policy classes by physical channel, preserving policy order
    by_channel: dict[str, list[PolicyClass]] = {}
    for pc in policy.classes:
        by_channel.setdefault(pc.channel, []).append(pc)

    for channel, pcs in by_channel.items():
        has_ring = any(pc.transport == "ring" for pc in pcs)
        if has_ring:
            # shared link: every class on this channel serializes through
            # one bucketed ring schedule (smalls stall behind bulk)
            idxs = [i for i, lc in enumerate(leaf_cls)
                    if lc.channel == channel]
            if idxs:
                ring_group(idxs, "+".join(pc.name for pc in pcs))
        else:
            for pc in pcs:
                idxs = [i for i, lc in enumerate(leaf_cls) if lc is pc]
                if idxs:
                    fused_psum(idxs, pc.name)

    return jax.tree.unflatten(treedef, out)
