"""Network-interface semantics: outstanding transactions + e2e flow control.

FlooNoC's NI injects a request only when the Reorder Buffer has space for the
response (end-to-end flow control), and keeps multiple transactions in
flight to hide latency. The SPMD analogue:

* a *transaction* = one chunked collective (ring RS/AG of one bucket);
* *multiple outstanding transactions* = several chunk collectives with no
  data dependence, which XLA schedules concurrently (async collectives on
  TPU) and overlaps with compute;
* *ROB capacity / flow control* = an explicit bound on how many chunks may
  be simultaneously un-ordered, enforced with ``lax.optimization_barrier``
  every ``window`` chunks — chunk ``i+window`` cannot issue before chunk
  ``i`` completed, exactly like a request stalling on ROB space.

The paper's ROB bypass rule (deterministic routing => same-destination
responses arrive in order) is what makes the static ring schedules of
``core/collectives.py`` legal with *zero* reordering logic: XLA program order is
the deterministic route.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives


@dataclass(frozen=True)
class TransactionWindow:
    """ROB-capacity model: at most ``window`` read-direction chunk
    transfers in flight, plus an independent ``write_window`` for the
    write direction — AXI4 reads (AR -> R) and writes (AW -> W -> B)
    hold separate outstanding budgets, so a gather stream and a scatter
    stream flow-control independently (the cycle simulator models the
    same split as per-class ``out_r``/``out_w`` ROB credits)."""
    chunks: int = 1
    window: int = 2
    write_window: int = 2

    @property
    def rob_bytes_per_flit(self) -> Callable[[int], int]:
        return lambda total: (total // max(self.chunks, 1)) * self.window

    @property
    def rob_bytes_per_flit_rw(self) -> Callable[[int], int]:
        """Both directions' working-set bound: the read ROB plus the
        posted-write buffer (paper: the wide ROB is sized to 2
        outstanding max-burst transactions per direction)."""
        return lambda total: (total // max(self.chunks, 1)) \
            * (self.window + self.write_window)


def windowed_transactions(
    thunks: Sequence[Callable[[], jax.Array]],
    window: int,
) -> list[jax.Array]:
    """Run transfer thunks with at most `window` outstanding (flow control).

    Dependencies are injected with ``optimization_barrier``: thunk i+window
    is data-dependent on thunk i's completion token, so the compiler cannot
    hoist more than `window` transfers into flight — the software ROB.
    """
    results: list[jax.Array] = []
    for i, thunk in enumerate(thunks):
        if window > 0 and i >= window:
            # gate on the (i-window)-th completion: zero-cost token dependence
            token = results[i - window]
            gated = lax.optimization_barrier((token,))[0]
            # re-materialize the gated value so later uses see the barrier
            results[i - window] = gated
        results.append(thunk())
    return results


def windowed_rw_transactions(
    read_thunks: Sequence[Callable[[], jax.Array]],
    write_thunks: Sequence[Callable[[], jax.Array]],
    *,
    window: int = 2,
    write_window: int = 2,
) -> tuple[list[jax.Array], list[jax.Array]]:
    """Issue read- and write-direction transfers with INDEPENDENT
    outstanding windows (the AXI AR/AW split).

    Reads and writes interleave in program order so XLA can overlap
    them on duplex links, but each direction's flow control only gates
    its own stream: a full write window (unacked B's, in hardware)
    never stalls read issue, and vice versa — the property PATRONoC
    shows determines DNN-traffic behavior.  Each returned list matches
    its thunks; the barriers are zero-cost token dependences exactly as
    in :func:`windowed_transactions`.
    """
    reads: list[jax.Array] = []
    writes: list[jax.Array] = []

    def gate(results: list[jax.Array], i: int, win: int) -> None:
        if win > 0 and i >= win:
            token = results[i - win]
            results[i - win] = lax.optimization_barrier((token,))[0]

    for i in range(max(len(read_thunks), len(write_thunks))):
        if i < len(read_thunks):
            gate(reads, i, window)
            reads.append(read_thunks[i]())
        if i < len(write_thunks):
            gate(writes, i, write_window)
            writes.append(write_thunks[i]())
    return reads, writes


def chunked_all_reduce(
    x: jax.Array,
    axes: Sequence[tuple[str, int]],
    *,
    chunks: int = 4,
    window: int = 2,
    bidir: bool = False,
) -> jax.Array:
    """All-reduce a flat buffer as `chunks` outstanding ring transactions.

    Chunking bounds the ROB (working buffer) to window*chunk bytes while
    still keeping the links busy — the NI's sustained-dataflow sizing rule
    (the paper sizes the wide ROB to 2 outstanding max-burst transactions).
    """
    total = 1
    for _, s in axes:
        total *= s
    if total == 1 or chunks <= 1:
        return collectives.dim_ordered_all_reduce(x, axes, dim=0, bidir=bidir)
    n = x.shape[0]
    per = -(-n // chunks)
    per += (-per) % (total * (2 if bidir else 1))   # flit-align each chunk
    pads = chunks * per - n
    xp = jnp.pad(x, (0, pads)) if pads else x
    parts = [lax.dynamic_slice_in_dim(xp, i * per, per) for i in range(chunks)]
    thunks = [
        (lambda p=p: collectives.dim_ordered_all_reduce(p, axes, dim=0, bidir=bidir))
        for p in parts
    ]
    outs = windowed_transactions(thunks, window)
    return jnp.concatenate(outs)[:n]
