"""Full FlooNoC simulation: NIs + 1 or 3 physical networks, scanned cycles.

Network configurations (paper §III-B, Table I):
  narrow-wide : three independent networks. narrow_req carries narrow read
                requests AND wide AR requests; narrow_rsp carries narrow
                read responses (and B); wide carries R burst beats.
  wide-only   : ablation baseline — ONE network carries everything; a
                narrow flit occupies a full wide-link cycle and burst
                packets hold links end-to-end (wormhole), which is what
                starves latency-critical smalls (paper Fig. 5a).

NI model (paper §III-A):
  * end-to-end flow control: a request is injected only if the source ROB
    has space for its response (per-class outstanding limits),
  * separate response buffers per physical link (narrow rsp / wide rsp),
  * read transactions: req flit -> target NI -> after `service_lat` cycles
    the response (1 narrow flit, or `burstlen` wide beats) streams back;
    a burst, once started, streams atomically (it is one packet),
  * responses to the same destination arrive in order (deterministic XY
    routing) — the ROB-bypass rule that removes reorder logic.

Traffic is a precomputed schedule (see traffic.py); everything is jitted
and scanned over cycles.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .router import (F_BEAT, F_DEST, F_KIND, F_SRC, F_TIME, F_TXN, N_FIELDS,
                     NetState, init_state, network_step)

# flit kinds
K_NARROW_REQ, K_NARROW_RSP, K_WIDE_REQ, K_WIDE_RSP = 0, 1, 2, 3
Q_NAR, Q_WIDE = 0, 1

RESP_Q_CAP = 256
BIG = 1 << 30


@dataclass(frozen=True)
class SimConfig:
    nx: int = 4
    ny: int = 4
    depth: int = 2
    narrow_wide: bool = True       # False = wide-only ablation
    burstlen: int = 16
    service_lat: int = 10          # target memory + NI latency (cycles)
    max_narrow_outstanding: int = 8
    max_wide_outstanding: int = 8  # 8kB ROB / 1kB burst (Fig. 5 setup)
    cycles: int = 4000

    @property
    def n_routers(self) -> int:
        return self.nx * self.ny


class NIState(NamedTuple):
    nar_ptr: jax.Array          # (R,)  schedule pointers
    wide_ptr: jax.Array         # (R,)
    nar_out: jax.Array          # (R,)  outstanding (ROB flow control)
    wide_out: jax.Array         # (R,)
    # response ring buffers, class-split: (R, 2, C)
    rq_head: jax.Array          # (R, 2)
    rq_tail: jax.Array          # (R, 2)
    rq_ready: jax.Array         # (R, 2, C)
    rq_dest: jax.Array          # (R, 2, C)
    rq_beats: jax.Array         # (R, 2, C)
    rq_time0: jax.Array         # (R, 2, C)
    rq_txn: jax.Array           # (R, 2, C)
    rq_kind: jax.Array          # (R, 2, C)
    w_started: jax.Array        # (R,) wide burst mid-stream (inject atomicity)
    inj_rr: jax.Array           # (R,) wide-only injection round-robin
    # metrics
    nar_lat_sum: jax.Array      # (R,)
    nar_lat_max: jax.Array      # (R,)
    nar_done: jax.Array         # (R,)
    wide_beats_rx: jax.Array    # (R,)
    wide_done: jax.Array        # (R,)
    wide_lat_sum: jax.Array     # (R,)
    first_beat_t: jax.Array     # (R,)
    last_beat_t: jax.Array      # (R,)


class SimState(NamedTuple):
    nets: tuple
    ni: NIState
    cycle: jax.Array


def init_ni(R: int) -> NIState:
    z = jnp.zeros((R,), jnp.int32)
    z2 = jnp.zeros((R, 2), jnp.int32)
    zc = jnp.zeros((R, 2, RESP_Q_CAP), jnp.int32)
    return NIState(z, z, z, z, z2, z2, zc, zc, zc, zc, zc, zc,
                   jnp.zeros((R,), jnp.bool_), z,
                   z, z, z, z, z, z, jnp.full((R,), BIG, jnp.int32), z)


def _q_push(ni: NIState, q: int, valid, dest, beats, time0, txn, ready_at,
            kind):
    rows = jnp.arange(valid.shape[0])
    slot = ni.rq_tail[:, q] % RESP_Q_CAP

    def upd(arr, val):
        return arr.at[rows, q, slot].set(
            jnp.where(valid, val, arr[rows, q, slot]))

    return ni._replace(
        rq_ready=upd(ni.rq_ready, ready_at),
        rq_dest=upd(ni.rq_dest, dest),
        rq_beats=upd(ni.rq_beats, beats),
        rq_time0=upd(ni.rq_time0, time0),
        rq_txn=upd(ni.rq_txn, txn),
        rq_kind=upd(ni.rq_kind, kind),
        rq_tail=ni.rq_tail.at[:, q].add(valid.astype(jnp.int32)),
    )


def _q_head(ni: NIState, q: int, now):
    rows = jnp.arange(ni.rq_head.shape[0])
    have = ni.rq_head[:, q] < ni.rq_tail[:, q]
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    ready = have & (ni.rq_ready[rows, q, slot] <= now)
    return {
        "ready": ready,
        "slot": slot,
        "dest": ni.rq_dest[rows, q, slot],
        "beats": ni.rq_beats[rows, q, slot],
        "time0": ni.rq_time0[rows, q, slot],
        "txn": ni.rq_txn[rows, q, slot],
        "kind": ni.rq_kind[rows, q, slot],
    }


def _q_sent(ni: NIState, q: int, sent):
    """Decrement head beats; pop when exhausted."""
    rows = jnp.arange(sent.shape[0])
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    left = ni.rq_beats[rows, q, slot] - sent.astype(jnp.int32)
    ni = ni._replace(
        rq_beats=ni.rq_beats.at[rows, q, slot].set(
            jnp.where(sent, left, ni.rq_beats[rows, q, slot])),
        rq_head=ni.rq_head.at[:, q].add(
            (sent & (left <= 0)).astype(jnp.int32)),
    )
    if q == Q_WIDE:
        ni = ni._replace(w_started=jnp.where(sent, left > 0, ni.w_started))
    return ni


def make_step(cfg: SimConfig, traffic):
    R = cfg.n_routers
    nx, ny = cfg.nx, cfg.ny
    rows = jnp.arange(R)
    nar_time = jnp.asarray(traffic["nar_time"])
    nar_dest = jnp.asarray(traffic["nar_dest"])
    wide_time = jnp.asarray(traffic["wide_time"])
    wide_dest = jnp.asarray(traffic["wide_dest"])
    Tn, Tw = nar_time.shape[1], wide_time.shape[1]

    def mk_flit(valid, dest, src, time, kind, txn, beat):
        f = jnp.zeros((R, N_FIELDS), jnp.int32)
        z = jnp.int32(0)
        for idx, val in ((F_DEST, dest), (F_SRC, src), (F_TIME, time),
                         (F_KIND, kind), (F_TXN, txn), (F_BEAT, beat)):
            f = f.at[:, idx].set(jnp.where(valid, val, z))
        return f

    def step(state: SimState, _):
        ni = state.ni
        now = state.cycle

        # ---- source side: request candidates (ROB flow control) -----------
        np_ = jnp.clip(ni.nar_ptr, 0, Tn - 1)
        nar_want = ((ni.nar_ptr < Tn) & (nar_time[rows, np_] <= now)
                    & (ni.nar_out < cfg.max_narrow_outstanding))
        nar_d = nar_dest[rows, np_]

        wp = jnp.clip(ni.wide_ptr, 0, Tw - 1)
        wide_want = ((ni.wide_ptr < Tw) & (wide_time[rows, wp] <= now)
                     & (ni.wide_out < cfg.max_wide_outstanding))
        wide_d = wide_dest[rows, wp]

        # ---- target side: response heads ----------------------------------
        hn = _q_head(ni, Q_NAR, now)
        hw = _q_head(ni, Q_WIDE, now)

        nets = state.nets
        if cfg.narrow_wide:
            # net0 narrow_req: narrow reqs + wide AR (narrow priority)
            req_valid = nar_want | wide_want
            use_nar = nar_want
            f_req = mk_flit(req_valid,
                            jnp.where(use_nar, nar_d, wide_d), rows, now,
                            jnp.where(use_nar, K_NARROW_REQ, K_WIDE_REQ),
                            jnp.where(use_nar, ni.nar_ptr, ni.wide_ptr), 1)
            net0, ok_req, dv0, df0, lm0 = network_step(nets[0], req_valid,
                                                       f_req, nx, ny)
            nar_injected = ok_req & use_nar
            wide_injected = ok_req & ~use_nar & wide_want

            # net1 narrow_rsp
            f_rsp = mk_flit(hn["ready"], hn["dest"], rows, hn["time0"],
                            K_NARROW_RSP, hn["txn"], 1)
            net1, ok1, dv1, df1, lm1 = network_step(nets[1], hn["ready"],
                                                    f_rsp, nx, ny)
            nar_rsp_sent = ok1 & hn["ready"]

            # net2 wide: R burst beats (atomic packet)
            f_beat = mk_flit(hw["ready"], hw["dest"], rows, hw["time0"],
                             K_WIDE_RSP, hw["txn"], hw["beats"])
            net2, ok2, dv2, df2, lm2 = network_step(nets[2], hw["ready"],
                                                    f_beat, nx, ny)
            wide_rsp_sent = ok2 & hw["ready"]

            new_nets = (net0, net1, net2)
            deliveries = [(dv0, df0), (dv1, df1), (dv2, df2)]
            link_moves, wide_moves = lm0 + lm1 + lm2, lm2
        else:
            # wide-only: one network. Injection priority per NI with burst
            # atomicity: an in-flight wide burst excludes everything else;
            # otherwise round-robin between classes (fair single-channel).
            # single shared response FIFO (one R channel on one link);
            # bursts stream atomically once started
            head_is_burst = hw["kind"] == K_WIDE_RSP
            burst_hold = ni.w_started & (hw["beats"] > 0)
            rr = ni.inj_rr % 3
            cand_valid = jnp.stack(
                [hw["ready"], nar_want, wide_want], axis=1)
            order = (jnp.arange(3)[None, :] + rr[:, None]) % 3
            ordered_valid = jnp.take_along_axis(cand_valid, order, axis=1)
            first = jnp.argmax(ordered_valid, axis=1)
            has_any = jnp.any(cand_valid, axis=1)
            choice = jnp.take_along_axis(order, first[:, None], axis=1)[:, 0]
            choice = jnp.where(burst_hold, 0, choice)       # burst streams on
            valid = has_any | burst_hold

            is_rsp = valid & (choice == 0) & hw["ready"]
            is_nreq = valid & (choice == 1)
            is_wreq = valid & (choice == 2)
            valid = is_rsp | is_nreq | is_wreq

            dest = jnp.where(is_rsp, hw["dest"],
                   jnp.where(is_nreq, nar_d, wide_d))
            kind = jnp.where(is_rsp, hw["kind"],
                   jnp.where(is_nreq, K_NARROW_REQ, K_WIDE_REQ))
            time = jnp.where(is_rsp, hw["time0"], now)
            txn = jnp.where(is_rsp, hw["txn"],
                  jnp.where(is_nreq, ni.nar_ptr, ni.wide_ptr))
            beat = jnp.where(is_rsp & head_is_burst, hw["beats"], 1)
            f = mk_flit(valid, dest, rows, time, kind, txn, beat)
            net0, ok, dv0, df0, lm0 = network_step(nets[0], valid, f, nx, ny)
            nar_injected = ok & is_nreq
            wide_injected = ok & is_wreq
            nar_rsp_sent = jnp.zeros_like(ok) & ok
            wide_rsp_sent = ok & is_rsp
            ni = ni._replace(
                inj_rr=jnp.where(ok & ~burst_hold, ni.inj_rr + 1, ni.inj_rr),
                w_started=ni.w_started |
                          (wide_rsp_sent & head_is_burst & (hw["beats"] > 1)))
            new_nets = (net0,)
            deliveries = [(dv0, df0)]
            link_moves = wide_moves = lm0

        if cfg.narrow_wide:
            ni = ni._replace(
                w_started=ni.w_started | (wide_rsp_sent & (hw["beats"] > 1)))

        # ---- pointer / outstanding / queue updates -------------------------
        ni = ni._replace(
            nar_ptr=ni.nar_ptr + nar_injected.astype(jnp.int32),
            wide_ptr=ni.wide_ptr + wide_injected.astype(jnp.int32),
            nar_out=ni.nar_out + nar_injected.astype(jnp.int32),
            wide_out=ni.wide_out + wide_injected.astype(jnp.int32),
        )
        ni = _q_sent(ni, Q_NAR, nar_rsp_sent)
        ni = _q_sent(ni, Q_WIDE, wide_rsp_sent)

        # ---- deliveries -----------------------------------------------------
        for dv, df in deliveries:
            kind = df[:, F_KIND]
            src = df[:, F_SRC]
            is_nreq = dv & (kind == K_NARROW_REQ)
            q_nar = Q_NAR if cfg.narrow_wide else Q_WIDE  # shared FIFO ablation
            ni = _q_push(ni, q_nar, is_nreq, src, jnp.ones((R,), jnp.int32),
                         df[:, F_TIME], df[:, F_TXN], now + cfg.service_lat,
                         jnp.full((R,), K_NARROW_RSP, jnp.int32))
            is_wreq = dv & (kind == K_WIDE_REQ)
            ni = _q_push(ni, Q_WIDE, is_wreq, src,
                         jnp.full((R,), cfg.burstlen, jnp.int32),
                         df[:, F_TIME], df[:, F_TXN], now + cfg.service_lat,
                         jnp.full((R,), K_WIDE_RSP, jnp.int32))
            is_nrsp = dv & (kind == K_NARROW_RSP)
            lat = now - df[:, F_TIME]
            ni = ni._replace(
                nar_lat_sum=ni.nar_lat_sum + jnp.where(is_nrsp, lat, 0),
                nar_lat_max=jnp.maximum(ni.nar_lat_max,
                                        jnp.where(is_nrsp, lat, 0)),
                nar_done=ni.nar_done + is_nrsp.astype(jnp.int32),
                nar_out=ni.nar_out - is_nrsp.astype(jnp.int32),
            )
            is_wrsp = dv & (kind == K_WIDE_RSP)
            last_beat = is_wrsp & (df[:, F_BEAT] <= 1)
            ni = ni._replace(
                wide_beats_rx=ni.wide_beats_rx + is_wrsp.astype(jnp.int32),
                first_beat_t=jnp.where(is_wrsp,
                                       jnp.minimum(ni.first_beat_t, now),
                                       ni.first_beat_t),
                last_beat_t=jnp.where(is_wrsp,
                                      jnp.maximum(ni.last_beat_t, now),
                                      ni.last_beat_t),
                wide_done=ni.wide_done + last_beat.astype(jnp.int32),
                wide_lat_sum=ni.wide_lat_sum + jnp.where(last_beat, lat, 0),
                wide_out=ni.wide_out - last_beat.astype(jnp.int32),
            )

        return SimState(new_nets, ni, now + 1), link_moves

    return step


def run_sim(cfg: SimConfig, traffic) -> dict:
    R = cfg.n_routers
    n_nets = 3 if cfg.narrow_wide else 1
    nets = tuple(init_state(cfg.nx, cfg.ny, cfg.depth) for _ in range(n_nets))
    state = SimState(nets, init_ni(R), jnp.int32(0))
    step = make_step(cfg, traffic)

    @jax.jit
    def go(state):
        return jax.lax.scan(step, state, None, length=cfg.cycles)

    final, link_moves = go(state)
    ni = final.ni
    nar_done = np.maximum(np.asarray(ni.nar_done), 1)
    wide_done = np.maximum(np.asarray(ni.wide_done), 1)
    span = np.maximum(np.asarray(ni.last_beat_t)
                      - np.minimum(np.asarray(ni.first_beat_t),
                                   np.asarray(ni.last_beat_t)), 1)
    return {
        "narrow_done": np.asarray(ni.nar_done),
        "narrow_avg_lat": np.asarray(ni.nar_lat_sum) / nar_done,
        "narrow_max_lat": np.asarray(ni.nar_lat_max),
        "wide_done": np.asarray(ni.wide_done),
        "wide_beats_rx": np.asarray(ni.wide_beats_rx),
        "wide_avg_lat": np.asarray(ni.wide_lat_sum) / wide_done,
        "wide_eff_bw": np.asarray(ni.wide_beats_rx) / span,
        "cycles": cfg.cycles,
        "total_link_moves": int(np.asarray(jnp.sum(link_moves))),
    }
