"""DEPRECATED shim — the seed's ``SimConfig``/``run_sim`` surface.

The cycle engine moved to :mod:`repro.noc` (declarative
``NocSpec``/``Workload``/``simulate``), which generalizes the hardcoded
``narrow_wide: bool`` 1-or-3-network branch that used to live here into
an arbitrary list of physical channels with a class->channel map.  The
generalized engine is cycle-exact with the seed simulator for both
paper presets (golden-checked in ``tests/test_noc_api.py``).

This module keeps the old names importable: ``SimConfig`` maps onto the
matching :class:`repro.noc.NocSpec` preset and ``run_sim`` feeds legacy
schedule dicts through :func:`repro.noc.simulate`, returning the same
result-dict keys the seed produced.  New code should use ``repro.noc``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

# re-exported for legacy callers that imported kinds from here
K_NARROW_REQ, K_NARROW_RSP, K_WIDE_REQ, K_WIDE_RSP = 0, 1, 2, 3

RESP_Q_CAP = 256
BIG = 1 << 30


@dataclass(frozen=True)
class SimConfig:
    """Legacy two-class config. Use :class:`repro.noc.NocSpec` presets
    (``NocSpec.narrow_wide`` / ``NocSpec.wide_only``) in new code."""
    nx: int = 4
    ny: int = 4
    depth: int = 2
    narrow_wide: bool = True       # False = wide-only ablation
    burstlen: int = 16
    service_lat: int = 10          # target memory + NI latency (cycles)
    max_narrow_outstanding: int = 8
    max_wide_outstanding: int = 8  # 8kB ROB / 1kB burst (Fig. 5 setup)
    cycles: int = 4000

    @property
    def n_routers(self) -> int:
        return self.nx * self.ny

    def to_spec(self):
        """The equivalent declarative :class:`repro.noc.NocSpec`."""
        from repro.noc import NocSpec
        preset = NocSpec.narrow_wide if self.narrow_wide else \
            NocSpec.wide_only
        return preset(
            self.nx, self.ny, depth=self.depth, burstlen=self.burstlen,
            service_lat=self.service_lat, cycles=self.cycles,
            max_narrow_outstanding=self.max_narrow_outstanding,
            max_wide_outstanding=self.max_wide_outstanding)


def run_sim(cfg: SimConfig, traffic) -> dict:
    """DEPRECATED: call :func:`repro.noc.simulate` instead."""
    warnings.warn(
        "repro.core.noc_sim.run_sim is deprecated; use "
        "repro.noc.simulate(NocSpec, Workload)", DeprecationWarning,
        stacklevel=2)
    from repro.noc import from_legacy_traffic, simulate_schedules
    spec = cfg.to_spec()
    return simulate_schedules(spec, from_legacy_traffic(spec, traffic)) \
        .to_legacy()
