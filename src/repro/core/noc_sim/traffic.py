"""Legacy traffic schedule generators (paper Fig. 5 setups).

Schedules are dense (R, T) int32 arrays of desired inject times (sorted
per NI; an entry beyond the horizon disables the slot) plus
destinations.  New code should declare a :class:`repro.noc.Workload`
("fig5" / "uniform_random" patterns carry the same semantics, typed
against the spec's traffic classes); these helpers remain for the
deprecated ``SimConfig``/``run_sim`` path.
"""
from __future__ import annotations

import numpy as np


def _empty(R: int):
    return {"nar_time": np.full((R, 1), 1 << 30, np.int32),
            "nar_dest": np.zeros((R, 1), np.int32),
            "wide_time": np.full((R, 1), 1 << 30, np.int32),
            "wide_dest": np.zeros((R, 1), np.int32)}


def fig5_traffic(cfg, *, num_narrow: int = 100, num_wide: int = 16,
                 wide_rate: float = 1.0, narrow_rate: float = 0.05,
                 src: int | None = None, dst: int | None = None,
                 bidir: bool = False):
    """Cluster-to-cluster accesses between two tiles (paper Fig. 5).

    src tile issues `num_narrow` narrow reads at `narrow_rate` (flits/cycle)
    and wide burst reads at `wide_rate` (bursts are back-to-back when the
    rate is 1.0). `bidir` mirrors the same traffic from dst to src.
    wide_rate/narrow_rate scale the injection gap (0 disables).

    The schedule is fully deterministic (the former ``seed`` parameter
    was accepted and ignored; it has been removed).
    """
    R = cfg.n_routers
    if src is None:
        src = 0
    if dst is None:
        dst = R - 1
    out = _empty(R)

    def sched(rate: float, count: int, stretch: int = 1):
        if rate <= 0 or count <= 0:
            return np.full((1,), 1 << 30, np.int32)
        gap = max(1, int(round(stretch / rate)))
        return (10 + np.arange(count) * gap).astype(np.int32)

    def add(kind: str, s: int, d: int, times: np.ndarray):
        tkey, dkey = f"{kind}_time", f"{kind}_dest"
        T = max(out[tkey].shape[1], times.shape[0])
        for key, fill in ((tkey, 1 << 30), (dkey, 0)):
            cur = out[key]
            if cur.shape[1] < T:
                pad = np.full((R, T - cur.shape[1]), fill, np.int32)
                out[key] = np.concatenate([cur, pad], axis=1)
        out[tkey][s, :times.shape[0]] = times
        out[dkey][s, :times.shape[0]] = d

    add("nar", src, dst, sched(narrow_rate, num_narrow))
    # wide bursts: one AR per burstlen beats; rate= beats/cycle target =>
    # AR gap = burstlen / rate
    add("wide", src, dst, sched(wide_rate, num_wide, stretch=cfg.burstlen))
    if bidir:
        add("nar", dst, src, sched(narrow_rate, num_narrow))
        add("wide", dst, src, sched(wide_rate, num_wide, stretch=cfg.burstlen))
    return {k: np.asarray(v) for k, v in out.items()}


def uniform_random(cfg, *, narrow_per_ni: int = 0, wide_per_ni: int = 0,
                   narrow_rate: float = 0.05, wide_rate: float = 0.25,
                   seed: int = 0):
    """Uniform-random background traffic (all NIs, random destinations)."""
    R = cfg.n_routers
    rng = np.random.default_rng(seed)
    out = _empty(R)

    def fill(kind, count, rate, stretch=1):
        if count <= 0 or rate <= 0:
            return
        gap = max(1, int(round(stretch / rate)))
        times = 10 + np.cumsum(rng.integers(1, 2 * gap, size=(R, count)),
                               axis=1).astype(np.int32)
        # never self: shared remap with the repro.noc workload patterns
        # (draw from [0, R-1) so the +1 shift can't wrap onto the source)
        from repro.noc.workload import _no_self_dests
        out[f"{kind}_time"] = times
        out[f"{kind}_dest"] = _no_self_dests(rng, R, count)

    fill("nar", narrow_per_ni, narrow_rate)
    fill("wide", wide_per_ni, wide_rate, stretch=cfg.burstlen)
    return {k: np.asarray(v) for k, v in out.items()}
