"""Analytic area / energy / bandwidth model, parameterized by the paper.

All published quantities (§V, §VI, Table I/II, Fig. 6):
  * 12nm FinFET, 1.23 GHz typical corner, 70 FO4 delay
  * links: narrow_req 119b, narrow_rsp 103b, wide 603b (duplex channel
    ~1600 wires + ~100%-utilized two metal layers -> 120 um channel slice)
  * wide link peak: 512b payload x 1.23 GHz = 629 Gbps (1.26 Tbps duplex)
  * energy: 0.19 pJ/B/hop (198 pJ to move 1 kB across one tile)
  * area: NoC ~500 kGE of a ~5 MGE tile (10%); tile power 139 mW during a
    1 kB DMA, NoC share 7%
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FlooNoCModel:
    freq_ghz: float = 1.23
    wide_payload_bits: int = 512
    narrow_payload_bits: int = 64
    link_bits_narrow_req: int = 119
    link_bits_narrow_rsp: int = 103
    link_bits_wide: int = 603
    pj_per_byte_hop: float = 0.19
    tile_area_mge: float = 5.0
    noc_area_kge: float = 500.0
    tile_power_mw: float = 139.0
    noc_power_frac: float = 0.07
    tile_mm: float = 1.0

    # -- bandwidth ----------------------------------------------------------
    def wide_link_gbps(self) -> float:
        """Peak payload bandwidth of one wide link direction."""
        return self.wide_payload_bits * self.freq_ghz           # Gbps

    def wide_link_duplex_tbps(self) -> float:
        return 2 * self.wide_link_gbps() / 1e3

    def mesh_boundary_bandwidth_tbs(self, nx: int, ny: int) -> float:
        """Aggregate duplex payload bandwidth crossing the mesh boundary
        (memory controllers on all four sides, as in Fig. 4a)."""
        edge_links = 2 * (nx + ny)
        bytes_per_s = edge_links * 2 * self.wide_link_gbps() / 8  # GB/s
        return bytes_per_s / 1e3                                  # TB/s

    # -- energy ---------------------------------------------------------------
    def energy_pj(self, n_bytes: int, hops: int) -> float:
        return self.pj_per_byte_hop * n_bytes * hops

    # -- area -----------------------------------------------------------------
    def noc_area_fraction(self) -> float:
        return self.noc_area_kge / (self.tile_area_mge * 1000.0)

    def duplex_channel_wires(self) -> int:
        return 2 * (self.link_bits_narrow_req + self.link_bits_narrow_rsp
                    + self.link_bits_wide)

    def routing_channel_um(self, wire_pitch_um: float = 0.15,
                           layers: int = 2, margin: float = 1.25) -> float:
        """Width of the physical routing channel slice (paper: ~120 um)."""
        wires = self.duplex_channel_wires()
        return wires * wire_pitch_um / layers * margin


PAPER = FlooNoCModel()

PAPER_CLAIMS = {
    "wide_link_gbps": 629.0,
    "wide_link_duplex_tbps": 1.26,
    "mesh7x7_boundary_tbs": 4.4,
    "pj_per_byte_hop": 0.19,
    "zero_load_round_trip_cycles": 18,
    "noc_area_fraction": 0.10,
    "noc_power_fraction": 0.07,
    "eff_bandwidth_utilization": 0.85,
    "wide_only_latency_degradation_x": 5.0,
}
