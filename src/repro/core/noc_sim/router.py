"""Vectorized cycle-level model of one FlooNoC physical network.

Faithful to §III-C / §V of the paper:
* input-buffered routers (depth-2 FIFO, registered ready/valid backpressure,
  full throughput),
* **two-cycle router**: an output elastic buffer (register) per port — the
  configuration the paper uses to close timing on the long physical routing
  channels (zero-load: 4 traversals x 2 cycles = 8 router cycles per
  round trip),
* XY dimension-ordered routing on a (non-torus) mesh,
* round-robin output arbitration,
* no virtual channels — each physical link (narrow_req / narrow_rsp / wide)
  is its own complete network instance,
* single-flit packets (header bits travel on parallel lines, no
  header/tail flits).

State layout (R = nx*ny routers, P = 5 ports [N,E,S,W,Local], D fifo depth,
F flit fields):
  fifo    : (R, P, D, F) int32   input FIFOs, slot 0 = head
  count   : (R, P)       int32   input occupancy
  rr_ptr  : (R, P)       int32   round-robin pointer per OUT port
  oreg    : (R, P, F)    int32   output elastic buffer
  oreg_v  : (R, P)       bool

Flit fields: [dest_router, src_router, inject_time, kind, txn_id, beat]
The per-cycle update (`network_step`) is the hot loop — mirrored by the
Pallas kernel in ``kernels/noc_router.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

N_PORTS = 5
PORT_N, PORT_E, PORT_S, PORT_W, PORT_L = range(5)
F_DEST, F_SRC, F_TIME, F_KIND, F_TXN, F_BEAT = range(6)
N_FIELDS = 6
NO_PORT = 9


class NetState(NamedTuple):
    fifo: jax.Array     # (R, P, D, F)
    count: jax.Array    # (R, P)
    rr_ptr: jax.Array   # (R, P)
    oreg: jax.Array     # (R, P, F)
    oreg_v: jax.Array   # (R, P)
    lock_in: jax.Array  # (R, P) wormhole: input port holding each output (-1)


def init_state(nx: int, ny: int, depth: int = 2) -> NetState:
    R = nx * ny
    return NetState(
        fifo=jnp.zeros((R, N_PORTS, depth, N_FIELDS), jnp.int32),
        count=jnp.zeros((R, N_PORTS), jnp.int32),
        rr_ptr=jnp.zeros((R, N_PORTS), jnp.int32),
        oreg=jnp.zeros((R, N_PORTS, N_FIELDS), jnp.int32),
        oreg_v=jnp.zeros((R, N_PORTS), jnp.bool_),
        lock_in=jnp.full((R, N_PORTS), -1, jnp.int32),
    )


def _geometry(nx: int, ny: int):
    """Static neighbor tables: nbr[r, out_port] = neighbor router (or -1),
    opp[out_port] = neighbor's input port."""
    R = nx * ny
    nbr = np.full((R, N_PORTS), -1, np.int64)
    for r in range(R):
        x, y = r % nx, r // nx
        if y > 0:
            nbr[r, PORT_N] = r - nx
        if x < nx - 1:
            nbr[r, PORT_E] = r + 1
        if y < ny - 1:
            nbr[r, PORT_S] = r + nx
        if x > 0:
            nbr[r, PORT_W] = r - 1
    opp = np.array([PORT_S, PORT_W, PORT_N, PORT_E, PORT_L])
    return nbr, opp


def xy_route(dest: jax.Array, r_idx: jax.Array, nx: int) -> jax.Array:
    """XY dimension-ordered output port for a flit at router r_idx."""
    x, y = r_idx % nx, r_idx // nx
    dx, dy = dest % nx, dest // nx
    return jnp.where(
        dx > x, PORT_E,
        jnp.where(dx < x, PORT_W,
                  jnp.where(dy > y, PORT_S,
                            jnp.where(dy < y, PORT_N, PORT_L))))


def network_step(state: NetState, inject_valid: jax.Array,
                 inject_flit: jax.Array, nx: int, ny: int):
    """One cycle of one network (two-cycle router: input FIFO -> output
    register -> link).

    inject_valid: (R,) bool — NI wants to push a flit into its Local port.
    inject_flit:  (R, F) int32.
    Returns (new_state, inject_ok (R,), deliver_valid (R,),
             deliver_flit (R, F), link_moves scalar).
    """
    R = nx * ny
    D = state.fifo.shape[2]
    nbr_np, opp_np = _geometry(nx, ny)
    nbr = jnp.asarray(nbr_np)

    heads = state.fifo[:, :, 0, :]                    # (R, P, F)
    head_valid = state.count > 0                      # (R, P)
    r_idx = jnp.arange(R)

    # ---------------- phase A: drain output registers -----------------------
    # downstream input-FIFO occupancy (registered, cycle start)
    nbr_count = state.count[jnp.clip(nbr, 0, R - 1)]              # (R,P,P_in)
    ds_count = jnp.stack(
        [nbr_count[:, o, opp_np[o]] for o in range(N_PORTS)], axis=1)
    can_drain = jnp.where(jnp.arange(N_PORTS)[None, :] == PORT_L,
                          True,                     # Local: NI always sinks
                          (nbr >= 0) & (ds_count < D))            # (R, P)
    drain = state.oreg_v & can_drain

    deliver_valid = drain[:, PORT_L]
    deliver_flit = state.oreg[:, PORT_L, :]

    # pushes into neighbor input FIFOs (one per input port max — one link)
    recv_valid = jnp.zeros((R, N_PORTS), jnp.bool_)
    recv_flit = jnp.zeros((R, N_PORTS, N_FIELDS), jnp.int32)
    tgt_r = jnp.where(nbr >= 0, nbr, 0)
    for o in range(N_PORTS - 1):   # N,E,S,W
        v = drain[:, o]
        recv_valid = recv_valid.at[tgt_r[:, o], opp_np[o]].max(v)
        recv_flit = recv_flit.at[tgt_r[:, o], opp_np[o]].add(
            jnp.where(v[:, None], state.oreg[:, o, :], 0))

    # NI injection into Local input port (cycle-start occupancy)
    local_ready = state.count[:, PORT_L] < D
    inj_ok = inject_valid & local_ready
    recv_valid = recv_valid.at[:, PORT_L].set(inj_ok)
    recv_flit = recv_flit.at[:, PORT_L].set(
        jnp.where(inj_ok[:, None], inject_flit, 0))

    # ---------------- phase B: arbitration into freed oregs -----------------
    # Wormhole: a multi-flit packet (burst) locks its output port from the
    # first beat until the tail beat (F_BEAT <= 1) has passed, so burst
    # beats are never interleaved — exactly the paper's burst semantics.
    oreg_free = (~state.oreg_v) | drain                           # (R, P)
    out_port = xy_route(heads[:, :, F_DEST], r_idx[:, None], nx)  # (R, P_in)
    out_port = jnp.where(head_valid, out_port, NO_PORT)
    req = (out_port[:, :, None] == jnp.arange(N_PORTS)[None, None, :])
    req = req & oreg_free[:, None, :]

    locked = state.lock_in >= 0                                   # (R, P_out)
    lock_hot = jax.nn.one_hot(jnp.clip(state.lock_in, 0, N_PORTS - 1),
                              N_PORTS, axis=1, dtype=jnp.bool_)   # (R,Pi,Po)
    # when locked: only the locked input may win; others masked off
    req = req & (~locked[:, None, :] | lock_hot)

    in_idx = jnp.arange(N_PORTS)
    prio = (in_idx[None, :, None] - state.rr_ptr[:, None, :]) % N_PORTS
    score = jnp.where(req, prio, 99)
    winner = jnp.argmin(score, axis=1)                            # (R, P_out)
    any_grant = jnp.min(score, axis=1) < 99
    grant = (jax.nn.one_hot(winner, N_PORTS, axis=1, dtype=jnp.bool_)
             & any_grant[:, None, :])                             # (R,Pi,Po)
    new_ptr = jnp.where(any_grant & ~locked, (winner + 1) % N_PORTS,
                        state.rr_ptr)

    pop = jnp.any(grant, axis=2)                                  # (R, P_in)
    flit_to_oreg = jnp.einsum("rio,rif->rof", grant.astype(jnp.int32), heads)

    # lock update: granted non-tail flit locks; granted tail releases
    granted_beat = flit_to_oreg[:, :, F_BEAT]                     # (R, P_out)
    is_tail = granted_beat <= 1
    new_lock = jnp.where(any_grant & ~is_tail, winner,
                         jnp.where(any_grant & is_tail, -1, state.lock_in))

    new_oreg_v = (state.oreg_v & ~drain) | any_grant
    new_oreg = jnp.where(any_grant[:, :, None], flit_to_oreg, state.oreg)

    # ---------------- input FIFO update: pop then push ----------------------
    shifted = jnp.concatenate(
        [state.fifo[:, :, 1:, :], jnp.zeros_like(state.fifo[:, :, :1, :])],
        axis=2)
    fifo = jnp.where(pop[:, :, None, None], shifted, state.fifo)
    count = state.count - pop.astype(jnp.int32)

    slot = jnp.clip(count, 0, D - 1)
    write = recv_valid & (count < D)
    onehot_slot = jax.nn.one_hot(slot, D, dtype=jnp.bool_)        # (R,P,D)
    sel = write[:, :, None] & onehot_slot
    fifo = jnp.where(sel[..., None], recv_flit[:, :, None, :], fifo)
    count = count + write.astype(jnp.int32)

    new_state = NetState(fifo=fifo, count=count, rr_ptr=new_ptr,
                         oreg=new_oreg, oreg_v=new_oreg_v, lock_in=new_lock)
    link_moves = jnp.sum(drain.astype(jnp.int32)
                         * (jnp.arange(N_PORTS)[None, :] != PORT_L))
    return new_state, inj_ok, deliver_valid, deliver_flit, link_moves
