"""Vectorized cycle-level model of one FlooNoC physical network.

Faithful to §III-C / §V of the paper:
* input-buffered routers (depth-2 FIFO, registered ready/valid backpressure,
  full throughput),
* **two-cycle router**: an output elastic buffer (register) per port — the
  configuration the paper uses to close timing on the long physical routing
  channels (zero-load: 4 traversals x 2 cycles = 8 router cycles per
  round trip),
* deterministic table-driven routing — the fabric is described by three
  static tables (neighbor / opposite-port / routing, see
  ``repro.noc.topology``), so one step function covers the paper's XY
  mesh, the torus wrap-around variant, and >5-port express-link routers,
* round-robin output arbitration with wormhole burst locking,
* each physical link class (narrow_req / narrow_rsp / wide) is its own
  complete network instance; *within* a network, virtual channels are
  modelled by table expansion (see ``repro.noc.routing``): each
  non-local physical port is unrolled into ``n_vcs`` virtual ports with
  their own FIFO, output register, round-robin pointer and wormhole
  lock, so the ordinary port-level arbitration below *is* VC-aware
  arbitration.  The only genuinely new behaviour is drain
  serialization (``n_vcs > 1``): one physical link still moves at most
  one flit per cycle, so phase A picks a single ready VC per physical
  port, highest VC index (the escape VC) first,
* single-flit packets (header bits travel on parallel lines, no
  header/tail flits).

State layout (R routers, P ports [directions..., Local last], D fifo
depth, F flit fields):
  fifo    : (R, P, D, F) int32   input FIFOs, slot 0 = head
  count   : (R, P)       int32   input occupancy
  rr_ptr  : (R, P)       int32   round-robin pointer per OUT port
  oreg    : (R, P, F)    int32   output elastic buffer
  oreg_v  : (R, P)       bool
  lock_in : (R, P)       int32   wormhole lock (input idx holding the
                                 output, or -1)

Flit fields: [dest_router, src_router, inject_time, kind, txn_id, beat].
``kind`` encodes the (traffic class, AXI flow) pair via
:func:`repro.core.flit.flow_kind` — the fabric never decodes it (flits
of AR/R reads and AW/W/B writes route identically); only the NI model
in ``repro.noc.engine`` interprets kinds.
The per-cycle update (`make_fabric_step`) is the hot loop; its phase-B
arbitration is pluggable (``arbiter=``) so the Pallas kernel in
``kernels/noc_router.py`` can replace the jnp reference
(:func:`arbiter_jnp`) behind the same engine — see
``repro.noc.backends``.

Two hot-path properties this module guarantees (the fused Pallas kernel
and the padded-depth sweep mode both rely on them):

* the neighbor push is expressed as a static *gather* through the
  precomputed inverse link map (:func:`feeder_tables`) — every input
  port has at most one feeder link, so the seed's per-output-port
  scatter loop and the single gather are exactly equivalent (validated
  at table-build time, not assumed);
* the FIFO depth is a **traced operand**: state is sized by the static
  ``fifo.shape[2]`` max, occupancy checks compare against the dynamic
  ``depth``, so one compilation serves every depth up to the max
  flit-for-flit identically to a natively-sized build.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

F_DEST, F_SRC, F_TIME, F_KIND, F_TXN, F_BEAT = range(6)
N_FIELDS = 6
NO_PORT = 99


class NetState(NamedTuple):
    fifo: jax.Array     # (R, P, D, F)
    count: jax.Array    # (R, P)
    rr_ptr: jax.Array   # (R, P)
    oreg: jax.Array     # (R, P, F)
    oreg_v: jax.Array   # (R, P)
    lock_in: jax.Array  # (R, P) wormhole: input port holding each output (-1)


def init_fabric_state(R: int, P: int, depth: int = 2) -> NetState:
    return NetState(
        fifo=jnp.zeros((R, P, depth, N_FIELDS), jnp.int32),
        count=jnp.zeros((R, P), jnp.int32),
        rr_ptr=jnp.zeros((R, P), jnp.int32),
        oreg=jnp.zeros((R, P, N_FIELDS), jnp.int32),
        oreg_v=jnp.zeros((R, P), jnp.bool_),
        lock_in=jnp.full((R, P), -1, jnp.int32),
    )


def arbiter_jnp(out_port: jax.Array, beat: jax.Array, rr_ptr: jax.Array,
                oreg_free: jax.Array, lock_in: jax.Array):
    """Reference phase-B arbitration: round-robin over requesting input
    heads into free output registers, honoring wormhole locks.

    ``out_port[r, i]`` is the routed output port of input head ``i``
    (``NO_PORT`` when the head slot is empty); ``beat`` its remaining
    burst beats.  Returns ``(winner, pop, new_ptr, new_lock)`` with
    ``winner[r, o]`` the granted input per output (-1: none) and
    ``pop[r, i]`` bool.  The round-robin pointer only advances on
    *unlocked* grants — a wormhole-held output keeps its arbitration
    state, exactly like the engine always behaved (the seed Pallas
    kernel advanced it on locked grants too; that parity bug is fixed
    on both sides).
    """
    R, P = out_port.shape
    o_ids = jnp.arange(P)[None, None, :]
    i_ids = jnp.arange(P)[None, :, None]
    req = (out_port[:, :, None] == o_ids) & oreg_free.astype(bool)[:, None, :]
    locked = lock_in[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock_in[:, None, :])

    prio = (i_ids - rr_ptr[:, None, :]) % P
    score = jnp.where(req, prio, NO_PORT)
    best = jnp.min(score, axis=1)                     # (R, P_out)
    granted = best < NO_PORT
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)

    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    new_ptr = jnp.where(granted & (lock_in < 0), (winner + 1) % P, rr_ptr)

    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :], beat[:, :, None], 0),
                     axis=1)
    new_lock = jnp.where(granted & (w_beat > 1), winner,
                         jnp.where(granted, -1, lock_in))
    return winner, pop, new_ptr, new_lock


def feeder_tables(nbr: np.ndarray,
                  opp: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Invert the link map: ``src_r[r, p]``/``src_o[r, p]`` name the
    router+output-port whose drain feeds input port ``p`` of router
    ``r`` (-1: no feeder).  Raises if two links feed one input port —
    the property that makes the scatter-form neighbor push and the
    gather-form used by the hot loop exactly equivalent.
    """
    R, P = nbr.shape
    # np.nonzero walks C order, so (t_idx, o_idx) lists the wired links
    # exactly as the old  for t: for o:  double loop visited them
    t_idx, o_idx = np.nonzero(nbr[:, :P - 1] >= 0)
    r, p = nbr[t_idx, o_idx], opp[t_idx, o_idx]
    flat = r * P + p
    order = np.argsort(flat, kind="stable")     # ties keep t-major order
    sf = flat[order]
    dup = sf[1:] == sf[:-1]
    if dup.any():
        i_new = order[1:][dup].min()            # first offending link
        i_old = order[np.searchsorted(sf, flat[i_new])]
        raise ValueError(
            f"input port {int(r[i_new])}:{int(p[i_new])} is fed by two "
            f"links ({int(t_idx[i_old])}:{int(o_idx[i_old])} and "
            f"{int(t_idx[i_new])}:{int(o_idx[i_new])})")
    src_r = np.full((R, P), -1, np.int64)
    src_o = np.full((R, P), -1, np.int64)
    src_r[r, p] = t_idx
    src_o[r, p] = o_idx
    for a in (src_r, src_o):
        a.setflags(write=False)
    return src_r, src_o


def make_fabric_step(nbr: np.ndarray, opp: np.ndarray, route: np.ndarray,
                     arbiter=None, n_vcs: int = 1, masked: bool = False):
    """Build the one-cycle update for a fabric described by static
    tables (see ``repro.noc.topology``): ``nbr[r, p]`` neighbor router
    per output port (-1 none, local port last), ``opp[r, p]`` the input
    port the link feeds, ``route[r, d]`` the routed output port.

    ``arbiter`` replaces the phase-B arbitration (same signature and
    semantics as :func:`arbiter_jnp`) — the hook the Pallas backend
    plugs into.

    ``n_vcs > 1`` declares the tables VC-expanded (``repro.noc.routing``):
    the ``P - 1`` non-local ports are ``(P - 1) / n_vcs`` physical links
    x ``n_vcs`` virtual channels, port ``p = link * n_vcs + vc``.  The
    update is identical except phase A drains at most one VC per
    physical link per cycle, preferring the highest ready VC index — the
    escape VC, so dateline traffic can always make progress.  With the
    default ``n_vcs=1`` the built step is the exact original (the
    serialization branch is not even traced).

    Returns ``step(state, inject_valid, inject_flit, depth) ->
    (new_state, inject_ok (R,), deliver_valid (R,), deliver_flit (R, F),
    link_moves scalar)``.  ``depth`` is the *dynamic* FIFO depth (traced
    int32, ``1 <= depth <= state.fifo.shape[2]``); the state arrays are
    sized by the static max so depth sweeps share one compilation.

    ``masked=True`` (fault injection, ``repro.noc.faults``) appends one
    traced operand: ``step(state, iv, iflit, depth, link_mask)`` with
    ``link_mask (R, P) bool`` marking output ports whose link is
    currently dead.  A masked link simply never drains — flits wait in
    the output register under ordinary backpressure (no loss), and heal
    transparently when the mask clears.  The default build does not
    trace the mask at all, keeping the healthy path bit-identical.
    """
    R, P = nbr.shape
    PORT_L = P - 1
    nbr_j = jnp.asarray(nbr, jnp.int32)
    opp_j = jnp.asarray(opp, jnp.int32)
    route_j = jnp.asarray(route, jnp.int32)
    src_r, src_o = feeder_tables(nbr, opp)
    has_feed = jnp.asarray(src_r >= 0)                            # (R, P)
    src_flat = jnp.asarray(np.clip(src_r, 0, None) * P
                           + np.clip(src_o, 0, None), jnp.int32)  # (R, P)
    arb = arbiter_jnp if arbiter is None else arbiter
    r_idx = jnp.arange(R)
    if (P - 1) % n_vcs:
        raise ValueError(
            f"{P - 1} non-local ports do not fold into {n_vcs} VCs")
    n_phys = (P - 1) // n_vcs

    def serialize_drain(ready):
        """At most one drained VC per physical link: highest ready VC
        index wins (escape-VC priority).  Identity when n_vcs == 1."""
        if n_vcs == 1:
            return ready
        e = ready[:, :P - 1].reshape(R, n_phys, n_vcs)
        rank = jnp.where(e, jnp.arange(n_vcs)[None, None, :], -1)
        win = e & (rank == jnp.max(rank, axis=2, keepdims=True))
        return jnp.concatenate(
            [win.reshape(R, P - 1), ready[:, P - 1:]], axis=1)

    def step(state: NetState, inject_valid: jax.Array,
             inject_flit: jax.Array, depth: jax.Array, *fault_args):
        heads = state.fifo[:, :, 0, :]                    # (R, P, F)
        head_valid = state.count > 0                      # (R, P)

        # ---------------- phase A: drain output registers -------------------
        # downstream input-FIFO occupancy (registered, cycle start)
        ds_count = state.count[jnp.clip(nbr_j, 0, R - 1), opp_j]   # (R, P)
        can_drain = jnp.where(jnp.arange(P)[None, :] == PORT_L,
                              True,                     # Local: NI always sinks
                              (nbr_j >= 0) & (ds_count < depth))
        if masked:
            (link_mask,) = fault_args                   # (R, P) bool, traced
            can_drain &= ~link_mask
        drain = serialize_drain(state.oreg_v & can_drain)

        deliver_valid = drain[:, PORT_L]
        deliver_flit = state.oreg[:, PORT_L, :]

        # pushes into neighbor input FIFOs, as ONE static gather through
        # the inverse link map (each input port has at most one feeder,
        # so this is exactly the seed's per-output-port scatter loop)
        recv_valid = has_feed & drain.reshape(-1)[src_flat]        # (R, P)
        recv_flit = jnp.where(
            recv_valid[:, :, None],
            state.oreg.reshape(-1, N_FIELDS)[src_flat], 0)         # (R, P, F)

        # NI injection into Local input port (cycle-start occupancy)
        local_ready = state.count[:, PORT_L] < depth
        inj_ok = inject_valid & local_ready
        recv_valid = recv_valid.at[:, PORT_L].set(inj_ok)
        recv_flit = recv_flit.at[:, PORT_L].set(
            jnp.where(inj_ok[:, None], inject_flit, 0))

        # ---------------- phase B: arbitration into freed oregs -------------
        # Wormhole: a multi-flit packet (burst) locks its output port from
        # the first beat until the tail beat (F_BEAT <= 1) has passed, so
        # burst beats are never interleaved — the paper's burst semantics.
        oreg_free = (~state.oreg_v) | drain                        # (R, P)
        out_port = route_j[r_idx[:, None], heads[:, :, F_DEST]]    # (R, P_in)
        out_port = jnp.where(head_valid, out_port, NO_PORT)
        winner, pop, new_ptr, new_lock = arb(
            out_port, heads[:, :, F_BEAT], state.rr_ptr, oreg_free,
            state.lock_in)

        any_grant = winner >= 0
        flit_to_oreg = heads[r_idx[:, None], jnp.clip(winner, 0)]  # (R, P, F)
        new_oreg_v = (state.oreg_v & ~drain) | any_grant
        new_oreg = jnp.where(any_grant[:, :, None], flit_to_oreg, state.oreg)

        # ---------------- input FIFO update: pop then push ------------------
        D = state.fifo.shape[2]                          # static max depth
        shifted = jnp.concatenate(
            [state.fifo[:, :, 1:, :],
             jnp.zeros_like(state.fifo[:, :, :1, :])], axis=2)
        fifo = jnp.where(pop[:, :, None, None], shifted, state.fifo)
        count = state.count - pop.astype(jnp.int32)

        slot = jnp.clip(count, 0, D - 1)
        write = recv_valid & (count < depth)
        onehot_slot = jax.nn.one_hot(slot, D, dtype=jnp.bool_)     # (R,P,D)
        sel = write[:, :, None] & onehot_slot
        fifo = jnp.where(sel[..., None], recv_flit[:, :, None, :], fifo)
        count = count + write.astype(jnp.int32)

        new_state = NetState(fifo=fifo, count=count, rr_ptr=new_ptr,
                             oreg=new_oreg, oreg_v=new_oreg_v,
                             lock_in=new_lock)
        link_moves = jnp.sum(drain.astype(jnp.int32)
                             * (jnp.arange(P)[None, :] != PORT_L))
        return new_state, inj_ok, deliver_valid, deliver_flit, link_moves

    return step
