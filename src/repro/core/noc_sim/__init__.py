"""Cycle-level NoC building blocks + legacy simulator surface.

The router micro-architecture (``router.py``) and analytic paper model
(``energy.py``) live here; the experiment surface moved to the
declarative :mod:`repro.noc` API (``NocSpec``/``Workload``/``simulate``
with vmapped sweeps). ``SimConfig``/``run_sim`` and the schedule
generators in ``traffic.py`` remain as deprecation shims over it.
"""
from .energy import PAPER, PAPER_CLAIMS, FlooNoCModel  # noqa: F401
from .mesh_sim import SimConfig, run_sim  # noqa: F401
from .router import NetState, init_state, network_step, xy_route  # noqa: F401
from .traffic import fig5_traffic, uniform_random  # noqa: F401
