from .energy import PAPER, PAPER_CLAIMS, FlooNoCModel  # noqa: F401
from .mesh_sim import SimConfig, run_sim  # noqa: F401
from .router import NetState, init_state, network_step, xy_route  # noqa: F401
from .traffic import fig5_traffic, uniform_random  # noqa: F401
