"""Cycle-level NoC building blocks.

The router micro-architecture (``router.py``: table-driven fabric step
+ reference arbiter) and the analytic paper model (``energy.py``) live
here; the experiment surface is the declarative :mod:`repro.noc` API
(``NocSpec``/``Workload``/``simulate`` with vmapped sweeps and
pluggable backends).  The seed's legacy config/runner shims and ad-hoc
schedule generators were migrated onto that API and deleted.
"""
from .energy import PAPER, PAPER_CLAIMS, FlooNoCModel  # noqa: F401
from .router import (NetState, arbiter_jnp, init_fabric_state,  # noqa: F401
                     make_fabric_step)
