"""Dimension-ordered (XY) ring collectives on ``jax.lax.ppermute``.

This is the FlooNoC router/link layer adapted to a TPU mesh (DESIGN.md §2):

* Each mesh axis is a ring of ICI links; a collective step moving one chunk
  one hop is a *flit* on a *wide physical channel*.
* Multi-axis reductions are **dimension-ordered** (reduce-scatter along X,
  then Y; all-gather back Y, then X) — the software analogue of XY routing,
  deadlock-free and congestion-free on a torus.
* ``bidir=True`` uses both ring directions concurrently — the paper's duplex
  links (1.26 Tbps duplex vs 629 Gbps simplex).
* The *wormhole* overlap of compute behind communication is
  ``collective_matmul_ag`` / ``collective_matmul_rs``: chunks of the matmul
  stream behind the ppermute pipeline exactly like flits behind a header.

All functions are static-shape, unrolled (n-1 ppermute steps appear in the
HLO, which makes the roofline collective-byte accounting exact), and are
valid inside ``jax.shard_map`` only.

(Formerly ``repro.core.routing`` — renamed so the NoC fabric routing
subsystem :mod:`repro.noc.routing` owns that name; this module is TPU
ring *collectives*, not route-table generation.)
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax


def _perm(n: int, direction: int) -> list[tuple[int, int]]:
    return [(i, (i + direction) % n) for i in range(n)]


def _split(x: jax.Array, n: int, dim: int) -> jax.Array:
    """Reshape x so that dim is split as a leading stacking axis (n, ...)."""
    assert x.shape[dim] % n == 0, (x.shape, dim, n)
    x = jnp.moveaxis(x, dim, 0)
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])


def _merge(xs: jax.Array, dim: int) -> jax.Array:
    """Concatenate stacked shards (n, ...) along `dim` of the inner shape."""
    xs = jnp.moveaxis(xs, 0, dim)          # n lands at position dim
    shape = (xs.shape[:dim]
             + (xs.shape[dim] * xs.shape[dim + 1],)
             + xs.shape[dim + 2:])
    return xs.reshape(shape)


# ---------------------------------------------------------------------------
# Ring reduce-scatter / all-gather (uni- and bidirectional)
# ---------------------------------------------------------------------------
def ring_reduce_scatter(x: jax.Array, axis: str, size: int, *, dim: int = 0,
                        bidir: bool = False) -> jax.Array:
    """Sum x across `axis`; device i keeps chunk i of `dim`. (== psum_scatter)

    bidir: each device's chunk is split row-wise; the two halves ride the
    two ring directions concurrently (duplex links) and land contiguously,
    so the output layout is IDENTICAL to the unidirectional ring.
    """
    if size == 1:
        return x
    n = size
    xs = _split(x, n, dim)                       # (n, c, ...)
    if bidir and xs.shape[1] % 2 == 0:
        h = xs.shape[1] // 2
        ra = _rs_stacked(xs[:, :h], axis, n, +1)
        rb = _rs_stacked(xs[:, h:], axis, n, -1)
        buf = jnp.concatenate([ra, rb], axis=0)  # (c, ...): my full chunk
    else:
        buf = _rs_stacked(xs, axis, n, +1)
    return jnp.moveaxis(buf, 0, dim)


def _rs_stacked(xs: jax.Array, axis: str, n: int, direction: int) -> jax.Array:
    """xs: (n, c, ...) chunk-stacked; returns device's reduced chunk (c, ...)."""
    idx = lax.axis_index(axis)
    buf = jnp.take(xs, (idx + direction) % n, axis=0)
    perm = _perm(n, direction)
    for t in range(1, n):
        buf = lax.ppermute(buf, axis, perm)
        buf = buf + jnp.take(xs, (idx + (t + 1) * direction) % n, axis=0)
    return buf


def ring_all_gather(x: jax.Array, axis: str, size: int, *, dim: int = 0,
                    bidir: bool = False) -> jax.Array:
    """Gather shards along `axis` into `dim` (tiled; chunk j from device j)."""
    if size == 1:
        return x
    n = size
    idx = lax.axis_index(axis)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, idx, 0)
    if not bidir or n <= 2:
        cur = x
        perm = _perm(n, +1)
        for t in range(1, n):
            cur = lax.ppermute(cur, axis, perm)
            out = lax.dynamic_update_index_in_dim(out, cur, (idx - t) % n, 0)
    else:
        fwd_steps = (n - 1 + 1) // 2
        bwd_steps = (n - 1) - fwd_steps
        cur_f, cur_b = x, x
        pf, pb = _perm(n, +1), _perm(n, -1)
        for t in range(1, fwd_steps + 1):
            cur_f = lax.ppermute(cur_f, axis, pf)
            out = lax.dynamic_update_index_in_dim(out, cur_f, (idx - t) % n, 0)
            if t <= bwd_steps:
                cur_b = lax.ppermute(cur_b, axis, pb)
                out = lax.dynamic_update_index_in_dim(out, cur_b, (idx + t) % n, 0)
    return _merge(out, dim)


def dim_ordered_reduce_scatter(x: jax.Array, axes: Sequence[tuple[str, int]],
                               *, dim: int = 0, bidir: bool = False) -> jax.Array:
    """XY-ordered reduce-scatter over multiple mesh axes (innermost first)."""
    for name, size in axes:
        x = ring_reduce_scatter(x, name, size, dim=dim, bidir=bidir)
    return x


def dim_ordered_all_gather(x: jax.Array, axes: Sequence[tuple[str, int]],
                           *, dim: int = 0, bidir: bool = False) -> jax.Array:
    """Inverse of dim_ordered_reduce_scatter (reversed axis order)."""
    for name, size in reversed(list(axes)):
        x = ring_all_gather(x, name, size, dim=dim, bidir=bidir)
    return x


def dim_ordered_all_reduce(x: jax.Array, axes: Sequence[tuple[str, int]],
                           *, dim: int = 0, bidir: bool = False) -> jax.Array:
    """Bandwidth-optimal all-reduce: RS down the dimension order, AG back up."""
    total = 1
    for _, s in axes:
        total *= s
    if total == 1:
        return x
    if x.shape[dim] % total != 0:
        # fall back to latency-optimal single op (narrow traffic never pads)
        return lax.psum(x, tuple(n for n, _ in axes))
    x = dim_ordered_reduce_scatter(x, axes, dim=dim, bidir=bidir)
    return dim_ordered_all_gather(x, axes, dim=dim, bidir=bidir)


# ---------------------------------------------------------------------------
# Wormhole-pipelined collective matmuls (compute streams behind ppermute)
# ---------------------------------------------------------------------------
def collective_matmul_ag(x: jax.Array, w: jax.Array, axis: str, size: int,
                         *, dim: int = 0) -> jax.Array:
    """Compute all_gather(x, dim) @ w with per-chunk overlap.

    x: (..., s_loc, d) local shard; w: (d, f). Returns (..., s_loc*size, f).
    Each step multiplies the currently-held shard while the next shard is in
    flight — the NoC wormhole: flit t computes while flit t+1 hops.
    """
    if size == 1:
        return x @ w
    n = size
    idx = lax.axis_index(axis)
    part0 = x @ w
    out = jnp.zeros((n,) + part0.shape, part0.dtype)
    out = lax.dynamic_update_index_in_dim(out, part0, idx, 0)
    cur = x
    perm = _perm(n, +1)
    for t in range(1, n):
        cur = lax.ppermute(cur, axis, perm)
        out = lax.dynamic_update_index_in_dim(out, cur @ w, (idx - t) % n, 0)
    return _merge(out, dim)


def collective_matmul_rs(x: jax.Array, w: jax.Array, axis: str, size: int,
                         *, dim: int = 0) -> jax.Array:
    """Compute reduce_scatter(x @ w, dim) with per-chunk overlap.

    x: (..., S, d); w: (d, f) -> (..., S/size, f), chunk idx kept locally.
    """
    if size == 1:
        return x @ w
    n = size
    xs = _split(x, n, dim)                     # (n, c, ..., d) chunks of S
    idx = lax.axis_index(axis)
    acc = jnp.take(xs, (idx + 1) % n, axis=0) @ w
    perm = _perm(n, +1)
    for t in range(1, n):
        acc = lax.ppermute(acc, axis, perm)
        acc = acc + jnp.take(xs, (idx + 1 + t) % n, axis=0) @ w
    return jnp.moveaxis(acc, 0, dim)


# ---------------------------------------------------------------------------
# All-to-all (MoE dispatch). The torus routes this XY natively; we keep the
# lax primitive so XLA emits the fused all-to-all, and account for it in the
# ledger at the call site.
# ---------------------------------------------------------------------------
def all_to_all(x: jax.Array, axis: str, *, split_dim: int, concat_dim: int) -> jax.Array:
    return lax.all_to_all(x, axis, split_axis=split_dim, concat_axis=concat_dim,
                          tiled=True)
