"""Flit packing: move many small tensors as ONE wide word.

FlooNoC sends header bits on parallel physical lines next to the payload so
that every message is a single flit (no header/tail serialization, which
would cap single-packet bandwidth at 33%). The software analogue: the
*header* is static Python metadata (treedef, shapes, dtypes, offsets) that
never enters the traced computation, and the *payload* is one flat buffer
per dtype. A pytree of N small tensors therefore costs ONE fused collective
instead of N latency-bound ones.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlitHeader:
    """Static 'parallel header lines' describing a packed payload."""
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    # per-leaf (group_key, offset, length)
    slots: tuple[tuple[str, int, int], ...]
    group_sizes: dict[str, int]

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(s)) * jnp.dtype(d).itemsize
                   for s, d in zip(self.shapes, self.dtypes))


def pack(tree: Any) -> tuple[dict[str, jax.Array], FlitHeader]:
    """Pack a pytree into one flat payload per dtype group."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(l.shape for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    offsets: dict[str, int] = {}
    slots = []
    groups: dict[str, list[jax.Array]] = {}
    for leaf in leaves:
        key = str(leaf.dtype)
        off = offsets.get(key, 0)
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        slots.append((key, off, n))
        offsets[key] = off + n
        groups.setdefault(key, []).append(leaf.reshape(-1))
    payload = {k: jnp.concatenate(v) if len(v) > 1 else v[0]
               for k, v in groups.items()}
    header = FlitHeader(treedef, shapes, dtypes, tuple(slots),
                        {k: int(v.shape[0]) for k, v in payload.items()})
    return payload, header


def unpack(payload: dict[str, jax.Array], header: FlitHeader) -> Any:
    leaves = []
    for shape, dtype, (key, off, n) in zip(header.shapes, header.dtypes,
                                           header.slots):
        flat = jax.lax.dynamic_slice_in_dim(payload[key], off, n)
        leaves.append(flat.reshape(shape).astype(dtype))
    return jax.tree.unflatten(header.treedef, leaves)


def pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Pad a flat payload so ring chunking divides evenly (wide flits only)."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.pad(x, (0, rem))
    return x, n
