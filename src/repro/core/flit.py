"""Flit vocabulary + flit packing.

Two halves, both named by the paper's flit:

1. **AXI4 flow vocabulary** — the canonical five AXI channels every
   traffic class decomposes into, shared by the cycle simulator
   (``repro.noc``), its workloads, and the tests.  A *flow* is one
   class's traffic on one AXI channel (``"<class>.ar"`` …); the flit
   ``kind`` field encodes (class, flow) so the fabric stays completely
   flow-agnostic — routers move int32 flits, only the NIs interpret
   kinds.

2. **Flit packing** — FlooNoC sends header bits on parallel physical
   lines next to the payload so that every message is a single flit (no
   header/tail serialization, which would cap single-packet bandwidth at
   33%). The software analogue: the *header* is static Python metadata
   (treedef, shapes, dtypes, offsets) that never enters the traced
   computation, and the *payload* is one flat buffer per dtype. A pytree
   of N small tensors therefore costs ONE fused collective instead of N
   latency-bound ones.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------- #
# AXI4 flow vocabulary (paper §II: fully AXI4-compatible NoC)
# --------------------------------------------------------------------- #
# Read transactions use AR (address read) -> R (read data burst); write
# transactions use AW (address write) -> W (write data burst) -> B
# (single-flit write response).  Order matters: it fixes the flit-kind
# encoding, and AR=0 / R=1 keep the two read kinds of class 0 at the
# same values the read-only engine used (kind is an opaque tag, but
# stability makes traces comparable across versions).
AXI_FLOWS: tuple[str, ...] = ("ar", "r", "aw", "w", "b")
N_FLOWS = len(AXI_FLOWS)
# request-direction flows travel source -> target; response-direction
# flows travel target -> source (B is the write acknowledgement)
REQUEST_FLOWS: tuple[str, ...] = ("ar", "aw", "w")
RESPONSE_FLOWS: tuple[str, ...] = ("r", "b")


def flow_kind(cls_idx: int, flow: str) -> int:
    """Flit ``kind`` tag for class ``cls_idx``'s ``flow``."""
    return N_FLOWS * cls_idx + AXI_FLOWS.index(flow)


def kind_class(kind: int) -> int:
    """Inverse of :func:`flow_kind`: the traffic-class index."""
    return kind // N_FLOWS


def kind_flow(kind: int) -> str:
    """Inverse of :func:`flow_kind`: the AXI flow name."""
    return AXI_FLOWS[kind % N_FLOWS]


@dataclass(frozen=True)
class FlitHeader:
    """Static 'parallel header lines' describing a packed payload."""
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    # per-leaf (group_key, offset, length)
    slots: tuple[tuple[str, int, int], ...]
    group_sizes: dict[str, int]

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(s)) * jnp.dtype(d).itemsize
                   for s, d in zip(self.shapes, self.dtypes))


def pack(tree: Any) -> tuple[dict[str, jax.Array], FlitHeader]:
    """Pack a pytree into one flat payload per dtype group."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(l.shape for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    offsets: dict[str, int] = {}
    slots = []
    groups: dict[str, list[jax.Array]] = {}
    for leaf in leaves:
        key = str(leaf.dtype)
        off = offsets.get(key, 0)
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        slots.append((key, off, n))
        offsets[key] = off + n
        groups.setdefault(key, []).append(leaf.reshape(-1))
    payload = {k: jnp.concatenate(v) if len(v) > 1 else v[0]
               for k, v in groups.items()}
    header = FlitHeader(treedef, shapes, dtypes, tuple(slots),
                        {k: int(v.shape[0]) for k, v in payload.items()})
    return payload, header


def unpack(payload: dict[str, jax.Array], header: FlitHeader) -> Any:
    leaves = []
    for shape, dtype, (key, off, n) in zip(header.shapes, header.dtypes,
                                           header.slots):
        flat = jax.lax.dynamic_slice_in_dim(payload[key], off, n)
        leaves.append(flat.reshape(shape).astype(dtype))
    return jax.tree.unflatten(header.treedef, leaves)


def pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Pad a flat payload so ring chunking divides evenly (wide flits only)."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.pad(x, (0, rem))
    return x, n
