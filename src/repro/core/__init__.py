"""FlooNoC-derived communication core (see DESIGN.md §2)."""
from . import channels, collectives, flit, ni  # noqa: F401
