"""FlooNoC-derived communication core (see DESIGN.md §2)."""
from . import channels, flit, ni, routing  # noqa: F401
