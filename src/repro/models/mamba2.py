"""Mamba-2 (SSD) mixer block, tensor-parallel over SSD heads.

Sharding: d_inner channels (== heads*head_dim) column-parallel over `model`;
B/C/dt projections replicated (n_groups=1, as in the published config —
matching the official Mamba-2 TP scheme where groups don't split); out
projection row-parallel with seq reduce-scatter. The sequence dim stays
local (chunked SSD scan is sequence-recurrent, no ring needed).

State caches for decode: conv state (B, W-1, conv_ch_loc) + SSD state
(B, H_loc, P, N).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..dist.backend import Backend
from ..kernels import ops
from .layers import pad_mult, wspec


def ssm_dims(cfg: RunConfig, mcfg: ModelConfig):
    model = cfg.tp_size
    h_pad = pad_mult(mcfg.ssm_heads, model)
    h_loc = h_pad // model
    p_dim = mcfg.ssm_head_dim
    di_pad = h_pad * p_dim
    return h_pad, h_loc, p_dim, di_pad


def ssm_specs(cfg: RunConfig, mcfg: ModelConfig, stack: int | None = None) -> dict:
    d = mcfg.d_model
    N = mcfg.ssm_state
    h_pad, _, p_dim, di_pad = ssm_dims(cfg, mcfg)
    W = mcfg.conv_width
    return {
        # x and z (gate) projections: column-parallel over heads
        "wx": wspec((d, h_pad, p_dim), cfg, model_dim=1, data_dim=0,
                    fan_in_axes=(0,), stack=stack),
        "wz": wspec((d, h_pad, p_dim), cfg, model_dim=1, data_dim=0,
                    fan_in_axes=(0,), stack=stack),
        # B, C projections: replicated over model (n_groups=1)
        "wB": wspec((d, N), cfg, model_dim=None, data_dim=0,
                    fan_in_axes=(0,), stack=stack),
        "wC": wspec((d, N), cfg, model_dim=None, data_dim=0,
                    fan_in_axes=(0,), stack=stack),
        "wdt": wspec((d, h_pad), cfg, model_dim=1, data_dim=0,
                     fan_in_axes=(0,), stack=stack),
        "dt_bias": wspec((h_pad,), cfg, model_dim=0, data_dim=None,
                         init="zeros", stack=stack),
        "A_log": wspec((h_pad,), cfg, model_dim=0, data_dim=None,
                       init="zeros", stack=stack),
        "D": wspec((h_pad,), cfg, model_dim=0, data_dim=None,
                   init="ones", stack=stack),
        # depthwise causal conv over x channels (local) — B/C conv replicated
        "conv_x": wspec((h_pad * p_dim, W), cfg, model_dim=0, data_dim=None,
                        init="scaled", fan_in_axes=(1,), stack=stack),
        "conv_bc": wspec((2 * N, W), cfg, model_dim=None, data_dim=None,
                         init="scaled", fan_in_axes=(1,), stack=stack),
        "wo": wspec((h_pad, p_dim, d), cfg, model_dim=0, data_dim=2,
                    fan_in_axes=(0, 1), stack=stack),
    }


def _head_mask(bk: Backend, mcfg: ModelConfig, h_loc: int):
    ridx = bk.axis_index("model")
    gids = ridx * h_loc + jnp.arange(h_loc)
    return (gids < mcfg.ssm_heads).astype(jnp.float32)


def apply_ssm(p, x_sp: jax.Array, x_full: jax.Array, bk: Backend,
              cfg: RunConfig, mcfg: ModelConfig, *, cache=None,
              mode: str = "train"):
    """x_sp: (B, S_loc, d) sequence-sharded; x_full: (B, S, d) gathered.

    Head-sharded projections (x/z/dt) consume x_full; the model-replicated
    B/C projections + conv consume x_sp (local-chunk gradients) with a
    ppermute halo for the causal conv across chunk boundaries, and their
    tiny outputs ride a seq all-gather (replicated-weight rule, DESIGN §4).

    Train/prefill: returns (partial_out (B,S,d), new_cache|None).
    Decode (S==1): single-step state update.
    """
    decode = mode == "decode"
    B, S, d = x_full.shape
    N = mcfg.ssm_state
    W = mcfg.conv_width
    h_loc = p["A_log"].shape[0]
    p_dim = mcfg.ssm_head_dim
    mask = _head_mask(bk, mcfg, h_loc)
    wbc = jnp.concatenate([p["wB"], p["wC"]], axis=1)

    xz = jnp.einsum("bsd,dhe->bshe", x_full, p["wx"])    # (B,S,h_loc,P)
    z = jnp.einsum("bsd,dhe->bshe", x_full, p["wz"])
    dt_raw = x_full @ p["wdt"] + p["dt_bias"]            # (B,S,h_loc)

    xf = xz.reshape(B, S, h_loc * p_dim)
    if decode:
        bc = x_full[:, 0] @ wbc                          # (B, 2N)
        conv_state, ssd_state, conv_bc_state = cache
        xc, new_conv = ops.causal_conv1d_step(xf[:, 0], p["conv_x"], conv_state)
        bcc, new_bc = ops.causal_conv1d_step(bc, p["conv_bc"], conv_bc_state)
        xc = jax.nn.silu(xc).reshape(B, h_loc, p_dim)
        bcc = jax.nn.silu(bcc)
        Bv, Cv = bcc[:, :N][:, None, :], bcc[:, N:][:, None, :]   # (B,1,N)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32))
        y, ssd_state = ops.ssd_decode(ssd_state, xc, dt, p["A_log"], Bv, Cv,
                                      p["D"].astype(jnp.float32))
        y = y[:, None]                                    # (B,1,h_loc,P)
        new_cache = (new_conv, ssd_state, new_bc)
    else:
        prev_conv = None if cache is None else cache[0]
        xc, conv_state = ops.causal_conv1d(xf, p["conv_x"], prev_conv)
        if mode == "train" and bk.model > 1:
            # replicated-weight rule: conv B/C on the local chunk with a
            # ppermute halo, then all-gather the tiny result
            bc_sp = x_sp @ wbc                           # (B, S_loc, 2N)
            halo = jax.lax.ppermute(
                bc_sp[:, -(W - 1):, :], "model",
                [(i, i + 1) for i in range(bk.model - 1)])
            bcc_sp, _ = ops.causal_conv1d(bc_sp, p["conv_bc"], halo)
            bcc = bk.seq_ag(bcc_sp, dim=1)
            conv_bc_state = None
        else:
            bc_full = (bk.seq_ag(x_sp @ wbc, dim=1)
                       if bk.model > 1 else x_sp @ wbc)
            prev_bc = None if cache is None else cache[2]
            bcc, conv_bc_state = ops.causal_conv1d(bc_full, p["conv_bc"],
                                                   prev_bc)
        xc = jax.nn.silu(xc).reshape(B, S, h_loc, p_dim)
        bcc = jax.nn.silu(bcc)
        Bv = bcc[..., :N][:, :, None, :]                  # (B,S,1,N)
        Cv = bcc[..., N:][:, :, None, :]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32))
        h0 = None if cache is None else cache[1]
        chunk = mcfg.ssd_chunk if S % mcfg.ssd_chunk == 0 else S
        y, ssd_state = ops.ssd(xc, dt, p["A_log"], Bv, Cv,
                               p["D"].astype(jnp.float32), chunk=chunk,
                               h0=h0, return_final_state=True)
        new_cache = (conv_state, ssd_state, conv_bc_state)

    y = y * jax.nn.silu(z if not decode else z[:, :1])
    y = y * mask[None, None, :, None].astype(y.dtype)
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"])         # partial over model
    return out, new_cache


def ssm_cache_shapes(cfg: RunConfig, mcfg: ModelConfig, batch_loc: int):
    """Per-layer decode cache ShapeDtypeStructs (local shapes)."""
    h_pad, h_loc, p_dim, _ = ssm_dims(cfg, mcfg)
    W = mcfg.conv_width
    N = mcfg.ssm_state
    dt = jnp.dtype(cfg.compute_dtype)
    return (
        jax.ShapeDtypeStruct((batch_loc, W - 1, h_loc * p_dim), dt),
        jax.ShapeDtypeStruct((batch_loc, h_loc, p_dim, N), jnp.float32),
        jax.ShapeDtypeStruct((batch_loc, W - 1, 2 * N), dt),
    )
