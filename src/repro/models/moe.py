"""Mixture-of-Experts FF with expert parallelism over the `model` axis.

Two strategies, selected statically (DESIGN.md §4):

* **EP** (``num_experts % model == 0``): tokens stay sequence-sharded; the
  router runs locally and tokens ride an ``all_to_all`` to their expert's
  rank — the textbook *wide* DMA burst of the paper, while router
  logits/aux-counters are *narrow* psums. Used by llama4-scout (16e/16).
* **TP-MoE** (``num_experts % model != 0``): every rank holds an ff-slice of
  every expert; tokens are dispatched locally into capacity buffers and the
  expert matmuls are ff-sharded (no all_to_all; reuses the block's seq
  AG/RS). Used by grok-1 (8e on a 16-wide axis).

Dispatch is capacity-based (GShard): per-expert capacity
``C = ceil(T * top_k * capacity_factor / E)``; overflow tokens drop (their
residual path still carries them). Aux: load-balance loss + router z-loss.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, RunConfig
from ..dist.backend import Backend
from .layers import cdtype, wspec


def moe_specs(cfg: RunConfig, mcfg: ModelConfig, stack: int | None = None) -> dict:
    d, ff, E = mcfg.d_model, mcfg.d_ff, mcfg.num_experts
    ep = E % cfg.tp_size == 0
    # EP: experts sharded over model on dim 0; TP: ff sharded on dim 2
    e_model_dim, f_model_dim = (0, None) if ep else (None, 2)
    out = {
        "router": wspec((d, E), cfg, model_dim=None, data_dim=None,
                        init="scaled", fan_in_axes=(0,), stack=stack),
        "wi": wspec((E, d, ff), cfg, model_dim=e_model_dim if ep else 2,
                    data_dim=1, fan_in_axes=(1,), stack=stack),
        "wd": wspec((E, ff, d), cfg, model_dim=0 if ep else 1,
                    data_dim=2, fan_in_axes=(1,), stack=stack),
    }
    if mcfg.mlp_act == "swiglu":
        out["wg"] = wspec((E, d, ff), cfg, model_dim=0 if ep else 2,
                          data_dim=1, fan_in_axes=(1,), stack=stack)
    if mcfg.shared_expert:
        out["s_wi"] = wspec((d, ff), cfg, model_dim=1, data_dim=0,
                            fan_in_axes=(0,), stack=stack)
        out["s_wd"] = wspec((ff, d), cfg, model_dim=0, data_dim=1,
                            fan_in_axes=(0,), stack=stack)
        if mcfg.mlp_act == "swiglu":
            out["s_wg"] = wspec((d, ff), cfg, model_dim=1, data_dim=0,
                                fan_in_axes=(0,), stack=stack)
    return out


def _expert_ff(p, x, mcfg: ModelConfig):
    """x: (E_loc, C, d) -> (E_loc, C, d); batched over local experts."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"])
    if mcfg.mlp_act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wd"])


def _route(logits: jax.Array, mcfg: ModelConfig):
    """logits (T, E) fp32 -> (topk_idx (T,k), topk_p (T,k), aux dict)."""
    probs = jax.nn.softmax(logits, axis=-1)
    k = mcfg.top_k
    topk_p, topk_idx = jax.lax.top_k(probs, k)
    if k > 1:
        topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)
    E = mcfg.num_experts
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32), axis=0)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return topk_idx, topk_p, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _router_logits(p, x: jax.Array) -> jax.Array:
    """x (..., d) -> (..., E) in fp32 (router math is always fp32)."""
    return x.astype(jnp.float32) @ p["router"].astype(jnp.float32)


def _dispatch(x_tok, topk_idx, topk_p, E: int, C: int):
    """Capacity-based scatter into (E, C, d) buffers.

    Returns (buffer, combine_fn(y_buffer) -> (T, d)).
    """
    T, d = x_tok.shape
    k = topk_idx.shape[1]
    flat_e = topk_idx.reshape(-1)                                # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                         # position per expert
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    x_rep = jnp.repeat(x_tok, k, axis=0)                         # (T*k, d)
    buf = jnp.zeros((E, C, d), x_tok.dtype)
    e_idx = jnp.where(keep, flat_e, 0)
    p_idx = jnp.where(keep, flat_pos, C - 1)
    buf = buf.at[e_idx, p_idx].add(
        jnp.where(keep[:, None], x_rep, 0), mode="drop")

    flat_p = topk_p.reshape(-1).astype(x_tok.dtype)

    def combine(y_buf):
        y_tok = y_buf[e_idx, p_idx]                              # (T*k, d)
        y_tok = jnp.where(keep[:, None], y_tok, 0) * flat_p[:, None]
        return jnp.sum(y_tok.reshape(T, k, d), axis=1)

    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return buf, combine, frac_dropped


def _capacity(T: int, mcfg: ModelConfig, mode: str) -> int:
    """Per-expert capacity. Training uses the GShard capacity factor
    (overflow drops, residual carries); prefill/decode size for the
    worst case so inference is drop-free — capacity dropping is a
    throughput/quality trade for training, but at serving time it
    breaks prefill->decode consistency (the LAST tokens overflow
    first, i.e. exactly the ones decode continues from)."""
    if mode == "train":
        C = int(np.ceil(T * mcfg.top_k * mcfg.capacity_factor /
                        mcfg.num_experts))
    else:
        C = T * mcfg.top_k
    return max(8, -(-C // 8) * 8)


def apply_moe(p, x_sp: jax.Array, x_full: jax.Array | None, bk: Backend,
              cfg: RunConfig, mcfg: ModelConfig, *, sp: bool = True,
              mode: str = "train"):
    """MoE FF. Returns (delta (B,S_loc,d), aux) — already reduced.

    x_sp: sequence-sharded input (B, S_loc, d) — used by the EP path.
    x_full: gathered input (B, S, d) or None — used by the TP path (the
    caller reuses the block's AG; partial output is reduced here).
    sp: sequence-parallel mode (train/prefill); decode reduces with psum.
    mode: train | prefill | decode (capacity sizing; see _capacity).
    """
    E = mcfg.num_experts
    ep = E % bk.model == 0
    dt = cdtype(cfg)
    reduce = (lambda t: bk.seq_rs(t, dim=1)) if sp else bk.psum_model

    if ep:
        B, S_loc, d = x_sp.shape
        T = B * S_loc
        x_tok = x_sp.reshape(T, d)
        topk_idx, topk_p, aux = _route(_router_logits(p, x_tok), mcfg)
        # objective = mean over rank-chunks; psum_inv keeps grads per-chunk
        aux = {k: bk.psum_model(v) / bk.model for k, v in aux.items()}
        C = _capacity(T, mcfg, mode)
        buf, combine, dropped = _dispatch(x_tok, topk_idx, topk_p, E, C)
        # wide burst: (E, C, d) -> rows regrouped by owner rank
        buf = bk.a2a_model(buf, split_dim=0, concat_dim=1)   # (E_loc, model*C, d)
        y = _expert_ff(jax.tree.map(lambda w: w.astype(dt), p), buf, mcfg)
        y = bk.a2a_model(y, split_dim=1, concat_dim=0)       # (E, C, d) back
        delta = combine(y).reshape(B, S_loc, d)
        if mcfg.shared_expert:
            xf = x_full if x_full is not None else bk.seq_ag(x_sp, dim=1)
            h = xf @ p["s_wi"].astype(dt)
            if mcfg.mlp_act == "swiglu":
                h = jax.nn.silu(h) * (xf @ p["s_wg"].astype(dt))
            else:
                h = jax.nn.gelu(h)
            delta = delta + reduce(h @ p["s_wd"].astype(dt))
        aux["moe_dropped"] = dropped
        return delta, aux

    # ---- TP-MoE: all experts on every rank, ff-sharded ----------------------
    assert x_full is not None, "TP-MoE path requires the gathered activations"
    B, S, d = x_full.shape
    T = B * S
    x_tok = x_full.reshape(T, d)
    # router consumes the seq-sharded activations (local-chunk gradients),
    # then the tiny logits ride a seq all-gather — replicated-weight rule.
    logits_sp = _router_logits(p, x_sp)            # (B, S_loc, E) or (B,1,E)
    logits = (bk.seq_ag(logits_sp, dim=1) if sp else logits_sp).reshape(T, E)
    topk_idx, topk_p, aux = _route(logits, mcfg)
    C = _capacity(T, mcfg, mode)
    buf, combine, dropped = _dispatch(x_tok, topk_idx, topk_p, E, C)
    y = _expert_ff(jax.tree.map(lambda w: w.astype(dt), p), buf, mcfg)
    delta = combine(y).reshape(B, S, d)       # partial over model (ff-sharded)
    if mcfg.shared_expert:
        h = x_full @ p["s_wi"].astype(dt)
        if mcfg.mlp_act == "swiglu":
            h = jax.nn.silu(h) * (x_full @ p["s_wg"].astype(dt))
        else:
            h = jax.nn.gelu(h)
        delta = delta + h @ p["s_wd"].astype(dt)
    delta = reduce(delta)
    aux["moe_dropped"] = dropped
    return delta, aux
