"""Arch id -> Model builder."""
from __future__ import annotations

from ..configs import get_arch
from ..configs.base import ModelConfig, RunConfig
from .transformer import Model


def build_model(arch: str | ModelConfig, cfg: RunConfig) -> Model:
    mcfg = get_arch(arch) if isinstance(arch, str) else arch
    return Model(mcfg, cfg)
