"""Generic segmented model covering all assigned architectures.

A model is a list of *segments*: homogeneous runs of identical blocks whose
parameters are stacked on a leading axis and executed with ``lax.scan``
(O(1) HLO for 64-layer models — essential for single-core dry-run compiles).
Heterogeneous layer patterns (VLM cross-attn layers, Hymba global-attention
layers, whisper enc/dec) become multiple segments via run-length grouping.

Block kinds:
  dense  — self-attn (full or SWA) + MLP
  moe    — self-attn + MoE FF (EP or TP; see models/moe.py)
  ssm    — Mamba-2 SSD mixer (no MLP)
  hyb    — parallel attn+SSM heads sharing the residual stream + MLP (Hymba)
  cross  — tanh-gated image cross-attn + gated MLP (VLM inserted layers)
  enc    — bidirectional self-attn + MLP (whisper encoder)
  dec    — causal self-attn + cross-attn(enc) + MLP (whisper decoder)

Modes: 'train'/'prefill' use sequence parallelism (activations sharded over
`model` between blocks); 'decode' keeps the single-token activations
replicated over `model` and reduces partial outputs with narrow psums.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig, ShapeConfig
from ..dist.backend import Backend
from ..dist.params import ParamSpec
from . import layers as L
from . import mamba2, moe as moe_mod
from .layers import HeadPlan, cdtype


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    name: str
    kind: str
    count: int
    window: int = 0          # sliding window for this segment's self-attn
    causal: bool = True


def build_plan(mcfg: ModelConfig) -> list[Segment]:
    segs: list[Segment] = []
    if mcfg.is_enc_dec:
        segs.append(Segment("enc", "enc", mcfg.num_encoder_layers, causal=False))
        segs.append(Segment("dec", "dec", mcfg.num_layers))
        return segs

    kinds = []
    for i in range(mcfg.num_layers):
        if mcfg.family == "vlm" and i in mcfg.cross_attn_layers:
            kinds.append(("cross", 0))
        elif mcfg.family == "moe":
            kinds.append(("moe", mcfg.sliding_window))
        elif mcfg.family == "ssm":
            kinds.append(("ssm", 0))
        elif mcfg.family == "hybrid":
            w = 0 if i in mcfg.global_layers else mcfg.sliding_window
            kinds.append(("hyb", w))
        else:
            kinds.append(("dense", mcfg.sliding_window))
    # run-length group
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        kind, window = kinds[i]
        segs.append(Segment(f"seg{len(segs)}_{kind}", kind, j - i, window))
        i = j
    return segs


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
class Model:
    def __init__(self, mcfg: ModelConfig, cfg: RunConfig):
        self.mcfg = mcfg
        self.cfg = cfg
        self.plan = build_plan(mcfg)
        self.head_plan = HeadPlan.build(mcfg.num_heads or 1,
                                        mcfg.num_kv_heads or 1,
                                        mcfg.head_dim or 1, cfg.tp_size)
        from ..dist import params as params_lib
        self._seg_pspecs = {
            s.name: params_lib.tree_pspecs(self._block_specs(s.kind, s.count))
            for s in self.plan
        }

    def _gather_params(self, bk: Backend, p, pspecs):
        """Cast to compute dtype + FSDP all-gather over `data` (per layer).

        pspecs carry the stacking axis (leading None); leaves inside the
        scan body lost it, hence the dim-1 offset.
        """
        dt = cdtype(self.cfg)

        def g(x, ps):
            if x.dtype == jnp.float32:
                x = x.astype(dt)
            for i, entry in enumerate(ps):
                if entry is None:
                    continue
                names = entry if isinstance(entry, (tuple, list)) else (entry,)
                if "data" in names:
                    return bk.param_ag(x, dim=i - 1)
            return x

        return jax.tree.map(g, p, pspecs)

    # -- parameter declaration ------------------------------------------------
    def _block_specs(self, kind: str, stack: int) -> dict:
        cfg, mcfg = self.cfg, self.mcfg
        p: dict[str, Any] = {"ln1": L.norm_specs(cfg, mcfg, stack)}
        if kind in ("dense", "moe", "enc", "dec"):
            p["attn"] = L.attention_specs(cfg, mcfg, stack)
        if kind == "dec":
            p["xattn"] = L.attention_specs(cfg, mcfg, stack)
            p["lnx"] = L.norm_specs(cfg, mcfg, stack)
        if kind == "cross":
            p["xattn"] = L.attention_specs(cfg, mcfg, stack)
            p["xgate"] = ParamSpec((stack,), jnp.dtype(cfg.param_dtype),
                                   init="zeros")
            p["mgate"] = ParamSpec((stack,), jnp.dtype(cfg.param_dtype),
                                   init="zeros")
        if kind == "ssm":
            p["ssm"] = mamba2.ssm_specs(cfg, mcfg, stack)
            return p  # no MLP, single norm
        if kind == "hyb":
            p["attn"] = L.attention_specs(cfg, mcfg, stack)
            p["ssm"] = mamba2.ssm_specs(cfg, mcfg, stack)
        if kind == "moe":
            p["moe"] = moe_mod.moe_specs(cfg, mcfg, stack)
        elif kind != "ssm":
            p["mlp"] = L.mlp_specs(cfg, mcfg, stack)
        p["ln2"] = L.norm_specs(cfg, mcfg, stack)
        return p

    def param_specs(self) -> dict:
        cfg, mcfg = self.cfg, self.mcfg
        tree: dict[str, Any] = {
            "embed": L.embed_specs(cfg, mcfg),
            "final_norm": L.norm_specs(cfg, mcfg),
            "segments": {s.name: self._block_specs(s.kind, s.count)
                         for s in self.plan},
        }
        return tree

    # -- block application ------------------------------------------------------
    def _self_attn(self, p, x_full, bk, *, seg: Segment, pos, mode,
                   cache=None, split_kv=False, cache_pos=None, kv_len=None):
        """Returns (partial_out, new_cache)."""
        mcfg = self.mcfg
        plan = self.head_plan
        theta = mcfg.rope_theta
        if mode == "decode":
            rope_q = (cache_pos + jnp.arange(1)) if mcfg.pos_emb == "rope" else None
            k_new, v_new = L.compute_kv(p, x_full, bk, plan,
                                        rope_pos=rope_q, theta=theta)
            kc, vc = cache
            kc, vc = _cache_append(kc, vc, k_new, v_new, cache_pos, bk,
                                   split_kv)
            k_off = (bk.axis_index("data") * kc.shape[1]) if split_kv else 0
            out = L.attention_core(
                p, x_full, kc, vc, bk, plan, causal=False, window=seg.window,
                rope_pos=rope_q, theta=theta,
                q_offset=cache_pos, k_offset=k_off, kv_len=kv_len,
                softcap=mcfg.logit_softcap, split_kv=split_kv)
            return out, (kc, vc)
        rope_pos = pos if mcfg.pos_emb == "rope" else None
        k_sel, v_sel = L.compute_kv(p, x_full, bk, plan,
                                    rope_pos=rope_pos, theta=theta)
        out = L.attention_core(
            p, x_full, k_sel, v_sel, bk, plan, causal=seg.causal,
            window=seg.window, rope_pos=rope_pos, theta=theta,
            softcap=mcfg.logit_softcap)
        new_cache = (k_sel, v_sel) if mode == "prefill" else None
        return out, new_cache

    def _cross_attn(self, p, x_full, bk, *, ctx_kv=None, ctx_full=None):
        """Cross-attention; kv either precomputed (decode) or from ctx_full."""
        plan = self.head_plan
        if ctx_kv is None:
            k_sel, v_sel = L.compute_kv(p, ctx_full, bk, plan)
            ctx_kv = (k_sel, v_sel)
        out = L.attention_core(p, x_full, ctx_kv[0], ctx_kv[1], bk, plan,
                               causal=False, window=0)
        return out, ctx_kv

    def _apply_block(self, seg: Segment, p, x, ctx, bk, *, mode,
                     pos, cache=None, split_kv=False, cache_pos=None,
                     kv_len=None):
        """One block. x: (B, S_loc, d) SP in train/prefill; (B,1,d) decode.

        Returns (x, new_cache, aux).
        """
        cfg, mcfg = self.cfg, self.mcfg
        sp = mode != "decode"
        aux: dict[str, Any] = {}
        new_cache: dict[str, Any] = {}
        p = self._gather_params(bk, p, self._seg_pspecs[seg.name])

        def gather(h):
            return bk.seq_ag(h, dim=1) if sp else h

        def reduce(partial):
            return bk.seq_rs(partial, dim=1) if sp else bk.psum_model(partial)

        h = L.apply_norm(p["ln1"], x, mcfg)
        h_full = gather(h)

        if seg.kind == "ssm":
            part, c = mamba2.apply_ssm(p["ssm"], h, h_full, bk, cfg, mcfg,
                                       cache=None if cache is None else cache.get("ssm"),
                                       mode=mode)
            if mode != "train":
                new_cache["ssm"] = c
            return x + reduce(part).astype(x.dtype), new_cache, aux

        if seg.kind == "cross":
            part, ckv = self._cross_attn(
                p["xattn"], h_full, bk,
                ctx_kv=None if cache is None else cache.get("xkv"),
                ctx_full=ctx.get("image_embeds"))
            gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * reduce(part).astype(x.dtype)
            if mode != "train":
                new_cache["xkv"] = ckv
            h2 = L.apply_norm(p["ln2"], x, mcfg)
            mgate = jnp.tanh(p["mgate"].astype(jnp.float32)).astype(x.dtype)
            part2 = L.apply_mlp(p["mlp"], gather(h2), mcfg)
            return x + mgate * reduce(part2).astype(x.dtype), new_cache, aux

        # --- self-attention (+parallel ssm for hyb) ---
        if seg.kind in ("dense", "moe", "enc", "dec", "hyb"):
            part, c = self._self_attn(
                p["attn"], h_full, bk, seg=seg, pos=pos,
                mode=mode, cache=None if cache is None else cache.get("attn"),
                split_kv=split_kv, cache_pos=cache_pos, kv_len=kv_len)
            if mode != "train" and c is not None:
                new_cache["attn"] = c
            if seg.kind == "hyb":
                part_s, cs = mamba2.apply_ssm(
                    p["ssm"], h, h_full, bk, cfg, mcfg,
                    cache=None if cache is None else cache.get("ssm"),
                    mode=mode)
                part = 0.5 * (part + part_s)
                if mode != "train":
                    new_cache["ssm"] = cs
            x = x + reduce(part).astype(x.dtype)

        if seg.kind == "dec":
            hx = L.apply_norm(p["lnx"], x, mcfg)
            part, ckv = self._cross_attn(
                p["xattn"], gather(hx), bk,
                ctx_kv=None if cache is None else cache.get("xkv"),
                ctx_full=ctx.get("enc_out"))
            x = x + reduce(part).astype(x.dtype)
            if mode != "train":
                new_cache["xkv"] = ckv

        # --- FF ---
        h2 = L.apply_norm(p["ln2"], x, mcfg)
        if seg.kind == "moe":
            h2_full = gather(h2) if (self.mcfg.num_experts % bk.model != 0
                                     or self.mcfg.shared_expert) else None
            delta, moe_aux = moe_mod.apply_moe(p["moe"], h2, h2_full, bk,
                                               cfg, mcfg, sp=sp, mode=mode)
            x = x + delta.astype(x.dtype)   # reduced inside apply_moe
            aux.update(moe_aux)
        else:
            part2 = L.apply_mlp(p["mlp"], gather(h2), mcfg)
            x = x + reduce(part2).astype(x.dtype)
        return x, new_cache, aux

    # -- backbone over segments -------------------------------------------------
    def _segment_scan(self, seg: Segment, p_seg, x, ctx, bk, *, mode, pos,
                      cache=None, split_kv=False, cache_pos=None, kv_len=None):
        """Scan a segment's stacked params (+cache) over its count."""
        remat = self.cfg.remat != "none" and mode == "train"

        def body(x, inp):
            p_i, c_i = inp
            x, c_new, aux = self._apply_block(
                seg, p_i, x, ctx, bk, mode=mode, pos=pos, cache=c_i,
                split_kv=split_kv, cache_pos=cache_pos, kv_len=kv_len)
            return x, (c_new, aux)

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)

        if cache is None:
            x, (caches, auxs) = jax.lax.scan(
                lambda carry, p_i: body(carry, (p_i, None)), x, p_seg)
        else:
            x, (caches, auxs) = jax.lax.scan(body, x, (p_seg, cache))
        aux = jax.tree.map(lambda a: jnp.mean(a, axis=0), auxs)
        return x, caches, aux

    def backbone(self, params, x, ctx, bk: Backend, *, mode, pos,
                 caches=None, split_kv=False, cache_pos=None, kv_len=None):
        all_caches = {}
        all_aux: dict[str, Any] = {}
        for seg in self.plan:
            if seg.kind == "enc":
                continue  # encoder handled separately in encode()
            c = None if caches is None else caches.get(seg.name)
            x, c_new, aux = self._segment_scan(
                seg, params["segments"][seg.name], x, ctx, bk, mode=mode,
                pos=pos, cache=c, split_kv=split_kv, cache_pos=cache_pos,
                kv_len=kv_len)
            if c_new is not None and mode != "train":
                all_caches[seg.name] = c_new
            for k, v in aux.items():
                all_aux[k] = all_aux.get(k, 0.0) + v * seg.count / self.mcfg.num_layers
        return x, all_caches, all_aux

    def encode(self, params, frames_sp, bk: Backend):
        """Whisper encoder: frames_sp (B, S_loc, d) -> enc_out_full (B, S, d)."""
        seg = self.plan[0]
        assert seg.kind == "enc"
        x, _, _ = self._segment_scan(seg, params["segments"][seg.name],
                                     frames_sp, {}, bk, mode="train", pos=None)
        return bk.seq_ag(x, dim=1)

    # ------------------------------------------------------------------
    # Top-level entry points (run INSIDE shard_map; see dist/step.py)
    # ------------------------------------------------------------------
    def _prepare_ctx(self, params, batch, bk: Backend, *, sp: bool = True):
        """Modality stubs -> cross-attention context. Returns (ctx, x_extra)."""
        mcfg, cfg = self.mcfg, self.cfg
        ctx: dict[str, Any] = {}
        if mcfg.family == "vlm":
            ctx["image_embeds"] = batch["image_embeds"].astype(cdtype(cfg))
        if mcfg.is_enc_dec and "frames" in batch:
            frames = batch["frames"].astype(cdtype(cfg))     # (B, S_enc, d)
            B, S_enc, d = frames.shape
            s_loc = S_enc // bk.model
            ridx = bk.axis_index("model")
            fr_sp = jax.lax.dynamic_slice_in_dim(frames, ridx * s_loc, s_loc, 1)
            pos = ridx * s_loc + jnp.arange(s_loc)
            fr_sp = fr_sp + L.sinusoidal_pos(pos, d, fr_sp.dtype)[None]
            ctx["enc_out"] = self.encode(params, fr_sp, bk)
        return ctx

    def _embed_sp(self, params, tokens, bk: Backend):
        """tokens (B,S) -> x_sp (B, S_loc, d) with positional handling."""
        mcfg, cfg = self.mcfg, self.cfg
        x_sp = L.embed_lookup(params["embed"], tokens, bk, cfg, mcfg)
        if mcfg.pos_emb == "sinusoidal":
            B, s_loc, d = x_sp.shape
            ridx = bk.axis_index("model")
            pos = ridx * s_loc + jnp.arange(s_loc)
            x_sp = x_sp + L.sinusoidal_pos(pos, d, x_sp.dtype)[None]
        return x_sp

    def loss_fn(self, params, batch, bk: Backend):
        """Causal-LM loss. batch: tokens/labels (B_loc, S) + modality stubs.

        Returns (loss, metrics). Labels < 0 are masked.
        """
        mcfg, cfg = self.mcfg, self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        ctx = self._prepare_ctx(params, batch, bk)
        x_sp = self._embed_sp(params, tokens, bk)
        pos = jnp.arange(S)
        x_sp, _, aux = self.backbone(params, x_sp, ctx, bk, mode="train",
                                     pos=pos)
        x_sp = L.apply_norm(params["final_norm"], x_sp, mcfg)
        x_full = bk.seq_ag(x_sp, dim=1)
        mask = (labels >= 0).astype(jnp.float32)
        loss_sum, count = L.chunked_xent(
            params["embed"], x_full, jnp.maximum(labels, 0), mask, bk, cfg,
            mcfg, z_loss=1e-4)
        # narrow-channel flit-packed metric reduction across dp ranks
        red = bk.psum_scalar_metrics({"loss_sum": loss_sum, "count": count})
        loss = red["loss_sum"] / jnp.maximum(red["count"], 1.0)
        total = loss
        metrics = {"ce_loss": loss}
        if "moe_lb_loss" in aux:
            total = total + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
            metrics.update({k: aux[k] for k in
                            ("moe_lb_loss", "moe_z_loss", "moe_dropped")})
        return total, metrics

    def prefill(self, params, batch, bk: Backend):
        """Prefill: returns (last-token logits (B,1,V_loc), caches)."""
        mcfg, cfg = self.mcfg, self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        ctx = self._prepare_ctx(params, batch, bk)
        x_sp = self._embed_sp(params, tokens, bk)
        pos = jnp.arange(S)
        x_sp, caches, _ = self.backbone(params, x_sp, ctx, bk, mode="prefill",
                                        pos=pos)
        x_sp = L.apply_norm(params["final_norm"], x_sp, mcfg)
        x_full = bk.seq_ag(x_sp, dim=1)
        logits = L.lm_logits(params["embed"], x_full[:, -1:], bk, cfg, mcfg)
        return logits, caches

    def decode_step(self, params, caches, tokens, pos, bk: Backend,
                    *, split_kv: bool = False):
        """One decode step. tokens (B,1); pos: scalar int32 (current length).

        Returns (logits (B,1,V_loc), new caches).
        """
        mcfg, cfg = self.mcfg, self.cfg
        x = L.embed_lookup(params["embed"], tokens, bk, cfg, mcfg, sp=False)
        if mcfg.pos_emb == "sinusoidal":
            x = x + L.sinusoidal_pos(pos + jnp.arange(1), x.shape[-1],
                                     x.dtype)[None]
        x, caches, _ = self.backbone(params, x, {}, bk, mode="decode",
                                     pos=pos, caches=caches,
                                     split_kv=split_kv, cache_pos=pos,
                                     kv_len=pos + 1)
        x = L.apply_norm(params["final_norm"], x, mcfg)
        logits = L.lm_logits(params["embed"], x, bk, cfg, mcfg)
        return logits, caches

    # ------------------------------------------------------------------
    # Input / cache specs (global shapes + PartitionSpecs; no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, *, split_kv: bool | None = None):
        """ShapeDtypeStructs + PartitionSpecs for every model input."""
        from jax.sharding import PartitionSpec as P
        mcfg, cfg = self.mcfg, self.cfg
        dp = cfg.dp_axes_eff
        dpx = dp if len(dp) > 1 else dp[0]
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.compute_dtype)
        if split_kv is None:
            split_kv = self._auto_split_kv(shape)

        if shape.kind in ("train", "prefill"):
            sds = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            specs = {"tokens": P(dpx, None)}
            if shape.kind == "train":
                sds["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
                specs["labels"] = P(dpx, None)
            if mcfg.family == "vlm":
                sds["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, mcfg.context_len, mcfg.d_model), dt)
                specs["image_embeds"] = P(dpx, None, None)
            if mcfg.is_enc_dec:
                sds["frames"] = jax.ShapeDtypeStruct((B, S, mcfg.d_model), dt)
                specs["frames"] = P(dpx, None, None)
            return sds, specs

        # decode: single-token inputs + caches
        sds = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        specs = {"tokens": P(None, None) if split_kv else P(dpx, None),
                 "pos": P()}
        return sds, specs

    def _auto_split_kv(self, shape: ShapeConfig) -> bool:
        dp = self.cfg.mesh.pod * self.cfg.mesh.data
        if self.cfg.flat_dp:
            dp *= self.cfg.mesh.model
        return shape.kind == "decode" and shape.global_batch < dp

    def cache_specs(self, shape: ShapeConfig, *, split_kv: bool | None = None):
        """Global cache ShapeDtypeStructs + PartitionSpecs for decode."""
        from jax.sharding import PartitionSpec as P
        mcfg, cfg = self.mcfg, self.cfg
        if split_kv is None:
            split_kv = self._auto_split_kv(shape)
        dp = cfg.dp_axes_eff
        dpx = dp if len(dp) > 1 else dp[0]
        B, S = shape.global_batch, shape.seq_len
        plan = self.head_plan
        dt = jnp.dtype(cfg.compute_dtype)
        n_kv_g = plan.n_kv_loc * cfg.tp_size

        if split_kv:
            b_spec, s_spec = None, "data"
        else:
            b_spec, s_spec = dpx, None

        sds: dict[str, Any] = {}
        specs: dict[str, Any] = {}
        for seg in self.plan:
            if seg.kind == "enc":
                continue
            entry_sds: dict[str, Any] = {}
            entry_spec: dict[str, Any] = {}
            if seg.kind in ("dense", "moe", "dec", "hyb"):
                kv = jax.ShapeDtypeStruct((seg.count, B, S, n_kv_g, mcfg.head_dim), dt)
                kv_sp = P(None, b_spec, s_spec, "model", None)
                entry_sds["attn"] = (kv, kv)
                entry_spec["attn"] = (kv_sp, kv_sp)
            if seg.kind in ("ssm", "hyb"):
                h_pad, h_loc, p_dim, _ = mamba2.ssm_dims(cfg, mcfg)
                W, N = mcfg.conv_width, mcfg.ssm_state
                entry_sds["ssm"] = (
                    jax.ShapeDtypeStruct((seg.count, B, W - 1, h_pad * p_dim), dt),
                    jax.ShapeDtypeStruct((seg.count, B, h_pad, p_dim, N), jnp.float32),
                    jax.ShapeDtypeStruct((seg.count, B, W - 1, 2 * N), dt),
                )
                entry_spec["ssm"] = (
                    P(None, b_spec, None, "model"),
                    P(None, b_spec, "model", None, None),
                    P(None, b_spec, None, None),
                )
            if seg.kind in ("dec", "cross"):
                S_ctx = mcfg.context_len if seg.kind == "cross" else S
                xkv = jax.ShapeDtypeStruct((seg.count, B, S_ctx, n_kv_g,
                                            mcfg.head_dim), dt)
                xkv_sp = P(None, b_spec, None, "model", None)
                entry_sds["xkv"] = (xkv, xkv)
                entry_spec["xkv"] = (xkv_sp, xkv_sp)
            sds[seg.name] = entry_sds
            specs[seg.name] = entry_spec
        return sds, specs


def _cast(tree, cfg: RunConfig):
    dt = cdtype(cfg)
    return jax.tree.map(
        lambda w: w.astype(dt) if w.dtype == jnp.float32 else w, tree)


def _cache_append(kc, vc, k_new, v_new, pos, bk: Backend, split_kv: bool):
    """Write the new token's kv at `pos` (global) into the cache.

    split_kv: cache seq dim is sharded over `data`; only the owner writes.
    """
    if split_kv:
        s_loc = kc.shape[1]
        didx = bk.axis_index("data")
        owner = (pos // s_loc) == didx
        p_loc = pos % s_loc
        kc_up = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), p_loc, 1)
        vc_up = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), p_loc, 1)
        kc = jnp.where(owner, kc_up, kc)
        vc = jnp.where(owner, vc_up, vc)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), pos, 1)
    return kc, vc
