from .registry import build_model  # noqa: F401
from .transformer import Model, Segment, build_plan  # noqa: F401
