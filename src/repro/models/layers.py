"""Sharded model building blocks.

All functions here run INSIDE ``jax.shard_map`` over the production mesh and
see *local* parameter/activation shards. Sharding contract (DESIGN.md §4):

* activations between blocks are sequence-sharded over `model` (SP);
* attention q/o projections are head-sharded over `model` (heads padded to a
  multiple of the axis size, padded heads exactly masked to zero);
* k/v projections are sharded over the head_dim and all-gathered (cheap),
  then each rank keeps only the deduplicated kv heads its local q heads
  need — the decode KV cache stores exactly that slice;
* parameters are additionally FSDP-sharded over `data` (dim 0 of each leaf
  after the layer-stacking axis) and gathered per layer via the backend.

Traffic classes: seq AG/RS and param AG are wide; all psums here are narrow.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, RunConfig
from ..dist.backend import Backend
from ..dist.params import ParamSpec
from ..kernels import ops


def pad_mult(n: int, m: int) -> int:
    return -(-n // m) * m


def cdtype(cfg: RunConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Head plan: padding + kv dedup gather (static)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HeadPlan:
    hq: int                 # real q heads
    hkv: int                # real kv heads
    hd: int
    model: int
    hq_pad: int
    hq_loc: int
    group: int              # real q-heads per kv head
    n_kv_loc: int           # deduped kv heads gathered/stored per rank
    hd_shard: bool          # kv projection sharded over head_dim?

    @staticmethod
    def build(hq: int, hkv: int, hd: int, model: int) -> "HeadPlan":
        hq_pad = pad_mult(hq, model)
        hq_loc = hq_pad // model
        group = max(1, hq // max(hkv, 1))
        kv_of = lambda h: min(h, hq - 1) // group
        n_kv = 1
        for r in range(model):
            lo, hi = kv_of(r * hq_loc), kv_of((r + 1) * hq_loc - 1)
            n_kv = max(n_kv, hi - lo + 1)
        return HeadPlan(hq, hkv, hd, model, hq_pad, hq_loc, group,
                        min(n_kv, hkv), hd % model == 0)

    # traced helpers --------------------------------------------------------
    def local_q_ids(self, ridx):
        return ridx * self.hq_loc + jnp.arange(self.hq_loc)

    def kv_of_q(self, q_ids):
        return jnp.minimum(q_ids, self.hq - 1) // self.group

    def first_kv(self, ridx):
        f = self.kv_of_q(ridx * self.hq_loc)
        return jnp.minimum(f, self.hkv - self.n_kv_loc)

    def local_kv_ids(self, ridx):
        return jnp.clip(self.first_kv(ridx) + jnp.arange(self.n_kv_loc),
                        0, self.hkv - 1)

    def q_to_local_kv(self, ridx):
        return self.kv_of_q(self.local_q_ids(ridx)) - self.first_kv(ridx)

    def q_mask(self, ridx):
        """1.0 for real heads, 0.0 for padded heads (exact zero masking)."""
        return (self.local_q_ids(ridx) < self.hq).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Param spec helpers
# ---------------------------------------------------------------------------
def wspec(shape: tuple[int, ...], cfg: RunConfig, *, model_dim: int | None,
          data_dim: int | None, init: str = "scaled",
          fan_in_axes: tuple[int, ...] = (), stack: int | None = None) -> ParamSpec:
    """Weight spec with optional stacking axis prepended.

    Under flat_dp the model axis carries no weight sharding; the FSDP dim is
    sharded over ('model','data') jointly.
    """
    fsdp_axes = cfg.fsdp_axes
    ax: list[Any] = [None] * len(shape)
    if model_dim is not None and not cfg.flat_dp:
        ax[model_dim] = "model"
    if fsdp_axes and data_dim is not None and ax[data_dim] is None:
        ax[data_dim] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    if stack is not None:
        shape = (stack,) + shape
        ax = [None] + ax
        fan_in_axes = tuple(a + 1 for a in fan_in_axes)
    return ParamSpec(tuple(shape), jnp.dtype(cfg.param_dtype), P(*ax),
                     init=init, fan_in_axes=fan_in_axes)


def nspec(d: int, cfg: RunConfig, stack: int | None = None,
          init: str = "ones") -> ParamSpec:
    shape = (d,) if stack is None else (stack, d)
    return ParamSpec(shape, jnp.dtype(cfg.param_dtype), P(), init=init)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_specs(cfg: RunConfig, mcfg: ModelConfig, stack: int | None = None):
    if mcfg.norm == "layernorm":
        return {"w": nspec(mcfg.d_model, cfg, stack, "ones"),
                "b": nspec(mcfg.d_model, cfg, stack, "zeros")}
    return {"w": nspec(mcfg.d_model, cfg, stack, "ones")}


def apply_norm(p, x, mcfg: ModelConfig):
    if mcfg.norm == "layernorm":
        return ops.layernorm(x, p["w"], p["b"], mcfg.norm_eps)
    return ops.rmsnorm(x, p["w"], mcfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE / positions
# ---------------------------------------------------------------------------
def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); pos: (S,) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]          # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_pos(pos: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freqs = 1.0 / (10_000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention (self / cross) with SP, head padding, dedup KV
# ---------------------------------------------------------------------------
def attention_specs(cfg: RunConfig, mcfg: ModelConfig, stack: int | None = None,
                    d_kv_src: int | None = None) -> dict:
    """q/o head-sharded; k/v sharded over head_dim (gathered at use)."""
    d = mcfg.d_model
    dsrc = d_kv_src if d_kv_src is not None else d
    plan = HeadPlan.build(mcfg.num_heads, mcfg.num_kv_heads, mcfg.head_dim,
                          cfg.tp_size)
    hd = mcfg.head_dim
    kv_model_dim = 2 if plan.hd_shard else None
    return {
        "wq": wspec((d, plan.hq_pad, hd), cfg, model_dim=1, data_dim=0,
                    fan_in_axes=(0,), stack=stack),
        "wk": wspec((dsrc, mcfg.num_kv_heads, hd), cfg, model_dim=kv_model_dim,
                    data_dim=0, fan_in_axes=(0,), stack=stack),
        "wv": wspec((dsrc, mcfg.num_kv_heads, hd), cfg, model_dim=kv_model_dim,
                    data_dim=0, fan_in_axes=(0,), stack=stack),
        "wo": wspec((plan.hq_pad, hd, d), cfg, model_dim=0, data_dim=2,
                    fan_in_axes=(0, 1), stack=stack),
    }


def compute_kv(p, src_full: jax.Array, bk: Backend, plan: HeadPlan,
               *, rope_pos=None, theta: float = 0.0):
    """src_full: (B, S, dsrc) -> deduped local kv (B, S, n_kv_loc, hd) x2.

    kv projection is computed sharded over head_dim (when divisible) and
    all-gathered over `model` — same bytes as the activations, far cheaper
    than replicating the projection compute.
    """
    k = jnp.einsum("bsd,dhe->bshe", src_full, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", src_full, p["wv"])
    if plan.hd_shard and bk.model > 1:
        k = bk.seq_ag(k, dim=3)     # gather head_dim shards
        v = bk.seq_ag(v, dim=3)
    if rope_pos is not None:
        k = apply_rope(k, rope_pos, theta)
    ridx = bk.axis_index("model")
    kv_ids = plan.local_kv_ids(ridx)
    k_sel = jnp.take(k, kv_ids, axis=2)
    v_sel = jnp.take(v, kv_ids, axis=2)
    return k_sel, v_sel


def attention_core(p, x_full: jax.Array, k_sel: jax.Array, v_sel: jax.Array,
                   bk: Backend, plan: HeadPlan, *, causal: bool, window: int,
                   rope_pos=None, theta: float = 0.0, q_offset=0, k_offset=0,
                   kv_len=None, softcap: float = 0.0, split_kv: bool = False):
    """q projection + attention + out projection (partial over model).

    x_full: (B, Sq, d). Returns partial out (B, Sq, d) — caller reduces
    (seq_rs for SP train, psum_model for decode).
    """
    B, Sq, _ = x_full.shape
    ridx = bk.axis_index("model")
    q = jnp.einsum("bsd,dhe->bshe", x_full, p["wq"])      # (B,Sq,hq_loc,hd)
    if rope_pos is not None:
        q = apply_rope(q, rope_pos, theta)
    # expand deduped kv to per-local-q-head
    q2kv = plan.q_to_local_kv(ridx)
    k_q = jnp.take(k_sel, q2kv, axis=2)                   # (B,Sk,hq_loc,hd)
    v_q = jnp.take(v_sel, q2kv, axis=2)
    if split_kv:
        _, (m, l, num) = ops.flash_attention(
            q, k_q, v_q, causal=causal, window=window, q_offset=q_offset,
            k_offset=k_offset, kv_len=kv_len, softcap=softcap,
            return_stats=True)
        out = ops.combine_attention_shards(m, l, num, bk.psum_data, bk.pmax_data)
    else:
        out = ops.flash_attention(
            q, k_q, v_q, causal=causal, window=window, q_offset=q_offset,
            k_offset=k_offset, kv_len=kv_len, softcap=softcap)
    out = out * plan.q_mask(ridx)[None, None, :, None].astype(out.dtype)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])      # partial over model


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU), column->row parallel over `model`
# ---------------------------------------------------------------------------
def mlp_specs(cfg: RunConfig, mcfg: ModelConfig, stack: int | None = None) -> dict:
    d, ff = mcfg.d_model, mcfg.d_ff
    out = {
        "wi": wspec((d, ff), cfg, model_dim=1, data_dim=0, fan_in_axes=(0,),
                    stack=stack),
        "wd": wspec((ff, d), cfg, model_dim=0, data_dim=1, fan_in_axes=(0,),
                    stack=stack),
    }
    if mcfg.mlp_act == "swiglu":
        out["wg"] = wspec((d, ff), cfg, model_dim=1, data_dim=0,
                          fan_in_axes=(0,), stack=stack)
    return out


def apply_mlp(p, x_full: jax.Array, mcfg: ModelConfig) -> jax.Array:
    """x_full (B,S,d) -> partial (B,S,d) (caller reduces over model)."""
    h = x_full @ p["wi"]
    if mcfg.mlp_act == "swiglu":
        h = jax.nn.silu(h) * (x_full @ p["wg"])
    else:
        h = jax.nn.gelu(h)
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# Embedding + sharded cross-entropy (vocab over `model`)
# ---------------------------------------------------------------------------
def embed_specs(cfg: RunConfig, mcfg: ModelConfig) -> dict:
    v_pad = pad_mult(mcfg.vocab_size, cfg.tp_size)
    out = {"table": wspec((v_pad, mcfg.d_model), cfg, model_dim=0, data_dim=1,
                          init="normal")}
    if not mcfg.tie_embeddings:
        out["head"] = wspec((mcfg.d_model, v_pad), cfg, model_dim=1, data_dim=0,
                            fan_in_axes=(0,), init="normal")
    return out


def embed_lookup(p, ids: jax.Array, bk: Backend, cfg: RunConfig,
                 mcfg: ModelConfig, *, sp: bool = True) -> jax.Array:
    """ids: (B, S) full -> x_sp (B, S/model, d) sequence-sharded (SP).

    With ``sp=False`` (decode) the partial embeddings are psum'd instead
    (narrow: a single token row).
    """
    table = bk.param_ag(p["table"], dim=1).astype(cdtype(cfg))
    v_loc = table.shape[0]
    off = bk.axis_index("model") * v_loc
    local = jnp.clip(ids - off, 0, v_loc - 1)
    hit = ((ids >= off) & (ids < off + v_loc))[..., None]
    emb = jnp.where(hit, jnp.take(table, local, axis=0), 0).astype(cdtype(cfg))
    if bk.model == 1:
        return emb
    return bk.seq_rs(emb, dim=1) if sp else bk.psum_model(emb)


def lm_logits(p, x_full: jax.Array, bk: Backend, cfg: RunConfig,
              mcfg: ModelConfig) -> jax.Array:
    """x_full (B, S, d) -> logits (B, S, V_loc) (vocab-sharded)."""
    if mcfg.tie_embeddings:
        table = bk.param_ag(p["table"], dim=1).astype(cdtype(cfg))
        return jnp.einsum("bsd,vd->bsv", x_full, table)
    head = bk.param_ag(p["head"], dim=0).astype(cdtype(cfg))
    return x_full @ head


def sharded_xent(logits: jax.Array, labels: jax.Array, bk: Backend,
                 mcfg: ModelConfig, *, z_loss: float = 0.0):
    """logits (B,S,V_loc) vocab-sharded; labels (B,S) global ids.

    Returns (per-token loss (B,S) fp32, aux metrics). Uses narrow-channel
    pmax/psum for the softmax stats — the textbook latency-critical smalls.
    """
    v_loc = logits.shape[-1]
    off = bk.axis_index("model") * v_loc
    gid = off + jnp.arange(v_loc)
    logits = jnp.where((gid < mcfg.vocab_size)[None, None, :],
                       logits.astype(jnp.float32), -1e30)
    m = jax.lax.stop_gradient(bk.pmax_model(jnp.max(logits, axis=-1)))
    se = bk.psum_model(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    lse = jnp.log(se) + m
    lab_local = jnp.clip(labels - off, 0, v_loc - 1)
    hit = (labels >= off) & (labels < off + v_loc)
    lab_logit = bk.psum_model(
        jnp.where(hit, jnp.take_along_axis(logits, lab_local[..., None],
                                           axis=-1)[..., 0], 0.0))
    loss = lse - lab_logit
    if z_loss > 0.0:
        loss = loss + z_loss * jnp.square(lse)
    return loss


def chunked_xent(embed_params, x_full: jax.Array, labels: jax.Array,
                 mask: jax.Array, bk: Backend, cfg: RunConfig,
                 mcfg: ModelConfig, *, z_loss: float = 0.0,
                 chunk: int = 512):
    """Fused LM-head + cross-entropy over sequence chunks.

    The (B, S, V_loc) logits are never materialized: each chunk's logits are
    computed, reduced to (loss_sum, count), and **rematerialized in the
    backward pass** (jax.checkpoint), bounding the peak buffer to
    (B, chunk, V_loc). This is what lets the big-vocab archs
    (llama*: 128k, scout: 202k) fit the per-device memory budget.
    """
    B, S, d = x_full.shape
    if mcfg.tie_embeddings:
        head = bk.param_ag(embed_params["table"], dim=1).astype(cdtype(cfg)).T
    else:
        head = bk.param_ag(embed_params["head"], dim=0).astype(cdtype(cfg))
    c = min(chunk, S)
    if S % c:
        c = S
    nc = S // c
    xc = jnp.moveaxis(x_full.reshape(B, nc, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, c), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        x_i, l_i, m_i = inp
        logits = x_i @ head
        loss_tok = sharded_xent(logits, l_i, bk, mcfg, z_loss=z_loss)
        ls, cnt = carry
        return (ls + jnp.sum(loss_tok * m_i), cnt + jnp.sum(m_i)), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc, mc))
    return loss_sum, count
