"""Version-compat backfills for the installed JAX.

The codebase (and its test suite) is written against the current JAX
surface; the container bakes in an older jax. Rather than scatter
version branches through every call site, this module backfills the
handful of renamed/moved entry points once, at ``import repro`` time.
Every shim is a no-op on a JAX that already provides the modern name,
so nothing here needs to change when the toolchain moves forward.

Backfills (old JAX only):

* ``jax.sharding.AxisType``        — enum added with explicit sharding;
  older meshes are implicitly Auto, so a placeholder enum suffices.
* ``jax.make_mesh(axis_types=...)`` — older signature lacks the kwarg;
  we accept and drop it (Auto was the only behaviour back then).
* ``jax.shard_map(... check_vma=)`` — older JAX has
  ``jax.experimental.shard_map.shard_map`` with the kwarg spelled
  ``check_rep``.
"""
from __future__ import annotations

import enum
import inspect

import jax


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # implicitly Auto on this JAX version
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kwargs):
            if check_rep is None:
                check_rep = True if check_vma is None else check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              **kwargs)

        jax.shard_map = shard_map


_install()
