"""Step builders: the shard_map'd programs everything else runs.

``build_train_step`` / ``build_prefill_step`` / ``build_decode_step``
take a model (``repro.models.transformer.Model``), a ShapeConfig and a
jax Mesh and return a :class:`StepArtifact`:

* ``fn``          — the jitted shard_map'd step
* ``param_specs`` / ``opt_specs`` — ParamSpec trees (materialize with
  ``params.materialize_sharded``)
* ``in_sds``      — sharded ShapeDtypeStructs so the multi-pod dry-run
  can ``fn.lower(*in_sds).compile()`` with zero allocation
* ``backend``     — the Backend whose ledger holds the static
  collective schedule recorded at trace time

The train step supports ``cfg.microbatches > 1`` by splitting the
local batch and accumulating gradients over an unrolled microbatch
loop (averaged, so the result is equivalent to the full-batch step
when every microbatch carries the same token count).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ShapeConfig
from . import params as params_lib
from .backend import Backend


@dataclass
class StepArtifact:
    fn: Callable
    param_specs: Any
    opt_specs: Any | None
    in_sds: tuple
    backend: Backend

    @property
    def ledger(self):
        """The backend's collective byte ledger.  Populated at trace
        time — run ``fn.lower(*in_sds)`` (or call ``fn``) first; feed
        it to ``repro.noc.Workload.from_ledger`` to replay the step's
        traffic on a simulated NoC."""
        return self.backend.ledger


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _dpx(cfg):
    dp = cfg.dp_axes_eff
    return dp if len(dp) > 1 else dp[0]


def _vocab_axis(cfg):
    return None if cfg.flat_dp else "model"


def _sharded_sds(sds_tree: Any, spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        sds_tree, spec_tree)


def _split_microbatches(batch: dict, m: int) -> list[dict]:
    """Split the LOCAL batch dim into m microbatches (list of dicts)."""
    out = []
    for i in range(m):
        mb = {}
        for k, v in batch.items():
            assert v.shape[0] % m == 0, \
                f"local batch {v.shape[0]} not divisible by {m} microbatches"
            sz = v.shape[0] // m
            mb[k] = jax.lax.slice_in_dim(v, i * sz, (i + 1) * sz, axis=0)
        out.append(mb)
    return out


def _accumulated_grad_step(model, bk: Backend, params, batch, *,
                           microbatches: int):
    """value_and_grad over `microbatches` sequential microbatches.

    Returns (loss, metrics, grads) with grads/loss averaged over the
    microbatches. Correctness note: the model's loss is normalized by
    the globally-psum'd token count, so any *replication* in the batch
    sharding (e.g. the pipeline schedule replicating over `pod`)
    automatically shrinks per-rank cotangents by the replication factor
    — the later sync psum then restores exactly the true gradient, with
    no explicit rescale.
    """
    def loss_of(p, b):
        return model.loss_fn(p, b, bk)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)
    m = max(1, microbatches)
    if m == 1:
        (loss, mets), grads = grad_fn(params, batch)
        return loss, mets, grads

    # 1F1B-shaped accumulation: microbatch i+1's forward is issued after
    # microbatch i's backward; unrolled (m is small) so XLA may overlap.
    loss_acc, mets_acc, grads_acc = None, None, None
    for mb in _split_microbatches(batch, m):
        (loss, mets), grads = grad_fn(params, mb)
        if grads_acc is None:
            loss_acc, mets_acc, grads_acc = loss, mets, grads
        else:
            loss_acc = loss_acc + loss
            mets_acc = jax.tree.map(jnp.add, mets_acc, mets)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
    inv = 1.0 / m
    return (loss_acc * inv,
            jax.tree.map(lambda x: x * inv, mets_acc),
            jax.tree.map(lambda g: g * inv, grads_acc))


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def build_train_step(model, shape: ShapeConfig, mesh, acfg=None,
                     *, batch_specs: Any | None = None) -> StepArtifact:
    """One optimizer step: fwd + bwd + policy-driven grad sync + AdamW.

    ``fn(params, opt_state, step, batch) -> (params, opt_state, metrics)``
    with metrics carrying at least ``loss`` and ``grad_norm``.

    ``batch_specs`` is the pipeline schedule's hook: it overrides the
    input sharding (e.g. batch replicated over `pod`, sharded over
    `data` only).
    """
    from ..train import optimizer as opt_mod   # deferred: import cycle

    cfg = model.cfg
    if acfg is None:
        acfg = opt_mod.AdamWConfig(lr=cfg.learning_rate,
                                   weight_decay=cfg.weight_decay)
    param_specs = model.param_specs()
    opt_specs = opt_mod.opt_state_specs(param_specs, cfg)
    p_ps = params_lib.tree_pspecs(param_specs)
    o_ps = params_lib.tree_pspecs(opt_specs)
    batch_sds, in_batch_specs = model.input_specs(shape)
    if batch_specs is not None:
        in_batch_specs = batch_specs
    bk = Backend(cfg)

    def step(params, opt_state, stepno, batch):
        loss, mets, grads = _accumulated_grad_step(
            model, bk, params, batch, microbatches=cfg.microbatches)
        new_p, new_o, stats = opt_mod.adamw_update(
            params, grads, opt_state, stepno, cfg, acfg, p_ps, bk)
        metrics = {"loss": loss, **mets, **stats}
        return new_p, new_o, metrics

    smapped = jax.shard_map(
        step, mesh=mesh,
        in_specs=(p_ps, o_ps, P(), in_batch_specs),
        out_specs=(p_ps, o_ps, P()),
        check_vma=False)
    fn = jax.jit(smapped)

    in_sds = (
        _sharded_sds(params_lib.tree_sds(param_specs), p_ps, mesh),
        _sharded_sds(params_lib.tree_sds(opt_specs), o_ps, mesh),
        jax.ShapeDtypeStruct((), jnp.int32),
        _sharded_sds(batch_sds, in_batch_specs, mesh),
    )
    return StepArtifact(fn=fn, param_specs=param_specs, opt_specs=opt_specs,
                        in_sds=in_sds, backend=bk)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def build_prefill_step(model, shape: ShapeConfig, mesh) -> StepArtifact:
    """``fn(params, batch) -> (last-token logits (B,1,V_loc), caches)``."""
    cfg = model.cfg
    param_specs = model.param_specs()
    p_ps = params_lib.tree_pspecs(param_specs)
    batch_sds, batch_specs = model.input_specs(shape)
    _, cache_specs = model.cache_specs(shape, split_kv=False)
    bk = Backend(cfg)
    dpx = _dpx(cfg)
    logits_spec = P(dpx, None, _vocab_axis(cfg))

    def pre(params, batch):
        return model.prefill(params, batch, bk)

    smapped = jax.shard_map(
        pre, mesh=mesh,
        in_specs=(p_ps, batch_specs),
        out_specs=(logits_spec, cache_specs),
        check_vma=False)
    fn = jax.jit(smapped)

    in_sds = (
        _sharded_sds(params_lib.tree_sds(param_specs), p_ps, mesh),
        _sharded_sds(batch_sds, batch_specs, mesh),
    )
    return StepArtifact(fn=fn, param_specs=param_specs, opt_specs=None,
                        in_sds=in_sds, backend=bk)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def build_decode_step(model, shape: ShapeConfig, mesh,
                      *, split_kv: bool | None = None) -> StepArtifact:
    """``fn(params, caches, tokens (B,1), pos) -> (logits, new caches)``.

    ``split_kv=True`` shards the cache *sequence* dim over `data`
    (small-batch decode: every rank attends to its cache slice, the
    partial softmax stats combine with narrow psums).
    """
    cfg = model.cfg
    if split_kv is None:
        split_kv = model._auto_split_kv(shape)
    param_specs = model.param_specs()
    p_ps = params_lib.tree_pspecs(param_specs)
    in_sds_d, in_specs_d = model.input_specs(shape, split_kv=split_kv)
    cache_sds, cache_specs = model.cache_specs(shape, split_kv=split_kv)
    bk = Backend(cfg)
    dpx = _dpx(cfg)
    batch_spec = None if split_kv else dpx
    logits_spec = P(batch_spec, None, _vocab_axis(cfg))

    def dec(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos, bk,
                                 split_kv=split_kv)

    smapped = jax.shard_map(
        dec, mesh=mesh,
        in_specs=(p_ps, cache_specs, in_specs_d["tokens"], P()),
        out_specs=(logits_spec, cache_specs),
        check_vma=False)
    fn = jax.jit(smapped)

    in_sds = (
        _sharded_sds(params_lib.tree_sds(param_specs), p_ps, mesh),
        _sharded_sds(cache_sds, cache_specs, mesh),
        jax.ShapeDtypeStruct(in_sds_d["tokens"].shape, jnp.int32,
                             sharding=NamedSharding(
                                 mesh, in_specs_d["tokens"])),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return StepArtifact(fn=fn, param_specs=param_specs, opt_specs=None,
                        in_sds=in_sds, backend=bk)
