"""Microbatched pipeline schedule over the `pod` axis.

The production mesh's `pod` axis is the slow inter-pod interconnect.
The flat DP train step shards the batch over it, which makes every
optimizer step pay a full-gradient all-reduce across the slowest links
at the *end* of the step. The pipeline schedule instead:

* replicates the batch over `pod` and shards it over `data` only, so
  the fast intra-pod links carry all activation traffic;
* runs the local batch as ``cfg.microbatches`` sequential microbatches
  (the 1F1B-shaped accumulation loop in ``step.py`` — microbatch i+1's
  forward issues behind microbatch i's backward, which is what lets
  XLA overlap the per-microbatch FSDP gathers with compute);
* leaves the gradient scale alone: the loss is normalized by the
  globally-psum'd token count, which doubles with the pod replication
  — per-rank cotangents shrink by exactly ``1/pod``, and the sync's
  psum over `pod` restores the true gradient. (MoE auxiliary losses
  are mean- rather than count-normalized, so their tiny 0.01-weighted
  gradients pick up a ``pod``-fold factor under this schedule — a
  known approximation, not load-bearing for any current config.) The
  pod axis then carries exactly one wide bulk transfer per step: the
  gradient sync itself (riding ``int8-pod`` compression when
  configured).

The step artifact is interchangeable with ``build_train_step``'s: same
``fn`` signature, same spec trees, equivalent loss/grad-norm (tested in
``tests/test_pipeline_flatdp.py``). True stage-partitioned PP (layer
segments resident per pod, activations ppermuted at stage boundaries)
can slot in behind the same artifact without touching callers.
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from ..configs.base import ShapeConfig
from . import step as step_lib


def build_pipeline_train_step(model, shape: ShapeConfig, mesh,
                              acfg=None) -> step_lib.StepArtifact:
    """Pipeline-scheduled train step (see module docstring).

    Requires a multi-pod mesh config; with ``pod == 1`` it degrades to
    the plain microbatched train step.
    """
    _, specs = model.input_specs(shape)
    # batch rides `data` only; pod ranks replicate and run in lockstep
    pipe_specs = {k: P("data", *tuple(v)[1:]) for k, v in specs.items()}
    return step_lib.build_train_step(model, shape, mesh, acfg,
                                     batch_specs=pipe_specs)
