"""Gradient compression for the slow inter-pod links.

``grad_compression="int8-pod"`` routes the pod-axis gradient all-reduce
(the cross-pod DP sync — the slowest links in the system, the paper's
latency-tolerant wide bulk par excellence) through blockwise-int8
payloads: each rank quantizes its local partial gradient with per-block
fp32 scales, the int8 payload + scales ride the wire (~4x fewer bytes
than fp32), and every rank dequantizes-and-sums the gathered shards.
Quantizing the *inputs* (not the sum) keeps the reduction associative
and deterministic across pod orderings; the per-block max-abs scale
bounds the element error at ``max|x| / 127`` per contribution.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.channels import Ledger, WIDE

_INT8_MAX = 127.0
_BLOCK = 256


def quantize_blockwise(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """int8-quantize along the last axis in `block`-sized groups.

    Returns ``(q int8 (same shape), scales f32 (..., last/block))``.
    Requires ``x.shape[-1] % block == 0``. All-zero blocks get scale 0
    and decode exactly to 0.
    """
    *lead, last = x.shape
    assert last % block == 0, (x.shape, block)
    xb = x.astype(jnp.float32).reshape(*lead, last // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / _INT8_MAX
    q = jnp.where(scale[..., None] > 0.0, xb / scale[..., None], 0.0)
    q = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_blockwise(q: jax.Array, scale: jax.Array,
                         block: int) -> jax.Array:
    *lead, last = q.shape
    xb = q.reshape(*lead, last // block, block).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(q.shape)


def compressed_all_reduce(x: jax.Array, axes: Sequence[tuple[str, int]], *,
                          ledger: Ledger | None = None,
                          wide_flit_bytes: int = 65536) -> jax.Array:
    """All-reduce one array with blockwise-int8 wire format.

    quantize(local) -> all_gather(q, scales) -> sum(dequantize(shards)).
    Exchanging quantized *inputs* makes the sum order-independent (every
    rank sums the same shard set), so the result is replicated without a
    second reduction.
    """
    names = tuple(a for a, _ in axes)
    total = 1
    for _, s in axes:
        total *= s
    if total == 1:
        return x
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))    # zero-pads quantize exactly
    block = _BLOCK
    q, s = quantize_blockwise(flat, block)
    qg = lax.all_gather(q, names)                      # (total, n_pad) int8
    sg = lax.all_gather(s, names)                      # (total, n_pad/block)
    if ledger is not None:
        wire = int(np.prod(q.shape)) + int(np.prod(s.shape)) * 4
        ledger.log("all_gather", names, wire * (total - 1), WIDE,
                   f"int8 grad AR block={block} "
                   f"(flit threshold {wide_flit_bytes}B)")
    red = jnp.sum(jax.vmap(dequantize_blockwise, in_axes=(0, 0, None))(
        qg, sg, block), axis=0)
    return red[:n].reshape(x.shape).astype(x.dtype)


def compressed_all_reduce_tree(leaves: Sequence[jax.Array],
                               axes: Sequence[tuple[str, int]], *,
                               ledger: Ledger | None = None,
                               wide_flit_bytes: int = 65536) -> list[jax.Array]:
    """Blockwise-int8 all-reduce of a leaf list (the optimizer's per-
    sync-group entry point for ``grad_compression="int8-pod"``)."""
    return [compressed_all_reduce(g, axes, ledger=ledger,
                                  wide_flit_bytes=wide_flit_bytes)
            for g in leaves]
