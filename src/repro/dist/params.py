"""Parameter/state declaration: ``ParamSpec`` trees and sharded init.

A model never allocates its own weights; it returns a pytree of
:class:`ParamSpec` leaves (global shape + dtype + ``PartitionSpec`` +
init rule) and the substrate materializes them. Two invariants matter:

* **Mesh-independence** — ``materialize_sharded`` draws every leaf from
  a key folded with a stable hash of the leaf's tree path and computes
  the GLOBAL array before sharding, so any mesh factorization of the
  same spec tree sees bit-identical parameters. This is what makes the
  cross-mesh equivalence suite (``tests/test_distributed.py``)
  meaningful.
* **Spec trees are data** — ``tree_pspecs`` / ``tree_sds`` project the
  same declaration into shard_map in/out_specs and dry-run
  ShapeDtypeStructs, so the train step, the serving engine, the
  checkpointer and the 512-chip dry-run all consume one source of
  truth.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter/state leaf (global view)."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    pspec: P = field(default_factory=P)
    init: str = "scaled"            # scaled | normal | zeros | ones
    fan_in_axes: tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype))
        object.__setattr__(self, "fan_in_axes",
                           tuple(int(a) for a in self.fan_in_axes))

    # ------------------------------------------------------------------
    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def fan_in(self) -> int:
        axes = self.fan_in_axes or ((0,) if len(self.shape) > 1 else ())
        n = 1
        for a in axes:
            n *= self.shape[a]
        return max(n, 1)

    def materialize(self, key: jax.Array) -> jax.Array:
        """Initialize the GLOBAL array for this leaf (unsharded)."""
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":                  # embeddings: fixed std
            x = jax.random.normal(key, self.shape, jnp.float32) * 0.02
        elif self.init == "scaled":                # LeCun-style fan-in
            std = 1.0 / np.sqrt(self.fan_in())
            x = jax.random.truncated_normal(
                key, -2.0, 2.0, self.shape, jnp.float32) * std
        else:
            raise ValueError(f"unknown init {self.init!r}")
        return x.astype(self.dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# Tree projections
# ---------------------------------------------------------------------------
def tree_pspecs(tree: Any) -> Any:
    """Spec tree -> PartitionSpec tree (shard_map in/out_specs)."""
    return jax.tree.map(lambda s: s.pspec, tree, is_leaf=is_spec)


def tree_sds(tree: Any) -> Any:
    """Spec tree -> ShapeDtypeStruct tree (dry-run / checkpoint targets)."""
    return jax.tree.map(lambda s: s.sds, tree, is_leaf=is_spec)


def _path_key(base: jax.Array, path: str) -> jax.Array:
    """Per-leaf key: fold a stable (process-independent) path hash."""
    h = int.from_bytes(hashlib.md5(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(base, h)


def materialize_sharded(tree: Any, key: jax.Array, mesh) -> Any:
    """Initialize a spec tree onto ``mesh`` with each leaf's pspec.

    Values depend only on (key, tree paths, specs) — NOT on the mesh —
    so the same declaration materializes identically on any
    factorization (sharding is applied after the global init).
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_spec)
    out = []
    for path, spec in leaves:
        sub = _path_key(key, jax.tree_util.keystr(path))
        arr = spec.materialize(sub)
        out.append(jax.device_put(arr, NamedSharding(mesh, spec.pspec)))
    return jax.tree.unflatten(treedef, out)
