"""The mesh-facing collective backend used INSIDE ``jax.shard_map``.

Every cross-device byte of a train/prefill/decode step goes through one
of these methods, which (a) dispatches to the FlooNoC software
collectives (``core/collectives.py`` dimension-ordered rings) or the plain
XLA primitives depending on ``cfg.backend``, and (b) records the
transfer in the collective :class:`~repro.core.channels.Ledger` with
its traffic class — the paper's narrow/wide separation applied to a
real training step:

* **wide**  — sequence AG/RS between blocks, FSDP parameter gathers,
  MoE all_to_all dispatch (bandwidth-bound bulk);
* **narrow** — partial-output psums, softmax/argmax stats, scalar
  metrics (latency-critical smalls, flit-packed).

``flat_dp`` semantics: when the run collapses tensor parallelism
(``cfg.flat_dp``), the ``model`` mesh axis carries batch shards
instead, so every TP collective here degenerates to the identity and
``axis_index("model")`` reports 0 — model code stays oblivious.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import MeshConfig, RunConfig
from ..core import channels, collectives, flit
from ..core.channels import Ledger, NARROW, WIDE


def _nbytes(x: jax.Array) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


# ---------------------------------------------------------------------------
# AD-correct cross-rank reductions for INSIDE-shard_map differentiation.
#
# With untracked replication (check_rep/check_vma off), jax transposes
# lax.psum to lax.psum — i.e. it re-accumulates the cotangent across
# ranks. Our psums all feed the REPLICATED loss (every rank seeds the
# same cotangent), where the true adjoint of "y = sum_i x_i, y and ybar
# replicated" is the identity: xbar_i = ybar. Without this, every
# gradient comes out n_ranks too large (caught by the cross-mesh
# equivalence suite). pmax is order statistics used only for softmax/
# argmax stabilization, so its input gradient is dropped by design —
# and must be, because jax has no JVP rule for pmax.
# ---------------------------------------------------------------------------
from functools import partial as _partial  # noqa: E402


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_rep(x, axes):
    return lax.psum(x, axes)


def _psum_rep_fwd(x, axes):
    return lax.psum(x, axes), None


def _psum_rep_bwd(axes, _, ct):
    return (ct,)


_psum_rep.defvjp(_psum_rep_fwd, _psum_rep_bwd)


def _pmax_ng(x, axes):
    return lax.pmax(lax.stop_gradient(x), axes)


def halo_permute(x: jax.Array, axis: str, n: int, *, shift: int = 1,
                 wrap: bool = False) -> jax.Array:
    """Neighbor exchange along one mesh axis: shard ``s`` receives the
    ``x`` held by shard ``s - shift`` (data moves ``+shift`` along the
    axis).  ``wrap`` closes the ring (torus halo); without it the edge
    shards receive zeros — ``lax.ppermute`` fills missing sources, so a
    mesh boundary needs no special-casing.  ``n == 1`` degenerates to
    the identity (wrap: the shard is its own neighbor) or zeros
    (no-wrap: there is no neighbor), with no collective issued.

    This is the halo step of the row-sharded NoC fabric
    (:mod:`repro.noc.farm`): per simulated cycle, each shard ships its
    boundary routers' occupancy and output registers to the adjacent
    shard instead of materializing the whole fabric anywhere.
    """
    if n == 1:
        return x if wrap else jnp.zeros_like(x)
    if wrap:
        perm = [(s, (s + shift) % n) for s in range(n)]
    else:
        perm = [(s, s + shift) for s in range(n) if 0 <= s + shift < n]
    return lax.ppermute(x, axis, perm)


class Backend:
    """Collective backend bound to one RunConfig (trace-time object).

    Safe to construct inside traced code: __init__ touches no jax
    primitives. Ledger entries are recorded at trace time (the schedule
    is static), which is what the dry-run reports as the collective
    ledger.
    """

    def __init__(self, cfg: RunConfig, ledger: Ledger | None = None):
        self.cfg = cfg
        self.mesh_cfg: MeshConfig = cfg.mesh
        self.ledger = ledger if ledger is not None else Ledger()
        self._sizes = dict(zip(cfg.mesh.axis_names, cfg.mesh.shape))

    # -- static topology ----------------------------------------------------
    @property
    def is_floo(self) -> bool:
        return self.cfg.backend == "floo"

    @property
    def model(self) -> int:
        """Effective TP degree (1 under flat_dp regardless of mesh)."""
        return self.cfg.tp_size

    def axis_size(self, name: str) -> int:
        return self._sizes.get(name, 1)

    def axis_index(self, name: str):
        if name == "model" and self.cfg.flat_dp:
            return jnp.int32(0)          # TP collapsed: every rank is rank 0
        if name not in self._sizes:
            return jnp.int32(0)
        return lax.axis_index(name)

    def _log(self, op: str, axes, nbytes: int, cls: str, note: str = ""):
        self.ledger.log(op, axes, nbytes, cls, note)

    # -- TP (model-axis) collectives ----------------------------------------
    def seq_ag(self, x: jax.Array, *, dim: int) -> jax.Array:
        """All-gather sequence/feature shards over `model` (wide bulk)."""
        n = self.model
        if n == 1:
            return x
        self._log("all_gather", ("model",), _nbytes(x) * (n - 1), WIDE,
                  f"seq AG dim={dim}")
        if self.is_floo:
            return collectives.ring_all_gather(x, "model", n, dim=dim,
                                           bidir=self.cfg.bidir_rings)
        return lax.all_gather(x, "model", axis=dim, tiled=True)

    def seq_rs(self, x: jax.Array, *, dim: int) -> jax.Array:
        """Reduce-scatter partial outputs over `model` (wide bulk)."""
        n = self.model
        if n == 1:
            return x
        self._log("reduce_scatter", ("model",),
                  _nbytes(x) * (n - 1) // n, WIDE, f"seq RS dim={dim}")
        if self.is_floo:
            return collectives.ring_reduce_scatter(x, "model", n, dim=dim,
                                               bidir=self.cfg.bidir_rings)
        return lax.psum_scatter(x, "model", scatter_dimension=dim, tiled=True)

    def psum_model(self, x: jax.Array) -> jax.Array:
        """Narrow latency-critical reduction over `model` (partial outs)."""
        if self.model == 1:
            return x
        self._log("psum", ("model",), _nbytes(x), NARROW, "TP partial")
        return _psum_rep(x, "model")

    def pmax_model(self, x: jax.Array) -> jax.Array:
        if self.model == 1:
            return x
        self._log("pmax", ("model",), _nbytes(x), NARROW, "softmax stat")
        return _pmax_ng(x, "model")

    def a2a_model(self, x: jax.Array, *, split_dim: int,
                  concat_dim: int) -> jax.Array:
        """MoE token dispatch over `model` (the textbook wide DMA burst)."""
        n = self.model
        if n == 1:
            return x
        self._log("all_to_all", ("model",), _nbytes(x) * (n - 1) // n, WIDE,
                  "MoE dispatch")
        return collectives.all_to_all(x, "model", split_dim=split_dim,
                                  concat_dim=concat_dim)

    # -- DP (data-axis) reductions (split-KV decode combine) ----------------
    def psum_data(self, x: jax.Array) -> jax.Array:
        if self.axis_size("data") == 1:
            return x
        self._log("psum", ("data",), _nbytes(x), NARROW, "split-KV combine")
        return _psum_rep(x, "data")

    def pmax_data(self, x: jax.Array) -> jax.Array:
        if self.axis_size("data") == 1:
            return x
        self._log("pmax", ("data",), _nbytes(x), NARROW, "split-KV stat")
        return _pmax_ng(x, "data")

    # -- FSDP parameter gathers ---------------------------------------------
    def param_ag(self, x: jax.Array, *, dim: int) -> jax.Array:
        """All-gather the FSDP-sharded dim over ``cfg.fsdp_axes``.

        The backward of this gather is the reduce-scatter that makes
        FSDP gradients arrive pre-reduced over the data axis (which is
        why 'data' never shows up in the optimizer's sync sets).
        """
        axes = [(a, self.axis_size(a)) for a in self.cfg.fsdp_axes
                if self.axis_size(a) > 1]
        total = 1
        for _, s in axes:
            total *= s
        if total == 1:
            return x
        names = tuple(a for a, _ in axes)
        self._log("all_gather", names, _nbytes(x) * (total - 1), WIDE,
                  f"FSDP param AG dim={dim}")
        if self.is_floo:
            return collectives.dim_ordered_all_gather(x, axes, dim=dim,
                                                  bidir=self.cfg.bidir_rings)
        return lax.all_gather(x, names, axis=dim, tiled=True)

    # -- narrow flit-packed scalar metrics ----------------------------------
    def psum_scalar_metrics(self, metrics: Mapping[str, Any]) -> dict:
        """One fused narrow psum for all scalar metrics across DP ranks.

        The flit-packed analogue of the paper's single-flit smalls: N
        scalars ride ONE latency-optimal psum per dtype instead of N.
        """
        axes = tuple(a for a in self.cfg.dp_axes_eff if self.axis_size(a) > 1)
        metrics = dict(metrics)
        if not axes:
            return metrics
        payload, header = flit.pack(metrics)
        reduced = {k: _psum_rep(v, axes) for k, v in payload.items()}
        for v in payload.values():
            self._log("psum", axes, _nbytes(v), NARROW,
                      f"flit-packed metrics x{len(metrics)}")
        return flit.unpack(reduced, header)

    # -- gradient sync entry (used by the optimizer) ------------------------
    def grad_policy(self) -> channels.ChannelPolicy:
        """The collective policy gradient sync rides (paper dual-channel)."""
        return channels.dual_policy(self.cfg.wide_flit_bytes)
