"""Distributed substrate: mesh-facing backend, parameter specs, step
builders, the pipeline schedule, and gradient compression.

This package is the seam between the model/optimizer code (which runs
INSIDE ``jax.shard_map`` on local shards) and the FlooNoC collective
layer (``repro.core``): every cross-device byte a training or serving
step moves goes through :class:`repro.dist.backend.Backend`, which
classifies it narrow/wide and logs it to the collective ledger — the
same channel vocabulary the cycle-accurate ``repro.noc`` simulator
speaks.
"""
from . import backend, compression, params, pipeline, step  # noqa: F401
from .backend import Backend  # noqa: F401
from .params import ParamSpec, is_spec, materialize_sharded, tree_sds  # noqa: F401
