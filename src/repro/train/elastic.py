"""Elastic scaling: re-derive the mesh for a changed world size and restore
checkpoints across the re-shard.

Policy: keep the `model` axis fixed (TP degree is an arch property), scale
`data` (and `pod`) with the fleet. A host failure therefore shrinks `data`
by one row (16 chips) at the next restart boundary; the checkpoint restore
path (train/checkpoint.py) reassembles any target sharding from the shard
files, so no reshard tool is needed.
"""
from __future__ import annotations

from ..configs.base import MeshConfig


def choose_mesh(num_devices: int, *, model: int = 16,
                pod_size: int = 256) -> MeshConfig:
    """Factor a (possibly shrunk) device count into (pod, data, model)."""
    assert num_devices % model == 0, (num_devices, model)
    rows = num_devices // model                   # data rows across pods
    if num_devices > pod_size:
        pods = max(1, num_devices // pod_size)
        data = rows // pods
        return MeshConfig(data=data, model=model, pod=pods)
    return MeshConfig(data=rows, model=model, pod=1)


def degraded_meshes(start: MeshConfig, failures: int) -> list[MeshConfig]:
    """Mesh sequence as rows of chips are quarantined one at a time."""
    out = []
    n = start.num_devices
    for k in range(failures + 1):
        remaining = n - k * start.model
        if remaining < start.model:
            break
        out.append(choose_mesh(remaining, model=start.model))
    return out
