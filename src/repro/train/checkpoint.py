"""Sharded, atomic, async checkpointing with elastic restore.

Layout::

    <dir>/step_000100.tmp/...      (written, fsynced)
    <dir>/step_000100/             (atomic rename marks completion)
        MANIFEST.json              tree structure, shapes, dtypes, pspecs,
                                   mesh, step, RunConfig digest
        <leaf-id>.shard<k>.npy     one file per (leaf, addressable shard)

Each host writes only its addressable shards (single-host here, but the
format is multi-host: shard files carry their global index ranges in the
manifest, so restore can reassemble ANY target sharding — including a
different mesh/world size (elastic restart) — by slicing the union of
shard files. Writes happen on a background thread (async checkpointing);
``wait()`` joins before the next save.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _leaf_id(path_str: str) -> str:
    return hashlib.md5(path_str.encode()).hexdigest()[:16]


def _pspec_to_json(ps: P) -> list:
    out = []
    for e in ps:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def _pspec_from_json(j) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e for e in j])


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, pspecs: Any, *, extra: dict | None = None,
             block: bool = False) -> None:
        """Async sharded save of a pytree of jax.Arrays."""
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        spec_leaves = jax.tree.flatten(
            jax.tree.map(lambda x: x, pspecs,
                         is_leaf=lambda x: isinstance(x, P)))[0]
        # snapshot to host (off the device) before threading
        host_shards = []
        for (path, arr), ps in zip(leaves, spec_leaves):
            pstr = jax.tree_util.keystr(path)
            shards = []
            for k, sh in enumerate(arr.addressable_shards):
                shards.append((k, sh.index, np.asarray(sh.data)))
            host_shards.append((pstr, arr.shape, str(arr.dtype),
                                _pspec_to_json(ps), shards))

        def _write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest: dict[str, Any] = {
                "step": step, "time": time.time(), "extra": extra or {},
                "leaves": []}
            for pstr, shape, dtype, ps_json, shards in host_shards:
                lid = _leaf_id(pstr)
                files = []
                for k, index, data in shards:
                    fn = f"{lid}.shard{k}.npy"
                    np.save(tmp / fn, data)
                    files.append({
                        "file": fn,
                        "index": [[s.start or 0,
                                   s.stop if s.stop is not None else dim]
                                  for s, dim in zip(index, shape)],
                    })
                manifest["leaves"].append({
                    "path": pstr, "id": lid, "shape": list(shape),
                    "dtype": dtype, "pspec": ps_json, "files": files})
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            os.replace(tmp, final)      # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp":
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, tree_like: Any, mesh,
                pspecs: Any | None = None) -> Any:
        """Restore into the CURRENT mesh/pspecs (elastic re-shard).

        tree_like: pytree of ShapeDtypeStructs or arrays defining the target
        structure. pspecs: target PartitionSpecs (defaults to saved ones).
        """
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        by_path = {l["path"]: l for l in manifest["leaves"]}

        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        spec_leaves = (jax.tree.flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P))[0]
            if pspecs is not None else None)

        out = []
        for i, (path, like) in enumerate(leaves):
            pstr = jax.tree_util.keystr(path)
            entry = by_path[pstr]
            shape = tuple(entry["shape"])
            assert shape == tuple(like.shape), (pstr, shape, like.shape)
            # assemble global array from shard files (streaming per-slice
            # assembly at true scale; full assembly is fine single-host)
            full = np.zeros(shape, dtype=entry["dtype"])
            for f in entry["files"]:
                idx = tuple(slice(a, b) for a, b in f["index"])
                full[idx] = np.load(d / f["file"])
            ps = (spec_leaves[i] if spec_leaves is not None
                  else _pspec_from_json(entry["pspec"]))
            out.append(jax.device_put(full, NamedSharding(mesh, ps)))
        return jax.tree.unflatten(treedef, out)
