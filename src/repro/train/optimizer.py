"""Sharded AdamW with optional 8-bit block-quantized states.

Runs INSIDE shard_map on local parameter shards. Correctness rule for
gradient synchronisation (DESIGN.md §4): a parameter's gradient must be
all-reduced over every mesh axis that does **not** appear in its
PartitionSpec (replicated axes see different local contributions).
FSDP-sharded dims already reduced inside the backward pass (transpose of
the parameter all-gather), which is why 'data' never shows up in the sync
set for FSDP leaves.

8-bit states (``opt_state_bits=8``): m and v are stored int8 with per-block
fp32 scales along the last axis; the block size is chosen per-leaf so it
divides the *local* last-dim extent (so quantization blocks never straddle
shard boundaries). This is what lets grok-1-314b's optimizer fit one pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import MeshConfig, RunConfig
from ..dist.backend import Backend
from ..dist.params import ParamSpec, is_spec

_B1, _B2, _EPS = 0.9, 0.95, 1e-8
_INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# grad sync axes
# ---------------------------------------------------------------------------
def pspec_axes(pspec: P) -> set[str]:
    out: set[str] = set()
    for entry in pspec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.update(entry)
        else:
            out.add(entry)
    return out


def sync_axes_for(pspec: P, mesh: MeshConfig) -> tuple[str, ...]:
    used = pspec_axes(pspec)
    return tuple(a for a in mesh.axis_names if a not in used)


def sync_grads(grads: Any, pspecs: Any, bk: Backend) -> Any:
    """Group leaves by sync-axes set; policy-driven multi-channel
    all-reduce per group (paper's narrow/wide separation)."""
    from ..core import channels
    g_leaves, treedef = jax.tree.flatten(grads)
    s_leaves = treedef.flatten_up_to(pspecs)
    groups: dict[tuple[str, ...], list[int]] = {}
    for i, ps in enumerate(s_leaves):
        axes = sync_axes_for(ps, bk.mesh_cfg)
        if axes:
            groups.setdefault(axes, []).append(i)
    out = list(g_leaves)
    for axes, idxs in sorted(groups.items()):
        sizes = [(a, bk.axis_size(a)) for a in axes]
        if all(s == 1 for _, s in sizes):
            continue
        sub = [g_leaves[i] for i in idxs]
        if bk.cfg.grad_compression == "int8-pod" and axes == ("pod",):
            from ..dist import compression
            red = compression.compressed_all_reduce_tree(
                sub, sizes, ledger=bk.ledger,
                wide_flit_bytes=bk.cfg.wide_flit_bytes)
        elif bk.is_floo:
            red = channels.multi_channel_all_reduce(
                sub, sizes, policy=bk.grad_policy(),
                bidir=bk.cfg.bidir_rings, ledger=bk.ledger)
        else:
            names = tuple(a for a, _ in sizes)
            red = [jax.lax.psum(g, names) for g in sub]
            for g in sub:
                bk.ledger.log("psum", names,
                              int(np.prod(g.shape)) * g.dtype.itemsize,
                              channels.WIDE, "xla grad AR")
        for j, i in enumerate(idxs):
            out[i] = red[j]
    return jax.tree.unflatten(treedef, out)


def global_grad_norm(grads: Any, pspecs: Any, bk: Backend) -> jax.Array:
    """Global L2 norm of the (synced) gradient across all shards."""
    g_leaves, treedef = jax.tree.flatten(grads)
    s_leaves = treedef.flatten_up_to(pspecs)
    total = 0.0
    for g, ps in zip(g_leaves, s_leaves):
        repl = 1
        for a in sync_axes_for(ps, bk.mesh_cfg):
            repl *= bk.axis_size(a)
        total = total + jnp.sum(jnp.square(g.astype(jnp.float32))) / repl
    axes = bk.mesh_cfg.axis_names
    return jnp.sqrt(jax.lax.psum(total, axes))


# ---------------------------------------------------------------------------
# 8-bit block quantization along the last axis (shard-aligned blocks)
# ---------------------------------------------------------------------------
def _block_for(global_last: int, shards: int) -> int:
    local = max(1, global_last // max(shards, 1))
    for b in (256, 128, 64, 32, 16, 8, 4, 2):
        if local % b == 0:
            return b
    return 1


def _last_axis_shards(pspec: P, shape: tuple[int, ...], mesh: MeshConfig) -> int:
    if len(pspec) < len(shape):
        return 1
    entry = pspec[len(shape) - 1]
    if entry is None:
        return 1
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for a in names:
        n *= dict(zip(mesh.axis_names, mesh.shape))[a]
    return n


def q8_zero(shape: tuple[int, ...], block: int):
    scale_shape = shape[:-1] + (shape[-1] // block,)
    return (jnp.zeros(shape, jnp.int8), jnp.zeros(scale_shape, jnp.float32))


def q8_encode(x: jax.Array, block: int):
    *lead, last = x.shape
    xb = x.reshape(*lead, last // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / _INT8_MAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def q8_decode(q: jax.Array, scale: jax.Array, block: int):
    *lead, last = q.shape
    xb = q.reshape(*lead, last // block, block).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(q.shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = _B1
    b2: float = _B2
    eps: float = _EPS
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = (jnp.minimum((step + 1.0) / cfg.warmup, 1.0)
            if cfg.warmup > 0 else 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def opt_state_specs(param_tree: Any, run_cfg: RunConfig) -> Any:
    """ParamSpec tree for (m, v [, scales]) mirroring the param sharding."""
    mesh = run_cfg.mesh

    def per_leaf(spec: ParamSpec):
        if run_cfg.opt_state_bits == 8:
            shards = _last_axis_shards(spec.pspec, spec.shape, mesh)
            block = _block_for(spec.shape[-1], shards)
            scale_shape = spec.shape[:-1] + (spec.shape[-1] // block,)
            scale_pspec = spec.pspec
            return {
                "m_q": ParamSpec(spec.shape, jnp.int8, spec.pspec, init="zeros"),
                "m_s": ParamSpec(scale_shape, jnp.float32, scale_pspec, init="zeros"),
                "v_q": ParamSpec(spec.shape, jnp.int8, spec.pspec, init="zeros"),
                "v_s": ParamSpec(scale_shape, jnp.float32, scale_pspec, init="zeros"),
            }
        return {
            "m": ParamSpec(spec.shape, jnp.float32, spec.pspec, init="zeros"),
            "v": ParamSpec(spec.shape, jnp.float32, spec.pspec, init="zeros"),
        }

    return jax.tree.map(per_leaf, param_tree, is_leaf=is_spec)


def adamw_update(params: Any, grads: Any, opt_state: Any, step: jax.Array,
                 run_cfg: RunConfig, acfg: AdamWConfig, pspecs: Any,
                 bk: Backend):
    """One AdamW step on local shards. Returns (params, opt_state, stats)."""
    grads = sync_grads(grads, pspecs, bk)
    gnorm = global_grad_norm(grads, pspecs, bk)
    clip = jnp.minimum(1.0, acfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(acfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - acfg.b1 ** t
    bc2 = 1.0 - acfg.b2 ** t

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    s_leaves = treedef.flatten_up_to(opt_state)

    new_p, new_s = [], []
    for p, g, s in zip(p_leaves, g_leaves, s_leaves):
        g = g.astype(jnp.float32) * clip
        if run_cfg.opt_state_bits == 8:
            block = p.shape[-1] // s["m_s"].shape[-1]
            m = q8_decode(s["m_q"], s["m_s"], block)
            v = q8_decode(s["v_q"], s["v_s"], block)
        else:
            m, v = s["m"], s["v"]
        m = acfg.b1 * m + (1 - acfg.b1) * g
        v = acfg.b2 * v + (1 - acfg.b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + acfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim > 1:
            upd = upd + acfg.weight_decay * p32
        p32 = p32 - lr * upd
        new_p.append(p32.astype(p.dtype))
        if run_cfg.opt_state_bits == 8:
            block = p.shape[-1] // s["m_s"].shape[-1]
            mq, ms = q8_encode(m, block)
            vq, vs = q8_encode(v, block)
            new_s.append({"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs})
        else:
            new_s.append({"m": m, "v": v})

    stats = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(treedef, new_p),
            jax.tree.unflatten(treedef, new_s), stats)
