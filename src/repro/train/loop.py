"""Training loop: microbatching, checkpoints, straggler watchdog, resume."""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig
from ..dist import params as params_lib, step as step_lib
from ..launch.mesh import make_mesh_from_config
from ..models import build_model
from . import optimizer as opt_mod
from .checkpoint import CheckpointManager
from .data import Prefetcher, SyntheticLM
from .straggler import StepTimer


@dataclass
class TrainResult:
    steps: int
    final_loss: float
    losses: list
    step_times: list
    resumed_from: int | None = None


def train(cfg: RunConfig, *, num_steps: int, ckpt_dir: str | Path | None = None,
          ckpt_every: int = 0, data: Iterator | None = None,
          log_every: int = 10, resume: bool = True,
          on_step: Callable[[int, dict], None] | None = None) -> TrainResult:
    mesh = make_mesh_from_config(cfg.mesh)
    model = build_model(cfg.model, cfg)
    acfg = opt_mod.AdamWConfig(lr=cfg.learning_rate,
                               weight_decay=cfg.weight_decay,
                               total_steps=max(num_steps, 100))
    art = step_lib.build_train_step(model, cfg.shape, mesh, acfg)
    p_pspecs = params_lib.tree_pspecs(art.param_specs)
    o_pspecs = params_lib.tree_pspecs(art.opt_specs)

    key = jax.random.key(cfg.seed)
    params = params_lib.materialize_sharded(art.param_specs, key, mesh)
    opt_state = params_lib.materialize_sharded(art.opt_specs, key, mesh)

    start_step = 0
    mgr = None
    if ckpt_dir is not None:
        mgr = CheckpointManager(ckpt_dir)
        last = mgr.latest()
        if resume and last is not None:
            restored = mgr.restore(
                last,
                {"params": params_lib.tree_sds(art.param_specs),
                 "opt": params_lib.tree_sds(art.opt_specs)},
                mesh, {"params": p_pspecs, "opt": o_pspecs})
            params, opt_state = restored["params"], restored["opt"]
            start_step = last

    if data is None:
        data = iter(SyntheticLM(model.mcfg.vocab_size, cfg.shape.seq_len,
                                cfg.shape.global_batch, seed=cfg.seed))
    data = Prefetcher(data, depth=2)

    timer = StepTimer()
    losses, times = [], []
    step = start_step
    for step in range(start_step, num_steps):
        batch = next(data)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        timer.start()
        params, opt_state, metrics = art.fn(params, opt_state,
                                            jnp.int32(step), batch)
        loss = float(metrics["loss"])
        dt = timer.stop()
        losses.append(loss)
        times.append(dt)
        if timer.flagged:
            # mitigation hook: at single-host scale we bump prefetch depth;
            # multi-host deployments call elastic.quarantine here
            pass
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.0f}ms", flush=True)
        if on_step is not None:
            on_step(step, metrics)
        if mgr is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     {"params": p_pspecs, "opt": o_pspecs})
    if mgr is not None:
        mgr.wait()
    data.close()
    return TrainResult(steps=step + 1 - start_step,
                       final_loss=losses[-1] if losses else float("nan"),
                       losses=losses, step_times=times,
                       resumed_from=start_step or None)
