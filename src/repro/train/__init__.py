from . import checkpoint, data, elastic, optimizer, straggler  # noqa: F401
