"""Data pipeline: synthetic LM stream + memmapped packed-token datasets,
host-sharded, with background prefetch.
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token data (Zipf-ish marginals).

    Same (seed, step, host) always yields the same batch — restarts resume
    bit-identically without data-state checkpoints.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch // num_hosts
        self.seed = seed
        self.host = host_id

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        u = rng.random((self.batch, self.seq + 1))
        toks = np.minimum((u ** 3) * self.vocab, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedBinDataset:
    """Memmapped flat token file (uint16/uint32) with host-sharded windows."""

    def __init__(self, path: str | Path, seq_len: int, global_batch: int,
                 *, dtype=np.uint16, seed: int = 0, host_id: int = 0,
                 num_hosts: int = 1):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.batch = global_batch // num_hosts
        self.n_windows = (len(self.tokens) - 1) // seq_len
        self.seed = seed
        self.host = host_id
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        idx = rng.integers(0, self.n_windows, size=self.batch)
        starts = idx * self.seq
        toks = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch queue (the NI's outstanding-transaction
    idea applied to the input pipeline: keep `depth` batches in flight)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=run, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
