"""Straggler detection + mitigation.

In synchronous SPMD training every step runs at the pace of the slowest
host. This module provides:

* :class:`StepTimer` — per-step wall-time EMA + z-score detection of a
  degrading host (in multi-host deployments each host reports its
  pre-barrier compute time; here the single process stands in),
* mitigation policies, applied by the training loop:
    - ``prefetch``   : bump input-pipeline prefetch depth (hides data jitter)
    - ``rebalance``  : shift one microbatch from the slow host to the
                       fastest (needs microbatches > 1)
    - ``quarantine`` : mark the host for removal; the elastic layer shrinks
                       the mesh at the next checkpoint boundary
* :class:`SimulatedCluster` — a closed-form harness quantifying each
  policy's effect on p50/p99 step time for a 1000+-host fleet
  (benchmarks/straggler_sim.py reports the table).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass
class StepTimer:
    alpha: float = 0.05
    z_threshold: float = 3.0
    warmup: int = 20
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    _t0: float = 0.0
    flagged: bool = False

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else \
                (self.mean * (self.n - 1) + dt) / self.n
            self.var = max(self.var, (dt - self.mean) ** 2)
            return dt
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        z = d / max(np.sqrt(self.var), 1e-9)
        self.flagged = z > self.z_threshold
        return dt


@dataclass
class SimulatedCluster:
    """Order statistics of synchronous step time under stragglers.

    Host step time ~ lognormal(mu, sigma); a fraction `slow_frac` of hosts
    runs `slow_x` times slower. Synchronous step time = max over hosts.
    """
    n_hosts: int = 1024
    sigma: float = 0.05
    slow_frac: float = 0.001
    slow_x: float = 3.0
    microbatches: int = 4
    seed: int = 0

    def step_times(self, policy: str = "none", steps: int = 2000) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        base = rng.lognormal(0.0, self.sigma, size=(steps, self.n_hosts))
        slow = rng.random((steps, self.n_hosts)) < self.slow_frac
        mult = np.where(slow, self.slow_x, 1.0)
        if policy == "none":
            host_t = base * mult
        elif policy == "rebalance":
            # slow host sheds 1 of k microbatches to the fastest host:
            # slow: (k-1)/k of its work; fastest: (k+1)/k
            k = self.microbatches
            host_t = base * mult
            worst = host_t.max(axis=1)
            shed = np.where(slow.any(axis=1),
                            worst * (k - 1) / k, worst)
            others = np.where(slow, 0, base).max(axis=1) * (k + 1) / k
            host_t = host_t.copy()
            host_t[np.arange(steps), host_t.argmax(1)] = shed
            host_t = np.maximum(host_t.max(1), others)
            return host_t
        elif policy == "quarantine":
            # slow host removed after `detect_steps`; amortized: its work
            # redistributes (n/(n-1) scaling) and tail disappears
            host_t = base.copy()
            host_t = host_t.max(axis=1) * (self.n_hosts / (self.n_hosts - 1))
            return host_t
        else:
            raise ValueError(policy)
        return host_t.max(axis=1)

    def report(self, steps: int = 2000) -> dict[str, dict[str, float]]:
        out = {}
        for pol in ("none", "rebalance", "quarantine"):
            t = self.step_times(pol, steps)
            out[pol] = {"p50": float(np.percentile(t, 50)),
                        "p99": float(np.percentile(t, 99)),
                        "mean": float(t.mean())}
        return out
