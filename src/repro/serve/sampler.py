"""Token samplers over vocab-sharded logits.

Greedy sampling is fully distributed (local arg-max + narrow-channel
combine encodes (value, index) so no full-vocab gather ever happens);
temperature/top-k gather the (small) per-rank top-k candidates only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_local(logits, v_offset):
    """logits (B, 1, V_loc) -> (val (B,), idx_global (B,)) local candidates."""
    val = jnp.max(logits[:, 0, :], axis=-1)
    idx = jnp.argmax(logits[:, 0, :], axis=-1) + v_offset
    return val.astype(jnp.float32), idx.astype(jnp.int32)


def combine_greedy(val, idx, pmax, psum):
    """Exact distributed argmax via value pmax + masked index psum."""
    best = pmax(val)
    mine = (val >= best)
    # ties: lowest global index wins (psum of min-encoded)
    cand = jnp.where(mine, idx, jnp.int32(2 ** 30))
    chosen = -pmax(-cand)
    return chosen


def sample_temperature(logits_full, key, *, temperature=1.0, top_k=0):
    x = logits_full.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 0:
        v, _ = jax.lax.top_k(x, top_k)
        x = jnp.where(x < v[..., -1:], -1e30, x)
    return jax.random.categorical(key, x, axis=-1)
