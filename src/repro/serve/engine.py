"""Batched serving engine: prefill + decode with KV caches.

A deliberately small but real engine: fixed-size decode batch, prompt
prefill (full-batch), greedy/temperature decoding, EOS handling. The
prefill and decode steps are the same shard_map'd programs the dry-run
lowers (dist/step.py), so served numbers reflect the production sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import RunConfig, ShapeConfig
from ..dist import params as params_lib, step as step_lib
from ..launch.mesh import make_mesh_from_config
from ..models import build_model
from . import kv_cache, sampler


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new)
    prompt_len: int
    steps: int


class Engine:
    def __init__(self, cfg: RunConfig, params=None, *, max_len: int = 512):
        self.cfg = cfg
        self.mesh = make_mesh_from_config(cfg.mesh)
        self.model = build_model(cfg.model, cfg)
        self.max_len = max_len
        self.params = params

    def init_params(self, seed: int = 0):
        specs = self.model.param_specs()
        self.params = params_lib.materialize_sharded(
            specs, jax.random.key(seed), self.mesh)
        return self.params

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 32,
                 eos_id: int = -1, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 extra_inputs: dict | None = None) -> GenerationResult:
        """prompts: (B, S_prompt) int32, already padded to equal length."""
        assert self.params is not None, "call init_params() or pass params"
        B, S = prompts.shape
        pshape = ShapeConfig("serve_prefill", S, B, "prefill")
        dshape = ShapeConfig("serve_decode", self.max_len, B, "decode")
        pre = step_lib.build_prefill_step(self.model, pshape, self.mesh)
        dec = step_lib.build_decode_step(self.model, dshape, self.mesh,
                                         split_kv=False)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, caches = pre.fn(self.params, batch)
        caches = kv_cache.promote(caches, self.max_len)

        out_tokens = np.zeros((B, max_new_tokens), np.int32)
        key = jax.random.key(seed)
        done = np.zeros((B,), bool)

        def pick(logits, key):
            if greedy:
                # logits here are vocab-sharded only outside shard_map via
                # jit output: gather is (B, V) once per step at engine level
                full = jax.device_get(logits[:, 0, :])
                return np.argmax(full, axis=-1).astype(np.int32)
            full = jnp.asarray(logits[:, 0, :])
            return np.asarray(sampler.sample_temperature(
                full, key, temperature=temperature)).astype(np.int32)

        tok = pick(logits, key)
        steps = 0
        for t in range(max_new_tokens):
            out_tokens[:, t] = np.where(done, eos_id if eos_id >= 0 else 0, tok)
            done |= (tok == eos_id)
            if done.all():
                steps = t + 1
                break
            key, sub = jax.random.split(key)
            logits, caches = dec.fn(self.params, caches,
                                    jnp.asarray(tok[:, None]),
                                    jnp.int32(S + t))
            tok = pick(logits, sub)
            steps = t + 1
        return GenerationResult(tokens=out_tokens, prompt_len=S, steps=steps)
