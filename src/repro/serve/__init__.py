from .engine import Engine, GenerationResult  # noqa: F401
