"""KV-cache management for the serving engine.

Caches are the model's per-segment trees (transformer.cache_specs).
This module provides allocation from specs, prefill->decode promotion
(padding the prefill-length cache into the decode-capacity buffer), and
simple occupancy accounting.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs.base import ShapeConfig


def allocate(model, shape: ShapeConfig, mesh, *, split_kv: bool = False):
    """Zero-filled decode cache with the model's sharding."""
    sds, specs = model.cache_specs(shape, split_kv=split_kv)

    def mk(s, p):
        return jax.device_put(jnp.zeros(s.shape, s.dtype),
                              NamedSharding(mesh, p))

    return jax.tree.map(mk, sds, specs)


def promote(prefill_caches: Any, decode_capacity: int) -> Any:
    """Pad prefill caches (seq dim = prompt length) to decode capacity.

    Attention caches are (count, B, S, n_kv, hd): pad dim 2; cross-attn and
    SSM caches pass through unchanged.
    """
    def pad_seg(seg: dict) -> dict:
        out = {}
        for k, v in seg.items():
            if k == "attn":
                out[k] = tuple(
                    jnp.pad(a, ((0, 0), (0, 0),
                                (0, decode_capacity - a.shape[2]),
                                (0, 0), (0, 0)))
                    for a in v)
            else:
                out[k] = v
        return out

    return {name: pad_seg(seg) for name, seg in prefill_caches.items()}
