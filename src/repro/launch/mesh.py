"""Production mesh construction.

A function (NOT a module-level constant) so importing never touches jax
device state. Axis semantics: `pod` = slow inter-pod links (DP or PP),
`data` = intra-pod DP + FSDP/ZeRO sharding, `model` = TP/SP/EP.
"""
from __future__ import annotations

import jax

from ..configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pod=2 if multi_pod else 1)


def make_mesh_from_config(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(cfg.shape))
