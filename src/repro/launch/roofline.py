"""Roofline analysis from compiled HLO (post-optimization, trip-count aware).

``compiled.cost_analysis()`` on the CPU backend does NOT scale loop bodies by
their trip counts (verified: a scan of L matmuls reports 1/L of the analytic
FLOPs), so we parse ``compiled.as_text()`` ourselves:

  * computations are mapped to multipliers: a ``while`` op's
    ``backend_config.known_trip_count`` multiplies its body (nested whiles
    compose),
  * collective bytes: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute x multiplier. For the
    floo backend (rings lowered to collective-permutes) this is exactly the
    per-device link traffic,
  * dot FLOPs: 2 x prod(result dims) x prod(contracting dims), resolved
    through a per-computation symbol table,
  * HBM-traffic proxy: operand+result bytes of fusion/dot/collective ops
    (inputs/outputs of fused regions ~ off-chip traffic once buffers exceed
    on-chip capacity — an upper bound; on TPU, VMEM reuse lowers it).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*"
                    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _group_size(rest: str) -> int:
    m = _GROUPS_V2_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 2


def _link_bytes(kind: str, operand_bytes: float, rest: str) -> float:
    """Per-device ICI link traffic for one collective op.

    collective-permute: operand bytes are exactly what the device sends.
    Fused ops: ring-algorithm equivalents —
      all-gather      operand is the local shard -> (g-1) x shard
      reduce-scatter  operand is the full partial -> (g-1)/g x operand
      all-reduce      RS + AG -> 2 (g-1)/g x operand
      all-to-all      (g-1)/g of the buffer leaves the device
    """
    if kind == "collective-permute":
        return operand_bytes
    g = max(_group_size(rest), 2)
    if kind == "all-gather":
        return operand_bytes * (g - 1)
    if kind == "reduce-scatter":
        return operand_bytes * (g - 1) / g
    if kind == "all-reduce":
        return operand_bytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return operand_bytes * (g - 1) / g
    return operand_bytes


def _type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str
    operands: list[str]


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_count: dict = field(default_factory=lambda: defaultdict(int))
    memory_bytes: float = 0.0
    while_trips: dict = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def parse_computations(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = comps.setdefault(m.group(1), [])
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        # operand names: up to attrs — take up to first "),"-ish boundary
        paren = rest.split(")", 1)[0]
        operands = _OPERANDS_RE.findall(paren)
        cur.append(Op(name, kind, rtype, rest, operands))
    return comps


def _dot_flops(op: Op, symtab: dict[str, str]) -> float:
    rd = _shape_dims(op.result_type)
    if rd is None:
        return 0.0
    result_elems = 1
    for d in rd[0]:
        result_elems *= d
    # contracting dims from lhs
    mC = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not mC or not op.operands:
        return 2.0 * result_elems  # fallback
    lhs_type = symtab.get(op.operands[0], "")
    ld = _shape_dims(lhs_type)
    if ld is None:
        return 2.0 * result_elems
    k = 1
    for idx in (int(i) for i in mC.group(1).split(",") if i):
        if idx < len(ld[0]):
            k *= ld[0][idx]
    return 2.0 * result_elems * k


def _custom_call_flops(op: Op, symtab: dict[str, str]) -> float:
    if "matmul" not in op.rest and "dot" not in op.rest.lower():
        return 0.0
    rd = _shape_dims(op.result_type)
    if rd is None or not op.operands:
        return 0.0
    result_elems = 1
    for d in rd[0]:
        result_elems *= d
    lhs = _shape_dims(symtab.get(op.operands[0], ""))
    k = lhs[0][-1] if lhs and lhs[0] else 1
    return 2.0 * result_elems * k


def analyze_hlo_text(text: str) -> HloCosts:
    comps = parse_computations(text)

    # build call graph with trip multipliers
    # find entry: computation not referenced by others
    referenced = set()
    calls: dict[str, list[tuple[str, float]]] = defaultdict(list)
    trip_of_body: dict[str, int] = {}
    for cname, ops in comps.items():
        for op in ops:
            for callee in _CALLS_RE.findall(op.rest):
                if callee in comps:
                    referenced.add(callee)
                    mult = 1.0
                    if op.kind == "while":
                        mt = _TRIP_RE.search(op.rest)
                        bm = _BODY_RE.search(op.rest)
                        trips = int(mt.group(1)) if mt else 1
                        if bm and callee == bm.group(1):
                            mult = float(trips)
                            trip_of_body[callee] = trips
                    calls[cname].append((callee, mult))
    entries = [c for c in comps if c not in referenced]

    # propagate multipliers (DAG; cycles impossible in HLO)
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] = max(mult[e], 1.0)
    order = list(comps.keys())
    # simple fixed-point (few computations)
    for _ in range(len(comps)):
        changed = False
        for cname in order:
            if mult[cname] <= 0:
                continue
            for callee, m in calls[cname]:
                nm = mult[cname] * m
                if nm > mult[callee]:
                    mult[callee] = nm
                    changed = True
        if not changed:
            break

    costs = HloCosts(while_trips=trip_of_body)
    for cname, ops in comps.items():
        f = mult[cname] if mult[cname] > 0 else 0.0
        if f <= 0:
            continue
        symtab = {op.name: op.result_type for op in ops}
        for op in ops:
            if op.kind in COLLECTIVES:
                in_bytes = sum(_type_bytes(symtab.get(o, ""))
                               for o in op.operands if o in symtab)
                if in_bytes == 0:
                    in_bytes = _type_bytes(op.result_type)
                costs.collective_bytes[op.kind] += \
                    f * _link_bytes(op.kind, in_bytes, op.rest)
                costs.collective_count[op.kind] += int(f)
                costs.memory_bytes += f * (in_bytes + _type_bytes(op.result_type))
            elif op.kind in ("dot", "dot-general"):
                fl = _dot_flops(op, symtab)
                costs.dot_flops += f * fl
                opb = sum(_type_bytes(symtab.get(o, "")) for o in op.operands
                          if o in symtab)
                costs.memory_bytes += f * (opb + _type_bytes(op.result_type))
            elif op.kind == "custom-call":
                fl = _custom_call_flops(op, symtab)
                costs.dot_flops += f * fl
                if fl:
                    opb = sum(_type_bytes(symtab.get(o, ""))
                              for o in op.operands if o in symtab)
                    costs.memory_bytes += f * (opb + _type_bytes(op.result_type))
            elif op.kind == "fusion":
                opb = sum(_type_bytes(symtab.get(o, "")) for o in op.operands
                          if o in symtab)
                costs.memory_bytes += f * (opb + _type_bytes(op.result_type))
    return costs


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_kind: dict
    model_flops_per_chip: float
    useful_ratio: float
    bottleneck: str

    def to_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "model_flops_per_chip": self.model_flops_per_chip,
            "useful_ratio": self.useful_ratio,
            "bottleneck": self.bottleneck,
        }


def roofline_from_costs(costs: HloCosts, model_flops_per_chip: float,
                        analytic_bytes_per_chip: float | None = None,
                        link_parallelism: float = 1.0) -> Roofline:
    """All quantities per chip (the HLO is the per-device SPMD program).

    link_parallelism: concurrent ICI links carrying the schedule — 2 for
    bidirectional rings (the paper's duplex channels: each direction is a
    separate physical link).
    """
    compute_s = costs.dot_flops / PEAK_FLOPS
    mem_bytes = analytic_bytes_per_chip if analytic_bytes_per_chip \
        else costs.memory_bytes
    memory_s = mem_bytes / HBM_BW
    collective_s = costs.total_collective_bytes / (ICI_BW * link_parallelism)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=costs.dot_flops, hlo_bytes=mem_bytes,
        collective_bytes=costs.total_collective_bytes,
        collective_by_kind=dict(costs.collective_bytes),
        model_flops_per_chip=model_flops_per_chip,
        useful_ratio=(model_flops_per_chip / costs.dot_flops
                      if costs.dot_flops else 0.0),
        bottleneck=bottleneck,
    )
