"""Closed-form FLOP / byte models per (arch, shape, mode).

Primary roofline numbers come from the trip-count-aware HLO parse
(roofline.py); these analytic forms serve as (a) the MODEL_FLOPS
definition from the assignment (6·N·D dense / 6·N_active·D MoE for
training; 2·N·D for inference lowers), (b) an attention-aware cross-check,
and (c) the HBM-traffic model for the memory term (parameter + optimizer +
activation + KV traffic), which the CPU HLO cannot give faithfully for a
TPU memory hierarchy.
"""
from __future__ import annotations


from ..configs.base import ModelConfig, RunConfig, ShapeConfig


def model_flops_global(mcfg: ModelConfig, shape: ShapeConfig) -> float:
    """Assignment definition: 6·N·D train, 2·N·D inference (fwd only)."""
    n = mcfg.active_param_count()
    tokens = shape.tokens if shape.kind == "train" else (
        shape.tokens if shape.kind == "prefill" else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def attention_flops_global(mcfg: ModelConfig, shape: ShapeConfig) -> float:
    """Extra attention score/value FLOPs (not in 6·N·D)."""
    if mcfg.num_heads == 0:
        return 0.0
    H, hd, L = mcfg.num_heads, mcfg.head_dim, mcfg.num_layers
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        ctx = S
        fwd = 4.0 * H * hd * ctx * B * L          # one token vs full cache
        return fwd
    # causal average context S/2; sliding window caps it
    ctx = S / 2 if mcfg.sliding_window == 0 else min(mcfg.sliding_window, S / 2)
    fwd = 4.0 * H * hd * ctx * B * S * L
    return (3.0 if shape.kind == "train" else 1.0) * fwd


def hbm_bytes_per_chip(mcfg: ModelConfig, shape: ShapeConfig,
                       cfg: RunConfig) -> float:
    """Per-chip, per-step HBM traffic estimate (TPU target).

    train : 3 weight passes (fwd, remat-fwd, bwd) in bf16 + optimizer
            read/write in fp32 (m, v, master) + ~12 activation tensors of
            (tokens_loc x d) per layer read+written.
    prefill: 1 weight pass + activations.
    decode : 1 weight pass (the classic decode bottleneck) + KV cache read
             + small state.
    """
    chips = cfg.mesh.num_devices
    p_total = mcfg.param_count()
    p_bytes_bf16 = 2.0 * p_total / chips
    tokens_loc = shape.tokens / chips
    d = mcfg.d_model
    L = mcfg.num_layers + mcfg.num_encoder_layers

    if shape.kind == "train":
        w = 3.0 * p_bytes_bf16
        opt = (4.0 + 4.0) * 2.0 * (p_total / chips) if cfg.opt_state_bits == 32 \
            else (1.0 + 1.0) * 2.0 * (p_total / chips) + 8.0 * p_total / chips / 64
        master = 2.0 * 4.0 * p_total / chips
        acts = 12.0 * 2.0 * tokens_loc * d * L * 2.0   # read+write bf16
        return w + opt + master + acts
    if shape.kind == "prefill":
        return p_bytes_bf16 + 8.0 * 2.0 * tokens_loc * d * L
    # decode
    kv = 0.0
    if mcfg.num_heads:
        n_kv_stored = max(1, mcfg.num_kv_heads)
        ctx = shape.seq_len if mcfg.sliding_window == 0 else mcfg.sliding_window
        if mcfg.family == "hybrid":
            glob = len(mcfg.global_layers)
            kv_tok = (glob * shape.seq_len
                      + (mcfg.num_layers - glob) * min(mcfg.sliding_window,
                                                       shape.seq_len))
        else:
            kv_tok = mcfg.num_layers * ctx
        kv = 2.0 * n_kv_stored * mcfg.head_dim * kv_tok * 2.0 \
            * shape.global_batch / chips
    ssm = 0.0
    if mcfg.ssm_state:
        ssm = (mcfg.ssm_heads * mcfg.ssm_head_dim * mcfg.ssm_state * 4.0 * 2.0
               * mcfg.num_layers * shape.global_batch / chips)
    return p_bytes_bf16 + kv + ssm


def describe(mcfg: ModelConfig, shape: ShapeConfig, cfg: RunConfig) -> dict:
    chips = cfg.mesh.num_devices
    mf = model_flops_global(mcfg, shape)
    af = attention_flops_global(mcfg, shape)
    return {
        "model_flops_global": mf,
        "attention_flops_global": af,
        "model_flops_per_chip": mf / chips,
        "analytic_flops_per_chip": (mf + af) / chips,
        "hbm_bytes_per_chip": hbm_bytes_per_chip(mcfg, shape, cfg),
        "params_total": mcfg.param_count(),
        "params_active": mcfg.active_param_count(),
    }
