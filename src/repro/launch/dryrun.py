import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. builds the step for the shape's kind (train_step / prefill / decode),
  3. ``.lower()`` with sharded ShapeDtypeStructs (zero allocation),
  4. ``.compile()`` — proving the distribution config is coherent,
  5. records memory_analysis / cost_analysis / the trip-count-aware HLO
     parse / the collective ledger into experiments/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
  python -m repro.launch.dryrun --arch X --shape Y --multipod --backend xla
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402


from ..configs import ARCHS, SHAPES, applicable, get_arch, get_shape  # noqa: E402
from ..configs.base import RunConfig  # noqa: E402
from . import analytic, roofline  # noqa: E402
from .mesh import make_mesh_from_config, production_mesh_config  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def default_run_overrides(arch: str, shape_name: str) -> dict:
    """Per-arch config tweaks needed at production scale."""
    o: dict = {}
    if arch == "grok-1-314b":
        o["opt_state_bits"] = 8          # optimizer fits one pod (DESIGN §7)
        o["microbatches"] = 4
    if arch in ("llama4-scout-17b-a16e",):
        o["microbatches"] = 2
    return o


def cell_id(arch: str, shape: str, multi_pod: bool, backend: str,
            tag: str = "") -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh}__{backend}{suffix}"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             backend: str = "floo", overrides: dict | None = None,
             tag: str = "", verbose: bool = True) -> dict:
    from ..dist import step as step_lib
    from ..models import build_model

    mcfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, why = applicable(mcfg, shape)
    if not ok:
        return {"cell": cell_id(arch, shape_name, multi_pod, backend, tag),
                "status": "skip", "reason": why}

    mesh_cfg = production_mesh_config(multi_pod=multi_pod)
    kw = default_run_overrides(arch, shape_name)
    kw.update(overrides or {})
    cfg = RunConfig(model=mcfg, shape=shape, mesh=mesh_cfg, backend=backend,
                    **kw)
    mesh = make_mesh_from_config(mesh_cfg)
    model = build_model(mcfg, cfg)

    t0 = time.time()
    if shape.kind == "train":
        art = step_lib.build_train_step(model, shape, mesh)
    elif shape.kind == "prefill":
        art = step_lib.build_prefill_step(model, shape, mesh)
    else:
        art = step_lib.build_decode_step(model, shape, mesh)

    lowered = art.fn.lower(*art.in_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- analyses -----------------------------------------------------------
    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_d[k] = getattr(mem, k, None)
    try:
        ca = compiled.cost_analysis() or {}
        cost_d = {k: float(v) for k, v in ca.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed", "transcendentals")}
    except Exception:
        cost_d = {}

    t0 = time.time()
    hlo_text = compiled.as_text()
    costs = roofline.analyze_hlo_text(hlo_text)
    t_parse = time.time() - t0

    ana = analytic.describe(mcfg, shape, cfg)
    link_par = 2.0 if (cfg.bidir_rings and backend == "floo") else 1.0
    rl = roofline.roofline_from_costs(
        costs, ana["model_flops_per_chip"],
        analytic_bytes_per_chip=ana["hbm_bytes_per_chip"],
        link_parallelism=link_par)

    result = {
        "cell": cell_id(arch, shape_name, multi_pod, backend, tag),
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh_cfg.shape), "backend": backend,
        "overrides": kw,
        "timings_s": {"lower": t_lower, "compile": t_compile,
                      "hlo_parse": t_parse},
        "memory_analysis": mem_d,
        "cost_analysis_raw": cost_d,
        "hlo": {
            "dot_flops_per_chip": costs.dot_flops,
            "collective_bytes_by_kind": dict(costs.collective_bytes),
            "collective_counts": dict(costs.collective_count),
            "memory_bytes_proxy": costs.memory_bytes,
            "while_trip_counts": costs.while_trips,
            "hlo_chars": len(hlo_text),
        },
        "analytic": ana,
        "roofline": rl.to_dict(),
        "ledger": art.backend.ledger.summary(),
    }
    if verbose:
        bl = rl.bottleneck
        print(f"[{result['cell']}] OK compile={t_compile:.1f}s "
              f"temp={(mem_d['temp_size_in_bytes'] or 0)/2**30:.2f}GiB "
              f"compute={rl.compute_s*1e3:.2f}ms mem={rl.memory_s*1e3:.2f}ms "
              f"coll={rl.collective_s*1e3:.2f}ms bottleneck={bl} "
              f"useful={rl.useful_ratio:.2f}", flush=True)
    return result


def save(result: dict) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    p = OUT_DIR / f"{result['cell']}.json"
    p.write_text(json.dumps(result, indent=1, default=str))
    return p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--backend", default="floo", choices=["floo", "xla"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    cells = []
    if args.all:
        pods = [False, True]
        if args.singlepod_only:
            pods = [False]
        if args.multipod_only:
            pods = [True]
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in pods:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape, args.multipod))

    n_ok = n_skip = n_fail = n_cached = 0
    for arch, shape, mp in cells:
        cid = cell_id(arch, shape, mp, args.backend, args.tag)
        out = OUT_DIR / f"{cid}.json"
        if out.exists() and not args.force:
            prev = json.loads(out.read_text())
            if prev.get("status") in ("ok", "skip"):
                n_cached += 1
                continue
        try:
            res = run_cell(arch, shape, multi_pod=mp, backend=args.backend,
                           overrides=overrides, tag=args.tag)
            save(res)
            if res["status"] == "ok":
                n_ok += 1
            else:
                n_skip += 1
                print(f"[{cid}] SKIP: {res['reason']}", flush=True)
        except Exception as e:
            n_fail += 1
            save({"cell": cid, "status": "fail", "arch": arch,
                  "shape": shape, "error": str(e)[:2000],
                  "traceback": traceback.format_exc()[-4000:]})
            print(f"[{cid}] FAIL: {str(e)[:300]}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail} cached={n_cached}",
          flush=True)


if __name__ == "__main__":
    main()
