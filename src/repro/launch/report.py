"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json

from .dryrun import OUT_DIR


def load_cells(backend: str = "floo", tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(OUT_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("backend", "floo") != backend and d.get("status") == "ok":
            continue
        if tag and not p.stem.endswith(f"__{tag}"):
            continue
        if not tag and d.get("status") == "ok" and len(p.stem.split("__")) > 4:
            continue
        cells.append(d)
    return cells


def fmt_ms(s: float) -> str:
    return f"{s*1e3:8.2f}"


def roofline_table(cells: list[dict], mesh_filter: str = "pod16x16") -> str:
    rows = ["| arch | shape | compute ms | memory ms | collective ms | "
            "bottleneck | MODEL_FLOPS/HLO | temp GiB | status |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        cid = d["cell"]
        if mesh_filter not in cid:
            continue
        if d["status"] == "skip":
            arch, shape = cid.split("__")[:2]
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                        f"SKIP ({d['reason'][:40]}…) |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {cid} | | | | | | | | FAIL |")
            continue
        r = d["roofline"]
        temp = (d["memory_analysis"].get("temp_size_in_bytes") or 0) / 2**30
        rows.append(
            f"| {d['arch']} | {d['shape']} | {fmt_ms(r['compute_s'])} | "
            f"{fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | {temp:.1f} | ok |")
    return "\n".join(rows)


def summary_stats(cells: list[dict]) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    fail = [c for c in cells if c["status"] not in ("ok", "skip")]
    bcounts: dict[str, int] = {}
    for c in ok:
        b = c["roofline"]["bottleneck"]
        bcounts[b] = bcounts.get(b, 0) + 1
    return {"ok": len(ok), "skip": len(skip), "fail": len(fail),
            "bottlenecks": bcounts}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--backend", default="floo")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load_cells(args.backend, args.tag)
    print(roofline_table(cells, args.mesh))
    print()
    print(json.dumps(summary_stats(cells), indent=1))


if __name__ == "__main__":
    main()
