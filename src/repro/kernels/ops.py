"""Public kernel entry points.

On TPU these dispatch to the Pallas kernels (BlockSpec/VMEM-tiled); on CPU
they fall back to the pure-jnp oracles in ``ref.py`` (same math, chunked, so
the dry-run lowers equivalent FLOPs/memory without O(S^2) intermediates).
Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to run the Pallas kernels in
interpret mode on CPU (used by the kernel test suite).
"""
from __future__ import annotations

import os

import jax

from . import ref

_FORCE_INTERPRET = "REPRO_FORCE_PALLAS_INTERPRET"


def _use_pallas() -> bool:
    if os.environ.get(_FORCE_INTERPRET) == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0, k_offset=0,
                    kv_len=None, softcap=0.0, return_stats=False):
    if _use_pallas() and not return_stats and kv_len is None and q.shape[1] >= 128:
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            softcap=softcap, interpret=_interpret())
    return ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        k_offset=k_offset, kv_len=kv_len, softcap=softcap,
        return_stats=return_stats)


def rmsnorm(x, weight, eps=1e-5):
    if _use_pallas() and x.shape[-1] % 128 == 0:
        from .rmsnorm import rmsnorm_pallas
        return rmsnorm_pallas(x, weight, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, weight, eps)


def layernorm(x, weight, bias, eps=1e-5):
    return ref.layernorm_ref(x, weight, bias, eps)


def ssd(x, dt, A_log, Bmat, Cmat, D, *, chunk=256, h0=None, return_final_state=False):
    if _use_pallas() and x.shape[1] % chunk == 0 and x.shape[1] >= chunk:
        from .ssd_scan import ssd_pallas
        return ssd_pallas(x, dt, A_log, Bmat, Cmat, D, chunk=chunk, h0=h0,
                          return_final_state=return_final_state,
                          interpret=_interpret())
    return ref.ssd_ref(x, dt, A_log, Bmat, Cmat, D, chunk=chunk, h0=h0,
                       return_final_state=return_final_state)


def ssd_decode(h, x, dt, A_log, Bv, Cv, D):
    return ref.ssd_decode_ref(h, x, dt, A_log, Bv, Cv, D)


def causal_conv1d(x, w, state=None):
    return ref.causal_conv1d_ref(x, w, state)


def causal_conv1d_step(x, w, state):
    return ref.causal_conv1d_step_ref(x, w, state)


def grouped_matmul(x, w, expert_of):
    if _use_pallas():
        from .moe_gemm import grouped_matmul_pallas
        return grouped_matmul_pallas(x, w, expert_of, interpret=_interpret())
    return ref.grouped_matmul_ref(x, w, expert_of)


combine_attention_shards = ref.combine_attention_shards
