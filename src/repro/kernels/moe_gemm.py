"""Pallas TPU grouped (per-expert) GEMM for MoE capacity buffers.

Computes y[e] = x[e] @ w[e] for the (E, C, d) dispatch buffer against
(E, d, f) expert weights — the batched GEMM at the heart of both the EP and
TP-MoE paths. Grid = (E, C-tiles, f-tiles) with (d)-full VMEM tiles; each
(bc x d) x (d x bf) product is MXU-shaped.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    o_ref[0] = jax.lax.dot(x, w).astype(o_ref.dtype)


def expert_gemm_pallas(x, w, *, block_c: int = 128, block_f: int = 256,
                       interpret: bool = False):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    grid = (E, pl.cdiv(C, block_c), pl.cdiv(f, block_f))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, ic, jf: (e, ic, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, ic, jf: (e, 0, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        interpret=interpret,
    )(x, w)


def expert_gemm_ref(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
