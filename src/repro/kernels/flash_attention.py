"""Pallas TPU flash attention (GQA, causal, sliding-window, soft-cap).

TPU mapping (DESIGN.md §2 — HW adaptation notes):
  * grid = (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
    'arbitrary' (sequential) so the online-softmax accumulator lives in
    VMEM scratch across kv steps;
  * BlockSpecs tile q/k/v into (block_q x head_dim) / (block_k x head_dim)
    VMEM tiles, MXU-aligned (block sizes multiples of 128 where the shape
    allows);
  * GQA is an index_map: the kv BlockSpec maps q-head h to kv-head
    h // group, so no materialized head expansion ever touches HBM;
  * causal/window masking is applied in-kernel; fully-masked kv blocks are
    skipped via `pl.when` (on TPU the block's DMA still issues — a
    production variant would prune the grid; the CPU execution path
    (ref.py) does prune, which keeps the dry-run roofline honest).

Validated against ref.flash_attention_ref with interpret=True on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, seq_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # static skip: block fully masked under causal/window?
    run = True
    if causal:
        run = jnp.logical_and(True, (ik * block_k) <=
                              (q_offset + iq * block_q + block_q - 1))
    if window > 0:
        run = jnp.logical_and(
            run, (ik * block_k + block_k - 1) >=
                 (q_offset + iq * block_q - window + 1))

    @pl.when(run if not isinstance(run, bool) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq,) in (bq,1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, q_offset=0,
                           softcap=0.0, block_q=128, block_k=128,
                           interpret=False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    scale = 1.0 / (D ** 0.5)

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, seq_k=Sk,
        q_offset=int(q_offset) if isinstance(q_offset, int) else 0)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
