"""Pallas kernel for the NoC router's combinational core (paper's hot spot).

One simulation cycle of the router pipeline stage — round-robin
arbitration of routed input heads into free output registers with
wormhole burst locking — for a TILE of routers held in VMEM.  This is
the integer/boolean analogue of the paper's single-cycle router
arbiter + crossbar, evaluated for all routers in parallel.

Route compute happens *outside* the kernel (a static routing-table
gather, see ``repro.noc.topology``), so the same kernel serves the XY
mesh, the torus, and >5-port express-link routers: the port count is a
static parameter.  ``repro.core.noc_sim.router.arbiter_jnp`` is the jnp
oracle; ``repro.noc.backends`` plugs this kernel into the cycle engine
as ``backend="pallas"``, equivalence-tested flit-for-flit against
``backend="jnp"``.

Layout (R routers, P ports, blocked over R):
  out_port  (R, P) int32   routed output port per input head (99: empty)
  beat      (R, P) int32   remaining burst beats per input head
  rr_ptr    (R, P) int32   per-output round-robin pointer
  oreg_free (R, P) int32   output register accepts this cycle
  lock_in   (R, P) int32   wormhole lock (input idx or -1)
outputs:
  winner    (R, P) int32   granted input per output (-1: none)
  pop       (R, P) int32   input head consumed
  new_ptr   (R, P) int32   (advances only on unlocked grants — matching
                           the engine; the seed kernel advanced it on
                           locked grants too, breaking parity)
  new_lock  (R, P) int32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO = 99


def _kernel(oport_ref, beat_ref, ptr_ref, free_ref, lock_ref,
            win_ref, pop_ref, nptr_ref, nlock_ref, *, n_ports: int,
            block_r: int):
    P = n_ports
    out_port = oport_ref[...]                         # (bR, P)
    beat = beat_ref[...]
    ptr = ptr_ref[...]
    free = free_ref[...] > 0
    lock = lock_ref[...]

    # request[r, i, o] with wormhole lock masking
    o_ids = jax.lax.broadcasted_iota(jnp.int32, (block_r, P, P), 2)
    i_ids = jax.lax.broadcasted_iota(jnp.int32, (block_r, P, P), 1)
    req = (out_port[:, :, None] == o_ids) & free[:, None, :]
    locked = lock[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock[:, None, :])

    prio = (i_ids - ptr[:, None, :]) % P
    score = jnp.where(req, prio, NO)
    best = jnp.min(score, axis=1)                     # (bR, P_out)
    granted = best < NO
    # winner = first input matching best score (scores are distinct)
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)

    win_ref[...] = winner
    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    pop_ref[...] = pop.astype(jnp.int32)
    # rr pointer holds while an output is wormhole-locked
    nptr_ref[...] = jnp.where(granted & (lock < 0), (winner + 1) % P, ptr)

    # lock update from granted flit's beat field
    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :],
                               beat[:, :, None], 0), axis=1)
    nlock_ref[...] = jnp.where(granted & (w_beat > 1), winner,
                               jnp.where(granted, -1, lock))


def _pick_block(R: int, block_r: int) -> int:
    """Largest block size <= block_r that divides R (R is never padded:
    a partial tile would arbitrate garbage head state)."""
    b = min(block_r, R)
    while R % b:
        b -= 1
    return b


def router_arbiter_pallas(out_port, beat, rr_ptr, oreg_free, lock_in,
                          *, block_r: int = 8, interpret: bool | None = None):
    """Phase-B arbitration for all routers; same contract as
    :func:`repro.core.noc_sim.router.arbiter_jnp` (``oreg_free`` may be
    bool or int mask; ``pop`` comes back as int32 0/1).

    ``interpret=None`` auto-selects interpreter mode off-TPU.
    """
    R, P = out_port.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_r = _pick_block(R, block_r)
    grid = (R // block_r,)

    kernel = functools.partial(_kernel, n_ports=P, block_r=block_r)
    spec = pl.BlockSpec((block_r, P), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((R, P), jnp.int32)] * 4,
        interpret=interpret,
    )(out_port.astype(jnp.int32), beat.astype(jnp.int32),
      rr_ptr.astype(jnp.int32), oreg_free.astype(jnp.int32),
      lock_in.astype(jnp.int32))


def router_arbiter_ref(out_port, beat, rr_ptr, oreg_free, lock_in):
    """jnp oracle — the engine's own arbitration, int-typed like the
    kernel outputs."""
    from repro.core.noc_sim.router import arbiter_jnp
    winner, pop, new_ptr, new_lock = arbiter_jnp(
        jnp.asarray(out_port, jnp.int32), jnp.asarray(beat, jnp.int32),
        jnp.asarray(rr_ptr, jnp.int32), jnp.asarray(oreg_free),
        jnp.asarray(lock_in, jnp.int32))
    return winner, pop.astype(jnp.int32), new_ptr, new_lock
