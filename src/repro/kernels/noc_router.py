"""Pallas kernel for the NoC router's combinational core (paper's hot spot).

One simulation cycle of the router pipeline stage — XY route compute,
round-robin arbitration into free output registers, pop/grant masks — for a
TILE of routers held in VMEM. This is the integer/boolean analogue of the
paper's 5x5 single-cycle router: route compute + RR arbiter + crossbar,
evaluated for all routers in parallel (the mesh_sim's `network_step` is the
jnp oracle; neighbor exchange stays outside the kernel, as links do outside
the router).

Layout (R routers padded to a multiple of block_r, P=5 ports, F=6 fields):
  heads      (R, P, F) int32   input-FIFO heads
  head_valid (R, P)    int32   0/1
  rr_ptr     (R, P)    int32   per-output round-robin pointer
  oreg_free  (R, P)    int32   output register accepts this cycle
  lock_in    (R, P)    int32   wormhole lock (input idx or -1)
outputs:
  grant_in   (R, P)    int32   which input each output granted (-1 none)
  pop        (R, P)    int32   input head consumed
  new_ptr    (R, P)    int32
  new_lock   (R, P)    int32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_PORTS = 5
F_DEST, F_SRC, F_TIME, F_KIND, F_TXN, F_BEAT = range(6)
NO = 99


def _kernel(heads_ref, valid_ref, ptr_ref, free_ref, lock_ref,
            grant_ref, pop_ref, nptr_ref, nlock_ref, *, nx: int, block_r: int,
            r0_stride: int):
    rblk = pl.program_id(0)
    r_base = rblk * block_r

    dest = heads_ref[:, :, F_DEST]                    # (bR, P)
    beat = heads_ref[:, :, F_BEAT]
    valid = valid_ref[...] > 0
    r_idx = r_base + jax.lax.broadcasted_iota(jnp.int32, dest.shape, 0)

    # XY dimension-ordered route per input head
    x, y = r_idx % nx, r_idx // nx
    dx, dy = dest % nx, dest // nx
    route = jnp.where(dx > x, 1,
             jnp.where(dx < x, 3,
              jnp.where(dy > y, 2, jnp.where(dy < y, 0, 4))))
    route = jnp.where(valid, route, NO)               # (bR, P_in)

    ptr = ptr_ref[...]
    free = free_ref[...] > 0
    lock = lock_ref[...]

    # request[r, i, o] with wormhole lock masking
    o_ids = jax.lax.broadcasted_iota(jnp.int32, (block_r, N_PORTS, N_PORTS), 2)
    i_ids = jax.lax.broadcasted_iota(jnp.int32, (block_r, N_PORTS, N_PORTS), 1)
    req = (route[:, :, None] == o_ids) & free[:, None, :]
    locked = lock[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock[:, None, :])

    prio = (i_ids - ptr[:, None, :]) % N_PORTS
    score = jnp.where(req, prio, NO)
    best = jnp.min(score, axis=1)                     # (bR, P_out)
    granted = best < NO
    # winner = first input matching best score
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)

    grant_ref[...] = winner
    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    pop_ref[...] = pop.astype(jnp.int32)
    nptr_ref[...] = jnp.where(granted, (winner + 1) % N_PORTS, ptr)

    # lock update from granted flit's beat field
    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :],
                               beat[:, :, None], 0), axis=1)
    is_tail = w_beat <= 1
    nlock_ref[...] = jnp.where(granted & ~is_tail, winner,
                               jnp.where(granted & is_tail, -1, lock))


def router_arbiter_pallas(heads, head_valid, rr_ptr, oreg_free, lock_in,
                          *, nx: int, block_r: int = 8, interpret=False):
    R = heads.shape[0]
    assert R % block_r == 0 or R < block_r
    block_r = min(block_r, R)
    grid = (pl.cdiv(R, block_r),)

    kernel = functools.partial(_kernel, nx=nx, block_r=block_r, r0_stride=0)
    specs2 = pl.BlockSpec((block_r, N_PORTS), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, N_PORTS, 6), lambda i: (i, 0, 0)),
            specs2, specs2, specs2, specs2,
        ],
        out_specs=[specs2, specs2, specs2, specs2],
        out_shape=[jax.ShapeDtypeStruct((R, N_PORTS), jnp.int32)] * 4,
        interpret=interpret,
    )(heads, head_valid.astype(jnp.int32), rr_ptr,
      oreg_free.astype(jnp.int32), lock_in)


def router_arbiter_ref(heads, head_valid, rr_ptr, oreg_free, lock_in, *, nx):
    """jnp oracle mirroring router.network_step's phase-B arbitration."""
    R = heads.shape[0]
    dest = heads[:, :, F_DEST]
    beat = heads[:, :, F_BEAT]
    valid = head_valid.astype(bool)
    r_idx = jnp.arange(R)[:, None]
    x, y = r_idx % nx, r_idx // nx
    dx, dy = dest % nx, dest // nx
    route = jnp.where(dx > x, 1,
             jnp.where(dx < x, 3,
              jnp.where(dy > y, 2, jnp.where(dy < y, 0, 4))))
    route = jnp.where(valid, route, NO)
    o_ids = jnp.arange(N_PORTS)[None, None, :]
    i_ids = jnp.arange(N_PORTS)[None, :, None]
    req = (route[:, :, None] == o_ids) & oreg_free.astype(bool)[:, None, :]
    locked = lock_in[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock_in[:, None, :])
    prio = (i_ids - rr_ptr[:, None, :]) % N_PORTS
    score = jnp.where(req, prio, NO)
    best = jnp.min(score, axis=1)
    granted = best < NO
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)
    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    nptr = jnp.where(granted, (winner + 1) % N_PORTS, rr_ptr)
    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :], beat[:, :, None], 0),
                     axis=1)
    is_tail = w_beat <= 1
    nlock = jnp.where(granted & ~is_tail, winner,
                      jnp.where(granted & is_tail, -1, lock_in))
    return winner, pop.astype(jnp.int32), nptr, nlock
