"""Pallas kernels for the NoC router hot loop (paper's hot spot).

Two kernels, both equivalence-tested flit-for-flit against the jnp
reference engine (``repro.core.noc_sim.router``):

* :func:`router_arbiter_pallas` — phase-B only: round-robin arbitration
  of routed input heads into free output registers with wormhole burst
  locking, for a TILE of routers held in VMEM (``backend="pallas"``).
* :func:`fused_fabric_step_pallas` — the FULL one-cycle network update
  (paper's single-cycle router datapath): output-register drain,
  neighbor push through the static inverse link map, arbitration, and
  input-FIFO pop/push, in ONE kernel over an ``(N, P*D*F)``-flattened
  row layout (``backend="pallas_fused"``).  ``N`` is routers with every
  physical channel folded into extra rows, so one kernel launch per
  simulated cycle advances the entire fabric — all channels, all
  routers — and the last axis stays a long contiguous lane dimension.

Route compute is a static-table gather (``route[row, dest]``), so the
same kernels serve the XY mesh, the torus, and >5-port express-link
routers: the port count is a static parameter.  FIFO depth reaches the
fused kernel as a traced per-row operand masked against the static
``D`` max, matching the engine's padded-depth sweep mode.

Off-TPU both kernels auto-select interpret mode; the row layout is
(8, 128)-tileable for a real Mosaic lowering, but the in-kernel static
gathers have only been validated under the interpreter (see README
"Performance" and ROADMAP).

Layout (R routers, P ports, blocked over R):
  out_port  (R, P) int32   routed output port per input head (99: empty)
  beat      (R, P) int32   remaining burst beats per input head
  rr_ptr    (R, P) int32   per-output round-robin pointer
  oreg_free (R, P) int32   output register accepts this cycle
  lock_in   (R, P) int32   wormhole lock (input idx or -1)
outputs:
  winner    (R, P) int32   granted input per output (-1: none)
  pop       (R, P) int32   input head consumed
  new_ptr   (R, P) int32   (advances only on unlocked grants — matching
                           the engine; the seed kernel advanced it on
                           locked grants too, breaking parity)
  new_lock  (R, P) int32
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO = 99

# conservative per-core VMEM budget for the no-grid fused kernel: every
# operand and output lives in VMEM at once, so a real Mosaic lowering of
# an oversized fabric dies with an opaque allocator error deep inside
# the compiler.  16 MiB matches the usable fraction of a v4/v5 core's
# VMEM after double-buffering headroom.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _arbitrate(out_port, beat, ptr, free, lock, *, n_rows: int, n_ports: int):
    """Shared phase-B math: ``free``/``lock`` per OUT port, ``out_port``/
    ``beat`` per IN head.  Returns (winner, pop, new_ptr, new_lock)."""
    P = n_ports
    o_ids = jax.lax.broadcasted_iota(jnp.int32, (n_rows, P, P), 2)
    i_ids = jax.lax.broadcasted_iota(jnp.int32, (n_rows, P, P), 1)
    req = (out_port[:, :, None] == o_ids) & free[:, None, :]
    locked = lock[:, None, :] >= 0
    req &= (~locked) | (i_ids == lock[:, None, :])

    prio = (i_ids - ptr[:, None, :]) % P
    score = jnp.where(req, prio, NO)
    best = jnp.min(score, axis=1)                     # (rows, P_out)
    granted = best < NO
    # winner = first input matching best score (scores are distinct)
    is_best = (score == best[:, None, :]) & req
    winner = jnp.argmax(is_best.astype(jnp.int32), axis=1)
    winner = jnp.where(granted, winner, -1)

    pop = jnp.any((i_ids == winner[:, None, :]) & granted[:, None, :], axis=2)
    # rr pointer holds while an output is wormhole-locked
    new_ptr = jnp.where(granted & (lock < 0), (winner + 1) % P, ptr)

    # lock update from granted flit's beat field
    w_beat = jnp.sum(jnp.where((i_ids == winner[:, None, :])
                               & granted[:, None, :],
                               beat[:, :, None], 0), axis=1)
    new_lock = jnp.where(granted & (w_beat > 1), winner,
                         jnp.where(granted, -1, lock))
    return winner, pop, new_ptr, new_lock


# --------------------------------------------------------------------- #
# phase-B arbiter kernel (backend="pallas")
# --------------------------------------------------------------------- #
def _arb_kernel(oport_ref, beat_ref, ptr_ref, free_ref, lock_ref,
                win_ref, pop_ref, nptr_ref, nlock_ref, *, n_ports: int,
                block_r: int):
    winner, pop, new_ptr, new_lock = _arbitrate(
        oport_ref[...], beat_ref[...], ptr_ref[...], free_ref[...] > 0,
        lock_ref[...], n_rows=block_r, n_ports=n_ports)
    win_ref[...] = winner
    pop_ref[...] = pop.astype(jnp.int32)
    nptr_ref[...] = new_ptr
    nlock_ref[...] = new_lock


def _pad_rows(R: int, block_r: int) -> tuple[int, int]:
    """(block, padded R): pad the row axis up to a block multiple with
    neutral rows instead of degrading the tile (a prime R used to fall
    all the way to ``block_r=1``).  Neutral rows (``out_port=NO``,
    ``oreg_free=0``, ``lock_in=-1``) are safe: empty heads never
    request, so they arbitrate to nothing and are sliced off."""
    b = min(block_r, R)
    return b, -(-R // b) * b


def router_arbiter_pallas(out_port, beat, rr_ptr, oreg_free, lock_in,
                          *, block_r: int = 8, interpret: bool | None = None):
    """Phase-B arbitration for all routers; same contract as
    :func:`repro.core.noc_sim.router.arbiter_jnp` (``oreg_free`` may be
    bool or int mask; ``pop`` comes back as int32 0/1).

    ``interpret=None`` auto-selects interpreter mode off-TPU.
    """
    R, P = out_port.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_r, R_pad = _pad_rows(R, block_r)
    grid = (R_pad // block_r,)

    def pad(a, fill):
        a = a.astype(jnp.int32)
        if R_pad == R:
            return a
        return jnp.concatenate(
            [a, jnp.full((R_pad - R, P), fill, jnp.int32)], axis=0)

    kernel = functools.partial(_arb_kernel, n_ports=P, block_r=block_r)
    spec = pl.BlockSpec((block_r, P), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((R_pad, P), jnp.int32)] * 4,
        interpret=interpret,
    )(pad(out_port, NO), pad(beat, 1), pad(rr_ptr, 0),
      pad(oreg_free, 0), pad(lock_in, -1))
    return tuple(o[:R] for o in out)


# --------------------------------------------------------------------- #
# fused full-cycle fabric kernel (backend="pallas_fused")
# --------------------------------------------------------------------- #
def _fused_kernel(fifo_ref, count_ref, ptr_ref, oreg_ref, oregv_ref,
                  lock_ref, iv_ref, iflit_ref, depth_ref, *rest,
                  n_rows: int, n_ports: int, d_max: int, n_fields: int,
                  f_dest: int, f_beat: int, n_vcs: int, masked: bool):
    # fault injection (masked=True) inserts one extra (N, P) link-mask
    # operand after depth; the healthy build keeps the original operand
    # list so the zero-fault program is untouched
    if masked:
        (mask_ref, nbr_ref, opp_ref, route_ref, src_ref,
         nfifo_ref, ncount_ref, nptr_ref, noreg_ref, noregv_ref,
         nlock_ref, injok_ref, dv_ref, dflit_ref, lm_ref) = rest
    else:
        mask_ref = None
        (nbr_ref, opp_ref, route_ref, src_ref,
         nfifo_ref, ncount_ref, nptr_ref, noreg_ref, noregv_ref,
         nlock_ref, injok_ref, dv_ref, dflit_ref, lm_ref) = rest
    N, P, D, F = n_rows, n_ports, d_max, n_fields
    fifo = fifo_ref[...].reshape(N, P, D, F)
    count = count_ref[...]                                 # (N, P)
    oreg = oreg_ref[...].reshape(N, P, F)
    oreg_v = oregv_ref[...] > 0
    depth = depth_ref[...]                                 # (N, 1)
    nbr = nbr_ref[...]
    opp = opp_ref[...]
    src = src_ref[...]

    heads = fifo[:, :, 0, :]                               # (N, P, F)
    head_valid = count > 0
    is_local = (jax.lax.broadcasted_iota(jnp.int32, (N, P), 1) == P - 1)

    # phase A: drain output registers toward downstream occupancy
    ds_idx = jnp.clip(nbr, 0, N - 1) * P + opp             # (N, P)
    ds_count = count.reshape(-1)[ds_idx]
    can_drain = jnp.where(is_local, True, (nbr >= 0) & (ds_count < depth))
    if masked:
        can_drain &= mask_ref[...] == 0        # dead link: grants suppressed
    drain = oreg_v & can_drain
    if n_vcs > 1:
        # VC-expanded tables: one physical link moves one flit/cycle, so
        # keep only the highest ready VC (escape VC first) per link
        n_phys = (P - 1) // n_vcs
        e = drain[:, :P - 1].reshape(N, n_phys, n_vcs)
        v_ids = jax.lax.broadcasted_iota(jnp.int32, (N, n_phys, n_vcs), 2)
        rank = jnp.where(e, v_ids, -1)
        win = e & (rank == jnp.max(rank, axis=2, keepdims=True))
        drain = jnp.concatenate(
            [win.reshape(N, P - 1), drain[:, P - 1:]], axis=1)

    dv_ref[...] = drain[:, P - 1:].astype(jnp.int32)       # (N, 1)
    dflit_ref[...] = oreg[:, P - 1, :]

    # neighbor push == static gather through the inverse link map
    recv_valid = (src >= 0) & drain.reshape(-1)[jnp.clip(src, 0)]
    recv_flit = jnp.where(recv_valid[:, :, None],
                          oreg.reshape(-1, F)[jnp.clip(src, 0)], 0)

    # NI injection into the Local input port
    inj_ok = (iv_ref[...][:, 0] > 0) & (count[:, P - 1] < depth[:, 0])
    recv_valid = jnp.where(is_local, inj_ok[:, None], recv_valid)
    recv_flit = jnp.where(is_local[:, :, None],
                          jnp.where(inj_ok[:, None, None],
                                    iflit_ref[...][:, None, :], 0),
                          recv_flit)
    injok_ref[...] = inj_ok[:, None].astype(jnp.int32)

    # phase B: arbitration into freed output registers
    oreg_free = (~oreg_v) | drain
    out_port = jnp.take_along_axis(route_ref[...], heads[:, :, f_dest],
                                   axis=1)
    out_port = jnp.where(head_valid, out_port, NO)
    winner, pop, new_ptr, new_lock = _arbitrate(
        out_port, heads[:, :, f_beat], ptr_ref[...], oreg_free,
        lock_ref[...], n_rows=N, n_ports=P)
    nptr_ref[...] = new_ptr
    nlock_ref[...] = new_lock

    any_grant = winner >= 0
    flit_to_oreg = jnp.take_along_axis(
        heads, jnp.clip(winner, 0)[:, :, None], axis=1)
    new_oreg = jnp.where(any_grant[:, :, None], flit_to_oreg, oreg)
    noreg_ref[...] = new_oreg.reshape(N, P * F)
    noregv_ref[...] = ((oreg_v & ~drain) | any_grant).astype(jnp.int32)

    # input FIFO update: pop then push
    shifted = jnp.concatenate(
        [fifo[:, :, 1:, :], jnp.zeros_like(fifo[:, :, :1, :])], axis=2)
    fifo = jnp.where(pop[:, :, None, None], shifted, fifo)
    count = count - pop.astype(jnp.int32)

    slot = jnp.clip(count, 0, D - 1)
    write = recv_valid & (count < depth)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (N, P, D), 2)
              == slot[:, :, None])
    sel = write[:, :, None] & onehot
    fifo = jnp.where(sel[..., None], recv_flit[:, :, None, :], fifo)
    nfifo_ref[...] = fifo.reshape(N, P * D * F)
    ncount_ref[...] = count + write.astype(jnp.int32)

    lm_ref[...] = jnp.sum((drain & ~is_local).astype(jnp.int32),
                          axis=1, keepdims=True)


def fused_fabric_step_pallas(fifo, count, rr_ptr, oreg, oreg_v, lock_in,
                             inject_valid, inject_flit, depth_rows,
                             nbr_rows, opp_rows, route_rows, src_rows,
                             *, n_vcs: int = 1, link_mask_rows=None,
                             interpret: bool | None = None,
                             vmem_budget_bytes: int | None =
                             VMEM_BUDGET_BYTES):
    """One full fabric cycle for ``N`` stacked router rows (channels
    folded into rows by the caller; see ``repro.noc.backends``).

    State arrives in the engine's logical shapes — ``fifo (N, P, D, F)``,
    ``oreg (N, P, F)``, the rest ``(N, P)`` — and is flattened to the
    kernel's 2D ``(N, P*D*F)`` lane layout here.  The static tables are
    row-indexed: ``nbr_rows``/``src_rows`` hold *row* (not router)
    indices, ``route_rows`` is ``(N, n_planes*R)`` over per-network (possibly
    multi-plane virtual) destinations.  ``depth_rows (N,)`` is the
    traced per-row FIFO depth.  Static ``n_vcs > 1`` declares the port
    axis VC-expanded and enables the per-physical-link drain
    serialization (escape VC first), matching the jnp engine.
    ``link_mask_rows (N, P)`` (fault injection) marks output ports whose
    link is currently dead — they never drain; ``None`` (the default)
    builds the original mask-free kernel, keeping the healthy program
    untouched.

    When compiling for a real TPU (``interpret=False``) the kernel is
    no-grid — every operand and output is resident in VMEM at once — so
    the total footprint is checked against ``vmem_budget_bytes`` up
    front and an over-budget fabric raises a ``ValueError`` carrying
    the byte estimate and resharding hints instead of an opaque Mosaic
    allocator failure.  ``vmem_budget_bytes=None`` disables the check.

    Returns ``(fifo, count, rr_ptr, oreg, oreg_v (int32), lock_in,
    inj_ok (N,) bool, deliver_valid (N,) bool, deliver_flit (N, F),
    link_moves_per_row (N,))``.
    """
    from repro.core.noc_sim.router import F_BEAT, F_DEST

    N, P, D, F = fifo.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    masked = link_mask_rows is not None
    kernel = functools.partial(
        _fused_kernel, n_rows=N, n_ports=P, d_max=D, n_fields=F,
        f_dest=F_DEST, f_beat=F_BEAT, n_vcs=n_vcs, masked=masked)
    out_shapes = [
        jax.ShapeDtypeStruct((N, P * D * F), jnp.int32),   # fifo
        jax.ShapeDtypeStruct((N, P), jnp.int32),           # count
        jax.ShapeDtypeStruct((N, P), jnp.int32),           # rr_ptr
        jax.ShapeDtypeStruct((N, P * F), jnp.int32),       # oreg
        jax.ShapeDtypeStruct((N, P), jnp.int32),           # oreg_v
        jax.ShapeDtypeStruct((N, P), jnp.int32),           # lock_in
        jax.ShapeDtypeStruct((N, 1), jnp.int32),           # inj_ok
        jax.ShapeDtypeStruct((N, 1), jnp.int32),           # deliver_valid
        jax.ShapeDtypeStruct((N, F), jnp.int32),           # deliver_flit
        jax.ShapeDtypeStruct((N, 1), jnp.int32),           # link_moves
    ]
    operands = [
        fifo.reshape(N, P * D * F).astype(jnp.int32),
        count.astype(jnp.int32), rr_ptr.astype(jnp.int32),
        oreg.reshape(N, P * F).astype(jnp.int32),
        oreg_v.astype(jnp.int32), lock_in.astype(jnp.int32),
        inject_valid.astype(jnp.int32)[:, None],
        inject_flit.astype(jnp.int32),
        depth_rows.astype(jnp.int32)[:, None],
    ]
    if masked:
        operands.append(link_mask_rows.astype(jnp.int32))
    operands += [
        nbr_rows.astype(jnp.int32), opp_rows.astype(jnp.int32),
        route_rows.astype(jnp.int32), src_rows.astype(jnp.int32)]
    if not interpret and vmem_budget_bytes is not None:
        est = 4 * (sum(math.prod(o.shape) for o in operands)
                   + sum(math.prod(s.shape) for s in out_shapes))
        if est > vmem_budget_bytes:
            raise ValueError(
                f"fused fabric kernel needs ~{est} bytes of VMEM for "
                f"{N} router rows (P={P}, D={D}, budget "
                f"{vmem_budget_bytes}); the no-grid kernel holds the "
                f"whole fabric resident.  Shrink the resident slab — "
                f"row-shard the mesh across devices "
                f"(simulate(..., shard=RowShard(n))), lower the padded "
                f"FIFO depth (depth sweeps pad every spec to the max "
                f"depth), or split physical channels into separate "
                f"sims — or raise vmem_budget_bytes if your core "
                f"really has the headroom.")
    (nfifo, ncount, nptr, noreg, noregv, nlock, injok, dv, dflit,
     lm) = pl.pallas_call(kernel, out_shape=out_shapes,
                          interpret=interpret)(*operands)
    return (nfifo.reshape(N, P, D, F), ncount, nptr,
            noreg.reshape(N, P, F), noregv, nlock,
            injok[:, 0].astype(jnp.bool_), dv[:, 0].astype(jnp.bool_),
            dflit, lm[:, 0])


def router_arbiter_ref(out_port, beat, rr_ptr, oreg_free, lock_in):
    """jnp oracle — the engine's own arbitration, int-typed like the
    kernel outputs."""
    from repro.core.noc_sim.router import arbiter_jnp
    winner, pop, new_ptr, new_lock = arbiter_jnp(
        jnp.asarray(out_port, jnp.int32), jnp.asarray(beat, jnp.int32),
        jnp.asarray(rr_ptr, jnp.int32), jnp.asarray(oreg_free),
        jnp.asarray(lock_in, jnp.int32))
    return winner, pop.astype(jnp.int32), new_ptr, new_lock
