"""Pallas TPU fused RMSNorm.

Rows are tiled into (block_rows x d) VMEM blocks; the reduction, rsqrt and
weight multiply fuse into one pass (one HBM read + one write per element —
the memory-bound ideal). d must be lane-aligned (mult of 128) on real TPU;
the wrapper falls back to the jnp ref otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, weight, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = False):
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
