"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

These are written memory-consciously (chunked online-softmax attention,
chunked SSD) so that the CPU dry-run lowers the same asymptotic math as the
TPU kernels without materializing O(S^2) intermediates.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layernorm_ref(x: jax.Array, weight: jax.Array, bias: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention (chunked online softmax; GQA; causal / sliding window;
# optional logit soft-capping; optional partial-softmax stats for split-KV)
# ---------------------------------------------------------------------------
def _apply_mask(scores: jax.Array, qpos: jax.Array, kpos: jax.Array,
                causal: bool, window: int) -> jax.Array:
    # scores: (B, Hkv, G, Sq, Ck); qpos (Sq,), kpos (Ck,)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(mask[None, None, None], scores, NEG_INF)


def _attn_inner(qg, k, v, *, q_lo, kv_lo, kv_hi, chunk, causal, window,
                q_offset, k_offset, kv_len, softcap, Sk_valid):
    """Online-softmax scan over kv chunks [kv_lo, kv_hi) for one q block.

    qg: (B, Hkv, G, Sq_blk, D) pre-scaled fp32. Returns (m, l, acc).
    """
    B, Hkv, G, Sq, D = qg.shape
    n_chunks = (kv_hi - kv_lo + chunk - 1) // chunk
    qpos = q_offset + q_lo + jnp.arange(Sq)

    def body(carry, idx):
        m, l, acc = carry
        start = kv_lo + idx * chunk
        kb = jax.lax.dynamic_slice_in_dim(k, start, chunk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, chunk, axis=1)
        kb = jnp.moveaxis(kb, 1, 2)                         # (B,Hkv,C,D)
        vb = jnp.moveaxis(vb, 1, 2)
        kpos = k_offset + start + jnp.arange(chunk)
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg, kb.astype(jnp.float32))
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        s = _apply_mask(s, qpos, kpos, causal, window)
        valid = kpos < (k_offset + Sk_valid if kv_len is None else kv_len)
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    if n_chunks == 1:
        return body((m0, l0, acc0), 0)[0]
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    return m, l, acc


def flash_attention_ref(
    q: jax.Array,                      # (B, Sq, Hq, D)
    k: jax.Array,                      # (B, Sk, Hkv, D)
    v: jax.Array,                      # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,                   # 0 = unlimited
    q_offset=0,                        # absolute position of q[0] (int or traced)
    k_offset=0,                        # absolute position of k[0]
    kv_len: Optional[jax.Array] = None,  # GLOBAL valid kv length (caches)
    softcap: float = 0.0,
    chunk: int = 1024,
    q_chunk: int = 2048,
    return_stats: bool = False,
):
    """Blocked attention with static causal/window kv-range skipping.

    q is processed in static blocks; for each block the kv range that can
    possibly be unmasked is computed statically (when q_offset is a Python
    int), so sliding-window and causal masking skip FLOPs instead of just
    masking them — matching what the TPU kernel does and keeping the
    dry-run roofline honest.

    Returns out (B, Sq, Hq, D) [, (m, l, num) stats for split-KV combine].
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    static_q = isinstance(q_offset, int) and isinstance(k_offset, int)
    chunk = min(chunk, Sk)
    Sk_valid = Sk
    if Sk % chunk:
        pad = -Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sk = k.shape[1]
    if Sq <= q_chunk or Sq % q_chunk != 0 or not static_q:
        q_blocks = [(0, Sq)]
    else:
        q_blocks = [(i * q_chunk, q_chunk) for i in range(Sq // q_chunk)]

    outs, ms, ls, nums = [], [], [], []
    for q_lo, q_len in q_blocks:
        qb = q[:, q_lo:q_lo + q_len]
        qg = qb.reshape(B, q_len, Hkv, G, D).transpose(0, 2, 3, 1, 4)
        qg = (qg * scale).astype(jnp.float32)
        kv_lo, kv_hi = 0, Sk
        if static_q:
            first_q = q_offset + q_lo
            last_q = q_offset + q_lo + q_len - 1
            if causal:
                kv_hi = min(Sk, max(0, last_q - k_offset + 1))
            if window > 0:
                kv_lo = max(0, first_q - window + 1 - k_offset)
            kv_lo = (kv_lo // chunk) * chunk
            kv_hi = min(Sk, -(-kv_hi // chunk) * chunk)
            if kv_hi <= kv_lo:
                kv_lo, kv_hi = 0, chunk
        m, l, acc = _attn_inner(
            qg, k, v, q_lo=q_lo, kv_lo=kv_lo, kv_hi=kv_hi, chunk=chunk,
            causal=causal, window=window, q_offset=q_offset,
            k_offset=k_offset, kv_len=kv_len, softcap=softcap,
            Sk_valid=Sk_valid)
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, q_len, Hq, D))
        if return_stats:
            ms.append(m.transpose(0, 3, 1, 2).reshape(B, q_len, Hq))
            ls.append(l.transpose(0, 3, 1, 2).reshape(B, q_len, Hq))
            nums.append(acc.transpose(0, 3, 1, 2, 4).reshape(B, q_len, Hq, D))

    out = (outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)).astype(q.dtype)
    if return_stats:
        cat = lambda xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs, axis=1)
        return out, (cat(ms), cat(ls), cat(nums))
    return out


def combine_attention_shards(m, l, num, psum, pmax):
    """Combine split-KV partial attention across an axis.

    m, l, num: per-shard stats from ``flash_attention_ref(..., return_stats=True)``.
    psum/pmax: callables reducing over the shard axis.
    """
    M = pmax(m)
    scale = jnp.exp(m - M)
    l_tot = psum(l * scale)
    num_tot = psum(num * scale[..., None])
    return (num_tot / jnp.maximum(l_tot, 1e-37)[..., None]).astype(num.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked scan
# ---------------------------------------------------------------------------
def _segsum(z: jax.Array) -> jax.Array:
    """z: (..., Q) -> (..., Q, Q) with S[i, j] = sum_{k=j+1..i} z_k (i>=j)."""
    cs = jnp.cumsum(z, axis=-1)
    S = cs[..., :, None] - cs[..., None, :]
    Q = z.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, S, NEG_INF)


def ssd_ref(
    x: jax.Array,        # (B, S, H, P)  — already includes dt scaling? NO: raw
    dt: jax.Array,       # (B, S, H)     — positive (softplus applied upstream)
    A_log: jax.Array,    # (H,)
    Bmat: jax.Array,     # (B, S, G, N)
    Cmat: jax.Array,     # (B, S, G, N)
    D: jax.Array,        # (H,)
    *,
    chunk: int = 256,
    h0: Optional[jax.Array] = None,   # (B, H, P, N) initial state
    return_final_state: bool = False,
):
    """Chunked SSD forward. y = SSM(A, B, C)(x*dt) + D*x  (groups broadcast
    over heads: H % G == 0)."""
    Bsz, S, H, P = x.shape
    _, _, G, N = Bmat.shape
    assert H % G == 0
    dtype = x.dtype

    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    a = -jnp.exp(A_log.astype(jnp.float32))           # (H,)
    dA = dt.astype(jnp.float32) * a                   # (B,S,H) decay exponents
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])

    # reshape into chunks
    xc = xdt.reshape(Bsz, nc, Q, H, P)
    dAc = dA.reshape(Bsz, nc, Q, H)
    rep = H // G
    Bc = jnp.repeat(Bmat.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, Q, H, N)
    Cc = jnp.repeat(Cmat.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, Q, H, N)

    cs = jnp.cumsum(dAc, axis=2)                      # (B,nc,Q,H)

    # --- intra-chunk (quadratic within chunk) ---
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))   # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Cc, Bc) * L.clip(0.0, None)
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", scores, xc)

    # --- chunk end-states ---
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)     # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xc)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cs[:, :, -1, :])            # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    hinit = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        scan_fn, hinit,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)        # (B,nc,H,P,N) state before chunk

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cc * jnp.exp(cs)[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(dtype)
    if return_final_state:
        return y, h_last
    return y


def ssd_decode_ref(h, x, dt, A_log, Bv, Cv, D):
    """Single-token SSD state update.

    h: (B, H, P, N); x: (B, H, P); dt: (B, H); Bv/Cv: (B, G, N); D: (H,)
    returns (y (B,H,P), h_new).
    """
    B_, H, P, N = h.shape
    G = Bv.shape[1]
    rep = H // G
    a = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * a)               # (B,H)
    Bh = jnp.repeat(Bv.astype(jnp.float32), rep, axis=1)   # (B,H,N)
    Ch = jnp.repeat(Cv.astype(jnp.float32), rep, axis=1)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    h_new = h * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch) + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba front conv) + single-step update
# ---------------------------------------------------------------------------
def causal_conv1d_ref(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """x: (B, S, C); w: (C, W) depthwise causal; state: (B, W-1, C) history."""
    B, S, C = x.shape
    _, W = w.shape
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+W-1, C)
    idx = jnp.arange(S)[:, None] + jnp.arange(W)[None, :]
    windows = xp[:, idx, :]                             # (B, S, W, C)
    y = jnp.einsum("bswc,cw->bsc", windows.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, S:, :] if W > 1 else state
    return y, new_state


def causal_conv1d_step_ref(x: jax.Array, w: jax.Array, state: jax.Array):
    """x: (B, C); state: (B, W-1, C) -> (y (B, C), new_state)."""
    W = w.shape[1]
    xp = jnp.concatenate([state, x[:, None, :]], axis=1)   # (B, W, C)
    y = jnp.einsum("bwc,cw->bc", xp.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)
    return y, xp[:, 1:, :] if W > 1 else state


# ---------------------------------------------------------------------------
# Grouped (per-expert segment) matmul for MoE
# ---------------------------------------------------------------------------
def grouped_matmul_ref(x: jax.Array, w: jax.Array, expert_of: jax.Array) -> jax.Array:
    """x: (T, d_in); w: (E, d_in, d_out); expert_of: (T,) int -> (T, d_out).

    Oracle: per-token weight gather contracted densely (memory-fine at test
    scale; the Pallas kernel tiles tokens grouped by expert).
    """
    E = w.shape[0]
    onehot = jax.nn.one_hot(expert_of, E, dtype=x.dtype)        # (T, E)
    # (T,E) x (E,di,do) with (T,di): contract per expert without gathering
    return jnp.einsum("te,ti,eio->to", onehot, x, w)
