"""Pallas TPU Mamba-2 SSD (state-space duality) chunked scan.

TPU mapping: grid = (batch, heads, chunks); the chunk axis is 'arbitrary'
(sequential) and the inter-chunk SSM state h (head_dim x state) lives in
VMEM scratch, carried across grid steps — the recurrence never round-trips
to HBM. Each step does the intra-chunk quadratic part on the MXU
(Q x Q score matrix, Q = chunk length) plus the state update/readout.

Validated against ref.ssd_ref with interpret=True on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

NEG_INF = -1e30


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
            h_ref, *, chunk: int, nchunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (Q, 1) -- blocked (Q,)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32)) # scalar in (1,)
    B = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    D = d_ref[0].astype(jnp.float32)

    dA = dt * a                                   # (Q, 1)
    cs = jnp.cumsum(dA, axis=0)                   # (Q, 1)
    xdt = x * dt                                  # (Q, P)

    # intra-chunk quadratic: L[i,j] = exp(cs_i - cs_j) (i >= j)
    Ls = cs - cs.T                                # (Q, Q) via (Q,1)-(1,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(Ls), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ()))) * L
    y = jax.lax.dot(scores, xdt)                  # (Q, P)

    # inter-chunk: readout of carried state, then state update
    h = h_ref[...]                                # (P, N)
    y = y + jnp.exp(cs) * jax.lax.dot_general(
        C, h, (((1,), (1,)), ((), ())))           # (Q,N)x(P,N)^T -> (Q,P)
    decay_end = jnp.exp(cs[-1:] - cs)             # (Q, 1)
    contrib = jax.lax.dot_general(
        xdt, B * decay_end, (((0,), (0,)), ((), ())))   # (P, N)
    h_ref[...] = jnp.exp(cs[-1]) * h + contrib

    y_ref[0, 0] = (y + x * D).astype(y_ref.dtype)

    @pl.when(ic == nchunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_pallas(x, dt, A_log, Bmat, Cmat, D, *, chunk=256, h0=None,
               return_final_state=False, interpret=False):
    """x: (B,S,H,P); dt: (B,S,H); A_log: (H,); B/C: (B,S,G,N); D: (H,).

    Groups broadcast to heads via index_map (no materialized repeat).
    h0 is unsupported in the kernel path (prefill continuation uses the
    ref); callers pass h0=None here.
    """
    assert h0 is None, "kernel path starts from h=0 (use ref for h0)"
    Bsz, S, H, P = x.shape
    _, _, G, N = Bmat.shape
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3)                       # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)[..., None]             # (B,H,S,1)
    Bt = Bmat.transpose(0, 2, 1, 3)                    # (B,G,S,N)
    Ct = Cmat.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, chunk=Q, nchunks=nc)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c, r=rep: (b, h // r, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c, r=rep: (b, h // r, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, A_log, Bt, Ct, D)

    y = y.transpose(0, 2, 1, 3)
    if return_final_state:
        return y, hlast
    return y
