"""Pallas-TPU version compat.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` across
JAX versions; resolve whichever the installed JAX provides so the next
rename is a one-line fix here instead of a sweep over every kernel.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def compiler_params(**kwargs):
    """Build the TPU compiler-params object for ``pl.pallas_call``."""
    return _COMPILER_PARAMS_CLS(**kwargs)
