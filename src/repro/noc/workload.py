"""Declarative traffic workloads typed against a NocSpec's classes.

A :class:`Workload` names a registered *pattern* plus per-class
parameters (rates in flits/cycle, transaction counts, read/write mix).
Patterns produce, for every declared
:class:`~repro.noc.spec.TrafficClass`, a dense ``(R, T)`` schedule of
desired inject times (sorted per NI; an entry at/after ``BIG`` disables
the slot), destinations, and a per-slot *write* flag — a write slot
issues an AXI write transaction (AW -> W burst -> B ack) instead of a
read (AR -> R burst).

Schedule tuple compatibility (3-tuple -> 4-tuple rule): a pattern may
return per class

* ``(times, dests)``                   — all-reads, single stream,
* ``(times, dests, writes)``           — the pre-stream form; on a
  class with ``n_streams > 1`` the entries are dealt round-robin
  across its AXI ID streams by :func:`repro.noc.stack_schedules`,
* ``(times, dests, writes, streams)``  — ``streams`` pins each entry's
  AXI ID stream explicitly (ints in ``[0, n_streams)``).

All three forms stay accepted everywhere a schedule mapping is taken
(``simulate_schedules``, ``stack_schedules``, custom patterns);
single-stream classes are bit-identical under every form.

Every pattern takes ``write_frac`` (one float for all classes or a
per-class mapping): the fraction of each class's transactions that are
writes.  Deterministic patterns interleave writes evenly and
deterministically (transaction ``j`` is a write iff
``floor((j+1)*wf) > floor(j*wf)``); the seeded random patterns draw the
direction from their rng.  ``write_frac=0`` (the default) reproduces
the read-only schedules bit-for-bit.

Built-in patterns:

* ``fig5``           — paper Fig. 5 cluster-to-cluster pair traffic,
* ``uniform_random`` — uniform-random background from every NI (with
  the seed's self-traffic remap bug fixed),
* ``hotspot``        — a fraction of traffic converges on one hot tile,
* ``transpose``      — tile (x, y) talks to tile (y, x),
* ``all_to_all``     — every NI sweeps all other tiles round-robin
  (PATRONoC-style DNN all-to-all phase).

Rates/counts referencing a class name the spec does not declare raise
immediately — workloads are typed against the spec, not stringly glued.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import numpy as np

from .spec import NocSpec

BIG = 1 << 30

PATTERNS: dict[str, Callable] = {}


def register_pattern(name: str):
    def deco(fn):
        PATTERNS[name] = fn
        return fn
    return deco


# dicts are frozen to a tagged tuple so thawing is exact (a user pattern
# taking a literal sequence of (str, value) pairs is NOT turned into a dict)
_DICT_TAG = "__frozen_mapping__"


def _freeze(v):
    if isinstance(v, Mapping):
        return (_DICT_TAG,
                tuple(sorted((k, _freeze(x)) for k, x in v.items())))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def _thaw(v):
    if (isinstance(v, tuple) and len(v) == 2 and v[0] == _DICT_TAG
            and isinstance(v[1], tuple)):
        return {k: _thaw(x) for k, x in v[1]}
    if isinstance(v, tuple):
        return tuple(_thaw(x) for x in v)
    return v


@dataclass(frozen=True)
class Workload:
    """A named traffic pattern with (frozen, hashable) parameters."""
    pattern: str
    params: tuple = ()

    @classmethod
    def make(cls, pattern: str, **params) -> "Workload":
        if pattern not in PATTERNS:
            raise KeyError(
                f"unknown pattern {pattern!r}; have {sorted(PATTERNS)}")
        # top level is always kwargs: store as plain (name, frozen) pairs
        return cls(pattern, tuple(sorted(
            (k, _freeze(v)) for k, v in params.items())))

    @property
    def kwargs(self) -> dict[str, Any]:
        return {k: _thaw(v) for k, v in self.params}

    @classmethod
    def from_ledger(cls, ledger, spec: NocSpec, *,
                    cycle_time_ns: float = 1.0, mapping=None,
                    **kw) -> "Workload":
        """Replay a ``repro.dist`` collective :class:`~repro.core.
        channels.Ledger` (a :class:`~repro.dist.step.StepArtifact`'s
        trace-time byte record) as NoC traffic on ``spec``'s topology.

        Each entry's collective is expanded into its link-level
        transfers (ring or recursive-doubling — see
        :mod:`repro.noc.traces`), ranks are laid onto tiles via
        ``mapping`` (``None`` = the whole mesh is one group;
        ``{"data": 2, "model": 4}`` = row-major rank grid with
        concurrent groups per non-collective axis), and consecutive
        same-class collectives round-robin across the class's AXI ID
        streams.  Extra keywords (``algorithm``, ``scale``,
        ``as_writes``, ``compute_ns``, ``start``, ``round_slack``) pass
        through to :func:`repro.noc.traces.ledger_schedules`.

        ``simulate(spec, Workload.from_ledger(art.ledger, spec))`` is
        the one-call real-workload experiment."""
        from . import traces  # deferred: registers "ledger_replay"
        entries = tuple(
            (e.phase, e.op, tuple(e.axes), int(e.nbytes),
             e.traffic_class) for e in ledger.entries)
        mapping_t = (tuple(mapping.items()) if isinstance(mapping, Mapping)
                     else tuple(mapping) if mapping is not None else ())
        wl = cls.make("ledger_replay", entries=entries,
                      cycle_time_ns=float(cycle_time_ns),
                      mapping=mapping_t, **kw)
        traces.ledger_schedules(  # validate eagerly against this spec
            spec, entries, cycle_time_ns=float(cycle_time_ns),
            mapping=mapping_t or None, **kw)
        return wl

    def schedules(self, spec: NocSpec) -> dict[str, tuple]:
        """Per-class ``(times, dests, writes[, streams])`` arrays, one
        entry per declared class; ``writes`` marks the slots that issue
        AXI write transactions (AW/W/B) instead of reads (AR/R), and
        the optional ``streams`` element pins per-entry AXI ID streams
        (see the module docstring's 3-tuple -> 4-tuple rule)."""
        out = PATTERNS[self.pattern](spec, **self.kwargs)
        for name in out:
            spec.class_index(name)      # typed against declared classes
            if len(out[name]) == 2:     # pattern predates the write flag
                t, d = out[name]
                out[name] = (t, d, np.zeros_like(np.asarray(t, np.int32)))
        for cls in spec.classes:
            out.setdefault(cls.name, _empty(spec.n_routers))
        return out


# --------------------------------------------------------------------- #
# helpers shared by the patterns
# --------------------------------------------------------------------- #
def _empty(R: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    return (np.full((R, 1), BIG, np.int32), np.zeros((R, 1), np.int32),
            np.zeros((R, 1), np.int32))


def _per_class(spec: NocSpec, m: Mapping[str, Any] | None,
               default) -> dict[str, Any]:
    m = dict(m or {})
    for name in m:
        spec.class_index(name)          # raises on undeclared class
    return {c.name: m.get(c.name, default) for c in spec.classes}


def _per_class_frac(spec: NocSpec,
                    wf: Mapping[str, float] | float) -> dict[str, float]:
    """Normalize a write_frac argument (scalar = every class)."""
    if isinstance(wf, Mapping):
        return _per_class(spec, wf, 0.0)
    return {c.name: float(wf) for c in spec.classes}


def _check_tile(spec: NocSpec, name: str, tile: int) -> int:
    if not 0 <= tile < spec.n_routers:
        raise ValueError(
            f"{name}={tile} outside the {spec.nx}x{spec.ny} mesh "
            f"(0..{spec.n_routers - 1})")
    return tile


def _gap(rate: float, stretch: int) -> int:
    return max(1, int(round(stretch / rate)))


def _ramp(rate: float, count: int, stretch: int = 1,
          start: int = 10) -> np.ndarray:
    """Evenly spaced inject times, the seed's deterministic schedule."""
    if rate <= 0 or count <= 0:
        return np.full((1,), BIG, np.int32)
    return (start + np.arange(count) * _gap(rate, stretch)).astype(np.int32)


def _no_self_dests(rng: np.random.Generator, R: int,
                   count: int) -> np.ndarray:
    """Uniform destinations excluding self: draw from [0, R-1) then shift
    past the source so dest == src is impossible (for R > 1)."""
    if R <= 1:
        return np.zeros((R, count), np.int32)
    draws = rng.integers(0, R - 1, size=(R, count)).astype(np.int32)
    return (draws + 1 + np.arange(R)[:, None]).astype(np.int32) % R


def _rand_writes(seed: int, cls_idx: int, R: int, count: int,
                 wf: float) -> np.ndarray:
    """Seeded write flags for the random patterns, drawn from an rng
    stream INDEPENDENT of the times/dests draws and keyed per class —
    turning the mix knob for one class must never reshuffle any
    class's schedule (the sweep would confound the knob with a reroll
    of the background traffic)."""
    if not 0.0 <= wf <= 1.0:
        raise ValueError(f"write_frac must be in [0, 1], got {wf}")
    if wf <= 0:
        return np.zeros((R, count), np.int32)
    wrng = np.random.default_rng([seed, cls_idx, 0xA11])
    return (wrng.random((R, count)) < wf).astype(np.int32)


def _mix_writes(count: int, wf: float) -> np.ndarray:
    """Deterministic evenly-interleaved write flags: transaction ``j``
    is a write iff ``floor((j+1)*wf) > floor(j*wf)`` — exactly
    ``round(count*wf)``-ish writes, spread through the sequence, with
    ``wf=0`` all-reads and ``wf=1`` all-writes."""
    if not 0.0 <= wf <= 1.0:
        raise ValueError(f"write_frac must be in [0, 1], got {wf}")
    j = np.arange(max(count, 1), dtype=np.float64)
    return (np.floor((j + 1) * wf) > np.floor(j * wf)).astype(np.int32)


class _Builder:
    """Accumulates per-NI schedules into dense sorted (R, T) arrays."""

    def __init__(self, R: int):
        self.R = R
        self.rows: list[list[tuple[int, int, int]]] = [[] for _ in range(R)]

    def add(self, src: int, times: np.ndarray, dests, writes=0) -> None:
        dests = np.broadcast_to(np.asarray(dests, np.int32), times.shape)
        writes = np.broadcast_to(np.asarray(writes, np.int32), times.shape)
        for t, d, w in zip(times.tolist(), dests.tolist(), writes.tolist()):
            if t < BIG:
                self.rows[src].append((t, d, w))

    def build(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        T = max(1, max(len(r) for r in self.rows))
        times = np.full((self.R, T), BIG, np.int32)
        dests = np.zeros((self.R, T), np.int32)
        writes = np.zeros((self.R, T), np.int32)
        for s, r in enumerate(self.rows):
            r.sort()
            for j, (t, d, w) in enumerate(r):
                times[s, j] = t
                dests[s, j] = d
                writes[s, j] = w
        return times, dests, writes


# --------------------------------------------------------------------- #
# patterns
# --------------------------------------------------------------------- #
@register_pattern("fig5")
def fig5(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
         counts: Mapping[str, int] | None = None, src: int | None = None,
         dst: int | None = None, bidir: bool = False,
         write_frac: Mapping[str, float] | float = 0.0) -> dict:
    """Cluster-to-cluster accesses between two tiles (paper Fig. 5).

    Each class issues ``counts[cls]`` transactions at ``rates[cls]``
    flits/cycle from src to dst (burst classes scale the address-flow
    gap by their burst length, so rate 1.0 means back-to-back bursts);
    ``bidir`` mirrors the traffic dst -> src.  ``write_frac[cls]`` of
    the transactions are writes (AW/W/B), evenly interleaved.
    """
    R = spec.n_routers
    src = 0 if src is None else _check_tile(spec, "src", src)
    dst = R - 1 if dst is None else _check_tile(spec, "dst", dst)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    wfrac = _per_class_frac(spec, write_frac)
    out = {}
    for cls in spec.classes:
        b = _Builder(R)
        times = _ramp(rates[cls.name], counts[cls.name],
                      stretch=cls.burst_beats)
        wr = _mix_writes(times.shape[0], wfrac[cls.name])
        b.add(src, times, dst, wr)
        if bidir:
            b.add(dst, times, src, wr)
        out[cls.name] = b.build()
    return out


@register_pattern("uniform_random")
def uniform_random(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
                   counts: Mapping[str, int] | None = None,
                   seed: int = 0,
                   write_frac: Mapping[str, float] | float = 0.0) -> dict:
    """Uniform-random background traffic (all NIs, random non-self dests,
    each transaction a write with probability ``write_frac[cls]``)."""
    R = spec.n_routers
    rng = np.random.default_rng(seed)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    wfrac = _per_class_frac(spec, write_frac)
    out = {}
    for ci, cls in enumerate(spec.classes):
        rate, count = rates[cls.name], counts[cls.name]
        if count <= 0 or rate <= 0:
            out[cls.name] = _empty(R)
            continue
        gap = _gap(rate, cls.burst_beats)
        times = 10 + np.cumsum(rng.integers(1, 2 * gap, size=(R, count)),
                               axis=1).astype(np.int32)
        dests = _no_self_dests(rng, R, count)
        out[cls.name] = (times.astype(np.int32), dests,
                         _rand_writes(seed, ci, R, count,
                                      wfrac[cls.name]))
    return out


@register_pattern("hotspot")
def hotspot(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
            counts: Mapping[str, int] | None = None,
            hot: int | None = None, hot_frac: float = 0.5,
            seed: int = 0,
            write_frac: Mapping[str, float] | float = 0.0) -> dict:
    """Uniform-random traffic with a fraction converging on one hot tile
    (memory-controller / parameter-server congestion archetype; with
    ``write_frac`` the hot tile absorbs write bursts — the DMA-into-HBM
    shape)."""
    R = spec.n_routers
    if hot is None:
        hot = (spec.ny // 2) * spec.nx + spec.nx // 2
    else:
        _check_tile(spec, "hot", hot)
    rng = np.random.default_rng(seed)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    wfrac = _per_class_frac(spec, write_frac)
    out = {}
    for ci, cls in enumerate(spec.classes):
        rate, count = rates[cls.name], counts[cls.name]
        if count <= 0 or rate <= 0:
            out[cls.name] = _empty(R)
            continue
        gap = _gap(rate, cls.burst_beats)
        times = 10 + np.cumsum(rng.integers(1, 2 * gap, size=(R, count)),
                               axis=1).astype(np.int32)
        dests = _no_self_dests(rng, R, count)
        to_hot = rng.random((R, count)) < hot_frac
        dests = np.where(to_hot, hot, dests).astype(np.int32)
        # the hot tile itself keeps its uniform destinations
        if R > 1:
            dests[hot] = _no_self_dests(
                np.random.default_rng(seed + 1), R, count)[hot]
        out[cls.name] = (times, dests,
                         _rand_writes(seed, ci, R, count,
                                      wfrac[cls.name]))
    return out


@register_pattern("transpose")
def transpose(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
              counts: Mapping[str, int] | None = None,
              write_frac: Mapping[str, float] | float = 0.0) -> dict:
    """Matrix-transpose permutation: tile (x, y) targets tile (y, x).
    Requires a square mesh; diagonal tiles stay silent."""
    if spec.nx != spec.ny:
        raise ValueError("transpose pattern needs a square mesh")
    R = spec.n_routers
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    wfrac = _per_class_frac(spec, write_frac)
    out = {}
    for cls in spec.classes:
        b = _Builder(R)
        times = _ramp(rates[cls.name], counts[cls.name],
                      stretch=cls.burst_beats)
        wr = _mix_writes(times.shape[0], wfrac[cls.name])
        for r in range(R):
            x, y = r % spec.nx, r // spec.nx
            d = x * spec.nx + y
            if d != r:
                b.add(r, times, d, wr)
        out[cls.name] = b.build()
    return out


@register_pattern("all_to_all")
def all_to_all(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
               rounds: Mapping[str, int] | None = None,
               write_frac: Mapping[str, float] | float = 0.0) -> dict:
    """Every NI sweeps all other tiles in src-staggered round-robin order
    (the DNN all-to-all / expert-exchange phase PATRONoC stresses; a
    50/50 ``write_frac`` makes it the push+pull expert exchange)."""
    R = spec.n_routers
    rates = _per_class(spec, rates, 0.0)
    rounds = _per_class(spec, rounds, 0)
    wfrac = _per_class_frac(spec, write_frac)
    out = {}
    for cls in spec.classes:
        rate, n_rounds = rates[cls.name], rounds[cls.name]
        count = n_rounds * (R - 1)
        if count <= 0 or rate <= 0 or R <= 1:
            out[cls.name] = _empty(R)
            continue
        b = _Builder(R)
        times = _ramp(rate, count, stretch=cls.burst_beats)
        wr = _mix_writes(times.shape[0], wfrac[cls.name])
        offs = np.arange(count) % (R - 1)        # 0..R-2 repeated
        for s in range(R):
            dests = (s + 1 + offs) % R           # sweeps all non-self tiles
            b.add(s, times, dests, wr)
        out[cls.name] = b.build()
    return out


