"""Declarative traffic workloads typed against a NocSpec's classes.

A :class:`Workload` names a registered *pattern* plus per-class
parameters (rates in flits/cycle, transaction counts).  Patterns
produce, for every declared :class:`~repro.noc.spec.TrafficClass`, a
dense ``(R, T)`` schedule of desired inject times (sorted per NI; an
entry at/after ``BIG`` disables the slot) and destinations, generalized
from the seed's hardcoded narrow/wide pair to the spec's declared class
list.

Built-in patterns:

* ``fig5``           — paper Fig. 5 cluster-to-cluster pair traffic,
* ``uniform_random`` — uniform-random background from every NI (with
  the seed's self-traffic remap bug fixed),
* ``hotspot``        — a fraction of traffic converges on one hot tile,
* ``transpose``      — tile (x, y) talks to tile (y, x),
* ``all_to_all``     — every NI sweeps all other tiles round-robin
  (PATRONoC-style DNN all-to-all phase).

Rates/counts referencing a class name the spec does not declare raise
immediately — workloads are typed against the spec, not stringly glued.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import numpy as np

from .spec import NocSpec

BIG = 1 << 30

PATTERNS: dict[str, Callable] = {}


def register_pattern(name: str):
    def deco(fn):
        PATTERNS[name] = fn
        return fn
    return deco


# dicts are frozen to a tagged tuple so thawing is exact (a user pattern
# taking a literal sequence of (str, value) pairs is NOT turned into a dict)
_DICT_TAG = "__frozen_mapping__"


def _freeze(v):
    if isinstance(v, Mapping):
        return (_DICT_TAG,
                tuple(sorted((k, _freeze(x)) for k, x in v.items())))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def _thaw(v):
    if (isinstance(v, tuple) and len(v) == 2 and v[0] == _DICT_TAG
            and isinstance(v[1], tuple)):
        return {k: _thaw(x) for k, x in v[1]}
    if isinstance(v, tuple):
        return tuple(_thaw(x) for x in v)
    return v


@dataclass(frozen=True)
class Workload:
    """A named traffic pattern with (frozen, hashable) parameters."""
    pattern: str
    params: tuple = ()

    @classmethod
    def make(cls, pattern: str, **params) -> "Workload":
        if pattern not in PATTERNS:
            raise KeyError(
                f"unknown pattern {pattern!r}; have {sorted(PATTERNS)}")
        # top level is always kwargs: store as plain (name, frozen) pairs
        return cls(pattern, tuple(sorted(
            (k, _freeze(v)) for k, v in params.items())))

    @property
    def kwargs(self) -> dict[str, Any]:
        return {k: _thaw(v) for k, v in self.params}

    def schedules(self, spec: NocSpec) -> dict[str, tuple[np.ndarray,
                                                          np.ndarray]]:
        """Per-class (times, dests) arrays, one entry per declared class."""
        out = PATTERNS[self.pattern](spec, **self.kwargs)
        for name in out:
            spec.class_index(name)      # typed against declared classes
        for cls in spec.classes:
            out.setdefault(cls.name, _empty(spec.n_routers))
        return out


# --------------------------------------------------------------------- #
# helpers shared by the patterns
# --------------------------------------------------------------------- #
def _empty(R: int) -> tuple[np.ndarray, np.ndarray]:
    return (np.full((R, 1), BIG, np.int32), np.zeros((R, 1), np.int32))


def _per_class(spec: NocSpec, m: Mapping[str, Any] | None,
               default) -> dict[str, Any]:
    m = dict(m or {})
    for name in m:
        spec.class_index(name)          # raises on undeclared class
    return {c.name: m.get(c.name, default) for c in spec.classes}


def _check_tile(spec: NocSpec, name: str, tile: int) -> int:
    if not 0 <= tile < spec.n_routers:
        raise ValueError(
            f"{name}={tile} outside the {spec.nx}x{spec.ny} mesh "
            f"(0..{spec.n_routers - 1})")
    return tile


def _gap(rate: float, stretch: int) -> int:
    return max(1, int(round(stretch / rate)))


def _ramp(rate: float, count: int, stretch: int = 1,
          start: int = 10) -> np.ndarray:
    """Evenly spaced inject times, the seed's deterministic schedule."""
    if rate <= 0 or count <= 0:
        return np.full((1,), BIG, np.int32)
    return (start + np.arange(count) * _gap(rate, stretch)).astype(np.int32)


def _no_self_dests(rng: np.random.Generator, R: int,
                   count: int) -> np.ndarray:
    """Uniform destinations excluding self: draw from [0, R-1) then shift
    past the source so dest == src is impossible (for R > 1)."""
    if R <= 1:
        return np.zeros((R, count), np.int32)
    draws = rng.integers(0, R - 1, size=(R, count)).astype(np.int32)
    return (draws + 1 + np.arange(R)[:, None]).astype(np.int32) % R


class _Builder:
    """Accumulates per-NI schedules into dense sorted (R, T) arrays."""

    def __init__(self, R: int):
        self.R = R
        self.rows: list[list[tuple[int, int]]] = [[] for _ in range(R)]

    def add(self, src: int, times: np.ndarray, dests) -> None:
        dests = np.broadcast_to(np.asarray(dests, np.int32), times.shape)
        for t, d in zip(times.tolist(), dests.tolist()):
            if t < BIG:
                self.rows[src].append((t, d))

    def build(self) -> tuple[np.ndarray, np.ndarray]:
        T = max(1, max(len(r) for r in self.rows))
        times = np.full((self.R, T), BIG, np.int32)
        dests = np.zeros((self.R, T), np.int32)
        for s, r in enumerate(self.rows):
            r.sort()
            for j, (t, d) in enumerate(r):
                times[s, j] = t
                dests[s, j] = d
        return times, dests


# --------------------------------------------------------------------- #
# patterns
# --------------------------------------------------------------------- #
@register_pattern("fig5")
def fig5(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
         counts: Mapping[str, int] | None = None, src: int | None = None,
         dst: int | None = None, bidir: bool = False) -> dict:
    """Cluster-to-cluster accesses between two tiles (paper Fig. 5).

    Each class issues ``counts[cls]`` reads at ``rates[cls]`` flits/cycle
    from src to dst (burst classes scale the AR gap by their burst
    length, so rate 1.0 means back-to-back bursts); ``bidir`` mirrors
    the traffic dst -> src.
    """
    R = spec.n_routers
    src = 0 if src is None else _check_tile(spec, "src", src)
    dst = R - 1 if dst is None else _check_tile(spec, "dst", dst)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    out = {}
    for cls in spec.classes:
        b = _Builder(R)
        times = _ramp(rates[cls.name], counts[cls.name],
                      stretch=cls.burst_beats)
        b.add(src, times, dst)
        if bidir:
            b.add(dst, times, src)
        out[cls.name] = b.build()
    return out


@register_pattern("uniform_random")
def uniform_random(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
                   counts: Mapping[str, int] | None = None,
                   seed: int = 0) -> dict:
    """Uniform-random background traffic (all NIs, random non-self dests)."""
    R = spec.n_routers
    rng = np.random.default_rng(seed)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    out = {}
    for cls in spec.classes:
        rate, count = rates[cls.name], counts[cls.name]
        if count <= 0 or rate <= 0:
            out[cls.name] = _empty(R)
            continue
        gap = _gap(rate, cls.burst_beats)
        times = 10 + np.cumsum(rng.integers(1, 2 * gap, size=(R, count)),
                               axis=1).astype(np.int32)
        out[cls.name] = (times.astype(np.int32),
                         _no_self_dests(rng, R, count))
    return out


@register_pattern("hotspot")
def hotspot(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
            counts: Mapping[str, int] | None = None,
            hot: int | None = None, hot_frac: float = 0.5,
            seed: int = 0) -> dict:
    """Uniform-random traffic with a fraction converging on one hot tile
    (memory-controller / parameter-server congestion archetype)."""
    R = spec.n_routers
    if hot is None:
        hot = (spec.ny // 2) * spec.nx + spec.nx // 2
    else:
        _check_tile(spec, "hot", hot)
    rng = np.random.default_rng(seed)
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    out = {}
    for cls in spec.classes:
        rate, count = rates[cls.name], counts[cls.name]
        if count <= 0 or rate <= 0:
            out[cls.name] = _empty(R)
            continue
        gap = _gap(rate, cls.burst_beats)
        times = 10 + np.cumsum(rng.integers(1, 2 * gap, size=(R, count)),
                               axis=1).astype(np.int32)
        dests = _no_self_dests(rng, R, count)
        to_hot = rng.random((R, count)) < hot_frac
        dests = np.where(to_hot, hot, dests).astype(np.int32)
        # the hot tile itself keeps its uniform destinations
        if R > 1:
            dests[hot] = _no_self_dests(
                np.random.default_rng(seed + 1), R, count)[hot]
        out[cls.name] = (times, dests)
    return out


@register_pattern("transpose")
def transpose(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
              counts: Mapping[str, int] | None = None) -> dict:
    """Matrix-transpose permutation: tile (x, y) targets tile (y, x).
    Requires a square mesh; diagonal tiles stay silent."""
    if spec.nx != spec.ny:
        raise ValueError("transpose pattern needs a square mesh")
    R = spec.n_routers
    rates = _per_class(spec, rates, 0.0)
    counts = _per_class(spec, counts, 0)
    out = {}
    for cls in spec.classes:
        b = _Builder(R)
        times = _ramp(rates[cls.name], counts[cls.name],
                      stretch=cls.burst_beats)
        for r in range(R):
            x, y = r % spec.nx, r // spec.nx
            d = x * spec.nx + y
            if d != r:
                b.add(r, times, d)
        out[cls.name] = b.build()
    return out


@register_pattern("all_to_all")
def all_to_all(spec: NocSpec, *, rates: Mapping[str, float] | None = None,
               rounds: Mapping[str, int] | None = None) -> dict:
    """Every NI sweeps all other tiles in src-staggered round-robin order
    (the DNN all-to-all / expert-exchange phase PATRONoC stresses)."""
    R = spec.n_routers
    rates = _per_class(spec, rates, 0.0)
    rounds = _per_class(spec, rounds, 0)
    out = {}
    for cls in spec.classes:
        rate, n_rounds = rates[cls.name], rounds[cls.name]
        count = n_rounds * (R - 1)
        if count <= 0 or rate <= 0 or R <= 1:
            out[cls.name] = _empty(R)
            continue
        b = _Builder(R)
        times = _ramp(rate, count, stretch=cls.burst_beats)
        offs = np.arange(count) % (R - 1)        # 0..R-2 repeated
        for s in range(R):
            dests = (s + 1 + offs) % R           # sweeps all non-self tiles
            b.add(s, times, dests)
        out[cls.name] = b.build()
    return out


