"""`simulate(spec, workload)` — the one entry point for NoC experiments.

The static half of an experiment (mesh dims, channel topology, FIFO
depths, cycle horizon, AXI flow map) lives in the frozen
:class:`NocSpec` and keys a cached jitted simulator; the dynamic half
(schedules + read/write mix, per-class service latency and jitter,
outstanding limits, burst lengths) are traced operands.  That split is
what makes sweeps cheap:

* :func:`simulate`        — one spec + one workload -> one SimResult,
* :func:`simulate_batch`  — one spec + N workloads (and optionally
  per-point scalar overrides) -> ONE vmapped jit call returning a
  batched SimResult, bit-identical to N individual runs,
* :func:`sweep`           — arbitrary (spec, workload) points; points
  sharing a static spec are grouped into vmapped batches, and (with
  ``pad_depths``, the default) points whose specs differ ONLY in
  channel FIFO depths are grouped too: depth is a traced operand
  masked against the group max, so a whole depth sweep shares one
  compilation (``sim_cache_stats()`` counts it).  Points that differ
  in any other static field (e.g. channel count) compile per group.

Per-class service-latency *distributions*: ``service_lat`` accepts one
int (every class) or a per-class vector of means; ``service_jitter``
adds a per-request uniform offset in ``[-j, +j]`` drawn from a seeded
static table (``jitter_seed``), so the target NIs answer after
``mean + offset`` cycles.  Both are traced operands — a latency-
distribution sweep vmaps like a rate sweep — and ``jitter=0``
reproduces the deterministic model bit-for-bit.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BIG, JITTER_TABLE_LEN, compiled_sim
from .result import SimResult
from .spec import NocSpec
from .workload import Workload

__all__ = ["simulate", "simulate_batch", "simulate_schedules", "sweep",
           "stack_schedules"]


def _verify(spec: NocSpec, verify: str) -> None:
    """Static-analysis gate (lazy import: repro.noc.analyze depends on
    this package's spec/engine modules)."""
    from .analyze import verify_spec
    verify_spec(spec, verify)


def _split_streams(cls_name, t, d, w, s, S):
    """Partition one class's per-NI schedule rows into ``S`` per-stream
    lanes, preserving each NI's entry order within a stream.  Rows are
    compacted with a stable argsort (stream-s entries first, original
    order kept) and re-padded with BIG sentinels."""
    valid = t < BIG
    if s is None:
        # 3-tuple schedule on a multi-stream class: deal entries
        # round-robin across the AXI ID streams per NI
        s = np.where(valid, (np.cumsum(valid, axis=1) - 1) % S, 0)
    else:
        bad = valid & ((s < 0) | (s >= S))
        if np.any(bad):
            raise ValueError(
                f"class {cls_name!r}: stream ids must be in [0, "
                f"n_streams={S}); got {np.unique(s[bad])}")
    lanes = []
    for si in range(S):
        mask = valid & (s == si)
        order = np.argsort(~mask, axis=1, kind="stable")
        mm = np.take_along_axis(mask, order, axis=1)
        width = max(1, int(mask.sum(axis=1).max()))
        tt = np.where(mm, np.take_along_axis(t, order, axis=1), BIG)
        dd = np.where(mm, np.take_along_axis(d, order, axis=1), 0)
        ww = np.where(mm, np.take_along_axis(w, order, axis=1), 0)
        lanes.append((tt[:, :width], dd[:, :width], ww[:, :width]))
    return lanes


def stack_schedules(spec: NocSpec,
                    schedules: Mapping[str, tuple],
                    T: int | None = None) -> tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    """Pad per-class ``(times, dests[, writes[, streams]])`` schedules
    to a common horizon and stack into the (n_lanes, R, T) operands the
    engine consumes — one lane per (class, AXI ID stream), class-major.
    A 2-tuple entry (a custom schedule source predating the write flag)
    is treated as all-reads; a 3-tuple on a class with ``n_streams >
    1`` is dealt round-robin across its streams; a 4-tuple's ``streams``
    array assigns each entry's AXI ID stream explicitly.  Classes at
    the default ``n_streams=1`` pass through without repacking, so
    single-stream operands are bit-identical to the pre-stream layout
    (n_lanes == n_cls)."""
    R = spec.n_routers
    per_lane = []
    for cls in spec.classes:
        entry = schedules[cls.name]
        t, d = entry[0], entry[1]
        t = np.asarray(t, np.int32).reshape(R, -1)
        d = np.asarray(d, np.int32).reshape(R, -1)
        w = (np.asarray(entry[2], np.int32).reshape(R, -1)
             if len(entry) > 2 and entry[2] is not None
             else np.zeros_like(t))
        s = (np.asarray(entry[3], np.int32).reshape(R, -1)
             if len(entry) > 3 and entry[3] is not None else None)
        for name, a in (("writes", w), ("streams", s)):
            if a is not None and a.shape != t.shape:
                raise ValueError(
                    f"class {cls.name!r}: {name} shape {a.shape} != "
                    f"times shape {t.shape}")
        if cls.n_streams == 1:
            # stream ids collapse onto the single AXI ID (so one
            # 4-tuple schedule compares n_streams settings directly)
            per_lane.append((t, d, w))
        else:
            per_lane.extend(_split_streams(cls.name, t, d, w, s,
                                           cls.n_streams))
    T_need = max(t.shape[1] for t, _, _ in per_lane)
    T = T_need if T is None else max(T, T_need)
    times = np.full((len(per_lane), R, T), BIG, np.int32)
    dests = np.zeros((len(per_lane), R, T), np.int32)
    writes = np.zeros((len(per_lane), R, T), np.int32)
    for i, (t, d, w) in enumerate(per_lane):
        times[i, :, :t.shape[1]] = t
        dests[i, :, :d.shape[1]] = d
        writes[i, :, :w.shape[1]] = w
    return times, dests, writes


def _per_class_vec(spec: NocSpec, v, default, name) -> np.ndarray:
    """Normalize a scalar-or-per-class knob to an (n_cls,) int32 vector."""
    n_cls = len(spec.classes)
    if v is None:
        v = np.asarray(default, np.int32)
    v = np.asarray(v, np.int32)
    if v.ndim == 0:
        return np.full((n_cls,), int(v), np.int32)
    if v.shape != (n_cls,):
        raise ValueError(
            f"{name} must be a scalar or length-{n_cls} per-class "
            f"vector; got shape {v.shape}")
    return v


def _dyn_scalars(spec: NocSpec, service_lat, max_outstanding, burst_beats):
    sl = _per_class_vec(
        spec, service_lat,
        [spec.service_lat if c.service_lat is None else c.service_lat
         for c in spec.classes], "service_lat")
    mo = _per_class_vec(spec, max_outstanding,
                        [c.max_outstanding for c in spec.classes],
                        "max_outstanding")
    bb = _per_class_vec(spec, burst_beats,
                        [c.burst_beats for c in spec.classes],
                        "burst_beats")
    return sl, mo, bb


def jitter_table(spec: NocSpec, service_jitter=None, *, seed: int = 0,
                 service_lat=None) -> np.ndarray:
    """Seeded static per-class jitter offsets, shape
    ``(n_cls, JITTER_TABLE_LEN)``: row ``i`` holds uniform draws from
    ``[-j_i, +j_i]`` (clipped so ``mean + offset >= 0``), indexed by
    (issuing NI, transaction id) inside the engine so the draws
    decorrelate across sources.  ``service_jitter=0`` rows are exactly
    zero — the deterministic model.  The table is a traced operand:
    sweeping jitter re-runs, never re-compiles."""
    jit = _per_class_vec(spec, service_jitter,
                         [c.service_jitter for c in spec.classes],
                         "service_jitter")
    if np.any(jit < 0):
        raise ValueError(f"service_jitter must be >= 0, got {jit}")
    sl, _, _ = _dyn_scalars(spec, service_lat, None, None)
    rng = np.random.default_rng(np.uint32(0xF100) + np.uint32(seed))
    tab = rng.integers(-jit[:, None], jit[:, None] + 1,
                       size=(len(spec.classes), JITTER_TABLE_LEN))
    return np.maximum(tab, -sl[:, None]).astype(np.int32)


def _depths(spec: NocSpec) -> np.ndarray:
    return np.asarray([ch.depth for ch in spec.channels], np.int32)


def _fault_ops(spec: NocSpec, timeout_cycles=None, max_retries=None,
               backoff_base=None) -> tuple:
    """The five extra traced operands of a faulted simulator (empty
    tuple when ``spec.faults is None`` — the healthy signature).  The
    keyword overrides shadow the FaultModel's declared robustness knobs
    without recompiling, exactly like ``service_lat`` etc."""
    if spec.faults is None:
        for name, v in (("timeout_cycles", timeout_cycles),
                        ("max_retries", max_retries),
                        ("backoff_base", backoff_base)):
            if v is not None:
                raise ValueError(
                    f"{name} override requires spec.faults (a FaultModel)")
        return ()
    from .faults import dynamic_events
    fm = spec.faults
    ev_fail, ev_heal, _ = dynamic_events(spec.topology, spec.routing, fm,
                                         spec.cycles)
    tmo = _per_class_vec(spec, timeout_cycles, fm.timeout_cycles,
                         "timeout_cycles")
    mr = np.int32(fm.max_retries if max_retries is None else max_retries)
    bo = np.int32(fm.backoff_base if backoff_base is None
                  else backoff_base)
    if bo < 1:
        raise ValueError(f"backoff_base must be >= 1, got {int(bo)}")
    return (ev_fail, ev_heal, tmo, mr, bo)


def _check_dead_traffic(spec: NocSpec, times: np.ndarray,
                        dests: np.ndarray) -> None:
    """Traffic sourced at or destined to a statically dead node is a
    workload/fault contradiction — reject it up front instead of
    reporting an undrained run."""
    fm = spec.faults
    if fm is None or not fm.dead_nodes:
        return
    dead = np.asarray(sorted(set(fm.dead_nodes)), np.int32)
    valid = times < BIG
    if valid[:, dead, :].any():
        bad = dead[valid[:, dead, :].any(axis=(0, 2))]
        raise ValueError(
            f"schedule sources traffic at dead node(s) {bad.tolist()}")
    to_dead = valid & np.isin(dests, dead)
    if to_dead.any():
        bad = sorted(set(dests[to_dead].tolist()))
        raise ValueError(
            f"schedule targets dead node(s) {bad}")


def simulate_schedules(spec: NocSpec,
                       schedules: Mapping[str, tuple],
                       *, service_lat=None,
                       max_outstanding: Sequence[int] | None = None,
                       burst_beats: Sequence[int] | None = None,
                       service_jitter=None, jitter_seed: int = 0,
                       timeout_cycles=None, max_retries=None,
                       backoff_base=None,
                       backend: str = "jnp",
                       verify: str = "fast",
                       shard=None) -> SimResult:
    """Run one experiment from raw per-class ``(times, dests[, writes])``
    schedules (the layer custom schedule sources go through).

    ``verify`` gates the static-analysis pass from
    :mod:`repro.noc.analyze` before any cycle is simulated: ``"fast"``
    (default) re-runs the cheap protocol/credit checks NocSpec
    construction already enforces, ``"full"`` adds the
    channel-dependency deadlock proof and route-table lint (lru-cached
    per (topology, routing) — e.g. a VC-less torus spec is rejected
    with the offending (link, VC) cycle instead of wedging), ``"off"``
    skips verification (how the wedge regressions simulate the
    documented-deadlocky configs on purpose).

    On a spec with a :class:`~repro.noc.faults.FaultModel`,
    ``timeout_cycles``/``max_retries``/``backoff_base`` shadow the
    model's declared NI robustness knobs (traced — no recompile) and
    the result carries :class:`~repro.noc.result.FaultStats`.

    ``shard=RowShard(n)`` (:mod:`repro.noc.farm`) spatially shards the
    fabric's router rows across ``n`` local devices with a per-cycle
    halo exchange of boundary-link state — flit-for-flit identical to
    the single-device engine; requires a plain Mesh/Torus, the
    ``jnp`` backend and a fault-free spec."""
    _verify(spec, verify)
    times, dests, writes = stack_schedules(spec, schedules)
    _check_dead_traffic(spec, times, dests)
    sl, mo, bb = _dyn_scalars(spec, service_lat, max_outstanding,
                              burst_beats)
    jt = jitter_table(spec, service_jitter, seed=jitter_seed,
                      service_lat=service_lat)
    fops = _fault_ops(spec, timeout_cycles, max_retries, backoff_base)
    if shard is not None:
        from .farm import compiled_rowshard_sim
        fn = compiled_rowshard_sim(spec, times.shape[-1], shard,
                                   backend=backend)
    else:
        fn = compiled_sim(spec, times.shape[-1], backend)
    raw = fn(times, dests, writes, sl, mo, bb, jt, _depths(spec), *fops)
    return SimResult.from_raw(spec, raw)


def simulate(spec: NocSpec, workload: Workload, *,
             service_lat=None,
             max_outstanding: Sequence[int] | None = None,
             burst_beats: Sequence[int] | None = None,
             service_jitter=None, jitter_seed: int = 0,
             timeout_cycles=None, max_retries=None, backoff_base=None,
             backend: str = "jnp", verify: str = "fast",
             shard=None) -> SimResult:
    """Run one experiment; scalar keyword overrides shadow the spec's
    declared values without recompiling (they are traced operands).
    ``service_lat``/``service_jitter`` take one int or a per-class
    vector — the per-class service-latency distribution.  ``backend``
    picks the router hot-loop implementation ("jnp" reference, the
    "pallas" arbiter kernel, or the fused "pallas_fused" full-cycle
    kernel — see :mod:`repro.noc.backends`); results are
    backend-invariant.  ``verify="full"`` statically rejects
    deadlock-prone specs before stepping (see
    :func:`simulate_schedules` / :mod:`repro.noc.analyze`).  The NI
    robustness knobs (``timeout_cycles``/``max_retries``/
    ``backoff_base``) require a spec with a FaultModel.
    ``shard=RowShard(n)`` row-shards one big fabric across ``n`` local
    devices (:mod:`repro.noc.farm` tier b), flit-for-flit identical."""
    return simulate_schedules(spec, workload.schedules(spec),
                              service_lat=service_lat,
                              max_outstanding=max_outstanding,
                              burst_beats=burst_beats,
                              service_jitter=service_jitter,
                              jitter_seed=jitter_seed,
                              timeout_cycles=timeout_cycles,
                              max_retries=max_retries,
                              backoff_base=backoff_base, backend=backend,
                              verify=verify, shard=shard)


def simulate_batch(spec: NocSpec, workloads: Sequence[Workload], *,
                   service_lat=None, max_outstanding=None,
                   burst_beats=None, service_jitter=None,
                   jitter_seed: int = 0,
                   backend: str = "jnp",
                   verify: str = "fast") -> SimResult:
    """Run N operating points in ONE vmapped jit call.

    ``workloads`` supplies per-point schedules (rate/seed/pattern/mix
    sweeps). The knobs (``service_lat``, ``max_outstanding``,
    ``burst_beats``, ``service_jitter``) each take one int (all
    classes, all points), a length-N sequence (swept per point), a
    length-n_cls vector (per-class, broadcast across points), or an
    (N, n_cls) array (fully swept).  When N == n_cls a 1-D vector is
    ambiguous and resolves to each knob's historical meaning —
    per-point for ``service_lat``, per-class for the rest; pass the
    explicit (N, n_cls) form to be unambiguous.  Returns a SimResult
    whose arrays carry a leading sweep axis.
    """
    n = len(workloads)
    if n == 0:
        raise ValueError("empty sweep")
    _verify(spec, verify)
    fops = _fault_ops(spec)    # fault knobs stay spec-declared per batch
    per_point = [wl.schedules(spec) for wl in workloads]
    T = max(max(np.asarray(t).reshape(spec.n_routers, -1).shape[1]
                for t, *_ in sched.values()) for sched in per_point)
    stacked = [stack_schedules(spec, sched, T=T) for sched in per_point]
    times = np.stack([t for t, _, _ in stacked])       # (n, n_cls, R, T)
    dests = np.stack([d for _, d, _ in stacked])
    writes = np.stack([w for _, _, w in stacked])
    n_cls = len(spec.classes)

    def per_class_axis(v, default, name, prefer):
        """scalar -> all classes; (n,) -> per-point; (n_cls,) ->
        broadcast; (n, n_cls) -> swept.  When N == n_cls a 1-D vector
        is ambiguous: ``prefer`` resolves it to the knob's historical
        meaning (per-point for ``service_lat``, per-class for the
        per-class knobs) — pass an explicit 2-D array to override."""
        if v is None:
            return _per_class_vec(spec, None, default, name), None
        v = np.asarray(v, np.int32)
        if v.ndim == 0:
            return np.full((n_cls,), int(v), np.int32), None
        interps = [("point", (n,)), ("class", (n_cls,))]
        interps.sort(key=lambda it: it[0] != prefer)
        for how, shape in interps:
            if v.shape != shape:
                continue
            if how == "point":     # per-point scalar, swept
                return np.broadcast_to(v[:, None], (n, n_cls)).copy(), 0
            return v, None
        if v.shape == (n, n_cls):
            return v, 0
        raise ValueError(
            f"{name} must be a scalar, length-{n} sweep, ({n_cls},) "
            f"per-class vector, or ({n}, {n_cls}) array; got shape "
            f"{v.shape}")

    sl, sl_ax = per_class_axis(
        service_lat,
        [spec.service_lat if c.service_lat is None else c.service_lat
         for c in spec.classes], "service_lat", prefer="point")
    mo, mo_ax = per_class_axis(
        max_outstanding, [c.max_outstanding for c in spec.classes],
        "max_outstanding", prefer="class")
    bb, bb_ax = per_class_axis(
        burst_beats, [c.burst_beats for c in spec.classes], "burst_beats",
        prefer="class")
    jit, jit_ax = per_class_axis(
        service_jitter, [c.service_jitter for c in spec.classes],
        "service_jitter", prefer="class")
    if sl_ax is None and jit_ax is None:
        jt = jitter_table(spec, jit, seed=jitter_seed, service_lat=sl)
        jt_ax = None
    else:                              # per-point means/jitter widths
        jt = np.stack([jitter_table(
            spec, jit[i] if jit_ax == 0 else jit, seed=jitter_seed,
            service_lat=sl[i] if sl_ax == 0 else sl) for i in range(n)])
        jt_ax = 0

    for t, d in ((times[i], dests[i]) for i in range(n)):
        _check_dead_traffic(spec, t, d)
    fn = compiled_sim(spec, T, backend)
    raw = jax.vmap(fn, in_axes=(0, 0, 0, sl_ax, mo_ax, bb_ax, jt_ax,
                                None, *((None,) * len(fops))))(
        jnp.asarray(times), jnp.asarray(dests), jnp.asarray(writes),
        jnp.asarray(sl), jnp.asarray(mo), jnp.asarray(bb),
        jnp.asarray(jt), jnp.asarray(_depths(spec)),
        *(jnp.asarray(x) for x in fops))
    return SimResult.from_raw(spec, raw)


def _strip_depths(spec: NocSpec) -> NocSpec:
    """Grouping key for :func:`sweep`: depth is a traced operand, so
    specs differing only in channel FIFO depths share a compilation."""
    return spec.with_(channels=tuple(
        replace(ch, depth=1) for ch in spec.channels))


def _batch_depth_sweep(specs: Sequence[NocSpec], wls: Sequence[Workload],
                       backend: str) -> SimResult:
    """Vmap points that differ only in FIFO depths through ONE
    padded-depth compilation (depth masked against the group max)."""
    base = specs[0]
    per_point = [wl.schedules(s) for s, wl in zip(specs, wls)]
    T = max(max(np.asarray(t).reshape(base.n_routers, -1).shape[1]
                for t, *_ in sched.values()) for sched in per_point)
    stacked = [stack_schedules(s, sched, T=T)
               for s, sched in zip(specs, per_point)]
    times = np.stack([t for t, _, _ in stacked])
    dests = np.stack([d for _, d, _ in stacked])
    writes = np.stack([w for _, _, w in stacked])
    sl, mo, bb = _dyn_scalars(base, None, None, None)
    jt = jitter_table(base)
    fops = _fault_ops(base)
    for t, d in ((times[i], dests[i]) for i in range(len(specs))):
        _check_dead_traffic(base, t, d)
    depths = np.stack([_depths(s) for s in specs])         # (n, n_ch)
    fn = compiled_sim(base, T, backend,
                      max_depth=int(depths.max()))
    raw = jax.vmap(fn, in_axes=(0, 0, 0, None, None, None, None, 0,
                                *((None,) * len(fops))))(
        jnp.asarray(times), jnp.asarray(dests), jnp.asarray(writes),
        jnp.asarray(sl), jnp.asarray(mo), jnp.asarray(bb),
        jnp.asarray(jt), jnp.asarray(depths),
        *(jnp.asarray(x) for x in fops))
    return SimResult.from_raw(base, raw)


def sweep(points: Sequence[tuple[NocSpec, Workload]], *,
          backend: str = "jnp", pad_depths: bool = True,
          verify: str = "fast",
          devices: int | None = None) -> list[SimResult]:
    """Simulate arbitrary (spec, workload) points, vmapping every group
    of points that shares a static spec. Results come back in input
    order, one unbatched SimResult per point.

    With ``pad_depths`` (default) points whose specs differ ONLY in
    channel FIFO depths also share one group: the group compiles once
    at the max depth with per-point depths a vmapped traced operand —
    a whole depth sweep costs a single ``compiled_sim`` compilation
    (count it with :func:`repro.noc.sim_cache_stats`).

    ``devices=N`` (:mod:`repro.noc.farm` tier a) shards each vmapped
    group across N local devices: the group batch splits on a
    ``specs`` shard_map axis (uneven groups padded with the last point
    and sliced back), per-point results bit-identical to the
    single-device path.  ``devices=None`` keeps the classic one-device
    vmap; size-1 groups always run unsharded.

    ``verify`` runs the :mod:`repro.noc.analyze` gate once per distinct
    spec before any simulation (the deadlock proof is lru-cached per
    (topology, routing), so a 70-point sweep pays it once)."""
    for s in {spec for spec, _ in points}:
        _verify(s, verify)
    groups: dict[NocSpec, list[int]] = {}
    for i, (spec, _) in enumerate(points):
        key = _strip_depths(spec) if pad_depths else spec
        groups.setdefault(key, []).append(i)
    out: list[SimResult | None] = [None] * len(points)
    for idxs in groups.values():
        specs = [points[i][0] for i in idxs]
        wls = [points[i][1] for i in idxs]
        if len(idxs) == 1:
            out[idxs[0]] = simulate(specs[0], wls[0], backend=backend)
        elif devices is not None:
            from .farm import farm_batch
            batched = farm_batch(specs, wls, devices, backend)
            for j, i in enumerate(idxs):
                # re-attach each point's own spec (the farm compiles
                # under the group's depth-padded base spec)
                out[i] = replace(batched.point(j), spec=specs[j])
        elif all(s == specs[0] for s in specs):
            batched = simulate_batch(specs[0], wls, backend=backend)
            for j, i in enumerate(idxs):
                out[i] = batched.point(j)
        else:
            batched = _batch_depth_sweep(specs, wls, backend)
            for j, i in enumerate(idxs):
                # re-attach each point's own spec (the batch compiled
                # under the group's depth-padded base spec)
                out[i] = replace(batched.point(j), spec=specs[j])
    return out  # type: ignore[return-value]
