"""`simulate(spec, workload)` — the one entry point for NoC experiments.

The static half of an experiment (mesh dims, channel topology, FIFO
depths, cycle horizon) lives in the frozen :class:`NocSpec` and keys a
cached jitted simulator; the dynamic half (schedules, service latency,
outstanding limits, burst lengths) are traced operands.  That split is
what makes sweeps cheap:

* :func:`simulate`        — one spec + one workload -> one SimResult,
* :func:`simulate_batch`  — one spec + N workloads (and optionally
  per-point scalar overrides) -> ONE vmapped jit call returning a
  batched SimResult, bit-identical to N individual runs,
* :func:`sweep`           — arbitrary (spec, workload) points; points
  sharing a static spec are grouped into vmapped batches, and (with
  ``pad_depths``, the default) points whose specs differ ONLY in
  channel FIFO depths are grouped too: depth is a traced operand
  masked against the group max, so a whole depth sweep shares one
  compilation (``sim_cache_stats()`` counts it).  Points that differ
  in any other static field (e.g. channel count) compile per group.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BIG, compiled_sim
from .result import SimResult
from .spec import NocSpec
from .workload import Workload

__all__ = ["simulate", "simulate_batch", "simulate_schedules", "sweep",
           "stack_schedules"]


def stack_schedules(spec: NocSpec,
                    schedules: Mapping[str, tuple[np.ndarray, np.ndarray]],
                    T: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Pad per-class (R, T_c) schedules to a common horizon and stack
    into the (n_cls, R, T) operands the engine consumes."""
    R = spec.n_routers
    per_cls = []
    for cls in spec.classes:
        t, d = schedules[cls.name]
        t = np.asarray(t, np.int32).reshape(R, -1)
        d = np.asarray(d, np.int32).reshape(R, -1)
        per_cls.append((t, d))
    T_need = max(t.shape[1] for t, _ in per_cls)
    T = T_need if T is None else max(T, T_need)
    times = np.full((len(per_cls), R, T), BIG, np.int32)
    dests = np.zeros((len(per_cls), R, T), np.int32)
    for i, (t, d) in enumerate(per_cls):
        times[i, :, :t.shape[1]] = t
        dests[i, :, :d.shape[1]] = d
    return times, dests


def _dyn_scalars(spec: NocSpec, service_lat, max_outstanding, burst_beats):
    sl = np.int32(spec.service_lat if service_lat is None else service_lat)
    mo = np.asarray([c.max_outstanding for c in spec.classes], np.int32) \
        if max_outstanding is None else np.asarray(max_outstanding, np.int32)
    bb = np.asarray([c.burst_beats for c in spec.classes], np.int32) \
        if burst_beats is None else np.asarray(burst_beats, np.int32)
    return sl, mo, bb


def _depths(spec: NocSpec) -> np.ndarray:
    return np.asarray([ch.depth for ch in spec.channels], np.int32)


def simulate_schedules(spec: NocSpec,
                       schedules: Mapping[str, tuple[np.ndarray, np.ndarray]],
                       *, service_lat: int | None = None,
                       max_outstanding: Sequence[int] | None = None,
                       burst_beats: Sequence[int] | None = None,
                       backend: str = "jnp") -> SimResult:
    """Run one experiment from raw per-class schedules (the layer custom
    schedule sources go through)."""
    times, dests = stack_schedules(spec, schedules)
    sl, mo, bb = _dyn_scalars(spec, service_lat, max_outstanding,
                              burst_beats)
    raw = compiled_sim(spec, times.shape[-1], backend)(
        times, dests, sl, mo, bb, _depths(spec))
    return SimResult.from_raw(spec, raw)


def simulate(spec: NocSpec, workload: Workload, *,
             service_lat: int | None = None,
             max_outstanding: Sequence[int] | None = None,
             burst_beats: Sequence[int] | None = None,
             backend: str = "jnp") -> SimResult:
    """Run one experiment; scalar keyword overrides shadow the spec's
    declared values without recompiling (they are traced operands).
    ``backend`` picks the router hot-loop implementation ("jnp"
    reference or the "pallas" arbiter kernel — see
    :mod:`repro.noc.backends`); results are backend-invariant."""
    return simulate_schedules(spec, workload.schedules(spec),
                              service_lat=service_lat,
                              max_outstanding=max_outstanding,
                              burst_beats=burst_beats, backend=backend)


def simulate_batch(spec: NocSpec, workloads: Sequence[Workload], *,
                   service_lat: Sequence[int] | int | None = None,
                   max_outstanding=None,
                   burst_beats=None, backend: str = "jnp") -> SimResult:
    """Run N operating points in ONE vmapped jit call.

    ``workloads`` supplies per-point schedules (rate/seed/pattern
    sweeps). ``service_lat`` may be one int (broadcast) or a length-N
    sequence (swept). ``max_outstanding`` / ``burst_beats`` are
    per-class: one int (all classes), a length-n_cls vector
    (broadcast), or an (N, n_cls) array (swept per point).
    Returns a SimResult whose arrays carry a leading sweep axis.
    """
    n = len(workloads)
    if n == 0:
        raise ValueError("empty sweep")
    per_point = [wl.schedules(spec) for wl in workloads]
    T = max(max(np.asarray(t).reshape(spec.n_routers, -1).shape[1]
                for t, _ in sched.values()) for sched in per_point)
    stacked = [stack_schedules(spec, sched, T=T) for sched in per_point]
    times = np.stack([t for t, _ in stacked])          # (n, n_cls, R, T)
    dests = np.stack([d for _, d in stacked])
    n_cls = len(spec.classes)

    def scalar_axis(v, default, name):
        """0-d -> broadcast; (n,) -> swept."""
        if v is None:
            return np.int32(default), None
        v = np.asarray(v, np.int32)
        if v.ndim == 0:
            return v, None
        if v.shape != (n,):
            raise ValueError(
                f"{name} must be a scalar or length-{n} sweep; got shape "
                f"{v.shape}")
        return v, 0

    def per_class_axis(v, default, name):
        """0-d -> all classes; (n_cls,) -> broadcast; (n, n_cls) -> swept."""
        if v is None:
            return np.asarray(default, np.int32), None
        v = np.asarray(v, np.int32)
        if v.ndim == 0:
            return np.full((n_cls,), v, np.int32), None
        if v.shape == (n_cls,):
            return v, None
        if v.shape == (n, n_cls):
            return v, 0
        raise ValueError(
            f"{name} must be a scalar, ({n_cls},) per-class vector, or "
            f"({n}, {n_cls}) sweep; got shape {v.shape}")

    sl, sl_ax = scalar_axis(service_lat, spec.service_lat, "service_lat")
    mo, mo_ax = per_class_axis(
        max_outstanding, [c.max_outstanding for c in spec.classes],
        "max_outstanding")
    bb, bb_ax = per_class_axis(
        burst_beats, [c.burst_beats for c in spec.classes], "burst_beats")

    fn = compiled_sim(spec, T, backend)
    raw = jax.vmap(fn, in_axes=(0, 0, sl_ax, mo_ax, bb_ax, None))(
        jnp.asarray(times), jnp.asarray(dests), jnp.asarray(sl),
        jnp.asarray(mo), jnp.asarray(bb), jnp.asarray(_depths(spec)))
    return SimResult.from_raw(spec, raw)


def _strip_depths(spec: NocSpec) -> NocSpec:
    """Grouping key for :func:`sweep`: depth is a traced operand, so
    specs differing only in channel FIFO depths share a compilation."""
    return spec.with_(channels=tuple(
        replace(ch, depth=1) for ch in spec.channels))


def _batch_depth_sweep(specs: Sequence[NocSpec], wls: Sequence[Workload],
                       backend: str) -> SimResult:
    """Vmap points that differ only in FIFO depths through ONE
    padded-depth compilation (depth masked against the group max)."""
    base = specs[0]
    per_point = [wl.schedules(s) for s, wl in zip(specs, wls)]
    T = max(max(np.asarray(t).reshape(base.n_routers, -1).shape[1]
                for t, _ in sched.values()) for sched in per_point)
    stacked = [stack_schedules(s, sched, T=T)
               for s, sched in zip(specs, per_point)]
    times = np.stack([t for t, _ in stacked])
    dests = np.stack([d for _, d in stacked])
    sl, mo, bb = _dyn_scalars(base, None, None, None)
    depths = np.stack([_depths(s) for s in specs])         # (n, n_ch)
    fn = compiled_sim(base, T, backend,
                      max_depth=int(depths.max()))
    raw = jax.vmap(fn, in_axes=(0, 0, None, None, None, 0))(
        jnp.asarray(times), jnp.asarray(dests), jnp.asarray(sl),
        jnp.asarray(mo), jnp.asarray(bb), jnp.asarray(depths))
    return SimResult.from_raw(base, raw)


def sweep(points: Sequence[tuple[NocSpec, Workload]], *,
          backend: str = "jnp", pad_depths: bool = True) -> list[SimResult]:
    """Simulate arbitrary (spec, workload) points, vmapping every group
    of points that shares a static spec. Results come back in input
    order, one unbatched SimResult per point.

    With ``pad_depths`` (default) points whose specs differ ONLY in
    channel FIFO depths also share one group: the group compiles once
    at the max depth with per-point depths a vmapped traced operand —
    a whole depth sweep costs a single ``compiled_sim`` compilation
    (count it with :func:`repro.noc.sim_cache_stats`)."""
    groups: dict[NocSpec, list[int]] = {}
    for i, (spec, _) in enumerate(points):
        key = _strip_depths(spec) if pad_depths else spec
        groups.setdefault(key, []).append(i)
    out: list[SimResult | None] = [None] * len(points)
    for idxs in groups.values():
        specs = [points[i][0] for i in idxs]
        wls = [points[i][1] for i in idxs]
        if len(idxs) == 1:
            out[idxs[0]] = simulate(specs[0], wls[0], backend=backend)
        elif all(s == specs[0] for s in specs):
            batched = simulate_batch(specs[0], wls, backend=backend)
            for j, i in enumerate(idxs):
                out[i] = batched.point(j)
        else:
            batched = _batch_depth_sweep(specs, wls, backend)
            for j, i in enumerate(idxs):
                # re-attach each point's own spec (the batch compiled
                # under the group's depth-padded base spec)
                out[i] = replace(batched.point(j), spec=specs[j])
    return out  # type: ignore[return-value]
