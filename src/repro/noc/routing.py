"""Virtual channels + pluggable routing algorithms for deadlock-free
cyclic fabrics.

A frozen :class:`RoutingPolicy` — routing algorithm x VC count x VC
assignment rule — compiles, per :class:`~repro.noc.topology.Topology`,
into the same kind of static tables the table-driven fabric already
consumes (:func:`repro.core.noc_sim.router.make_fabric_step`), so every
backend (``jnp`` / ``pallas`` / ``pallas_fused``) gets virtual channels
and adaptive routing without new per-cycle machinery.  Two reductions
make that work:

**Virtual channels are folded into the port axis.**  A router with
``P`` physical ports and ``V`` VCs becomes a router with
``P' = (P-1)*V + 1`` *virtual* ports: non-local port ``p`` expands to
``V`` slots ``p*V + v`` — each its own input FIFO, output register,
round-robin pointer, and wormhole lock (the per-VC locks the AXI
preemptive-VC scheme needs) — while the local/NI port keeps one slot
so injection and delivery are untouched.  The existing output
arbitration over virtual inputs *is* VC-aware arbitration: it
round-robins across the ready VCs of every input port and grants into
per-(port, VC) output registers.  The only genuinely new fabric
behavior is **link serialization**: one physical link still moves one
flit per cycle, so the drain phase picks a single ready (port, VC)
output register per link, escape-VC (highest index) first — see
``make_fabric_step(n_vcs=...)``.

**Route + VC selection are a wider static table.**  Multi-path
algorithms emit ``n_planes`` candidate route tables; the flit's dest
field carries a *virtual destination* ``plane*R + dest`` and the
expanded route table ``(R, n_planes*R)`` maps it to a virtual output
port — physical port *and* next-hop VC in one lookup.  The plane is
chosen deterministically per (src, dst, txn) at the NI (all beats of a
burst share it), so paths spread across planes without breaking
wormhole atomicity.

Provided algorithms (deadlock-freedom by VC partitioning — each plane
owns a VC range whose channel-dependency graph is acyclic):

* ``"xy"``     — the topology's own deterministic route table (1
  plane).  On a torus, ``n_vcs >= 2`` enables the **dateline / escape
  VC** discipline: a flit rides VC0 while the wrap link of its current
  ring still lies ahead and flips into the escape VC when it crosses
  (or never needed) the wrap — the classic proof that minimal-wrap
  dimension-ordered torus routing is deadlock-free.  ``n_vcs=1``
  reproduces today's VC-less fabric bit-for-bit (and on a torus keeps
  its documented wedge).
* ``"o1turn"`` — two planes, XY and YX dimension order, near-optimal
  worst-case throughput on meshes.  Needs one VC per plane (2 on a
  mesh; 4 on a torus, where each plane also needs its dateline bit).
* ``"valiant"`` — ``n_valiant`` planes of two-phase detour routing
  (X to a per-plane waypoint column, Y to the destination row, X to
  the destination): phase 1+2 is plain XY routing to a waypoint and
  rides the plane's VC0, the final X leg rides VC1, so each plane
  needs 2 VCs.  Mesh only.

Every compiled table set passes the same structural validation as the
base topologies (:func:`repro.noc.topology.validate_tables`:
termination, duplex links, local-port-last).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from .topology import Mesh, Topology, Torus, validate_tables

__all__ = ["RoutingPolicy", "RouteTables"]

# direction order of the stride-1 port group (matches topology._DIRS)
_N, _E, _S, _W = 0, 1, 2, 3


class RouteTables(NamedTuple):
    """Compiled fabric tables for one (policy, topology) pair.

    ``nbr``/``opp`` are in *virtual-port* space (``(R, P')`` with
    ``P' = (P-1)*n_vcs + 1``); ``route`` is ``(R, n_planes*R)`` over
    virtual destinations ``plane*R + dest`` and yields virtual output
    ports (physical port and next-hop VC in one lookup).  ``vc_of_hop``
    keeps the per-plane VC assignment ``(n_planes, R, R)`` for
    introspection and tests.  All arrays are read-only numpy (cached
    and shared across simulators).
    """
    nbr: np.ndarray
    opp: np.ndarray
    route: np.ndarray
    vc_of_hop: np.ndarray
    n_vcs: int
    n_planes: int
    n_base_ports: int


@dataclass(frozen=True)
class RoutingPolicy:
    """Frozen routing-algorithm x VC configuration of a NocSpec.

    ``algorithm`` is one of ``"xy"`` / ``"o1turn"`` / ``"valiant"``;
    ``n_vcs`` the virtual-channel count per physical link;
    ``n_valiant`` the number of detour planes for ``"valiant"``.
    Hashable — it lives inside a :class:`~repro.noc.spec.NocSpec` and
    keys the cached jitted simulator like every other static field.
    """
    algorithm: str = "xy"
    n_vcs: int = 1
    n_valiant: int = 2

    def __post_init__(self):
        if self.algorithm not in ("xy", "o1turn", "valiant"):
            raise ValueError(
                f"unknown routing algorithm {self.algorithm!r}; "
                f"have ('xy', 'o1turn', 'valiant')")
        if not isinstance(self.n_vcs, int) or isinstance(self.n_vcs, bool) \
                or self.n_vcs < 1:
            raise ValueError(f"n_vcs must be an int >= 1, got {self.n_vcs!r}")
        if self.algorithm == "valiant" and self.n_valiant < 1:
            raise ValueError(
                f"valiant needs n_valiant >= 1 planes, got {self.n_valiant}")

    # ------------------------------------------------------------------ #
    @classmethod
    def xy(cls, n_vcs: int = 1) -> "RoutingPolicy":
        """The topology's own deterministic routing; ``n_vcs >= 2`` adds
        the dateline/escape-VC discipline on cyclic fabrics."""
        return cls("xy", n_vcs)

    @classmethod
    def o1turn(cls, n_vcs: int = 2) -> "RoutingPolicy":
        return cls("o1turn", n_vcs)

    @classmethod
    def valiant(cls, n_vcs: int = 4, n_valiant: int = 2) -> "RoutingPolicy":
        return cls("valiant", n_vcs, n_valiant)

    # ------------------------------------------------------------------ #
    @property
    def n_planes(self) -> int:
        return {"xy": 1, "o1turn": 2,
                "valiant": self.n_valiant}[self.algorithm]

    def vcs_per_plane(self, topology: Topology) -> int:
        """VCs one plane needs for its deadlock-freedom argument: 2
        where the plane's own channel graph has a cycle hazard (torus
        rings -> dateline bit; valiant's second X leg -> phase bit)."""
        return 2 if (isinstance(topology, Torus)
                     or self.algorithm == "valiant") else 1

    def required_vcs(self, topology: Topology) -> int:
        """VC count below which the policy's deadlock-freedom claim
        does not hold on ``topology``."""
        return self.n_planes * self.vcs_per_plane(topology)

    def is_deadlock_free(self, topology: Topology) -> bool:
        """Whether this (policy, topology) pair carries the escape-VC /
        plane-partition deadlock-freedom guarantee.  ``xy`` on a mesh is
        free by the turn model alone; on a torus it needs the dateline
        VCs; multi-plane algorithms always validate their VC budget."""
        if self.algorithm == "xy" and not isinstance(topology, Torus):
            return True
        return self.n_vcs >= self.required_vcs(topology)

    def validate_for(self, topology: Topology) -> None:
        """Raise early for (policy, topology) pairs that cannot compile
        (called from NocSpec validation; cheap — no table build)."""
        if self.algorithm != "xy":
            if getattr(topology, "express", ()):
                raise ValueError(
                    f"{self.algorithm!r} routing supports plain Mesh/"
                    f"Torus only, not express topologies ({topology!r})")
            if self.algorithm == "valiant" and isinstance(topology, Torus):
                raise ValueError(
                    "valiant routing is mesh-only (torus would need a "
                    "dateline bit per detour leg)")
            if self.n_vcs < self.required_vcs(topology):
                raise ValueError(
                    f"{self.algorithm!r} on {topology!r} needs n_vcs >= "
                    f"{self.required_vcs(topology)} for deadlock "
                    f"freedom, got {self.n_vcs}")
        n_ports = (topology.n_ports - 1) * self.n_vcs + 1
        if n_ports >= 99:
            raise ValueError(
                f"n_vcs={self.n_vcs} expands {topology!r} to {n_ports} "
                f"virtual ports, colliding with the NO-ROUTE sentinel (99)")

    def compile(self, topology: Topology) -> RouteTables:
        """Static tables for this policy on ``topology`` (cached)."""
        self.validate_for(topology)
        return _compile(self, topology)


# --------------------------------------------------------------------- #
# per-plane route construction (plain 5-port mesh/torus coordinates)
# --------------------------------------------------------------------- #
def _wrap_delta(a: np.ndarray, b: np.ndarray, size: int) -> np.ndarray:
    """Signed minimal wrap distance a -> b on a ring (ties positive)."""
    d = (b - a) % size
    return np.where(d <= size - d, d, d - size)


def _dim_port(delta: np.ndarray, axis: str) -> np.ndarray:
    """Port for one signed step along ``axis`` (x: E/W, y: S/N)."""
    if axis == "x":
        return np.where(delta > 0, _E, _W)
    return np.where(delta > 0, _S, _N)


def _coords(R: int, nx: int):
    r = np.arange(R)
    return r % nx, r // nx


def _dor_route(topo: Topology, order: str) -> np.ndarray:
    """Dimension-ordered route table (R, R) for a plain mesh/torus:
    ``order="xy"`` resolves X first, ``"yx"`` Y first.  Wrap deltas on
    the torus, plain deltas on the mesh."""
    nx, ny, R, P = topo.nx, topo.ny, topo.n_routers, topo.n_ports
    x, y = _coords(R, nx)
    dx_, dy_ = _coords(R, nx)
    if isinstance(topo, Torus):
        # gather from the small per-coordinate wrap tables instead of
        # running int64 modulo over the full (R, R) matrices
        wx = _wrap_delta(np.arange(nx)[:, None], np.arange(nx)[None, :], nx)
        wy = _wrap_delta(np.arange(ny)[:, None], np.arange(ny)[None, :], ny)
        ddx = wx[x[:, None], dx_[None, :]]
        ddy = wy[y[:, None], dy_[None, :]]
    else:
        ddx = dx_[None, :] - x[:, None]
        ddy = dy_[None, :] - y[:, None]
    px, py = _dim_port(ddx, "x"), _dim_port(ddy, "y")
    if order == "xy":
        route = np.where(ddx != 0, px, np.where(ddy != 0, py, P - 1))
    else:
        route = np.where(ddy != 0, py, np.where(ddx != 0, px, P - 1))
    return route.astype(np.int64)


def _valiant_route(topo: Mesh, k: int) -> np.ndarray:
    """Plane ``k`` of valiant-style detour routing on a mesh: X to the
    waypoint column ``c_k(dest)``, Y to the destination row, X to the
    destination column.  Functional in (router, dest), so it fits the
    table-driven fabric; the waypoint varies per plane and per dest so
    txn-spread traffic covers ``n_valiant`` distinct paths."""
    nx, ny, R, P = topo.nx, topo.ny, topo.n_routers, topo.n_ports
    x, y = _coords(R, nx)
    dx_, dy_ = _coords(R, nx)
    c = (dx_ + 1 + k) % nx                               # waypoint col per dest
    ddx = dx_[None, :] - x[:, None]
    ddy = dy_[None, :] - y[:, None]
    ddc = c[None, :] - x[:, None]
    at_row = ddy == 0
    # final X leg once on the destination row; else X to waypoint, then Y
    route = np.where(
        at_row, np.where(ddx != 0, _dim_port(ddx, "x"), P - 1),
        np.where(ddc != 0, _dim_port(ddc, "x"), _dim_port(ddy, "y")))
    return route.astype(np.int64)


def _dateline_bits(topo: Torus, route: np.ndarray) -> np.ndarray:
    """Per-(router, dest) dateline bit for one torus route plane: 0
    while the current ring's wrap link still lies ahead of the next
    hop, 1 (the escape VC) once the flit has crossed it — or never
    needed it.  Wrap links therefore always *deliver into* the escape
    VC, splitting each ring's channel-dependency cycle into two acyclic
    runs."""
    nx, ny, R = topo.nx, topo.ny, topo.n_routers
    x, y = _coords(R, nx)
    dx_, dy_ = _coords(R, nx)
    x2 = {_E: (x + 1) % nx, _W: (x - 1) % nx}
    y2 = {_N: (y - 1) % ny, _S: (y + 1) % ny}
    wrap_ahead = np.zeros_like(route, dtype=bool)
    for p, ahead in (
            (_E, x2[_E][:, None] > dx_[None, :]),
            (_W, x2[_W][:, None] < dx_[None, :]),
            (_S, y2[_S][:, None] > dy_[None, :]),
            (_N, y2[_N][:, None] < dy_[None, :])):
        wrap_ahead |= (route == p) & ahead
    return np.where(wrap_ahead, 0, 1).astype(np.int64)


def _plane_tables(policy: RoutingPolicy,
                  topo: Topology) -> tuple[list[np.ndarray],
                                           list[np.ndarray]]:
    """(route planes, per-plane VC bits), each (R, R)."""
    base_route = topo.tables()[2]
    zeros = np.zeros_like(base_route)
    if policy.algorithm == "xy":
        planes = [np.asarray(base_route, np.int64)]
        bits = [_dateline_bits(topo, planes[0])
                if isinstance(topo, Torus) and policy.n_vcs >= 2 else zeros]
    elif policy.algorithm == "o1turn":
        planes = [np.asarray(base_route, np.int64),
                  _dor_route(topo, "yx")]
        bits = ([_dateline_bits(topo, p) for p in planes]
                if isinstance(topo, Torus) else [zeros, zeros])
    else:                                                # valiant (mesh)
        planes = [_valiant_route(topo, k)
                  for k in range(policy.n_valiant)]
        # phase bit: the final X leg (already on the destination row)
        # rides each plane's second VC — the plane-private escape lane
        _, y = _coords(topo.n_routers, topo.nx)
        _, dy_ = _coords(topo.n_routers, topo.nx)
        phase = (y[:, None] == dy_[None, :]).astype(np.int64)
        bits = [phase for _ in planes]
    return planes, bits


# --------------------------------------------------------------------- #
# VC expansion: fold the VC axis into the port axis
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=64)
def _compile(policy: RoutingPolicy, topo: Topology) -> RouteTables:
    nbr, opp, _ = topo.tables()
    R, P = nbr.shape
    V, K = policy.n_vcs, policy.n_planes
    v_pp = policy.vcs_per_plane(topo)
    planes, bits = _plane_tables(policy, topo)

    # per-plane VC of each hop, clamped into the declared VC budget
    # (only reachable for xy, where fewer VCs is allowed — documented
    # as forfeiting the torus deadlock-freedom guarantee)
    vc_of_hop = np.minimum(np.arange(K)[:, None, None] * v_pp
                           + np.stack(bits), V - 1)      # (K, R, R)
    dest_ids = np.arange(R)
    vc_of_hop[:, dest_ids, dest_ids] = 0                 # no VC on delivery

    # virtual ports: non-local port p -> slots p*V + v, local port last
    Pv = (P - 1) * V + 1
    nbr_v = np.full((R, Pv), -1, np.int64)
    opp_v = np.full((R, Pv), Pv - 1, np.int64)
    nbr_v[:, :Pv - 1] = np.repeat(nbr[:, :P - 1], V, axis=1)
    vcs = np.tile(np.arange(V), P - 1)                   # v of slot p*V + v
    opp_v[:, :Pv - 1] = np.where(
        nbr_v[:, :Pv - 1] >= 0,
        np.repeat(opp[:, :P - 1], V, axis=1) * V + vcs, Pv - 1)

    off_diag = dest_ids[:, None] != dest_ids[None, :]    # (R, R)
    virt = np.stack(planes) * V + vc_of_hop              # (K, R, R)
    route_v = np.where(off_diag[None, :, :], virt, Pv - 1)
    route_v = np.ascontiguousarray(
        route_v.transpose(1, 0, 2).reshape(R, K * R))

    validate_tables(nbr_v, opp_v, route_v)
    vc_of_hop.setflags(write=False)
    for a in (nbr_v, opp_v, route_v):
        a.setflags(write=False)
    return RouteTables(nbr=nbr_v, opp=opp_v, route=route_v,
                       vc_of_hop=vc_of_hop, n_vcs=V, n_planes=K,
                       n_base_ports=P)
