"""Pluggable engine backends behind the one ``simulate()`` surface.

A backend turns a :class:`~repro.noc.topology.Topology` into the
network-level primitives the cycle engine consumes: an ``init(depth)``
producing a fresh :class:`~repro.core.noc_sim.router.NetState` and a
``step(state, inject_valid, inject_flit)`` advancing one physical
network one cycle.  Both built-ins share the table-driven fabric update
(:func:`~repro.core.noc_sim.router.make_fabric_step`); they differ only
in who runs the hot phase-B arbitration loop:

* ``"jnp"``    — the pure-jnp reference (:func:`arbiter_jnp`),
* ``"pallas"`` — the Pallas router-arbiter kernel
  (``kernels/noc_router.py``), auto-interpreted off-TPU.

Backends are equivalence-tested flit-for-flit on the paper presets
(``tests/test_noc_api.py -k backend``).  Register custom engines with
:func:`register_backend`; select one with
``simulate(spec, wl, backend="pallas")``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp

from repro.core.noc_sim.router import (NetState, init_fabric_state,
                                       make_fabric_step)
from .topology import Topology

__all__ = ["Network", "BACKENDS", "register_backend", "get_backend",
           "list_backends"]


class Network(NamedTuple):
    """One physical network instance as the engine sees it."""
    init: Callable[[int], NetState]      # depth -> fresh state
    step: Callable                       # (state, inject_valid, flit) -> ...


BACKENDS: dict[str, Callable[[Topology], Network]] = {}


def register_backend(name: str):
    """Register ``fn(topology) -> Network`` under ``name``."""
    def deco(fn):
        BACKENDS[name] = fn
        return fn
    return deco


def list_backends() -> list[str]:
    return sorted(BACKENDS)


def get_backend(name: str) -> Callable[[Topology], Network]:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; have {list_backends()}") from None


def _network(topo: Topology, arbiter=None) -> Network:
    nbr, opp, route = topo.tables()
    R, P = nbr.shape
    return Network(
        init=lambda depth: init_fabric_state(R, P, depth),
        step=make_fabric_step(nbr, opp, route, arbiter=arbiter))


@register_backend("jnp")
def _jnp_backend(topo: Topology) -> Network:
    return _network(topo)


@register_backend("pallas")
def _pallas_backend(topo: Topology) -> Network:
    from repro.kernels.noc_router import router_arbiter_pallas

    def arbiter(out_port, beat, rr_ptr, oreg_free, lock_in):
        winner, pop, new_ptr, new_lock = router_arbiter_pallas(
            out_port, beat, rr_ptr, oreg_free, lock_in)
        return winner, pop.astype(jnp.bool_), new_ptr, new_lock

    return _network(topo, arbiter=arbiter)
