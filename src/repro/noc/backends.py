"""Pluggable engine backends behind the one ``simulate()`` surface.

A backend turns a :class:`~repro.noc.topology.Topology` into the
network-level primitives the cycle engine consumes, for ALL physical
channels at once: the engine carries one *stacked* state (every array
has a leading ``n_ch`` axis) and each cycle makes a single backend call
that advances every channel of the fabric together.  That stacking is
the fused hot loop's first win — n_ch identical router updates become
one batched update instead of n_ch separate op sequences in the scan
body.

* ``"jnp"``          — the pure-jnp reference
  (:func:`~repro.core.noc_sim.router.make_fabric_step` vmapped over
  the channel axis),
* ``"pallas"``       — same fabric step with phase-B arbitration
  replaced by the Pallas router-arbiter kernel
  (``kernels/noc_router.py``), auto-interpreted off-TPU,
* ``"pallas_fused"`` — the FULL one-cycle network update (drain +
  neighbor push + arbitration + FIFO pop/push) in ONE Pallas kernel
  over channel-folded router rows
  (:func:`~repro.kernels.noc_router.fused_fabric_step_pallas`).

The protocol:

* ``init(n_ch, depth_max)`` — fresh stacked
  :class:`~repro.core.noc_sim.router.NetState`, arrays shaped
  ``(n_ch, R, ...)`` with FIFOs sized by the static ``depth_max``;
* ``step(state, inject_valid (C, R), inject_flit (C, R, F),
  depths (C,))`` — one cycle; ``depths`` is the *traced* per-channel
  FIFO depth (≤ ``depth_max``), so depth sweeps share one compilation.
  Returns ``(state, inj_ok (C, R), deliver_valid (C, R),
  deliver_flit (C, R, F), link_moves (C,))``.

With a :class:`~repro.noc.faults.FaultModel` (``faults=``) the step
takes one extra traced operand — ``link_mask (R, P') bool`` marking
virtual output ports whose physical link is currently dead (shared by
every channel: the fault is physical).  Masked links drop their grants;
flits wait under backpressure, nothing is lost.  ``faults=None`` (the
default) builds the original mask-free step, so healthy specs stay
bit-identical.  Static dead links/nodes additionally swap the route
table for the fault-aware cut-out tables
(:func:`repro.noc.faults.cut_tables`).

A backend factory takes ``(topology, routing=None, faults=None)``: with a
:class:`~repro.noc.routing.RoutingPolicy` the fabric runs on that
policy's compiled VC/plane-expanded tables (each non-local physical
port unrolled into ``n_vcs`` virtual ports, route tables widened to
``n_planes`` virtual destination planes) and the same step machinery
advances every VC; ``None`` keeps the topology's own base tables —
bit-identical to the pre-VC engine, as is the default
``RoutingPolicy.xy(n_vcs=1)``.

Backends are **flow-agnostic**: they move int32 flits whose ``kind``
field encodes the (class, AXI flow) pair — AR/R reads and AW/W/B
writes look identical down here, only the NI model in ``engine.py``
interprets kinds.  That is what lets one fabric implementation serve
the full AXI4 transaction set unchanged.  Backends are
equivalence-tested flit-for-flit on the paper presets, torus, and
express meshes, including mixed read/write traffic
(``tests/test_noc_api.py -k backend``, ``tests/test_noc_axi4.py``).
Register custom engines with :func:`register_backend`; select one with
``simulate(spec, wl, backend="pallas_fused")``.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc_sim.router import (N_FIELDS, NetState, feeder_tables,
                                       make_fabric_step)
from .topology import Topology

__all__ = ["Network", "BACKENDS", "register_backend", "get_backend",
           "list_backends"]


class Network(NamedTuple):
    """All physical channels of one fabric, as the engine sees them."""
    init: Callable[[int, int], NetState]  # (n_ch, depth_max) -> state
    step: Callable                        # (state, iv, flit, depths) -> ...


BACKENDS: dict[str, Callable[..., Network]] = {}


def register_backend(name: str):
    """Register ``fn(topology, routing=None, faults=None) -> Network``
    under ``name``."""
    def deco(fn):
        BACKENDS[name] = fn
        return fn
    return deco


def list_backends() -> list[str]:
    return sorted(BACKENDS)


def _resolve_tables(topo: Topology, routing, faults=None):
    """``(nbr, opp, route, n_vcs)`` — the policy's compiled expanded
    tables, or the topology's base tables when ``routing`` is None.
    A ``FaultModel`` with static dead links/nodes (and ``reroute=True``)
    swaps in the fault-aware cut-out route table instead."""
    if faults is not None and faults.has_static and faults.reroute:
        from .faults import cut_tables
        from .routing import RoutingPolicy
        rt = cut_tables(topo, routing or RoutingPolicy(), faults)
        return rt.nbr, rt.opp, rt.route, rt.n_vcs
    if routing is None:
        nbr, opp, route = topo.tables()
        return nbr, opp, route, 1
    rt = routing.compile(topo)
    return rt.nbr, rt.opp, rt.route, rt.n_vcs


def get_backend(name: str) -> Callable[..., Network]:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; have {list_backends()}") from None


def _stacked_init(R: int, P: int) -> Callable[[int, int], NetState]:
    def init(n_ch: int, depth_max: int) -> NetState:
        return NetState(
            fifo=jnp.zeros((n_ch, R, P, depth_max, N_FIELDS), jnp.int32),
            count=jnp.zeros((n_ch, R, P), jnp.int32),
            rr_ptr=jnp.zeros((n_ch, R, P), jnp.int32),
            oreg=jnp.zeros((n_ch, R, P, N_FIELDS), jnp.int32),
            oreg_v=jnp.zeros((n_ch, R, P), jnp.bool_),
            lock_in=jnp.full((n_ch, R, P), -1, jnp.int32),
        )
    return init


def _vmapped_network(topo: Topology, routing=None, arbiter=None,
                     faults=None) -> Network:
    nbr, opp, route, n_vcs = _resolve_tables(topo, routing, faults)
    R, P = nbr.shape
    masked = faults is not None
    one = make_fabric_step(nbr, opp, route, arbiter=arbiter, n_vcs=n_vcs,
                           masked=masked)
    # the link mask is shared across channels (the fault is physical)
    axes = (0, 0, 0, 0, None) if masked else (0, 0, 0, 0)
    return Network(init=_stacked_init(R, P),
                   step=jax.vmap(one, in_axes=axes))


@register_backend("jnp")
def _jnp_backend(topo: Topology, routing=None, faults=None) -> Network:
    return _vmapped_network(topo, routing, faults=faults)


@register_backend("pallas")
def _pallas_backend(topo: Topology, routing=None, faults=None) -> Network:
    from repro.kernels.noc_router import router_arbiter_pallas

    def arbiter(out_port, beat, rr_ptr, oreg_free, lock_in):
        winner, pop, new_ptr, new_lock = router_arbiter_pallas(
            out_port, beat, rr_ptr, oreg_free, lock_in)
        return winner, pop.astype(jnp.bool_), new_ptr, new_lock

    return _vmapped_network(topo, routing, arbiter=arbiter, faults=faults)


@functools.lru_cache(maxsize=64)
def _fused_tables(topo: Topology, routing, n_ch: int, faults=None):
    """Row-folded static tables for the fused kernel: channel ``c``'s
    router ``r`` becomes row ``c*R + r``; neighbor/feeder indices are
    offset into the row space so one kernel advances every channel.
    ``routing`` (a hashable policy or None) selects the VC/plane-
    expanded table set — the fold is oblivious to which.
    Returned as *numpy* — this cache is often first populated inside a
    jit trace, and caching jnp constants would leak tracers into later
    traces."""
    nbr, opp, route, _ = _resolve_tables(topo, routing, faults)
    src_r, src_o = feeder_tables(nbr, opp)
    R, P = nbr.shape
    offs = (np.arange(n_ch) * R)[:, None, None]             # (C, 1, 1)
    nbr_rows = np.where(nbr[None] >= 0, nbr[None] + offs,
                        -1).reshape(n_ch * R, P)
    opp_rows = np.tile(opp, (n_ch, 1))
    route_rows = np.tile(route, (n_ch, 1))                  # (C*R, K*R)
    src_rows = np.where(
        src_r[None] >= 0,
        (src_r[None] + offs) * P + src_o[None], -1).reshape(n_ch * R, P)
    return (nbr_rows.astype(np.int32), opp_rows.astype(np.int32),
            route_rows.astype(np.int32), src_rows.astype(np.int32))


@register_backend("pallas_fused")
def _pallas_fused_backend(topo: Topology, routing=None,
                          faults=None) -> Network:
    from repro.kernels.noc_router import fused_fabric_step_pallas

    nbr, _, _, n_vcs = _resolve_tables(topo, routing, faults)
    R, P = nbr.shape
    masked = faults is not None

    def step(state: NetState, inject_valid, inject_flit, depths,
             *fault_args):
        C = state.count.shape[0]
        D, F = state.fifo.shape[3], state.fifo.shape[4]
        N = C * R
        tables = _fused_tables(topo, routing, C, faults)
        depth_rows = jnp.repeat(depths.astype(jnp.int32), R)
        mask_rows = None
        if masked:
            (link_mask,) = fault_args                # (R, P), channel-shared
            mask_rows = jnp.tile(link_mask, (C, 1))  # (N, P)
        (fifo, count, rr_ptr, oreg, oreg_v, lock_in, inj_ok, dv, dflit,
         lm_rows) = fused_fabric_step_pallas(
            state.fifo.reshape(N, P, D, F),
            state.count.reshape(N, P),
            state.rr_ptr.reshape(N, P),
            state.oreg.reshape(N, P, F),
            state.oreg_v.reshape(N, P),
            state.lock_in.reshape(N, P),
            inject_valid.reshape(N), inject_flit.reshape(N, F),
            depth_rows, *tables, n_vcs=n_vcs, link_mask_rows=mask_rows)
        new_state = NetState(
            fifo=fifo.reshape(C, R, P, D, F),
            count=count.reshape(C, R, P),
            rr_ptr=rr_ptr.reshape(C, R, P),
            oreg=oreg.reshape(C, R, P, F),
            oreg_v=(oreg_v > 0).reshape(C, R, P),
            lock_in=lock_in.reshape(C, R, P))
        return (new_state, inj_ok.reshape(C, R), dv.reshape(C, R),
                dflit.reshape(C, R, F), lm_rows.reshape(C, R).sum(axis=1))

    return Network(init=_stacked_init(R, P), step=step)
