"""Generalized N-channel FlooNoC cycle engine — fused hot loop with a
full AXI4 flow model.

Every traffic class decomposes into the five AXI channels
(:data:`repro.core.flit.AXI_FLOWS`): reads are AR -> R, writes are
AW -> W -> B.  The *fabric* (``make_fabric_step`` + every backend in
:mod:`repro.noc.backends`) stays completely flow-agnostic — routers
move int32 flits whose ``kind`` field encodes (class, flow); only the
batched NI model here interprets kinds.  The NI write path (paper
§III-A, journal version's end-to-end parallel streams):

* **AW injection** — a scheduled write becomes a single-flit AW
  candidate on its ``aw`` channel, gated by the issuing *lane*'s write
  ROB budget: reads and writes hold separate credits, and a class's
  ``max_outstanding`` is split (near-)evenly across its ``n_streams``
  AXI ID lanes, NOT pooled per (NI, class) — two streams of one class
  stall independently (journal version's parallel multi-stream ROB);
* **W data trailing the AW grant** — the moment an AW wins injection,
  a W burst entry (``burst_beats`` beats) is pushed into the class's
  W ring; its beats stream onto the ``w`` channel from the next cycle
  on, wormhole-atomic exactly like R response bursts;
* **B responses on the response plumbing** — when the last W beat
  lands, the target NI pushes a single-flit B entry into the response
  ring of the class's ``b`` channel (sharing the ring — and therefore
  the FIFO order — with R entries mapped to the same channel), ready
  after the class's service latency; B delivery at the source
  completes the write and frees its ROB slot.

Per channel, the injection policy is derived from which flows the
``class_map`` routes onto it:

* one response ring, nothing else      -> direct streaming (paper's
  dedicated narrow_rsp network),
* request-direction flows only         -> static priority: single-flit
  address flows (AR/AW, latency-critical classes first), then W rings;
  a started W burst is atomic and pins the channel,
* response rings and request flows mixed -> per-NI round-robin over
  [response rings..., one slot per class with request-direction flows]
  with burst atomicity (the wide-only ablation).  Within a class slot,
  AR/AW beat a fresh W burst; a started W burst pins the slot.

The candidate structure is built so that **read-only traffic is
flit-for-flit identical to the pre-AXI4 engine** (golden-checked): W
rings and AW/B flows that never carry traffic never win arbitration,
never advance round-robin state differently, and never reorder pushes.

Service latency is a per-class *(mean, jitter)* distribution: the
``service_lat`` operand is a per-class vector and a seeded static
jitter table adds a per-request offset (indexed by txn id) to every
R/B ready time — both traced, so latency-distribution sweeps vmap like
every other knob, and ``jitter=0`` reproduces the fixed-latency model
exactly.

The per-cycle structure keeps the fused-hot-loop shape: ONE stacked
fabric call for all channels, batched ``(R, n_cls)`` NI state (one
column per (class, AXI ID stream) *lane* — see :class:`FlowPlan`), the
response rings as one ``(R, n_rq, resp_q_cap, 6)`` array updated with
a single segment-style scatter per cycle (the per-class W rings live
in a separate small ``(R, n_cls, w_cap, 6)`` array — W occupancy is
bounded by the write ROB credit, so it never pays the response-ring
capacity), and FIFO depth as a traced operand (padded-depth sweeps
share one compilation).  The engine also watches liveness: ``max_stall_cycles``
(longest streak with transactions in flight but zero fabric activity)
and ``drained`` (every scheduled transaction completed) make deadlock
observable, and per-VC FIFO occupancy (sum + peak per channel) shows
*where* flits sit when the spec's
:class:`~repro.noc.routing.RoutingPolicy` runs multiple virtual
channels — a wedged single-VC torus pins VC0 full while the escape VC
of a ``n_vcs>=2`` dateline policy keeps draining.

The routing policy is threaded through statically: the backend gets
``(spec.topology, spec.routing)`` and runs on the policy's compiled
VC/plane-expanded tables; for multi-plane policies (O1TURN, Valiant)
the NI picks each transaction's plane with a deterministic hash of
(source, destination, txn id) folded into the flit's *virtual*
destination ``plane * R + dest``, so every beat of a burst — and every
retransmission of the same txn — takes the same path while different
transactions spread across planes.

Static structure (topology, channel list, max FIFO depth, class->
channel flow map, horizon) keys one jitted simulator per backend in a
stats-instrumented cache (:func:`sim_cache_stats`); dynamic knobs
(schedules incl. the write mask, per-class service latency + jitter
table, outstanding limits, burst lengths, FIFO depths) are traced
operands so ``jax.vmap`` batches whole sweeps in one jit.
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from dataclasses import replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flit import flow_kind
from repro.core.noc_sim.router import (F_BEAT, F_KIND, F_SRC, F_TIME,
                                       F_TXN)
from .backends import get_backend
from .spec import NocSpec

BIG = 1 << 30

# ring-entry field order, shared by the response and W ring arrays
Q_READY, Q_DEST, Q_BEATS, Q_TIME0, Q_TXN, Q_KIND = range(6)
N_QFIELDS = 6

# static length of the seeded per-class jitter table (prime, so txn-id
# indexing doesn't alias power-of-two burst/count periodicities)
JITTER_TABLE_LEN = 251


class ShardInfo(NamedTuple):
    """Row-sharding geometry for a spatially-partitioned fabric step
    (:mod:`repro.noc.farm` tier b).  ``make_step(..., shard=)`` builds
    the NI update for ``local_R`` contiguous router rows living on one
    device of a ``shard_map`` mesh axis ``axis`` with ``n`` shards;
    per-cycle scalar reductions (stall streak, VC occupancy) become
    ``lax.psum`` over that axis so every shard observes the global
    value, keeping sharded runs flit-for-flit identical to the
    single-device engine.  ``None`` (the default everywhere) leaves the
    healthy single-device program byte-identical."""
    axis: str
    n: int
    local_R: int
    global_R: int


def req_kind(cls_idx: int) -> int:
    """Legacy two-flow kind tag (pinned baseline engine only)."""
    return 2 * cls_idx


def rsp_kind(cls_idx: int) -> int:
    """Legacy two-flow kind tag (pinned baseline engine only)."""
    return 2 * cls_idx + 1


class ChannelPlan(NamedTuple):
    """Legacy read-shaped plan (kept for the pinned baseline engine and
    collectives-derivation tests): request flows are the AR channels,
    response queues the R channels — exactly the pre-AXI4 vocabulary."""
    n_cls: int
    n_ch: int
    n_q: int
    queue_of_class: tuple[int, ...]   # class -> response queue id
    reqs_on: tuple[tuple[int, ...], ...]   # channel -> req class ids (prio order)
    queues_on: tuple[tuple[int, ...], ...]  # channel -> rsp queue ids


def build_channel_plan(spec: NocSpec) -> ChannelPlan:
    n_cls, n_ch = len(spec.classes), len(spec.channels)
    # queues: one per distinct response channel, in first-appearance order
    rsp_ch_of_q: list[int] = []
    queue_of_class = []
    for cls in spec.classes:
        ch = spec.rsp_channel(cls.name)
        if ch not in rsp_ch_of_q:
            rsp_ch_of_q.append(ch)
        queue_of_class.append(rsp_ch_of_q.index(ch))
    # per-channel request classes, latency-critical (single-beat) first
    reqs_on = []
    for c in range(n_ch):
        ids = [i for i, cls in enumerate(spec.classes)
               if spec.req_channel(cls.name) == c]
        ids.sort(key=lambda i: (spec.classes[i].burst_beats > 1, i))
        reqs_on.append(tuple(ids))
    queues_on = tuple(
        tuple(q for q, ch in enumerate(rsp_ch_of_q) if ch == c)
        for c in range(n_ch))
    return ChannelPlan(n_cls, n_ch, len(rsp_ch_of_q),
                       tuple(queue_of_class), tuple(reqs_on), queues_on)


class FlowPlan(NamedTuple):
    """Static routing of the five AXI flows onto channels and rings,
    derived from a NocSpec (the *logical* half of the fabric; the
    physical half is the spec's :class:`~repro.noc.topology.Topology`).

    The plan's unit is the **lane** — one (class, AXI ID stream) pair.
    A class declaring ``n_streams=S`` contributes S consecutive lanes
    (class-major order), each with its own schedule pointer, its own
    slice of the class's per-direction ROB credits, its own W ring and
    its own round-robin slot, so independent streams never
    false-serialize (journal version's end-to-end parallel multi-stream
    support).  With every class at the default ``n_streams=1`` lanes
    coincide with classes and the plan is field-for-field the pre-
    stream plan — ``n_cls`` keeps its name but counts lanes.

    Ring space: response rings (one per distinct channel carrying any
    R or B flow, first-appearance order) come first, then one W ring
    per lane (id ``n_rq + lane``).  Head/tail/started bookkeeping is
    one stacked ``(R, n_q)`` set, but the entry storage is split:
    response rings are ``(R, n_rq, resp_q_cap, 6)`` while W rings are
    ``(R, n_lanes, w_cap, 6)`` with ``w_cap`` derived from the classes'
    declared ``max_outstanding`` — a W ring can never hold more
    pending bursts than the write ROB grants credits, so it doesn't
    pay the big response-ring capacity (raising ``max_outstanding``
    above the declared value via the traced override can overflow the
    W ring, the same unchecked-overflow contract as ``resp_q_cap``).
    """
    n_cls: int                       # number of LANES (see class doc)
    n_ch: int
    n_rq: int                        # response rings (channel-keyed)
    n_q: int                         # n_rq + n_lanes (per-lane W rings)
    w_cap: int                       # static W-ring capacity per lane
    rq_of_r: tuple[int, ...]         # lane -> ring its R entries enter
    rq_of_b: tuple[int, ...]         # lane -> ring its B entries enter
    chan_of_q: tuple[int, ...]       # every queue -> physical channel
    # channel -> ordered single-flit address-flow slots ((lane, "ar"|"aw"))
    singles_on: tuple[tuple[tuple[int, str], ...], ...]
    wqs_on: tuple[tuple[int, ...], ...]   # channel -> W ring ids
    rqs_on: tuple[tuple[int, ...], ...]   # channel -> response ring ids
    # channel -> lane ids with ANY request-direction flow on it (the
    # round-robin lane slots of mixed channels), prio order
    rr_classes: tuple[tuple[int, ...], ...]
    push_order_r: tuple[int, ...]    # R-push sequential order (lane ids)
    cls_of_lane: tuple[int, ...]     # lane -> declaring class index
    stream_of_lane: tuple[int, ...]  # lane -> AXI ID stream within class


def build_flow_plan(spec: NocSpec) -> FlowPlan:
    n_ch = len(spec.channels)
    # lanes: one per (class, stream), class-major — every class with
    # n_streams=1 contributes exactly one lane, so single-stream specs
    # reproduce the per-class plan verbatim
    lanes = [(ci, s) for ci, c in enumerate(spec.classes)
             for s in range(c.n_streams)]
    n_ln = len(lanes)
    lane_cls = [spec.classes[ci] for ci, _ in lanes]
    ch_of = {f: [spec.flow_channel(c.name, f) for c in lane_cls]
             for f in ("ar", "aw", "w", "r", "b")}
    # response rings: channel-keyed, first-appearance order over the R
    # flows then the B flows — R-only specs get exactly the pre-AXI4
    # ring order, B flows sharing an R channel share its ring (and its
    # FIFO order: the shared-channel ablation covers acks too).  Lanes
    # of one class share that class's channels, so streams share rings;
    # deliveries de-mux on the lane-specific flit kind.
    ring_ch: list[int] = []
    for ch in [*ch_of["r"], *ch_of["b"]]:
        if ch not in ring_ch:
            ring_ch.append(ch)
    n_rq = len(ring_ch)
    prio = sorted(range(n_ln),
                  key=lambda l: (lane_cls[l].burst_beats > 1, l))
    singles_on = tuple(
        tuple((i, f) for i in prio for f in ("ar", "aw")
              if ch_of[f][i] == c)
        for c in range(n_ch))
    wqs_on = tuple(tuple(n_rq + i for i in prio if ch_of["w"][i] == c)
                   for c in range(n_ch))
    rqs_on = tuple(tuple(q for q in range(n_rq) if ring_ch[q] == c)
                   for c in range(n_ch))
    rr_classes = tuple(
        tuple(i for i in prio
              if c in (ch_of["ar"][i], ch_of["aw"][i], ch_of["w"][i]))
        for c in range(n_ch))
    # sequential R-push order of the read-only engine: channel-major,
    # then the channel's priority order — preserves exact ring-slot
    # ordering when several lanes push one shared ring per cycle
    push_order_r = tuple(i for c in range(n_ch) for i in prio
                         if ch_of["ar"][i] == c)
    return FlowPlan(
        n_cls=n_ln, n_ch=n_ch, n_rq=n_rq, n_q=n_rq + n_ln,
        w_cap=max(2, max(c.max_outstanding for c in spec.classes)),
        rq_of_r=tuple(ring_ch.index(ch) for ch in ch_of["r"]),
        rq_of_b=tuple(ring_ch.index(ch) for ch in ch_of["b"]),
        chan_of_q=tuple(ring_ch) + tuple(ch_of["w"]),
        singles_on=singles_on, wqs_on=wqs_on, rqs_on=rqs_on,
        rr_classes=rr_classes, push_order_r=push_order_r,
        cls_of_lane=tuple(ci for ci, _ in lanes),
        stream_of_lane=tuple(s for _, s in lanes))


class _PlanArrays(NamedTuple):
    """Static index/selector arrays derived from a FlowPlan, shared by
    every cycle of the batched NI update.  Kept as *numpy* so index
    lookups stay concrete at trace time (a jnp constant would turn
    ``ar_ch[i]`` into a traced op inside the scan body).  All arrays
    are lane-indexed; the flit ``kind`` encodes (lane, flow), so a
    stream's identity rides the fabric's opaque kind field and
    deliveries de-mux back to the issuing lane."""
    ar_ch: np.ndarray         # (n_lanes,) channel per flow
    aw_ch: np.ndarray
    w_ch: np.ndarray
    r_ch: np.ndarray
    b_ch: np.ndarray
    ar_kinds: np.ndarray      # (n_cls,) flit kind tags per flow
    aw_kinds: np.ndarray
    r_kinds: np.ndarray
    w_kinds: np.ndarray
    b_kinds: np.ndarray
    # response-ring push machinery: slot s in [0, 2*n_lanes) is the R
    # push of lane s or the B push of lane s-n_lanes; one masked
    # scatter serves both (W pushes go to the per-lane W-ring array,
    # where each ring has exactly one pusher — no ordering needed).
    q_of_slot: np.ndarray     # (2*n_lanes,) destination ring per push slot
    push_before: np.ndarray   # (2n, 2n) 1 where slot j pushes the same
    #                           ring as slot i earlier in sequential order
    q_onehot: np.ndarray      # (2*n_lanes, n_rq) slot -> ring one-hot


def _plan_arrays(spec: NocSpec, plan: FlowPlan) -> _PlanArrays:
    n_cls = plan.n_cls
    lane_cls = [spec.classes[ci] for ci in plan.cls_of_lane]
    ch = {f: np.asarray([spec.flow_channel(c.name, f)
                         for c in lane_cls], np.int32)
          for f in ("ar", "aw", "w", "r", "b")}
    kinds = {f: np.asarray([flow_kind(i, f) for i in range(n_cls)],
                           np.int32) for f in ("ar", "aw", "r", "w", "b")}
    q_of_slot = np.concatenate([
        np.asarray(plan.rq_of_r, np.int64),
        np.asarray(plan.rq_of_b, np.int64)])
    # sequential order: R pushes (read-only engine's channel-major
    # order) first, then B pushes — read-only traffic never activates
    # the trailing slots, so its slot order is exact
    pos = np.empty(2 * n_cls, np.int64)
    pos[list(plan.push_order_r)] = np.arange(n_cls)
    pos[n_cls:] = np.arange(n_cls, 2 * n_cls)
    push_before = ((pos[None, :] < pos[:, None])
                   & (q_of_slot[None, :] == q_of_slot[:, None])
                   ).astype(np.int32)
    q_onehot = (q_of_slot[:, None] == np.arange(plan.n_rq)[None, :]
                ).astype(np.int32)
    return _PlanArrays(
        ar_ch=ch["ar"], aw_ch=ch["aw"], w_ch=ch["w"], r_ch=ch["r"],
        b_ch=ch["b"], ar_kinds=kinds["ar"], aw_kinds=kinds["aw"],
        r_kinds=kinds["r"], w_kinds=kinds["w"], b_kinds=kinds["b"],
        q_of_slot=q_of_slot.astype(np.int32), push_before=push_before,
        q_onehot=q_onehot)


class NIState(NamedTuple):
    ptr: jax.Array          # (R, n_cls) schedule pointers
    out_r: jax.Array        # (R, n_cls) outstanding reads (ROB credits)
    out_w: jax.Array        # (R, n_cls) outstanding writes (write ROB)
    rq_head: jax.Array      # (R, n_q) rsp rings first, then W rings
    rq_tail: jax.Array      # (R, n_q)
    rq: jax.Array           # (R, n_rq, resp_q_cap, 6) response rings
    wq: jax.Array           # (R, n_cls, w_cap, 6) per-class W rings
    w_started: jax.Array    # (R, n_q) burst mid-stream (inject atomicity)
    inj_rr: jax.Array       # (R, n_ch) mixed-channel round-robin
    # per-class read metrics: (R, n_cls), measured at the requester
    lat_sum: jax.Array
    lat_max: jax.Array
    done: jax.Array
    beats_rx: jax.Array
    first_t: jax.Array
    last_t: jax.Array
    # per-class write metrics: latency/done at the issuing NI (B
    # arrival), W-beat counts/span at the receiving NI
    w_lat_sum: jax.Array
    w_lat_max: jax.Array
    w_done: jax.Array
    w_beats_rx: jax.Array
    w_first_t: jax.Array
    w_last_t: jax.Array


class FaultState(NamedTuple):
    """NI robustness state, live only when the spec carries a
    :class:`~repro.noc.faults.FaultModel` (``spec.faults is None``
    compiles all of this out — the healthy program is untouched).

    The pending table tracks every in-flight transaction per (NI, lane):
    ``p_cap`` slots hold (txn id, dest, original issue time, current
    attempt start / retry due time, retries left, direction).  A slot is
    free when ``pend_txn < 0``; inserts take the first free slot and
    completions match by txn id, so late or duplicate responses (a
    retried transaction whose original eventually arrives) are
    recognized and dropped instead of double-freeing ROB credits.
    ``p_cap = 2 * w_cap`` covers the read + write ROB budgets; raising
    ``max_outstanding`` past the declared value via the traced override
    can overflow it — the same unchecked-overflow contract as
    ``resp_q_cap`` and the W rings."""
    pend_txn: jax.Array     # (R, n_cls, p_cap) int32, -1 = free slot
    pend_dest: jax.Array    # (R, n_cls, p_cap)
    pend_t0: jax.Array      # (R, n_cls, p_cap) original issue cycle
    pend_at: jax.Array      # (R, n_cls, p_cap) attempt start / retry due
    pend_left: jax.Array    # (R, n_cls, p_cap) retries left
    pend_wait: jax.Array    # (R, n_cls, p_cap) bool: attempt in flight
    pend_wr: jax.Array      # (R, n_cls, p_cap) bool: write transaction
    # degradation counters
    retries: jax.Array      # (R, n_cls) retry re-injections
    timeouts: jax.Array     # (R, n_cls) watchdog firings
    slverr: jax.Array       # (R, n_cls) SLVERR error responses
    dlv_fault: jax.Array    # (R, n_cls) completions while a fault active
    beats_fault: jax.Array  # (R, n_cls) data beats rx while fault active
    flc: jax.Array          # scalar: sum over cycles of #dead links
    fcyc: jax.Array         # scalar: cycles with any fault active


def fault_p_cap(plan: "FlowPlan") -> int:
    """Pending-table capacity per lane: reads + writes each hold up to
    ``w_cap`` (= max declared ``max_outstanding``) credits."""
    return 2 * plan.w_cap


def init_faults(R: int, n_cls: int, p_cap: int) -> FaultState:
    z3 = jnp.zeros((R, n_cls, p_cap), jnp.int32)
    b3 = jnp.zeros((R, n_cls, p_cap), jnp.bool_)
    z2 = jnp.zeros((R, n_cls), jnp.int32)
    return FaultState(
        pend_txn=jnp.full((R, n_cls, p_cap), -1, jnp.int32),
        pend_dest=z3, pend_t0=z3, pend_at=z3, pend_left=z3,
        pend_wait=b3, pend_wr=b3,
        retries=z2, timeouts=z2, slverr=z2, dlv_fault=z2, beats_fault=z2,
        flc=jnp.int32(0), fcyc=jnp.int32(0))


class SimState(NamedTuple):
    net: NamedTuple         # stacked NetState, (n_ch, R, ...) leaves
    ni: NIState
    cycle: jax.Array
    moves: jax.Array        # (n_ch,) link traversals per channel
    cur_stall: jax.Array    # scalar: current zero-activity streak
    max_stall: jax.Array    # scalar: longest such streak
    vc_occ_sum: jax.Array   # (n_ch, n_vcs) summed per-VC FIFO occupancy
    vc_occ_max: jax.Array   # (n_ch, n_vcs) peak per-VC FIFO occupancy
    fs: NamedTuple | tuple = ()   # FaultState, or () when faults=None


def init_ni(R: int, plan: FlowPlan, cap: int) -> NIState:
    zc = jnp.zeros((R, plan.n_cls), jnp.int32)
    zq = jnp.zeros((R, plan.n_q), jnp.int32)
    big = jnp.full((R, plan.n_cls), BIG, jnp.int32)
    return NIState(
        ptr=zc, out_r=zc, out_w=zc, rq_head=zq, rq_tail=zq,
        rq=jnp.zeros((R, plan.n_rq, cap, N_QFIELDS), jnp.int32),
        wq=jnp.zeros((R, plan.n_cls, plan.w_cap, N_QFIELDS), jnp.int32),
        w_started=jnp.zeros((R, plan.n_q), jnp.bool_),
        inj_rr=jnp.zeros((R, plan.n_ch), jnp.int32),
        lat_sum=zc, lat_max=zc, done=zc, beats_rx=zc,
        first_t=big, last_t=zc,
        w_lat_sum=zc, w_lat_max=zc, w_done=zc, w_beats_rx=zc,
        w_first_t=big, w_last_t=zc)


def make_step(spec: NocSpec, plan: FlowPlan, T: int, net_step,
              shard: ShardInfo | None = None):
    """Build the per-cycle transition. Dynamic operands arrive via the
    closure-free ``dyn`` dict (schedules + write mask + scalar knobs +
    jitter table + depths); ``net_step`` is the backend's stacked
    one-cycle fabric update (:class:`repro.noc.backends.Network`).

    ``shard`` (row-sharded farm mode, :mod:`repro.noc.farm`) narrows the
    NI update to that shard's ``local_R`` contiguous router rows: local
    row indices keep driving the scatters into the shard's own state,
    while the *global* row id (``local + axis_index * local_R``) is what
    enters every flit's src field and the multi-plane hash — those ids
    travel the fabric and come back as response destinations, so they
    must live in the global router id space.  Per-cycle liveness /
    occupancy scalars are psummed over the shard axis.  ``shard=None``
    builds the exact single-device program."""
    R = spec.n_routers if shard is None else shard.local_R
    R_virt = spec.n_routers        # global id space (plane folding, src)
    cap = spec.resp_q_cap
    w_cap = plan.w_cap
    pa = _plan_arrays(spec, plan)
    n_planes = spec.routing.n_planes
    n_vcs = spec.routing.n_vcs
    rows = jnp.arange(R)
    rq_ids = jnp.arange(plan.n_rq)
    wq_ids = jnp.arange(plan.n_cls)
    n_cls = plan.n_cls

    # fault machinery is built ONLY when the spec declares a FaultModel:
    # the healthy program below is literally the pre-fault code path
    faulted = spec.faults is not None
    if faulted and shard is not None:
        raise NotImplementedError(
            "row-sharded simulation does not support FaultModel specs "
            "yet (the event link-masks and retry jitter are keyed to "
            "global rows); run faulted specs unsharded")
    if faulted:
        from .faults import dynamic_events
        _, _, _masks = dynamic_events(spec.topology, spec.routing,
                                      spec.faults, spec.cycles)
        M_np = np.asarray(_masks)            # (E, R, P') static per-event
        p_cap = fault_p_cap(plan)
        lane_ids = jnp.arange(n_cls)
        p_ids = jnp.arange(p_cap)

    def step(dyn, state: SimState, _):
        times, dests = dyn["times"], dyn["dests"]     # (R, n_cls, T)
        writes = dyn["writes"]                        # (R, n_cls, T)
        service_lat = dyn["service_lat"]              # (n_cls,)
        jitter = dyn["jitter"]                        # (n_cls, JT)
        max_out, burst_beats = dyn["max_out"], dyn["burst_beats"]
        ni = state.ni
        now = state.cycle
        # global router id of each local row: what flits carry as src
        # (responses route back to it) and what the plane hash keys on
        rows_g = rows if shard is None \
            else rows + jax.lax.axis_index(shard.axis) * R

        if faulted:
            # ---- link mask from the event schedule ----------------------
            ev_fail, ev_heal = dyn["ev_fail"], dyn["ev_heal"]   # (E,)
            timeout = dyn["timeout"]                   # (n_cls,) lanes
            max_retries = dyn["max_retries"]           # scalar
            backoff = dyn["backoff"]                   # scalar
            fs = state.fs
            dead_e = (ev_fail <= now) & (now < ev_heal)          # (E,)
            link_mask = jnp.any(
                dead_e[:, None, None] & jnp.asarray(M_np), axis=0)

            # ---- watchdog scan: timeout -> retry or SLVERR --------------
            act = fs.pend_txn >= 0
            tmo = timeout[None, :, None]
            to = act & fs.pend_wait & (tmo > 0) & (now - fs.pend_at >= tmo)
            exh = to & (fs.pend_left <= 0)             # retries exhausted
            rearm = to & (fs.pend_left > 0)
            # exponential backoff with seeded jitter (reuses the service-
            # jitter table, keyed off (txn, attempt, NI) so concurrent
            # retries desynchronize instead of thundering back together)
            used = jnp.clip(max_retries - fs.pend_left, 0, 16)
            jidx = (fs.pend_txn * 7 + used * 13
                    + rows[:, None, None] * 131) % JITTER_TABLE_LEN
            jt_l = jnp.asarray(dyn["jitter"], jnp.int32)
            joff = jnp.abs(jt_l[lane_ids[None, :, None], jidx])
            due_at = now + (backoff << used) + joff
            # SLVERR: drop the transaction, free its ROB credit — the
            # requester observes an error response instead of data
            ni = ni._replace(
                out_r=ni.out_r - jnp.sum(
                    exh & ~fs.pend_wr, axis=2).astype(jnp.int32),
                out_w=ni.out_w - jnp.sum(
                    exh & fs.pend_wr, axis=2).astype(jnp.int32))
            fs = fs._replace(
                pend_txn=jnp.where(exh, -1, fs.pend_txn),
                pend_wait=fs.pend_wait & ~to,
                pend_left=fs.pend_left - rearm.astype(jnp.int32),
                pend_at=jnp.where(rearm, due_at, fs.pend_at),
                timeouts=fs.timeouts
                + jnp.sum(to, axis=2).astype(jnp.int32),
                slverr=fs.slverr + jnp.sum(exh, axis=2).astype(jnp.int32))

            # ---- retry candidate per lane: first backoff-expired slot ---
            rdy = (fs.pend_txn >= 0) & ~fs.pend_wait & (fs.pend_at <= now)
            has_rt = jnp.any(rdy, axis=2)              # (R, n_cls)
            rslot = jnp.argmax(rdy, axis=2)

            def _take_slot(a, s):
                return jnp.take_along_axis(a, s[:, :, None],
                                           axis=2)[:, :, 0]

            r_txn = _take_slot(fs.pend_txn, rslot)
            r_dest = _take_slot(fs.pend_dest, rslot)
            r_wr = _take_slot(fs.pend_wr, rslot)

        # ---- source side: per-class AR/AW candidates (ROB gated) --------
        p = jnp.clip(ni.ptr, 0, T - 1)[:, :, None]
        t_sel = jnp.take_along_axis(times, p, axis=2)[:, :, 0]
        is_wr = jnp.take_along_axis(writes, p, axis=2)[:, :, 0] > 0
        due = (ni.ptr < T) & (t_sel <= now)            # (R, n_cls)
        want_ar = due & ~is_wr & (ni.out_r < max_out[None, :])
        want_aw = due & is_wr & (ni.out_w < max_out[None, :])
        req_d = jnp.take_along_axis(dests, p, axis=2)[:, :, 0]
        txn_src = ni.ptr
        if faulted:
            # a pending retry preempts the lane's fresh candidate: same
            # injection machinery, but dest/txn come from the pending
            # table and no new schedule entry is consumed
            want_ar = jnp.where(has_rt, ~r_wr, want_ar)
            want_aw = jnp.where(has_rt, r_wr, want_aw)
            req_d = jnp.where(has_rt, r_dest, req_d)
            txn_src = jnp.where(has_rt, r_txn, ni.ptr)

        # ---- ring heads (response rings + W rings), all at once ---------
        slot_hr = ni.rq_head[:, :plan.n_rq] % cap      # (R, n_rq)
        slot_hw = ni.rq_head[:, plan.n_rq:] % w_cap    # (R, n_cls)
        h = jnp.concatenate([
            jnp.take_along_axis(ni.rq, slot_hr[:, :, None, None],
                                axis=2)[:, :, 0, :],
            jnp.take_along_axis(ni.wq, slot_hw[:, :, None, None],
                                axis=2)[:, :, 0, :]], axis=1)  # (R, n_q, 6)
        have = ni.rq_head < ni.rq_tail
        h_ready = have & (h[..., Q_READY] <= now)
        h_dest, h_beats = h[..., Q_DEST], h[..., Q_BEATS]
        h_time0, h_txn, h_kind = h[..., Q_TIME0], h[..., Q_TXN], h[..., Q_KIND]
        h_held = ni.w_started & (h_beats > 0)          # burst mid-stream

        # ---- per-channel injection policy (small static loop) -----------
        sel_ar: dict[int, jax.Array] = {}   # class -> AR selected
        sel_aw: dict[int, jax.Array] = {}   # class -> AW selected
        sel_q: dict[int, jax.Array] = {}    # ring -> head streamed
        hold_of_ch: dict[int, jax.Array] = {}
        iv_cols, flit_cols = [], []
        zero = jnp.zeros((R,), jnp.int32)
        false = jnp.zeros((R,), jnp.bool_)

        def pick_head(q, s, dest, kind, txn, time, beat):
            sel_q[q] = sel_q.get(q, false) | s
            return (jnp.where(s, h_dest[:, q], dest),
                    jnp.where(s, h_kind[:, q], kind),
                    jnp.where(s, h_txn[:, q], txn),
                    jnp.where(s, h_time0[:, q], time),
                    jnp.where(s, h_beats[:, q], beat))

        def pick_single(i, fl, s, dest, kind, txn, beat):
            if fl == "ar":
                sel_ar[i] = sel_ar.get(i, false) | s
                kind_v = int(pa.ar_kinds[i])
            else:
                sel_aw[i] = sel_aw.get(i, false) | s
                kind_v = int(pa.aw_kinds[i])
            return (jnp.where(s, req_d[:, i], dest),
                    jnp.where(s, kind_v, kind),
                    jnp.where(s, txn_src[:, i], txn),
                    jnp.where(s, 1, beat))

        for c in range(plan.n_ch):
            singles = plan.singles_on[c]
            wqs, rqs = plan.wqs_on[c], plan.rqs_on[c]
            rr_cls = plan.rr_classes[c]
            dest = kind = txn = beat = zero
            time = jnp.broadcast_to(now, (R,)).astype(jnp.int32)
            if not singles and not wqs and not rqs:    # idle channel
                valid = false
            elif not singles and not wqs and len(rqs) == 1:
                # dedicated response channel: stream the ring head
                q = rqs[0]
                valid = h_ready[:, q]
                sel_q[q] = valid
                dest, kind, txn = h_dest[:, q], h_kind[:, q], h_txn[:, q]
                time, beat = h_time0[:, q], h_beats[:, q]
            elif not rqs:
                # request-direction channel: a started W burst pins the
                # channel; else static priority — address flows
                # (latency-critical classes first), then fresh W bursts
                taken = false
                for q in wqs:
                    s = h_held[:, q] & ~taken
                    taken = taken | s
                    dest, kind, txn, time, beat = pick_head(
                        q, s, dest, kind, txn, time, beat)
                for i, fl in singles:
                    cand = want_ar[:, i] if fl == "ar" else want_aw[:, i]
                    s = cand & ~taken
                    taken = taken | s
                    dest, kind, txn, beat = pick_single(
                        i, fl, s, dest, kind, txn, beat)
                for q in wqs:
                    s = h_ready[:, q] & ~taken
                    taken = taken | s
                    dest, kind, txn, time, beat = pick_head(
                        q, s, dest, kind, txn, time, beat)
                valid = taken
            else:
                # mixed channel: round-robin over [response rings...,
                # class slots...] with burst atomicity — an in-flight
                # burst (response or W) excludes everything else
                cand = [("rq", q) for q in rqs] + [("cls", i)
                                                   for i in rr_cls]
                n_cand = len(cand)

                def cls_valid(i):
                    v = false
                    if int(pa.ar_ch[i]) == c:
                        v = v | want_ar[:, i]
                    if int(pa.aw_ch[i]) == c:
                        v = v | want_aw[:, i]
                    if int(pa.w_ch[i]) == c:
                        v = v | h_ready[:, plan.n_rq + i]
                    return v

                cand_valid = jnp.stack(
                    [h_ready[:, q] for q in rqs]
                    + [cls_valid(i) for i in rr_cls], axis=1)
                rr = ni.inj_rr[:, c] % n_cand
                order = (jnp.arange(n_cand)[None, :] + rr[:, None]) % n_cand
                ordered = jnp.take_along_axis(cand_valid, order, axis=1)
                first = jnp.argmax(ordered, axis=1)
                has_any = jnp.any(cand_valid, axis=1)
                choice = jnp.take_along_axis(order, first[:, None],
                                             axis=1)[:, 0]
                hold = false
                for k, q in enumerate(rqs):
                    hq = h_held[:, q]
                    choice = jnp.where(hq & ~hold, k, choice)
                    hold = hold | hq
                for k2, i in enumerate(rr_cls):
                    if int(pa.w_ch[i]) != c:
                        continue
                    hq = h_held[:, plan.n_rq + i]
                    choice = jnp.where(hq & ~hold, len(rqs) + k2, choice)
                    hold = hold | hq
                hold_of_ch[c] = hold
                valid0 = has_any | hold

                valid = false
                for k, (tag, idx) in enumerate(cand):
                    if tag == "rq":
                        s = valid0 & (choice == k) & h_ready[:, idx]
                        valid = valid | s
                        dest, kind, txn, time, beat = pick_head(
                            idx, s, dest, kind, txn, time, beat)
                        continue
                    # class slot: held W first, then AR/AW, then fresh W
                    i = idx
                    s_slot = valid0 & (choice == k)
                    taken_in = false
                    wq = plan.n_rq + i if int(pa.w_ch[i]) == c else None
                    if wq is not None:
                        s = s_slot & h_held[:, wq]
                        taken_in = taken_in | s
                        dest, kind, txn, time, beat = pick_head(
                            wq, s, dest, kind, txn, time, beat)
                    if int(pa.ar_ch[i]) == c:
                        s = s_slot & want_ar[:, i] & ~taken_in
                        taken_in = taken_in | s
                        dest, kind, txn, beat = pick_single(
                            i, "ar", s, dest, kind, txn, beat)
                    if int(pa.aw_ch[i]) == c:
                        s = s_slot & want_aw[:, i] & ~taken_in
                        taken_in = taken_in | s
                        dest, kind, txn, beat = pick_single(
                            i, "aw", s, dest, kind, txn, beat)
                    if wq is not None:
                        s = s_slot & h_ready[:, wq] & ~taken_in
                        taken_in = taken_in | s
                        dest, kind, txn, time, beat = pick_head(
                            wq, s, dest, kind, txn, time, beat)
                    valid = valid | taken_in
            iv_cols.append(valid)
            if n_planes > 1:
                # multi-plane policy: deterministic per-(src, dest, txn)
                # plane choice, folded into the *virtual* destination
                # plane*R + dest.  Every beat of a burst (constant
                # dest/txn at its ring head) hashes to the same plane,
                # so wormhole trains never straddle paths.
                plane = (rows_g * 7 + dest * 13 + txn * 31) % n_planes
                dest = plane * R_virt + dest
            flit = jnp.stack([dest, rows_g, time, kind, txn, beat], axis=1)
            flit_cols.append(jnp.where(valid[:, None], flit, 0))

        # ---- ONE stacked fabric step for every channel ------------------
        iv = jnp.stack(iv_cols)                        # (n_ch, R)
        iflit = jnp.stack(flit_cols)                   # (n_ch, R, F)
        if faulted:
            net, ok_ch, dv_ch, df_ch, lm = net_step(
                state.net, iv, iflit, dyn["depths"], link_mask)
        else:
            net, ok_ch, dv_ch, df_ch, lm = net_step(
                state.net, iv, iflit, dyn["depths"])

        # per-VC input-FIFO occupancy (non-local ports; virtual port
        # q = link * n_vcs + vc under the routing policy's table fold)
        occ = jnp.sum(net.count[:, :, :-1].reshape(
            net.count.shape[0], R, -1, n_vcs), axis=(1, 2))   # (n_ch, V)
        if shard is not None:      # fabric-wide occupancy, every shard
            occ = jax.lax.psum(occ, shard.axis)
        vc_occ_sum = state.vc_occ_sum + occ
        vc_occ_max = jnp.maximum(state.vc_occ_max, occ)

        # ---- pointer / ROB / ring-head updates --------------------------
        inj_ar = jnp.stack(
            [ok_ch[int(pa.ar_ch[i])] & sel_ar[i]
             if i in sel_ar else false for i in range(n_cls)], axis=1)
        inj_aw = jnp.stack(
            [ok_ch[int(pa.aw_ch[i])] & sel_aw[i]
             if i in sel_aw else false for i in range(n_cls)], axis=1)
        sent = jnp.stack(
            [ok_ch[plan.chan_of_q[q]] & sel_q[q]
             if q in sel_q else false for q in range(plan.n_q)], axis=1)
        inj_rr = ni.inj_rr
        for c, hold in hold_of_ch.items():
            inj_rr = inj_rr.at[:, c].add((ok_ch[c] & ~hold).astype(jnp.int32))

        txn0 = txn_src        # injected txn per lane (== pre-advance ptr
        #                       for fresh issues; pending txn on a retry)
        if faulted:
            # retries advance no pointer and consume no fresh credit —
            # the transaction still owns its original ROB slot
            inj_any = inj_ar | inj_aw
            fresh = inj_any & ~has_rt
            retry_inj = inj_any & has_rt
            inj = fresh.astype(jnp.int32)
            cr_ar = (inj_ar & ~has_rt).astype(jnp.int32)
            cr_aw = (inj_aw & ~has_rt).astype(jnp.int32)
        else:
            inj = (inj_ar | inj_aw).astype(jnp.int32)
            cr_ar = inj_ar.astype(jnp.int32)
            cr_aw = inj_aw.astype(jnp.int32)
        left = h_beats - sent.astype(jnp.int32)
        beats_upd = jnp.where(sent, left, h_beats)     # (R, n_q)
        rq = ni.rq.at[rows[:, None], rq_ids[None, :], slot_hr,
                      Q_BEATS].set(beats_upd[:, :plan.n_rq])
        wq = ni.wq.at[rows[:, None], wq_ids[None, :], slot_hw,
                      Q_BEATS].set(beats_upd[:, plan.n_rq:])
        ni = ni._replace(
            ptr=ni.ptr + inj, out_r=ni.out_r + cr_ar,
            out_w=ni.out_w + cr_aw, inj_rr=inj_rr,
            rq=rq, wq=wq,
            rq_head=ni.rq_head + (sent & (left <= 0)).astype(jnp.int32),
            w_started=jnp.where(sent, left > 0, ni.w_started))

        if faulted:
            # pending-table bookkeeping: fresh issues insert at the first
            # free slot; a granted retry re-arms its slot's watchdog
            oh_r = (p_ids[None, None, :] == rslot[:, :, None]) \
                & retry_inj[:, :, None]
            slot_f = jnp.argmax(fs.pend_txn < 0, axis=2)
            oh_f = (p_ids[None, None, :] == slot_f[:, :, None]) \
                & fresh[:, :, None]
            now3 = jnp.broadcast_to(now, oh_f.shape).astype(jnp.int32)
            fs = fs._replace(
                pend_txn=jnp.where(oh_f, txn0[:, :, None], fs.pend_txn),
                pend_dest=jnp.where(oh_f, req_d[:, :, None],
                                    fs.pend_dest),
                pend_t0=jnp.where(oh_f, now3, fs.pend_t0),
                pend_at=jnp.where(oh_f | oh_r, now3, fs.pend_at),
                pend_wait=fs.pend_wait | oh_f | oh_r,
                pend_wr=jnp.where(oh_f, is_wr[:, :, None], fs.pend_wr),
                pend_left=jnp.where(
                    oh_f, jnp.broadcast_to(max_retries, oh_f.shape
                                           ).astype(jnp.int32),
                    fs.pend_left),
                retries=fs.retries + retry_inj.astype(jnp.int32))

        # ---- deliveries: gather each flow through its static channel ----
        def flow_dv(ch_arr, kind_arr):
            dv = dv_ch[ch_arr].T                       # (R, n_cls)
            df = jnp.moveaxis(df_ch[ch_arr], 0, 1)     # (R, n_cls, F)
            return dv & (df[..., F_KIND] == kind_arr[None, :]), df

        is_ar, df_ar = flow_dv(pa.ar_ch, pa.ar_kinds)
        is_w, df_w = flow_dv(pa.w_ch, pa.w_kinds)
        is_r, df_r = flow_dv(pa.r_ch, pa.r_kinds)
        is_b, df_b = flow_dv(pa.b_ch, pa.b_kinds)
        is_w_last = is_w & (df_w[..., F_BEAT] <= 1)

        # ---- ring pushes: ONE response-ring scatter + one W scatter -----
        # response slot layout: [R pushes | B pushes] per class; the
        # slot's ring slot = its ring's tail + #earlier same-ring
        # pushes.  W pushes land in the per-class W-ring array, where
        # each ring has exactly one pusher per cycle (its own AW grant)
        sl = service_lat[None, :].astype(jnp.int32)
        jt = jnp.asarray(jitter, jnp.int32)

        def jit_of(txn, src):                          # (R, n_cls) offsets
            # key the per-request draw by (issuing NI, txn id) so the
            # jitter decorrelates across sources — same-j transactions
            # at different NIs must not share an offset (the table
            # length is prime, so the affine fold cycles through all
            # of it); with a zero table this is exactly the
            # deterministic model
            idx = ((txn + 131 * src) % JITTER_TABLE_LEN)[:, :, None]
            return jnp.take_along_axis(
                jnp.broadcast_to(jt[None, :, :],
                                 (R, n_cls, JITTER_TABLE_LEN)),
                idx, axis=2)[:, :, 0]

        bb = jnp.broadcast_to(burst_beats[None, :], (R, n_cls))
        push_r = jnp.stack([
            now + sl + jit_of(df_ar[..., F_TXN], df_ar[..., F_SRC]),
            df_ar[..., F_SRC], bb, df_ar[..., F_TIME],
            df_ar[..., F_TXN],
            jnp.broadcast_to(pa.r_kinds[None, :], (R, n_cls)),
        ], axis=-1)
        push_b = jnp.stack([
            now + sl + jit_of(df_w[..., F_TXN], df_w[..., F_SRC]),
            df_w[..., F_SRC], jnp.ones((R, n_cls), jnp.int32),
            df_w[..., F_TIME], df_w[..., F_TXN],
            jnp.broadcast_to(pa.b_kinds[None, :], (R, n_cls)),
        ], axis=-1)
        push_w = jnp.stack([
            jnp.broadcast_to(now + 1, (R, n_cls)), req_d, bb,
            jnp.broadcast_to(now, (R, n_cls)), txn0,
            jnp.broadcast_to(pa.w_kinds[None, :], (R, n_cls)),
        ], axis=-1)
        active = jnp.concatenate([is_ar, is_w_last], axis=1)
        push_val = jnp.concatenate([push_r, push_b],
                                   axis=1).astype(jnp.int32)
        offset = jnp.einsum("rj,ij->ri", active.astype(jnp.int32),
                            jnp.asarray(pa.push_before))
        tail_of_slot = ni.rq_tail[:, pa.q_of_slot]     # (R, 2*n_cls)
        slot_p = (tail_of_slot + offset) % cap
        slot_p = jnp.where(active, slot_p, cap)  # masked -> OOB, dropped
        rq = ni.rq.at[rows[:, None], pa.q_of_slot[None, :],
                      slot_p].set(push_val, mode="drop")
        tail_w = ni.rq_tail[:, plan.n_rq:]             # (R, n_cls)
        slot_pw = jnp.where(inj_aw, tail_w % w_cap, w_cap)
        wq = ni.wq.at[rows[:, None], wq_ids[None, :],
                      slot_pw].set(push_w.astype(jnp.int32), mode="drop")
        tail_inc = jnp.concatenate(
            [active.astype(jnp.int32) @ pa.q_onehot,
             inj_aw.astype(jnp.int32)], axis=1)        # (R, n_q)
        ni = ni._replace(rq=rq, wq=wq, rq_tail=ni.rq_tail + tail_inc)

        # ---- per-class per-direction metrics, vectorized ----------------
        last_r = is_r & (df_r[..., F_BEAT] <= 1)
        if faulted:
            # completion gating through the pending table: only a
            # response matching a live pending txn completes (a stale
            # duplicate after a retry, or after SLVERR, is dropped);
            # latency is measured from the ORIGINAL issue time, so a
            # retried transaction pays its full end-to-end delay
            eq_r = (fs.pend_txn == df_r[..., F_TXN][:, :, None]) \
                & ~fs.pend_wr & (fs.pend_txn >= 0)
            hit_r = last_r & jnp.any(eq_r, axis=2)
            t0_r = jnp.take_along_axis(
                fs.pend_t0, jnp.argmax(eq_r, axis=2)[:, :, None],
                axis=2)[:, :, 0]
            lat_r = jnp.where(hit_r, now - t0_r, 0)
            li_r = hit_r.astype(jnp.int32)
            eq_b = (fs.pend_txn == df_b[..., F_TXN][:, :, None]) \
                & fs.pend_wr & (fs.pend_txn >= 0)
            hit_b = is_b & jnp.any(eq_b, axis=2)
            t0_b = jnp.take_along_axis(
                fs.pend_t0, jnp.argmax(eq_b, axis=2)[:, :, None],
                axis=2)[:, :, 0]
            lat_b = jnp.where(hit_b, now - t0_b, 0)
            li_b = hit_b.astype(jnp.int32)
            clear = (eq_r & last_r[:, :, None]) | (eq_b & is_b[:, :, None])
            fs = fs._replace(
                pend_txn=jnp.where(clear, -1, fs.pend_txn))
        else:
            lat_r = jnp.where(last_r, now - df_r[..., F_TIME], 0)
            li_r = last_r.astype(jnp.int32)
            lat_b = jnp.where(is_b, now - df_b[..., F_TIME], 0)
            li_b = is_b.astype(jnp.int32)
        ni = ni._replace(
            beats_rx=ni.beats_rx + is_r.astype(jnp.int32),
            first_t=jnp.where(is_r, jnp.minimum(ni.first_t, now),
                              ni.first_t),
            last_t=jnp.where(is_r, jnp.maximum(ni.last_t, now),
                             ni.last_t),
            done=ni.done + li_r,
            lat_sum=ni.lat_sum + lat_r,
            lat_max=jnp.maximum(ni.lat_max, lat_r),
            out_r=ni.out_r - li_r,
            w_beats_rx=ni.w_beats_rx + is_w.astype(jnp.int32),
            w_first_t=jnp.where(is_w, jnp.minimum(ni.w_first_t, now),
                                ni.w_first_t),
            w_last_t=jnp.where(is_w, jnp.maximum(ni.w_last_t, now),
                               ni.w_last_t),
            w_done=ni.w_done + li_b,
            w_lat_sum=ni.w_lat_sum + lat_b,
            w_lat_max=jnp.maximum(ni.w_lat_max, lat_b),
            out_w=ni.out_w - li_b,
        )

        # ---- liveness: stall streak while transactions are in flight ----
        activity = (jnp.any(iv & ok_ch) | jnp.any(dv_ch)
                    | (jnp.sum(lm) > 0))
        pending = jnp.any((ni.out_r + ni.out_w) > 0)
        if shard is not None:      # global liveness: stall streaks must
            flags = jax.lax.psum(   # agree bit-for-bit across shards
                jnp.stack([activity, pending]).astype(jnp.int32),
                shard.axis)
            activity, pending = flags[0] > 0, flags[1] > 0
        cur = jnp.where(pending & ~activity, state.cur_stall + 1, 0)
        new_moves = state.moves + lm.astype(jnp.int32)
        if faulted:
            # degradation counters: what kept flowing while links were down
            fault_on = jnp.any(link_mask)
            fs = fs._replace(
                flc=fs.flc + jnp.sum(dead_e.astype(jnp.int32)),
                fcyc=fs.fcyc + fault_on.astype(jnp.int32),
                dlv_fault=fs.dlv_fault + jnp.where(fault_on,
                                                   li_r + li_b, 0),
                beats_fault=fs.beats_fault + jnp.where(
                    fault_on,
                    is_r.astype(jnp.int32) + is_w.astype(jnp.int32), 0))
        return SimState(net, ni, now + 1, new_moves, cur,
                        jnp.maximum(state.max_stall, cur),
                        vc_occ_sum, vc_occ_max,
                        fs if faulted else state.fs), None

    return step


# --------------------------------------------------------------------- #
# compiled-simulator cache (stats-instrumented, partitioned per backend)
# --------------------------------------------------------------------- #
SIM_CACHE_MAXSIZE = 256          # per backend partition

_caches: dict[str, OrderedDict] = {}
_stats = {"hits": 0, "misses": 0, "evictions": 0}
_cache_lock = threading.Lock()


def sim_cache_stats() -> dict:
    """Cache behavior of :func:`compiled_sim` (and the farm wrappers in
    :mod:`repro.noc.farm`, which live in their own partitions —
    ``"farm[n]:backend"`` / ``"rowshard[n]:backend"`` — so a sharded
    sweep at a fixed device count compiles once and every later sweep
    at that count is a hit, never a silent per-device-count recompile):
    ``misses`` counts actual simulator builds (one jit compilation
    each), ``hits`` reuses, and ``evictions`` should stay 0 for any
    sane sweep — each partition holds :data:`SIM_CACHE_MAXSIZE`
    entries, so a 70-spec grid compiles each spec exactly once
    (tested)."""
    with _cache_lock:
        return {**_stats,
                "size": sum(len(c) for c in _caches.values()),
                "partitions": {b: len(c) for b, c in _caches.items()}}


def _cache_get(partition: str, key):
    """Look up a compiled function in one stats-instrumented LRU
    partition (``None`` = miss, already counted).  The partition string
    is free-form — ``compiled_sim`` uses the backend name, the farm
    wrappers embed their device count — so differently-sharded builds
    of one spec never collide *or* evict each other."""
    with _cache_lock:
        part = _caches.setdefault(partition, OrderedDict())
        if key in part:
            part.move_to_end(key)
            _stats["hits"] += 1
            return part[key]
        _stats["misses"] += 1
        return None


def _cache_put(partition: str, key, fn):
    """Insert a freshly-built compiled function; evicts LRU entries
    beyond :data:`SIM_CACHE_MAXSIZE` per partition.  Returns ``fn``."""
    with _cache_lock:
        part = _caches.setdefault(partition, OrderedDict())
        part[key] = fn
        part.move_to_end(key)
        while len(part) > SIM_CACHE_MAXSIZE:
            part.popitem(last=False)
            _stats["evictions"] += 1
    return fn


def sim_cache_clear() -> None:
    with _cache_lock:
        _caches.clear()
        _stats.update(hits=0, misses=0, evictions=0)


def _depth_normalized(spec: NocSpec, max_depth: int | None):
    """(key spec, static max depth): the compiled simulator is depth-
    agnostic up to the static max, so the cache key replaces every
    channel depth with that max — specs differing only in FIFO depth
    share one compilation."""
    depths = tuple(ch.depth for ch in spec.channels)
    d_max = max(depths) if max_depth is None else int(max_depth)
    if d_max < max(depths):
        raise ValueError(
            f"max_depth={max_depth} below spec channel depths {depths}")
    key_spec = spec.with_(channels=tuple(
        replace(ch, depth=d_max) for ch in spec.channels))
    return key_spec, d_max


def compiled_sim(spec: NocSpec, T: int, backend: str = "jnp", *,
                 max_depth: int | None = None):
    """One jitted simulator per (depth-normalized spec, horizon,
    backend) triple, from a stats-instrumented per-backend cache.

    Returns ``fn(times, dests, writes, service_lat, max_out,
    burst_beats, jitter, depths)`` — plus, when the spec carries a
    :class:`~repro.noc.faults.FaultModel`, five extra traced operands
    ``(ev_fail, ev_heal, timeout_cycles, max_retries, backoff_base)``
    (the first two from :func:`repro.noc.faults.dynamic_events`, the
    rest per-class/scalar robustness knobs) and eight extra raw outputs
    (the degradation counters).  ``times``/``dests``/``writes``
    are (n_lanes, R, T) int32 schedules — one row per (class, AXI ID
    stream) lane, class-major, so with every class at ``n_streams=1``
    that is exactly the per-class (n_cls, R, T) layout
    (:func:`repro.noc.stack_schedules` builds them either way) and
    ``writes`` marks AXI write transactions.  The knobs stay
    per-CLASS — the ``service_lat`` vector, the (n_cls,
    JITTER_TABLE_LEN) service-jitter offset table,
    ``max_out``/``burst_beats`` — and are expanded to lanes inside the
    jit (each lane gets ``max_out[cls]//S`` credits, earlier streams
    take the remainder); with the per-channel FIFO ``depths`` vector
    all are traced, so the whole function is vmappable over a leading
    batch axis for rate/seed/latency/depth sweeps in a single jit.

    ``max_depth`` pads the FIFO state to a larger static bound than the
    spec declares, letting one compilation serve every depth up to that
    bound (the padded-depth sweep mode); results are flit-for-flit
    identical to a natively-sized build.  ``backend`` selects who runs
    the fabric hot loop (see :mod:`repro.noc.backends`); every backend
    must produce identical results behind this one surface.

    Off-CPU the big ``times``/``dests``/``writes`` operands are DONATED
    (the scan carry workspace aliases them): pass numpy arrays (always
    safe — a fresh device buffer is created per call, which is what
    every ``repro.noc`` caller does) or fresh device arrays; reusing a
    jnp array across calls on GPU/TPU raises "Array has been deleted".
    """
    key_spec, d_max = _depth_normalized(spec, max_depth)
    key = (key_spec, T)
    fn = _cache_get(backend, key)
    if fn is not None:
        return fn
    return _cache_put(backend, key, _build_sim(key_spec, T, backend, d_max))


def _build_sim(spec: NocSpec, T: int, backend: str, d_max: int):
    plan = build_flow_plan(spec)
    bk = get_backend(backend)
    faulted = spec.faults is not None
    # only pass faults= when present: custom two-arg backend factories
    # (and the healthy jaxpr) stay exactly as before
    network = bk(spec.topology, spec.routing, faults=spec.faults) \
        if faulted else bk(spec.topology, spec.routing)
    step = make_step(spec, plan, T, network.step)
    n_ch, R = plan.n_ch, spec.n_routers
    n_vcs = spec.routing.n_vcs

    # lane expansion of the per-CLASS traced knobs: static gather
    # indices (class of each lane) plus the credit split — lane s of a
    # class with S streams gets max_out//S credits, the first
    # max_out%S lanes one extra.  Single-stream specs skip the gather
    # entirely so their jaxpr (and goldens) are untouched.
    multi_stream = any(c.n_streams > 1 for c in spec.classes)
    cls_of = np.asarray(plan.cls_of_lane, np.int32)
    s_of = np.asarray(plan.stream_of_lane, np.int32)
    S_of = np.asarray([spec.classes[ci].n_streams
                       for ci in plan.cls_of_lane], np.int32)

    def to_lanes(service_lat, max_out, burst_beats, jitter):
        if not multi_stream:
            return service_lat, max_out, burst_beats, jitter
        mo_c = max_out[cls_of]
        mo = mo_c // S_of + (s_of < mo_c % S_of)
        return (service_lat[cls_of], mo, burst_beats[cls_of],
                jitter[cls_of])

    # donating the big schedule operands lets XLA alias them into the
    # scan carry's workspace; CPU can't donate (it would only warn)
    donate = () if jax.default_backend() == "cpu" else (0, 1, 2)

    def _run(times, dests, writes, service_lat, max_out, burst_beats,
             jitter, depths, fault_ops):
        state = SimState(network.init(n_ch, d_max),
                         init_ni(R, plan, spec.resp_q_cap), jnp.int32(0),
                         jnp.zeros((n_ch,), jnp.int32), jnp.int32(0),
                         jnp.int32(0),
                         jnp.zeros((n_ch, n_vcs), jnp.int32),
                         jnp.zeros((n_ch, n_vcs), jnp.int32),
                         init_faults(R, plan.n_cls, fault_p_cap(plan))
                         if faulted else ())
        service_lat, max_out, burst_beats, jitter = to_lanes(
            service_lat, max_out, burst_beats, jitter)
        times = jnp.moveaxis(times, 0, 1)              # (R, n_lanes, T)
        dyn = {"times": times,
               "dests": jnp.moveaxis(dests, 0, 1),
               "writes": jnp.moveaxis(writes, 0, 1),
               "service_lat": service_lat, "max_out": max_out,
               "burst_beats": burst_beats, "jitter": jitter,
               "depths": jnp.asarray(depths, jnp.int32)}
        if faulted:
            ev_fail, ev_heal, tout, max_retries, backoff = fault_ops
            tout = jnp.asarray(tout, jnp.int32)        # (n_classes,)
            if multi_stream:
                tout = tout[cls_of]                    # expand to lanes
            dyn.update(ev_fail=jnp.asarray(ev_fail, jnp.int32),
                       ev_heal=jnp.asarray(ev_heal, jnp.int32),
                       timeout=tout,
                       max_retries=jnp.asarray(max_retries, jnp.int32),
                       backoff=jnp.asarray(backoff, jnp.int32))
        final, _ = jax.lax.scan(functools.partial(step, dyn), state, None,
                                length=spec.cycles)
        ni = final.ni
        n_sched = jnp.sum(times < BIG, axis=2)         # (R, n_cls)
        drained = (jnp.all(ni.ptr >= n_sched) & jnp.all(ni.out_r == 0)
                   & jnp.all(ni.out_w == 0))
        raw = {
            "done": ni.done, "lat_sum": ni.lat_sum, "lat_max": ni.lat_max,
            "beats_rx": ni.beats_rx, "first_t": ni.first_t,
            "last_t": ni.last_t,
            "w_done": ni.w_done, "w_lat_sum": ni.w_lat_sum,
            "w_lat_max": ni.w_lat_max, "w_beats_rx": ni.w_beats_rx,
            "w_first_t": ni.w_first_t, "w_last_t": ni.w_last_t,
            "link_moves": final.moves,
            "max_stall_cycles": final.max_stall, "drained": drained,
            "vc_occ_sum": final.vc_occ_sum,
            "vc_occ_max": final.vc_occ_max,
        }
        if faulted:
            fst = final.fs
            raw.update({
                "retries": fst.retries, "timeouts": fst.timeouts,
                "slverr": fst.slverr,
                "delivered_despite_fault": fst.dlv_fault,
                "beats_under_fault": fst.beats_fault,
                "faulted_link_cycles": fst.flc,
                "fault_cycles": fst.fcyc,
                "undone": (jnp.maximum(n_sched - ni.ptr, 0)
                           + ni.out_r + ni.out_w),
            })
        return raw

    if faulted:
        @functools.partial(jax.jit, donate_argnums=donate)
        def run(times, dests, writes, service_lat, max_out, burst_beats,
                jitter, depths, ev_fail, ev_heal, timeout_cycles,
                max_retries, backoff_base):
            return _run(times, dests, writes, service_lat, max_out,
                        burst_beats, jitter, depths,
                        (ev_fail, ev_heal, timeout_cycles, max_retries,
                         backoff_base))
    else:
        @functools.partial(jax.jit, donate_argnums=donate)
        def run(times, dests, writes, service_lat, max_out, burst_beats,
                jitter, depths):
            return _run(times, dests, writes, service_lat, max_out,
                        burst_beats, jitter, depths, None)

    return run
