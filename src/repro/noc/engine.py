"""Generalized N-channel FlooNoC cycle engine.

This is the seed ``mesh_sim.py`` engine refactored from a hardcoded
``narrow_wide: bool`` (1-or-3 network) branch into a topology-driven
loop over the channels declared in a :class:`~repro.noc.spec.NocSpec`.
Per channel, the injection policy is derived from which flows the
``class_map`` routes onto it:

* only response flows from one queue  -> direct streaming (paper's
  dedicated narrow_rsp / wide networks),
* only request flows                  -> static priority, latency-
  critical (1-beat) classes first (paper's shared narrow_req carrying
  narrow reqs + wide ARs with narrow priority),
* requests and responses mixed       -> per-NI round-robin over all
  flows with wormhole burst atomicity (the paper's wide-only ablation,
  where a started burst excludes everything else on the link).

Response reorder buffers are keyed by *response channel*: classes whose
responses share one physical channel share one FIFO (the shared-FIFO
ablation — one R channel on one link), classes with dedicated response
channels get dedicated FIFOs.  For the two paper presets this engine is
cycle-exact with the seed simulator (golden-checked by the test suite).

NI model (paper §III-A) is unchanged: end-to-end ROB flow control,
read transactions req -> target NI -> after ``service_lat`` cycles a
response of ``burst_beats`` beats streams back atomically, in-order
delivery via deterministic table-driven routing (XY on the mesh,
minimal-wrap dimension-ordered on the torus, greedy largest-stride on
express meshes — see ``repro.noc.topology``).

Static structure (topology, channel list, FIFO depths, class->channel
map, horizon) lives in the spec and keys one jitted simulator per
backend; dynamic knobs (schedules, service latency, outstanding limits,
burst lengths) are traced operands so ``jax.vmap`` batches whole sweeps
in one jit.  The router hot loop itself is pluggable
(``repro.noc.backends``: pure-jnp reference vs the Pallas arbiter
kernel) behind the identical ``simulate()``/``SimResult`` surface.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.noc_sim.router import (F_BEAT, F_DEST, F_KIND, F_SRC, F_TIME,
                                       F_TXN, N_FIELDS)
from .backends import get_backend
from .spec import NocSpec

RESP_Q_CAP = 256
BIG = 1 << 30


def req_kind(cls_idx: int) -> int:
    return 2 * cls_idx


def rsp_kind(cls_idx: int) -> int:
    return 2 * cls_idx + 1


class ChannelPlan(NamedTuple):
    """Static routing of flows onto channels, derived from a NocSpec
    (the *logical* half of the fabric; the physical half is the spec's
    :class:`~repro.noc.topology.Topology`)."""
    n_cls: int
    n_ch: int
    n_q: int
    queue_of_class: tuple[int, ...]   # class -> response queue id
    reqs_on: tuple[tuple[int, ...], ...]   # channel -> req class ids (prio order)
    queues_on: tuple[tuple[int, ...], ...]  # channel -> rsp queue ids


def build_channel_plan(spec: NocSpec) -> ChannelPlan:
    n_cls, n_ch = len(spec.classes), len(spec.channels)
    # queues: one per distinct response channel, in first-appearance order
    rsp_ch_of_q: list[int] = []
    queue_of_class = []
    for cls in spec.classes:
        ch = spec.rsp_channel(cls.name)
        if ch not in rsp_ch_of_q:
            rsp_ch_of_q.append(ch)
        queue_of_class.append(rsp_ch_of_q.index(ch))
    # per-channel request classes, latency-critical (single-beat) first
    reqs_on = []
    for c in range(n_ch):
        ids = [i for i, cls in enumerate(spec.classes)
               if spec.req_channel(cls.name) == c]
        ids.sort(key=lambda i: (spec.classes[i].burst_beats > 1, i))
        reqs_on.append(tuple(ids))
    queues_on = tuple(
        tuple(q for q, ch in enumerate(rsp_ch_of_q) if ch == c)
        for c in range(n_ch))
    return ChannelPlan(n_cls, n_ch, len(rsp_ch_of_q),
                       tuple(queue_of_class), tuple(reqs_on), queues_on)


class NIState(NamedTuple):
    ptr: jax.Array          # (R, n_cls) schedule pointers
    out: jax.Array          # (R, n_cls) outstanding (ROB flow control)
    # response ring buffers: (R, n_q, C)
    rq_head: jax.Array      # (R, n_q)
    rq_tail: jax.Array      # (R, n_q)
    rq_ready: jax.Array
    rq_dest: jax.Array
    rq_beats: jax.Array
    rq_time0: jax.Array
    rq_txn: jax.Array
    rq_kind: jax.Array
    w_started: jax.Array    # (R, n_q) burst mid-stream (inject atomicity)
    inj_rr: jax.Array       # (R, n_ch) mixed-channel round-robin
    # per-class metrics: (R, n_cls)
    lat_sum: jax.Array
    lat_max: jax.Array
    done: jax.Array
    beats_rx: jax.Array
    first_t: jax.Array
    last_t: jax.Array


class SimState(NamedTuple):
    nets: tuple
    ni: NIState
    cycle: jax.Array
    moves: jax.Array        # (n_ch,) link traversals per channel


def init_ni(R: int, topo: ChannelPlan) -> NIState:
    zc = jnp.zeros((R, topo.n_cls), jnp.int32)
    zq = jnp.zeros((R, topo.n_q), jnp.int32)
    zqc = jnp.zeros((R, topo.n_q, RESP_Q_CAP), jnp.int32)
    return NIState(
        ptr=zc, out=zc, rq_head=zq, rq_tail=zq, rq_ready=zqc, rq_dest=zqc,
        rq_beats=zqc, rq_time0=zqc, rq_txn=zqc, rq_kind=zqc,
        w_started=jnp.zeros((R, topo.n_q), jnp.bool_),
        inj_rr=jnp.zeros((R, topo.n_ch), jnp.int32),
        lat_sum=zc, lat_max=zc, done=zc, beats_rx=zc,
        first_t=jnp.full((R, topo.n_cls), BIG, jnp.int32), last_t=zc)


def _q_push(ni: NIState, q: int, valid, dest, beats, time0, txn, ready_at,
            kind):
    rows = jnp.arange(valid.shape[0])
    slot = ni.rq_tail[:, q] % RESP_Q_CAP

    def upd(arr, val):
        return arr.at[rows, q, slot].set(
            jnp.where(valid, val, arr[rows, q, slot]))

    return ni._replace(
        rq_ready=upd(ni.rq_ready, ready_at),
        rq_dest=upd(ni.rq_dest, dest),
        rq_beats=upd(ni.rq_beats, beats),
        rq_time0=upd(ni.rq_time0, time0),
        rq_txn=upd(ni.rq_txn, txn),
        rq_kind=upd(ni.rq_kind, kind),
        rq_tail=ni.rq_tail.at[:, q].add(valid.astype(jnp.int32)),
    )


def _q_head(ni: NIState, q: int, now):
    rows = jnp.arange(ni.rq_head.shape[0])
    have = ni.rq_head[:, q] < ni.rq_tail[:, q]
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    ready = have & (ni.rq_ready[rows, q, slot] <= now)
    return {
        "ready": ready,
        "dest": ni.rq_dest[rows, q, slot],
        "beats": ni.rq_beats[rows, q, slot],
        "time0": ni.rq_time0[rows, q, slot],
        "txn": ni.rq_txn[rows, q, slot],
        "kind": ni.rq_kind[rows, q, slot],
    }


def _q_sent(ni: NIState, q: int, sent):
    """Decrement head beats; pop when exhausted; track burst-in-flight."""
    rows = jnp.arange(sent.shape[0])
    slot = ni.rq_head[:, q] % RESP_Q_CAP
    left = ni.rq_beats[rows, q, slot] - sent.astype(jnp.int32)
    return ni._replace(
        rq_beats=ni.rq_beats.at[rows, q, slot].set(
            jnp.where(sent, left, ni.rq_beats[rows, q, slot])),
        rq_head=ni.rq_head.at[:, q].add(
            (sent & (left <= 0)).astype(jnp.int32)),
        w_started=ni.w_started.at[:, q].set(
            jnp.where(sent, left > 0, ni.w_started[:, q])),
    )


def make_step(spec: NocSpec, topo: ChannelPlan, T: int, net_step):
    """Build the per-cycle transition. Dynamic operands arrive via the
    carried closure-free ``dyn`` dict (schedules + scalar knobs);
    ``net_step`` is the backend's one-network one-cycle update
    (:class:`repro.noc.backends.Network`)."""
    R = spec.n_routers
    rows = jnp.arange(R)

    def mk_flit(valid, dest, src, time, kind, txn, beat):
        f = jnp.zeros((R, N_FIELDS), jnp.int32)
        z = jnp.int32(0)
        for idx, val in ((F_DEST, dest), (F_SRC, src), (F_TIME, time),
                         (F_KIND, kind), (F_TXN, txn), (F_BEAT, beat)):
            f = f.at[:, idx].set(jnp.where(valid, val, z))
        return f

    def step(dyn, state: SimState, _):
        times, dests = dyn["times"], dyn["dests"]
        service_lat = dyn["service_lat"]
        max_out, burst_beats = dyn["max_out"], dyn["burst_beats"]
        ni = state.ni
        now = state.cycle

        # ---- source side: per-class request candidates (ROB gated) ------
        want, req_d = [], []
        for i in range(topo.n_cls):
            p = jnp.clip(ni.ptr[:, i], 0, T - 1)
            want.append((ni.ptr[:, i] < T) & (times[i, rows, p] <= now)
                        & (ni.out[:, i] < max_out[i]))
            req_d.append(dests[i, rows, p])

        # ---- target side: response queue heads --------------------------
        heads = [_q_head(ni, q, now) for q in range(topo.n_q)]

        injected = [jnp.zeros((R,), jnp.bool_) for _ in range(topo.n_cls)]
        sent = [jnp.zeros((R,), jnp.bool_) for _ in range(topo.n_q)]
        new_nets, deliveries, moves = [], [], []

        for c in range(topo.n_ch):
            reqs, qs = topo.reqs_on[c], topo.queues_on[c]
            if not reqs and not qs:          # idle channel: still steps
                net, _, dv, df, lm = net_step(
                    state.nets[c], jnp.zeros((R,), jnp.bool_),
                    jnp.zeros((R, N_FIELDS), jnp.int32))
            elif not reqs and len(qs) == 1:
                # dedicated response channel: stream the queue head
                q = qs[0]
                h = heads[q]
                f = mk_flit(h["ready"], h["dest"], rows, h["time0"],
                            h["kind"], h["txn"], h["beats"])
                net, ok, dv, df, lm = net_step(state.nets[c], h["ready"], f)
                sent[q] = ok & h["ready"]
            elif reqs and not qs:
                # request-only channel: static priority, smalls first
                taken = jnp.zeros((R,), jnp.bool_)
                sel = []
                for i in reqs:
                    s = want[i] & ~taken
                    sel.append((i, s))
                    taken = taken | s
                dest = kind = txn = jnp.zeros((R,), jnp.int32)
                for i, s in sel:
                    dest = jnp.where(s, req_d[i], dest)
                    kind = jnp.where(s, req_kind(i), kind)
                    txn = jnp.where(s, ni.ptr[:, i], txn)
                f = mk_flit(taken, dest, rows, now, kind, txn, 1)
                net, ok, dv, df, lm = net_step(state.nets[c], taken, f)
                for i, s in sel:
                    injected[i] = ok & s
            else:
                # mixed channel: round-robin over [rsp heads..., reqs...]
                # with burst atomicity — an in-flight burst excludes all
                cand = ([("rsp", q) for q in qs]
                        + [("req", i) for i in reqs])
                n_cand = len(cand)
                cand_valid = jnp.stack(
                    [heads[q]["ready"] for q in qs]
                    + [want[i] for i in reqs], axis=1)
                rr = ni.inj_rr[:, c] % n_cand
                order = (jnp.arange(n_cand)[None, :] + rr[:, None]) % n_cand
                ordered = jnp.take_along_axis(cand_valid, order, axis=1)
                first = jnp.argmax(ordered, axis=1)
                has_any = jnp.any(cand_valid, axis=1)
                choice = jnp.take_along_axis(order, first[:, None],
                                             axis=1)[:, 0]
                hold = jnp.zeros((R,), jnp.bool_)
                for k, q in enumerate(qs):
                    hq = ni.w_started[:, q] & (heads[q]["beats"] > 0)
                    choice = jnp.where(hq & ~hold, k, choice)
                    hold = hold | hq
                valid0 = has_any | hold

                sel_masks = []
                for k, (tag, idx) in enumerate(cand):
                    gate = heads[idx]["ready"] if tag == "rsp" else want[idx]
                    sel_masks.append(valid0 & (choice == k) & gate)
                valid = functools.reduce(jnp.logical_or, sel_masks)

                dest = kind = txn = beat = jnp.zeros((R,), jnp.int32)
                time = jnp.broadcast_to(now, (R,)).astype(jnp.int32)
                for (tag, idx), s in zip(cand, sel_masks):
                    if tag == "rsp":
                        h = heads[idx]
                        dest = jnp.where(s, h["dest"], dest)
                        kind = jnp.where(s, h["kind"], kind)
                        txn = jnp.where(s, h["txn"], txn)
                        time = jnp.where(s, h["time0"], time)
                        beat = jnp.where(s, h["beats"], beat)
                    else:
                        dest = jnp.where(s, req_d[idx], dest)
                        kind = jnp.where(s, req_kind(idx), kind)
                        txn = jnp.where(s, ni.ptr[:, idx], txn)
                        beat = jnp.where(s, 1, beat)
                f = mk_flit(valid, dest, rows, time, kind, txn, beat)
                net, ok, dv, df, lm = net_step(state.nets[c], valid, f)
                for (tag, idx), s in zip(cand, sel_masks):
                    if tag == "rsp":
                        sent[idx] = sent[idx] | (ok & s)
                    else:
                        injected[idx] = ok & s
                ni = ni._replace(inj_rr=ni.inj_rr.at[:, c].add(
                    (ok & ~hold).astype(jnp.int32)))
            new_nets.append(net)
            deliveries.append((dv, df))
            moves.append(lm)

        # ---- pointer / outstanding / queue updates ----------------------
        inj = jnp.stack(injected, axis=1).astype(jnp.int32)
        ni = ni._replace(ptr=ni.ptr + inj, out=ni.out + inj)
        for q in range(topo.n_q):
            ni = _q_sent(ni, q, sent[q])

        # ---- deliveries --------------------------------------------------
        for c, (dv, df) in enumerate(deliveries):
            kind = df[:, F_KIND]
            src = df[:, F_SRC]
            lat = now - df[:, F_TIME]
            for i in topo.reqs_on[c]:
                is_req = dv & (kind == req_kind(i))
                ni = _q_push(
                    ni, topo.queue_of_class[i], is_req, src,
                    jnp.broadcast_to(burst_beats[i], (R,)).astype(jnp.int32),
                    df[:, F_TIME], df[:, F_TXN], now + service_lat,
                    jnp.full((R,), rsp_kind(i), jnp.int32))
            rsp_classes = [i for i in range(topo.n_cls)
                           if topo.queue_of_class[i] in topo.queues_on[c]]
            for i in rsp_classes:
                is_rsp = dv & (kind == rsp_kind(i))
                last = is_rsp & (df[:, F_BEAT] <= 1)
                li = last.astype(jnp.int32)
                col = (jnp.arange(topo.n_cls) == i)
                ni = ni._replace(
                    beats_rx=ni.beats_rx + jnp.where(
                        col, is_rsp.astype(jnp.int32)[:, None], 0),
                    first_t=jnp.where(
                        col & is_rsp[:, None],
                        jnp.minimum(ni.first_t, now), ni.first_t),
                    last_t=jnp.where(
                        col & is_rsp[:, None],
                        jnp.maximum(ni.last_t, now), ni.last_t),
                    done=ni.done + jnp.where(col, li[:, None], 0),
                    lat_sum=ni.lat_sum + jnp.where(
                        col, jnp.where(last, lat, 0)[:, None], 0),
                    lat_max=jnp.maximum(ni.lat_max, jnp.where(
                        col, jnp.where(last, lat, 0)[:, None], 0)),
                    out=ni.out - jnp.where(col, li[:, None], 0),
                )

        new_moves = state.moves + jnp.stack(moves).astype(jnp.int32)
        return SimState(tuple(new_nets), ni, now + 1, new_moves), None

    return step


@functools.lru_cache(maxsize=64)
def compiled_sim(spec: NocSpec, T: int, backend: str = "jnp"):
    """One jitted simulator per (static spec, horizon, backend) triple.

    Returns ``fn(times, dests, service_lat, max_out, burst_beats)`` where
    ``times``/``dests`` are (n_cls, R, T) int32 schedules and the scalar
    knobs are traced — so the whole function is vmappable over a leading
    batch axis for rate/seed/latency sweeps in a single jit.

    ``backend`` selects who runs the router hot loop (see
    :mod:`repro.noc.backends`); every backend must produce flit-for-flit
    identical results behind this one surface.
    """
    topo = build_channel_plan(spec)
    network = get_backend(backend)(spec.topology)
    step = make_step(spec, topo, T, network.step)

    @jax.jit
    def run(times, dests, service_lat, max_out, burst_beats):
        nets = tuple(network.init(ch.depth) for ch in spec.channels)
        state = SimState(nets, init_ni(spec.n_routers, topo), jnp.int32(0),
                         jnp.zeros((topo.n_ch,), jnp.int32))
        dyn = {"times": times, "dests": dests,
               "service_lat": service_lat, "max_out": max_out,
               "burst_beats": burst_beats}
        final, _ = jax.lax.scan(functools.partial(step, dyn), state, None,
                                length=spec.cycles)
        ni = final.ni
        return {
            "done": ni.done, "lat_sum": ni.lat_sum, "lat_max": ni.lat_max,
            "beats_rx": ni.beats_rx, "first_t": ni.first_t,
            "last_t": ni.last_t, "link_moves": final.moves,
        }

    return run
