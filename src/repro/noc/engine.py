"""Generalized N-channel FlooNoC cycle engine — the fused hot loop.

This is the tentpole of the perf PR: the scan body that used to be a
Python-unrolled tour over channels, classes, and queues (one fabric op
sequence per channel, 6 scatters per ``_q_push``, per-class ``col``
masked metric updates) is now three batched blocks per cycle:

1. **one stacked fabric call** — every physical channel's router update
   runs as a single backend step over ``(n_ch, R, ...)`` state (the
   ``"pallas_fused"`` backend collapses it further into ONE kernel
   launch per cycle; see :mod:`repro.noc.backends`),
2. **batched NI source/sink state** — schedule pointers, outstanding
   counters, and metrics live as ``(R, n_cls)`` arrays; the response
   reorder rings are ONE ``(R, n_q, cap, 6)`` array updated with a
   single segment-style scatter per cycle (multi-class pushes into a
   shared ring are ordered by a static prefix matrix, preserving the
   sequential engine's slot order exactly),
3. **traced FIFO depth** — state is sized by a static max and occupancy
   checks compare against the dynamic per-channel ``depths`` operand,
   so FIFO-depth sweeps share one compilation (``compiled_sim``'s
   ``max_depth=`` padded mode; see :func:`repro.noc.api.sweep`).

Per channel, the injection policy is derived from which flows the
``class_map`` routes onto it:

* only response flows from one queue  -> direct streaming (paper's
  dedicated narrow_rsp / wide networks),
* only request flows                  -> static priority, latency-
  critical (1-beat) classes first (paper's shared narrow_req carrying
  narrow reqs + wide ARs with narrow priority),
* requests and responses mixed       -> per-NI round-robin over all
  flows with wormhole burst atomicity (the paper's wide-only ablation,
  where a started burst excludes everything else on the link).

Response reorder buffers are keyed by *response channel*: classes whose
responses share one physical channel share one ring (the shared-FIFO
ablation — one R channel on one link), classes with dedicated response
channels get dedicated rings.  Ring capacity comes from the spec
(``NocSpec.resp_q_cap``) so small studies stop carrying
``(R, n_q, 256)``-sized state.  For the two paper presets this engine
is cycle-exact with the seed simulator (golden-checked by the suite).

NI model (paper §III-A) is unchanged: end-to-end ROB flow control,
read transactions req -> target NI -> after ``service_lat`` cycles a
response of ``burst_beats`` beats streams back atomically, in-order
delivery via deterministic table-driven routing.

Static structure (topology, channel list, max FIFO depth, class->
channel map, horizon) keys one jitted simulator per backend in a
stats-instrumented cache (:func:`sim_cache_stats`); dynamic knobs
(schedules, service latency, outstanding limits, burst lengths, FIFO
depths) are traced operands so ``jax.vmap`` batches whole sweeps in one
jit.
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from dataclasses import replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noc_sim.router import (F_BEAT, F_DEST, F_KIND, F_SRC, F_TIME,
                                       F_TXN, N_FIELDS)
from .backends import get_backend
from .spec import NocSpec

BIG = 1 << 30

# response-ring field order within the stacked (R, n_q, cap, 6) array
Q_READY, Q_DEST, Q_BEATS, Q_TIME0, Q_TXN, Q_KIND = range(6)
N_QFIELDS = 6


def req_kind(cls_idx: int) -> int:
    return 2 * cls_idx


def rsp_kind(cls_idx: int) -> int:
    return 2 * cls_idx + 1


class ChannelPlan(NamedTuple):
    """Static routing of flows onto channels, derived from a NocSpec
    (the *logical* half of the fabric; the physical half is the spec's
    :class:`~repro.noc.topology.Topology`)."""
    n_cls: int
    n_ch: int
    n_q: int
    queue_of_class: tuple[int, ...]   # class -> response queue id
    reqs_on: tuple[tuple[int, ...], ...]   # channel -> req class ids (prio order)
    queues_on: tuple[tuple[int, ...], ...]  # channel -> rsp queue ids


def build_channel_plan(spec: NocSpec) -> ChannelPlan:
    n_cls, n_ch = len(spec.classes), len(spec.channels)
    # queues: one per distinct response channel, in first-appearance order
    rsp_ch_of_q: list[int] = []
    queue_of_class = []
    for cls in spec.classes:
        ch = spec.rsp_channel(cls.name)
        if ch not in rsp_ch_of_q:
            rsp_ch_of_q.append(ch)
        queue_of_class.append(rsp_ch_of_q.index(ch))
    # per-channel request classes, latency-critical (single-beat) first
    reqs_on = []
    for c in range(n_ch):
        ids = [i for i, cls in enumerate(spec.classes)
               if spec.req_channel(cls.name) == c]
        ids.sort(key=lambda i: (spec.classes[i].burst_beats > 1, i))
        reqs_on.append(tuple(ids))
    queues_on = tuple(
        tuple(q for q, ch in enumerate(rsp_ch_of_q) if ch == c)
        for c in range(n_ch))
    return ChannelPlan(n_cls, n_ch, len(rsp_ch_of_q),
                       tuple(queue_of_class), tuple(reqs_on), queues_on)


class _PlanArrays(NamedTuple):
    """Static index/selector arrays derived from a ChannelPlan, shared
    by every cycle of the batched NI update.  Kept as *numpy* so index
    lookups stay concrete at trace time (a jnp constant would turn
    ``req_ch[i]`` into a traced op inside the scan body)."""
    q_of_cls: np.ndarray      # (n_cls,) response queue per class
    req_ch: np.ndarray        # (n_cls,) channel carrying each class's reqs
    rsp_ch: np.ndarray        # (n_cls,) channel carrying each class's rsps
    req_kinds: np.ndarray     # (n_cls,)
    rsp_kinds: np.ndarray     # (n_cls,)
    push_before: np.ndarray   # (n_cls, n_cls) 1 where j pushes the same
    #                           queue as i earlier in the sequential order
    q_onehot: np.ndarray      # (n_cls, n_q) class -> queue one-hot


def _plan_arrays(spec: NocSpec, plan: ChannelPlan) -> _PlanArrays:
    n_cls, n_q = plan.n_cls, plan.n_q
    q_of = np.asarray(plan.queue_of_class, np.int32)
    req_ch = np.asarray([spec.req_channel(c.name) for c in spec.classes],
                        np.int32)
    rsp_ch = np.asarray([spec.rsp_channel(c.name) for c in spec.classes],
                        np.int32)
    # sequential push order of the pre-fusion engine: channel-major, then
    # the channel's priority order — preserves exact ring-slot ordering
    # when several classes push one shared queue in the same cycle
    order = [i for c in range(plan.n_ch) for i in plan.reqs_on[c]]
    pos = np.empty(n_cls, np.int64)
    pos[order] = np.arange(n_cls)
    push_before = ((pos[None, :] < pos[:, None])
                   & (q_of[None, :] == q_of[:, None])).astype(np.int32)
    q_onehot = (q_of[:, None] == np.arange(n_q)[None, :]).astype(np.int32)
    return _PlanArrays(
        q_of_cls=q_of, req_ch=req_ch, rsp_ch=rsp_ch,
        req_kinds=np.asarray([req_kind(i) for i in range(n_cls)], np.int32),
        rsp_kinds=np.asarray([rsp_kind(i) for i in range(n_cls)], np.int32),
        push_before=push_before, q_onehot=q_onehot)


class NIState(NamedTuple):
    ptr: jax.Array          # (R, n_cls) schedule pointers
    out: jax.Array          # (R, n_cls) outstanding (ROB flow control)
    rq_head: jax.Array      # (R, n_q)
    rq_tail: jax.Array      # (R, n_q)
    rq: jax.Array           # (R, n_q, cap, 6) stacked response rings
    w_started: jax.Array    # (R, n_q) burst mid-stream (inject atomicity)
    inj_rr: jax.Array       # (R, n_ch) mixed-channel round-robin
    # per-class metrics: (R, n_cls)
    lat_sum: jax.Array
    lat_max: jax.Array
    done: jax.Array
    beats_rx: jax.Array
    first_t: jax.Array
    last_t: jax.Array


class SimState(NamedTuple):
    net: NamedTuple         # stacked NetState, (n_ch, R, ...) leaves
    ni: NIState
    cycle: jax.Array
    moves: jax.Array        # (n_ch,) link traversals per channel


def init_ni(R: int, plan: ChannelPlan, cap: int) -> NIState:
    zc = jnp.zeros((R, plan.n_cls), jnp.int32)
    zq = jnp.zeros((R, plan.n_q), jnp.int32)
    return NIState(
        ptr=zc, out=zc, rq_head=zq, rq_tail=zq,
        rq=jnp.zeros((R, plan.n_q, cap, N_QFIELDS), jnp.int32),
        w_started=jnp.zeros((R, plan.n_q), jnp.bool_),
        inj_rr=jnp.zeros((R, plan.n_ch), jnp.int32),
        lat_sum=zc, lat_max=zc, done=zc, beats_rx=zc,
        first_t=jnp.full((R, plan.n_cls), BIG, jnp.int32), last_t=zc)


def make_step(spec: NocSpec, plan: ChannelPlan, T: int, net_step):
    """Build the per-cycle transition. Dynamic operands arrive via the
    closure-free ``dyn`` dict (schedules + scalar knobs + depths);
    ``net_step`` is the backend's stacked one-cycle fabric update
    (:class:`repro.noc.backends.Network`)."""
    R = spec.n_routers
    cap = spec.resp_q_cap
    pa = _plan_arrays(spec, plan)
    rows = jnp.arange(R)
    q_ids = jnp.arange(plan.n_q)

    def step(dyn, state: SimState, _):
        times, dests = dyn["times"], dyn["dests"]     # (R, n_cls, T)
        service_lat = dyn["service_lat"]
        max_out, burst_beats = dyn["max_out"], dyn["burst_beats"]
        ni = state.ni
        now = state.cycle

        # ---- source side: per-class request candidates (ROB gated) ------
        p = jnp.clip(ni.ptr, 0, T - 1)[:, :, None]
        t_sel = jnp.take_along_axis(times, p, axis=2)[:, :, 0]
        want = ((ni.ptr < T) & (t_sel <= now)
                & (ni.out < max_out[None, :]))        # (R, n_cls)
        req_d = jnp.take_along_axis(dests, p, axis=2)[:, :, 0]

        # ---- target side: response ring heads, all queues at once -------
        slot_h = ni.rq_head % cap                      # (R, n_q)
        h = jnp.take_along_axis(ni.rq, slot_h[:, :, None, None],
                                axis=2)[:, :, 0, :]    # (R, n_q, 6)
        have = ni.rq_head < ni.rq_tail
        h_ready = have & (h[..., Q_READY] <= now)
        h_dest, h_beats = h[..., Q_DEST], h[..., Q_BEATS]
        h_time0, h_txn, h_kind = h[..., Q_TIME0], h[..., Q_TXN], h[..., Q_KIND]

        # ---- per-channel injection policy (small static loop) -----------
        sel_req: dict[int, jax.Array] = {}   # class -> selected this cycle
        sel_rsp: dict[int, jax.Array] = {}   # queue -> streamed this cycle
        hold_of_ch: dict[int, jax.Array] = {}
        iv_cols, flit_cols = [], []
        zero = jnp.zeros((R,), jnp.int32)
        for c in range(plan.n_ch):
            reqs, qs = plan.reqs_on[c], plan.queues_on[c]
            dest = kind = txn = beat = zero
            time = jnp.broadcast_to(now, (R,)).astype(jnp.int32)
            if not reqs and not qs:          # idle channel: still steps
                valid = jnp.zeros((R,), jnp.bool_)
            elif not reqs and len(qs) == 1:
                # dedicated response channel: stream the queue head
                q = qs[0]
                valid = h_ready[:, q]
                sel_rsp[q] = valid
                dest, kind, txn = h_dest[:, q], h_kind[:, q], h_txn[:, q]
                time, beat = h_time0[:, q], h_beats[:, q]
            elif reqs and not qs:
                # request-only channel: static priority, smalls first
                taken = jnp.zeros((R,), jnp.bool_)
                for i in reqs:
                    s = want[:, i] & ~taken
                    sel_req[i] = s
                    taken = taken | s
                    dest = jnp.where(s, req_d[:, i], dest)
                    kind = jnp.where(s, req_kind(i), kind)
                    txn = jnp.where(s, ni.ptr[:, i], txn)
                valid, beat = taken, jnp.where(taken, 1, 0)
            else:
                # mixed channel: round-robin over [rsp heads..., reqs...]
                # with burst atomicity — an in-flight burst excludes all
                cand = ([("rsp", q) for q in qs]
                        + [("req", i) for i in reqs])
                n_cand = len(cand)
                cand_valid = jnp.stack(
                    [h_ready[:, q] for q in qs]
                    + [want[:, i] for i in reqs], axis=1)
                rr = ni.inj_rr[:, c] % n_cand
                order = (jnp.arange(n_cand)[None, :] + rr[:, None]) % n_cand
                ordered = jnp.take_along_axis(cand_valid, order, axis=1)
                first = jnp.argmax(ordered, axis=1)
                has_any = jnp.any(cand_valid, axis=1)
                choice = jnp.take_along_axis(order, first[:, None],
                                             axis=1)[:, 0]
                hold = jnp.zeros((R,), jnp.bool_)
                for k, q in enumerate(qs):
                    hq = ni.w_started[:, q] & (h_beats[:, q] > 0)
                    choice = jnp.where(hq & ~hold, k, choice)
                    hold = hold | hq
                hold_of_ch[c] = hold
                valid0 = has_any | hold

                valid = jnp.zeros((R,), jnp.bool_)
                for k, (tag, idx) in enumerate(cand):
                    gate = h_ready[:, idx] if tag == "rsp" else want[:, idx]
                    s = valid0 & (choice == k) & gate
                    valid = valid | s
                    if tag == "rsp":
                        sel_rsp[idx] = s
                        dest = jnp.where(s, h_dest[:, idx], dest)
                        kind = jnp.where(s, h_kind[:, idx], kind)
                        txn = jnp.where(s, h_txn[:, idx], txn)
                        time = jnp.where(s, h_time0[:, idx], time)
                        beat = jnp.where(s, h_beats[:, idx], beat)
                    else:
                        sel_req[idx] = s
                        dest = jnp.where(s, req_d[:, idx], dest)
                        kind = jnp.where(s, req_kind(idx), kind)
                        txn = jnp.where(s, ni.ptr[:, idx], txn)
                        beat = jnp.where(s, 1, beat)
            iv_cols.append(valid)
            flit = jnp.stack([dest, rows, time, kind, txn, beat], axis=1)
            flit_cols.append(jnp.where(valid[:, None], flit, 0))

        # ---- ONE stacked fabric step for every channel ------------------
        iv = jnp.stack(iv_cols)                        # (n_ch, R)
        iflit = jnp.stack(flit_cols)                   # (n_ch, R, F)
        net, ok_ch, dv_ch, df_ch, lm = net_step(
            state.net, iv, iflit, dyn["depths"])

        # ---- pointer / outstanding / ring-head updates ------------------
        injected = jnp.stack(
            [ok_ch[int(pa.req_ch[i])] & sel_req[i]
             if i in sel_req else jnp.zeros((R,), jnp.bool_)
             for i in range(plan.n_cls)], axis=1)      # (R, n_cls)
        q_to_ch = {q: c for c in range(plan.n_ch) for q in plan.queues_on[c]}
        sent = jnp.stack(
            [ok_ch[q_to_ch[q]] & sel_rsp[q]
             if q in sel_rsp else jnp.zeros((R,), jnp.bool_)
             for q in range(plan.n_q)], axis=1)        # (R, n_q)
        inj_rr = ni.inj_rr
        for c, hold in hold_of_ch.items():
            inj_rr = inj_rr.at[:, c].add((ok_ch[c] & ~hold).astype(jnp.int32))

        inj = injected.astype(jnp.int32)
        left = h_beats - sent.astype(jnp.int32)
        rq = ni.rq.at[rows[:, None], q_ids[None, :], slot_h, Q_BEATS].set(
            jnp.where(sent, left, h_beats))
        ni = ni._replace(
            ptr=ni.ptr + inj, out=ni.out + inj, inj_rr=inj_rr, rq=rq,
            rq_head=ni.rq_head + (sent & (left <= 0)).astype(jnp.int32),
            w_started=jnp.where(sent, left > 0, ni.w_started))

        # ---- deliveries: batched push + batched per-class metrics -------
        # gather each class's req/rsp delivery through its static channel
        dv_req = dv_ch[pa.req_ch].T                    # (R, n_cls)
        df_req = jnp.moveaxis(df_ch[pa.req_ch], 0, 1)  # (R, n_cls, F)
        is_req = dv_req & (df_req[..., F_KIND] == pa.req_kinds[None, :])

        # ONE segment-style scatter pushes every class's response entry:
        # slot = tail of its queue + #earlier same-queue pushes this cycle
        offset = jnp.einsum("rj,ij->ri", is_req.astype(jnp.int32),
                            jnp.asarray(pa.push_before))
        tail_of_cls = ni.rq_tail[:, pa.q_of_cls]       # (R, n_cls)
        slot_p = (tail_of_cls + offset) % cap
        slot_p = jnp.where(is_req, slot_p, cap)  # masked -> OOB, dropped
        push_val = jnp.stack([
            jnp.broadcast_to(now + service_lat, is_req.shape),
            df_req[..., F_SRC],
            jnp.broadcast_to(burst_beats[None, :], is_req.shape),
            df_req[..., F_TIME],
            df_req[..., F_TXN],
            jnp.broadcast_to(pa.rsp_kinds[None, :], is_req.shape),
        ], axis=-1).astype(jnp.int32)                  # (R, n_cls, 6)
        rq = ni.rq.at[rows[:, None], pa.q_of_cls[None, :],
                      slot_p].set(push_val, mode="drop")
        tail_inc = is_req.astype(jnp.int32) @ pa.q_onehot
        ni = ni._replace(rq=rq, rq_tail=ni.rq_tail + tail_inc)

        # per-class response metrics, fully vectorized over (R, n_cls)
        dv_rsp = dv_ch[pa.rsp_ch].T
        df_rsp = jnp.moveaxis(df_ch[pa.rsp_ch], 0, 1)
        is_rsp = dv_rsp & (df_rsp[..., F_KIND] == pa.rsp_kinds[None, :])
        last = is_rsp & (df_rsp[..., F_BEAT] <= 1)
        lat = jnp.where(last, now - df_rsp[..., F_TIME], 0)
        li = last.astype(jnp.int32)
        ni = ni._replace(
            beats_rx=ni.beats_rx + is_rsp.astype(jnp.int32),
            first_t=jnp.where(is_rsp, jnp.minimum(ni.first_t, now),
                              ni.first_t),
            last_t=jnp.where(is_rsp, jnp.maximum(ni.last_t, now),
                             ni.last_t),
            done=ni.done + li,
            lat_sum=ni.lat_sum + lat,
            lat_max=jnp.maximum(ni.lat_max, lat),
            out=ni.out - li,
        )

        new_moves = state.moves + lm.astype(jnp.int32)
        return SimState(net, ni, now + 1, new_moves), None

    return step


# --------------------------------------------------------------------- #
# compiled-simulator cache (stats-instrumented, partitioned per backend)
# --------------------------------------------------------------------- #
SIM_CACHE_MAXSIZE = 256          # per backend partition

_caches: dict[str, OrderedDict] = {}
_stats = {"hits": 0, "misses": 0, "evictions": 0}
_cache_lock = threading.Lock()


def sim_cache_stats() -> dict:
    """Cache behavior of :func:`compiled_sim`: ``misses`` counts actual
    simulator builds (one jit compilation each), ``hits`` reuses, and
    ``evictions`` should stay 0 for any sane sweep — the cache is
    partitioned per backend with :data:`SIM_CACHE_MAXSIZE` entries each,
    so a 70-spec grid compiles each spec exactly once (tested)."""
    with _cache_lock:
        return {**_stats,
                "size": sum(len(c) for c in _caches.values()),
                "partitions": {b: len(c) for b, c in _caches.items()}}


def sim_cache_clear() -> None:
    with _cache_lock:
        _caches.clear()
        _stats.update(hits=0, misses=0, evictions=0)


def _depth_normalized(spec: NocSpec, max_depth: int | None):
    """(key spec, static max depth): the compiled simulator is depth-
    agnostic up to the static max, so the cache key replaces every
    channel depth with that max — specs differing only in FIFO depth
    share one compilation."""
    depths = tuple(ch.depth for ch in spec.channels)
    d_max = max(depths) if max_depth is None else int(max_depth)
    if d_max < max(depths):
        raise ValueError(
            f"max_depth={max_depth} below spec channel depths {depths}")
    key_spec = spec.with_(channels=tuple(
        replace(ch, depth=d_max) for ch in spec.channels))
    return key_spec, d_max


def compiled_sim(spec: NocSpec, T: int, backend: str = "jnp", *,
                 max_depth: int | None = None):
    """One jitted simulator per (depth-normalized spec, horizon,
    backend) triple, from a stats-instrumented per-backend cache.

    Returns ``fn(times, dests, service_lat, max_out, burst_beats,
    depths)`` where ``times``/``dests`` are (n_cls, R, T) int32
    schedules and the scalar knobs — including the per-channel FIFO
    ``depths`` vector — are traced, so the whole function is vmappable
    over a leading batch axis for rate/seed/latency/depth sweeps in a
    single jit.

    ``max_depth`` pads the FIFO state to a larger static bound than the
    spec declares, letting one compilation serve every depth up to that
    bound (the padded-depth sweep mode); results are flit-for-flit
    identical to a natively-sized build.  ``backend`` selects who runs
    the fabric hot loop (see :mod:`repro.noc.backends`); every backend
    must produce identical results behind this one surface.

    Off-CPU the big ``times``/``dests`` operands are DONATED (the scan
    carry workspace aliases them): pass numpy arrays (always safe — a
    fresh device buffer is created per call, which is what every
    ``repro.noc`` caller does) or fresh device arrays; reusing a jnp
    array across calls on GPU/TPU raises "Array has been deleted".
    """
    key_spec, d_max = _depth_normalized(spec, max_depth)
    key = (key_spec, T)
    with _cache_lock:
        part = _caches.setdefault(backend, OrderedDict())
        if key in part:
            part.move_to_end(key)
            _stats["hits"] += 1
            return part[key]
        _stats["misses"] += 1
    fn = _build_sim(key_spec, T, backend, d_max)
    with _cache_lock:
        part = _caches.setdefault(backend, OrderedDict())
        part[key] = fn
        part.move_to_end(key)
        while len(part) > SIM_CACHE_MAXSIZE:
            part.popitem(last=False)
            _stats["evictions"] += 1
    return fn


def _build_sim(spec: NocSpec, T: int, backend: str, d_max: int):
    plan = build_channel_plan(spec)
    network = get_backend(backend)(spec.topology)
    step = make_step(spec, plan, T, network.step)
    n_ch, R = plan.n_ch, spec.n_routers

    # donating the big schedule operands lets XLA alias them into the
    # scan carry's workspace; CPU can't donate (it would only warn)
    donate = () if jax.default_backend() == "cpu" else (0, 1)

    @functools.partial(jax.jit, donate_argnums=donate)
    def run(times, dests, service_lat, max_out, burst_beats, depths):
        state = SimState(network.init(n_ch, d_max),
                         init_ni(R, plan, spec.resp_q_cap), jnp.int32(0),
                         jnp.zeros((n_ch,), jnp.int32))
        dyn = {"times": jnp.moveaxis(times, 0, 1),     # (R, n_cls, T)
               "dests": jnp.moveaxis(dests, 0, 1),
               "service_lat": service_lat, "max_out": max_out,
               "burst_beats": burst_beats,
               "depths": jnp.asarray(depths, jnp.int32)}
        final, _ = jax.lax.scan(functools.partial(step, dyn), state, None,
                                length=spec.cycles)
        ni = final.ni
        return {
            "done": ni.done, "lat_sum": ni.lat_sum, "lat_max": ni.lat_max,
            "beats_rx": ni.beats_rx, "first_t": ni.first_t,
            "last_t": ni.last_t, "link_moves": final.moves,
        }

    return run
