"""Spec-time static verification of NoC configurations.

PR 5 discovered the VC-less torus deadlock only by watching a
simulation wedge (``max_stall_cycles`` ~ horizon, ``drained=False``);
PR 6 fixed it dynamically with escape-VC datelines.  This module turns
that from "simulate and hope it drains" into "reject bad specs at
construction time": a pure-numpy static-analysis pass over the
artifacts the simulator already compiles — the
:class:`~repro.noc.routing.RoutingPolicy`'s VC-expanded
:class:`~repro.noc.routing.RouteTables`, the topology nbr/opp tables,
and the :class:`~repro.noc.spec.NocSpec` flow map — with three
verifier families:

**Routing deadlock** (``family="routing"``).  The channel-dependency
graph (Dally & Seitz): one node per *(link, VC)* — a virtual-port
input buffer — and one edge per consecutive channel pair along any
(src, dest, plane) route walk.  Route tables are *functional* (exactly
one (port, VC) per (router, virtual destination)), so the dependency
set is enumerated exactly, not sampled, and Dally's condition is both
necessary and sufficient: a cycle among used dependencies is a real
cyclic wait some saturating wormhole workload can close.  The
escape-VC discipline is visible to this analysis precisely because VC
selection is baked into the tables — the dateline policy's wrap links
deliver into the escape VC, which removes the ring cycle from the CDG
itself (a link-level graph that ignored VCs would wrongly flag
``xy(n_vcs=2)`` on the torus).  When a cycle IS found, the analyzer
still checks Duato's escape condition before calling it fatal: a cycle
is non-fatal only if some flow on it has an alternative next channel
outside the cycle's strongly-connected component; with functional
tables there are none, so the check documents *why* the cycle cannot
be escaped and suggests the policy that removes it (e.g.
``RoutingPolicy.xy(n_vcs=2)``).

**Protocol / message deadlock** (``family="protocol"``).  AXI imposes
a message-dependency order (R answers AR, B answers the last W beat);
a class_map that parks a response flow behind its own request flow on
a shared channel can deadlock a hardware NI with finite response
buffering.  This engine's NI sinks deliveries unconditionally and
round-robins mixed channels, so the analyzer *proves* that structure
from the compiled :class:`~repro.noc.engine.FlowPlan` (every response
ring drains via dedicated streaming or a round-robin slot — never
behind a static request priority) and WARNs where the mapping would
need VC separation on real hardware (shared request/response channel
with a single VC — the configuration FlooNoC's decoupled-channel
design exists to avoid).  The credit lint checks ``resp_q_cap``
conservation against the declared ``max_outstanding`` budgets: FAIL
when a single (class, direction) stream can overflow a response ring,
WARN when one source running every class at full tilt can.

**Route-table lint** (``family="lint"``).  The scattered structural
asserts of :func:`repro.noc.topology.validate_tables` promoted into
named, individually-reportable checks (sentinel headroom, local-port
structure, duplex links, route structure, termination), plus
reachability of every (src, dest, plane) triple, per-plane minimality
against BFS distances (detour planes report their stretch instead),
base-hop-table consistency, and dateline-bit monotonicity along wrap
rings (the VC of a route never steps back down within one
dimension ring — the walk-level statement of the escape-VC proof).

Everything lands in a frozen :class:`AnalysisReport` (verdict per
check, offending coordinates such as the CDG cycle's ``((u, v), vc)``
links, suggested fix).  Threading through the stack:

* ``NocSpec`` validation runs the cheap protocol checks at
  construction (FAILs raise :class:`AnalysisError` immediately),
* ``simulate(..., verify="full"|"fast"|"off")`` gates the expensive
  CDG pass — lru-cached per (topology, routing), so one rejection or
  proof serves every spec sharing the fabric,
* ``SimResult.summary()`` attaches a one-line analyzer verdict to any
  undrained run (wedges are self-diagnosing),
* ``python -m repro.noc.analyze`` prints reports for any
  preset/policy combination, and ``--all-presets`` is the CI gate: it
  asserts the PR-5 VC-less torus wedge is flagged with a concrete
  cycle while every committed preset/policy passes.

The analyzer proves *deadlock* freedom, not starvation freedom: the
drain rule's strict escape-VC priority can delay (never indefinitely
block) low-VC traffic, and finite schedules always retire.
"""
from __future__ import annotations

import argparse
import functools
import sys
from dataclasses import dataclass, field

import numpy as np

from .faults import FaultModel
from .routing import RoutingPolicy, RouteTables
from .spec import NocSpec
from .topology import Mesh, Topology, Torus, hop_table, run_table_checks

__all__ = ["CheckResult", "AnalysisReport", "AnalysisError", "analyze",
           "analyze_routing", "check_protocol", "verify_spec", "main"]

PASS, WARN, FAIL = "PASS", "WARN", "FAIL"
_RANK = {PASS: 0, WARN: 1, FAIL: 2}


@dataclass(frozen=True)
class CheckResult:
    """One named verifier outcome.

    ``coords`` carries the offending coordinates in the check's own
    vocabulary — for ``cdg_acyclic`` the cycle as ``((u, v), vc)``
    link/VC pairs, for table lint the first offending (router, port) or
    (src, dest, plane) triple, for credit lint the (channel, class,
    flow) feeder.  ``suggestion`` is a concrete fix when one exists
    (e.g. ``RoutingPolicy.xy(n_vcs=2)``)."""
    name: str
    family: str                   # "routing" | "protocol" | "lint"
    verdict: str                  # PASS | WARN | FAIL
    detail: str
    coords: tuple = ()
    suggestion: str = ""


@dataclass(frozen=True)
class AnalysisReport:
    """Machine-readable result of one spec analysis."""
    subject: str
    checks: tuple[CheckResult, ...]
    level: str = "full"

    @property
    def verdict(self) -> str:
        worst = PASS
        for c in self.checks:
            if _RANK[c.verdict] > _RANK[worst]:
                worst = c.verdict
        return worst

    @property
    def ok(self) -> bool:
        """No FAIL — WARNs are advisory, not rejections."""
        return self.verdict != FAIL

    @property
    def failures(self) -> tuple[CheckResult, ...]:
        return tuple(c for c in self.checks if c.verdict == FAIL)

    def __getitem__(self, name: str) -> CheckResult:
        for c in self.checks:
            if c.name == name:
                return c
        raise KeyError(name)

    def summary_line(self) -> str:
        """One line: the verdict, and the worst check when not PASS."""
        if self.verdict == PASS:
            return f"PASS ({len(self.checks)} checks) — {self.subject}"
        worst = next(c for c in self.checks if c.verdict == self.verdict)
        fix = f"; fix: {worst.suggestion}" if worst.suggestion else ""
        return (f"{self.verdict} {worst.family}/{worst.name} — "
                f"{worst.detail}{fix}")

    def render(self) -> str:
        lines = [f"spec: {self.subject}"]
        for c in self.checks:
            lines.append(f"  [{c.verdict:<4}] {c.family}/{c.name:<24} "
                         f"{c.detail}")
            if c.coords:
                lines.append(f"          at: {c.coords}")
            if c.suggestion:
                lines.append(f"          fix: {c.suggestion}")
        lines.append(f"verdict: {self.verdict}")
        return "\n".join(lines)


class AnalysisError(ValueError):
    """A spec failed static verification; ``.report`` has the details."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        fails = "; ".join(f"{c.family}/{c.name}: {c.detail}"
                          + (f" (fix: {c.suggestion})" if c.suggestion
                             else "")
                          for c in report.failures)
        super().__init__(
            f"static verification rejected {report.subject}: {fails}")


# --------------------------------------------------------------------- #
# family 1: routing deadlock — the channel-dependency graph
# --------------------------------------------------------------------- #
def _chan_coords(rt: RouteTables, cid: int) -> tuple[tuple[int, int], int]:
    """Channel id -> ((src_router, dst_router), vc)."""
    V = rt.n_vcs
    n_phys = rt.n_base_ports - 1
    u, rem = divmod(cid, n_phys * V)
    p, vc = divmod(rem, V)
    return (u, int(rt.nbr[u, p * V])), vc


def _cdg_edges(rt: RouteTables) -> tuple[np.ndarray, np.ndarray]:
    """Exact channel-dependency edge set over (link, VC) channels.

    Channel id of virtual port ``q`` at router ``u`` is
    ``(u * n_phys + q // V) * V + q % V``.  For every (router ``u``,
    virtual destination ``j``) with ``u != dest(j)`` the functional
    route table names ONE outgoing channel; if the next router is not
    the destination either, the pair of consecutive channels is a
    dependency.  Returns ``(edges (E, 2) channel-id pairs, labels (E,)
    inducing virtual destination)`` — deduplicated, one representative
    label per edge.
    """
    R, n_vd = rt.route.shape
    V = rt.n_vcs
    n_phys = rt.n_base_ports - 1
    dest = np.arange(n_vd) % R
    u = np.repeat(np.arange(R), n_vd).reshape(R, n_vd)
    j = np.tile(np.arange(n_vd), (R, 1))
    m0 = u != dest[None, :]
    q1 = rt.route
    r1 = rt.nbr[u, np.where(m0, q1, 0)]             # next router
    m1 = m0 & (r1 != dest[None, :])
    q2 = rt.route[np.where(m1, r1, 0), j]
    c1 = (u * n_phys + q1 // V) * V + q1 % V
    c2 = (r1 * n_phys + q2 // V) * V + q2 % V
    n_chan = R * n_phys * V
    # dedup on the scalar-encoded pair (a 1D int64 sort beats
    # np.unique(axis=0)'s structured row sort several-fold)
    enc = c1[m1].astype(np.int64) * n_chan + c2[m1]
    enc, idx = np.unique(enc, return_index=True)
    edges = np.stack([enc // n_chan, enc % n_chan], axis=1)
    return edges, j[m1][idx]


def _sccs(n: int, adj: list[list[int]]) -> list[list[int]]:
    """Iterative Tarjan strongly-connected components."""
    index = [-1] * n
    low = [0] * n
    onstk = [False] * n
    stack: list[int] = []
    sccs: list[list[int]] = []
    counter = 0
    for root in range(n):
        if index[root] >= 0:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = counter
                counter += 1
                stack.append(v)
                onstk[v] = True
            descended = False
            for i in range(pi, len(adj[v])):
                w = adj[v][i]
                if index[w] < 0:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    descended = True
                    break
                if onstk[w]:
                    low[v] = min(low[v], index[w])
            if descended:
                continue
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    onstk[w] = False
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(scc)
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[v])
    return sccs


def _extract_cycle(scc: list[int], adj_map: dict[int, list[int]]) -> list[int]:
    """A concrete cycle inside one nontrivial SCC (node ids, in order)."""
    inside = set(scc)
    path, seen = [], {}
    v = scc[0]
    while v not in seen:
        seen[v] = len(path)
        path.append(v)
        v = next(w for w in adj_map[v] if w in inside)
    return path[seen[v]:]


def _cdg_check(topology: Topology, routing: RoutingPolicy,
               rt: RouteTables) -> CheckResult:
    edges, labels = _cdg_edges(rt)
    n_chan = rt.nbr.shape[0] * (rt.n_base_ports - 1) * rt.n_vcs
    adj: list[list[int]] = [[] for _ in range(n_chan)]
    adj_map: dict[int, list[int]] = {}
    for (a, b) in edges:
        adj[a].append(int(b))
        adj_map.setdefault(int(a), []).append(int(b))
    bad = [s for s in _sccs(n_chan, adj) if len(s) > 1]
    bad += [[a] for a, b in edges if a == b]          # self-dependency
    if not bad:
        return CheckResult(
            "cdg_acyclic", "routing", PASS,
            f"channel-dependency graph acyclic over {len(edges)} "
            f"dependencies on {n_chan} (link, VC) channels — "
            "deadlock-free by Dally's condition (routes are "
            "deterministic, so the condition is exact)")
    cycle = _extract_cycle(min(bad, key=len), adj_map)
    coords = tuple(_chan_coords(rt, c) for c in cycle)
    label_of = {(int(a), int(b)): int(lab)
                for (a, b), lab in zip(edges, labels)}
    R = rt.nbr.shape[0]
    sample = label_of.get((cycle[0], cycle[1 % len(cycle)]), 0)
    req = routing.required_vcs(topology)
    if routing.n_vcs < req:
        args = f"n_vcs={req}"
        if routing.algorithm == "valiant":
            args += f", n_valiant={routing.n_valiant}"
        fix = f"RoutingPolicy.{routing.algorithm}({args})"
    else:
        fix = ("restructure the route tables; the declared VC budget "
               "does not break this cycle")
    return CheckResult(
        "cdg_acyclic", "routing", FAIL,
        f"channel-dependency cycle over {len(cycle)} (link, VC) "
        f"channels (e.g. induced by routes to router {sample % R}, "
        f"plane {sample // R}); routes are functional — one (port, VC) "
        "per (router, dest, plane) — so no escape subnetwork can "
        "cover it (Duato) and a saturating wormhole workload can "
        "close the wait cycle",
        coords=coords, suggestion=fix)


# --------------------------------------------------------------------- #
# family 3: route-table lint (named checks over the compiled tables)
# --------------------------------------------------------------------- #
_LINT_OK = {
    "no_port_sentinel": "port space clear of the NO-ROUTE sentinel",
    "local_port": "local port is last and carries no link",
    "duplex_links": "every wired link is duplex",
    "route_structure": "routes use wired links; local port only at dest",
    "route_termination": "every route walk terminates",
}


def _bfs_dists(nbr: np.ndarray) -> np.ndarray:
    """(R, R) shortest-path hop counts over the physical link graph.

    All-sources frontier BFS: one (R, R) boolean frontier matrix is
    expanded one level per pass via a padded-gather over the (R, P-1)
    neighbor table — O(diameter) numpy passes, no per-source python
    walk.  Links are duplex (validated upstream), so gathering each
    node's out-neighbors reaches exactly its in-frontier."""
    R, P = nbr.shape
    t = nbr[:, :P - 1]
    # missing ports self-loop: a self-gather lands inside ``seen`` and
    # is masked right back out, so no padding column is needed
    adj = np.where(t >= 0, t, np.arange(R)[:, None])
    # source axis packed 8 sources/byte: each level is a few hundred
    # kB of gathers + bitwise-ORs instead of multi-MB bool temps
    frontier = np.packbits(np.eye(R, dtype=bool), axis=0)
    seen = frontier.copy()
    dist = np.full((R, R), -1, np.int64)
    np.fill_diagonal(dist, 0)
    d = 0
    while frontier.any():
        d += 1
        nxt = np.bitwise_or.reduce(frontier[:, adj], axis=2) & ~seen
        seen |= nxt
        dist[np.unpackbits(nxt, axis=0, count=R).astype(bool)] = d
        frontier = nxt
    return dist


def _dateline_check(topology: Topology, rt: RouteTables,
                    detour_vc: int | None = None) -> CheckResult:
    """VC-of-hop monotonicity within each dimension run of every route:
    the escape/dateline (or valiant phase) bit may only step up — a
    downward step would re-enter the cycle-prone low VC after the
    escape transition, voiding the deadlock-freedom argument.

    The condition is local: route tables are functional in (router,
    virtual destination), so the hop pair around any router on any walk
    is fully determined by (that router, dest) — checking every
    consecutive (hop at ``s``, hop at ``nbr(s)``) pair for every
    (s, dest) covers every suffix of every walk in one vectorized pass
    (the old per-hop walk re-derived exactly these pairs).

    ``detour_vc`` (fault cut-outs) exempts hops on the dedicated detour
    VC: the detour tree is outside the dateline discipline, and its own
    acyclicity is covered by the CDG proof over the patched tables.
    """
    if rt.n_vcs == 1:
        return CheckResult(
            "dateline_monotonicity", "lint", PASS,
            "n/a (single VC — no escape transition to order)")
    R = rt.nbr.shape[0]
    V, K = rt.n_vcs, rt.n_planes
    rr = np.arange(R)[:, None]
    dd = np.arange(R)[None, :]
    off = rr != dd
    for k in range(K):
        route_k = rt.route[:, k * R:(k + 1) * R]
        q1 = route_k                                  # hop taken at s
        r2 = rt.nbr[rr, np.where(off, q1, 0)]         # next router
        live = off & (r2 != dd)
        q2 = route_k[np.where(live, r2, 0), dd]       # hop taken there
        vc1, vc2 = q1 % V, q2 % V
        dim1 = (q1 // V) % 4 % 2 == 1                 # E/W: x, N/S: y
        dim2 = (q2 // V) % 4 % 2 == 1
        bad = live & (dim1 == dim2) & (vc2 < vc1)
        if detour_vc is not None:
            bad &= (vc1 != detour_vc) & (vc2 != detour_vc)
        if bad.any():
            s, d = map(int, np.argwhere(bad)[0])
            return CheckResult(
                "dateline_monotonicity", "lint", FAIL,
                f"plane {k}: route {s} -> {d} steps its VC back "
                f"down (VC {int(vc1[s, d])} -> {int(vc2[s, d])} "
                f"at router {int(r2[s, d])}) within one dimension "
                "ring — the escape transition must be one-way",
                coords=(k, s, d, int(r2[s, d])))
    note = (" (fault-detour VC %d exempt — proved by the CDG pass)"
            % detour_vc if detour_vc is not None else "")
    return CheckResult(
        "dateline_monotonicity", "lint", PASS,
        "VC-of-hop monotone within every dimension run across "
        f"{K} plane(s) (escape transitions are one-way){note}")


def _lint_checks(topology: Topology, routing: RoutingPolicy,
                 rt: RouteTables, faults=None) -> list[CheckResult]:
    """``faults`` (a FaultModel with static cuts, or None) marks ``rt``
    as fault-regenerated cut-out tables: minimality is no longer
    claimed (detours stretch), the base-hop-table comparison is
    meaningless, and the dedicated detour VC is exempt from the
    dateline discipline (covered by the CDG pass instead)."""
    out = []
    results, hops = run_table_checks(rt.nbr, rt.opp, rt.route)
    for name, err, coords in results:
        out.append(CheckResult(
            name, "lint", FAIL if err else PASS,
            err or _LINT_OK[name], coords=coords))
    if hops is None:                  # structural failure: stop linting
        return out
    R = rt.nbr.shape[0]
    K = rt.n_planes
    out.append(CheckResult(
        "route_reachability", "lint", PASS,
        f"all {R}x{R} (src, dest) pairs deliver on every one of "
        f"{K} plane(s)"))

    dist = _bfs_dists(np.asarray(topology.tables()[0]))
    off = ~np.eye(R, dtype=bool)
    minimal_claim = (routing.algorithm in ("xy", "o1turn")
                     and not getattr(topology, "express", ())
                     and faults is None)
    worst = 0.0
    for k in range(K):
        hk = hops[:, k * R:(k + 1) * R]
        if minimal_claim and np.any(hk[off] > dist[off]):
            s, d = map(int, np.argwhere((hk > dist) & off)[0])
            out.append(CheckResult(
                "route_minimality", "lint", FAIL,
                f"plane {k}: route {s} -> {d} takes {int(hk[s, d])} "
                f"hops, shortest path is {int(dist[s, d])}",
                coords=(k, s, d)))
            break
        worst = max(worst, float(np.max(hk[off] / dist[off])))
    else:
        why = ("non-minimal around the cut" if faults is not None
               else "non-minimal by design")
        note = ("minimal (hop counts equal BFS shortest paths)"
                if minimal_claim else
                f"{why}, worst stretch {worst:.2f}x "
                "over BFS shortest paths")
        out.append(CheckResult(
            "route_minimality", "lint", PASS,
            f"{K} plane(s) {note}"))

    if faults is not None:
        out.append(CheckResult(
            "hop_consistency", "lint", PASS,
            "n/a (fault detours diverge from the base hop table)"))
    elif routing.algorithm in ("xy", "o1turn"):
        base = hop_table(topology)
        h0 = hops[:, :R]
        if np.array_equal(h0, base):
            out.append(CheckResult(
                "hop_consistency", "lint", PASS,
                "plane 0 walk matches the topology's hop table"))
        else:
            s, d = map(int, np.argwhere(h0 != base)[0])
            out.append(CheckResult(
                "hop_consistency", "lint", FAIL,
                "plane 0 walk disagrees with hop_table at "
                f"{s} -> {d}: {int(h0[s, d])} != {int(base[s, d])}",
                coords=(s, d)))
    else:
        out.append(CheckResult(
            "hop_consistency", "lint", PASS,
            "n/a (detour planes do not follow the base hop table)"))

    detour_vc = rt.n_vcs - 1 if faults is not None else None
    out.append(_dateline_check(topology, rt, detour_vc=detour_vc))
    return out


@functools.lru_cache(maxsize=128)
def analyze_routing(topology: Topology, routing: RoutingPolicy,
                    faults=None) -> tuple[CheckResult, ...]:
    """Fabric-level verification (CDG + route-table lint) for one
    (topology, routing) pair — the expensive half, cached so one proof
    or rejection serves every spec sharing the fabric.

    ``faults`` (a :class:`~repro.noc.faults.FaultModel`) verifies the
    fabric *as cut*: static dead links/nodes (with ``reroute=True``)
    swap in the regenerated cut-out tables, a ``fault_reroute`` check
    reports the regeneration (FAIL with the disconnecting coordinates
    when the cut is unroutable — no other check can run without
    tables), and the full lint + CDG proof runs over the patched
    tables, so every fault detour is *proved* deadlock-free, never
    assumed.  Dynamic-only fault models verify identically to the
    healthy fabric (masked links stall flits, they never re-route)."""
    from .faults import UnroutableCutError, cut_tables
    cut = (faults is not None and faults.has_static and faults.reroute)
    if cut:
        try:
            rt = cut_tables(topology, routing, faults)
        except UnroutableCutError as e:
            return (CheckResult(
                "fault_reroute", "lint", FAIL, str(e), coords=e.coords,
                suggestion="drop the isolating dead links/nodes from "
                           "the FaultModel, or set reroute=False and "
                           "accept the wedge"),)
        checks = _lint_checks(topology, routing, rt, faults=faults)
        nl = len(set(map(tuple, map(sorted, faults.dead_links))))
        checks.insert(0, CheckResult(
            "fault_reroute", "lint", PASS,
            f"cut-out tables regenerated around {nl} dead link(s) and "
            f"{len(faults.dead_nodes)} dead node(s); detours ride "
            f"dedicated VC {rt.n_vcs - 1} along a spanning tree of "
            "the surviving fabric"))
    else:
        rt = routing.compile(topology)
        checks = _lint_checks(topology, routing, rt)
    structural_fail = any(c.verdict == FAIL and c.family == "lint"
                          and c.name in _LINT_OK for c in checks)
    if not structural_fail:
        checks.append(_cdg_check(topology, routing, rt))
    return tuple(checks)


# --------------------------------------------------------------------- #
# family 2: protocol / message-dependency + credit lint (cheap)
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=256)
def check_protocol(spec: NocSpec) -> tuple[CheckResult, ...]:
    """Message-order + ROB/credit checks from the compiled FlowPlan.
    Cheap (pure-python plan inspection) — NocSpec validation runs this
    at construction and raises on FAIL."""
    from .engine import build_flow_plan
    plan = build_flow_plan(spec)
    out = []

    # message order: every response ring must drain via dedicated
    # streaming or a round-robin slot of its channel's injection
    # policy — never parked behind a static request priority (B waits
    # on W, R on AR; a starvable response flow would complete the
    # AR -> R -> ROB-credit -> AR dependency cycle on a hardware NI).
    starved = []
    shared = []
    for q in range(plan.n_rq):
        c = plan.chan_of_q[q]
        has_req = bool(plan.singles_on[c] or plan.wqs_on[c])
        dedicated = not has_req and len(plan.rqs_on[c]) == 1
        if not (dedicated or q in plan.rqs_on[c]):
            starved.append((spec.channels[c].name, q))
        # address flows (AR/AW) on a response channel close the
        # AR -> R (AW -> B) request/response loop FlooNoC's decoupled
        # networks break; W data sharing an R channel is the paper's
        # own wide-channel design (W rings always sink — see the
        # credit check) and stays PASS
        if plan.singles_on[c]:
            shared.append(spec.channels[c].name)
    if starved:
        out.append(CheckResult(
            "message_order", "protocol", FAIL,
            "response ring(s) not drainable on their channel "
            f"(starvable behind request flows): {starved}",
            coords=tuple(starved),
            suggestion="map the class's R/B flows to a dedicated "
                       "response channel"))
    elif shared and spec.routing.n_vcs < 2:
        cls_notes = []
        for cls in spec.classes:
            rsp = {f: spec.flow_map[f"{cls.name}.{f}"] for f in ("r", "b")}
            req = {f: spec.flow_map[f"{cls.name}.{f}"]
                   for f in ("ar", "aw")}
            both = sorted(set(rsp.values()) & set(req.values()))
            if both:
                cls_notes.append((cls.name, tuple(both)))
        out.append(CheckResult(
            "message_order", "protocol", WARN,
            "response flows share channel(s) "
            f"{sorted(set(shared))} with AR/AW request flows at "
            "n_vcs=1, closing the AR -> R (AW -> B) loop — safe for "
            "this engine's always-sinking NI (mixed channels "
            "round-robin), but a hardware NI with finite response "
            "buffering needs VC separation or FlooNoC's decoupled "
            "req/rsp channels", coords=tuple(cls_notes),
            suggestion="give responses their own channel (narrow_wide "
                       "mapping) or a RoutingPolicy with n_vcs >= 2"))
    else:
        out.append(CheckResult(
            "message_order", "protocol", PASS,
            "every response ring drains via dedicated streaming or a "
            "round-robin slot, and no response channel carries AR/AW "
            "address flows without VC separation — the AXI "
            "message-dependency order (R after AR, B after W) cannot "
            "starve (W data sharing an R channel is the paper's wide-"
            "channel design; W rings always sink)"))

    # credit conservation: resp_q_cap vs the declared ROB budgets.
    feeders: dict[int, set[tuple[int, str]]] = {}
    for lane in range(plan.n_cls):
        ci = plan.cls_of_lane[lane]
        feeders.setdefault(plan.rq_of_r[lane], set()).add((ci, "r"))
        feeders.setdefault(plan.rq_of_b[lane], set()).add((ci, "b"))
    cap = spec.resp_q_cap
    worst_pair, worst_src = None, None
    for q, fs in feeders.items():
        pair = max(spec.classes[ci].max_outstanding for ci, _ in fs)
        src = sum(spec.classes[ci].max_outstanding for ci, _ in fs)
        if worst_pair is None or pair > worst_pair[0]:
            big = max(fs, key=lambda f: spec.classes[f[0]].max_outstanding)
            worst_pair = (pair, q, big)
        if worst_src is None or src > worst_src[0]:
            worst_src = (src, q)
    n_src = spec.n_routers - 1
    if worst_pair is not None and cap < worst_pair[0]:
        pair, q, (ci, fl) = worst_pair
        ch = spec.channels[plan.chan_of_q[q]].name
        out.append(CheckResult(
            "credit_conservation", "protocol", FAIL,
            f"resp_q_cap={cap} < max_outstanding={pair} of class "
            f"{spec.classes[ci].name!r} ({fl} flow) — a single "
            f"source/dest pair can overflow the {ch!r} response ring "
            "(the engine does not check overflow at runtime)",
            coords=(ch, spec.classes[ci].name, fl),
            suggestion=f"resp_q_cap>={pair} (worst-case all-to-one "
                       f"needs {n_src * worst_src[0]})"))
    elif worst_src is not None and cap < worst_src[0]:
        src, q = worst_src
        ch = spec.channels[plan.chan_of_q[q]].name
        out.append(CheckResult(
            "credit_conservation", "protocol", WARN,
            f"resp_q_cap={cap} < {src} (every class of one source at "
            f"full max_outstanding into the {ch!r} ring); worst-case "
            f"all-to-one traffic needs {n_src * src}",
            coords=(ch,),
            suggestion=f"resp_q_cap>={src}"))
    else:
        bound = 0 if worst_src is None else worst_src[0]
        out.append(CheckResult(
            "credit_conservation", "protocol", PASS,
            f"resp_q_cap={cap} covers any single source's responses "
            f"(<= {bound}); worst-case all-to-one needs "
            f"{n_src * bound}; W rings are sized from the declared "
            "max_outstanding by construction; per-stream lanes split "
            "their class budget (validated n_streams <= "
            "max_outstanding)"))
    return tuple(out)


# --------------------------------------------------------------------- #
# composition + gating
# --------------------------------------------------------------------- #
def _subject(spec: NocSpec) -> str:
    t = spec.topology
    kind = type(t).__name__
    ex = f" express={t.express}" if getattr(t, "express", ()) else ""
    r = spec.routing
    extra = f", n_valiant={r.n_valiant}" if r.algorithm == "valiant" else ""
    fx = ""
    if spec.faults is not None:
        f = spec.faults
        bits = []
        if f.dead_links:
            bits.append(f"{len(f.dead_links)} dead link(s)")
        if f.dead_nodes:
            bits.append(f"{len(f.dead_nodes)} dead node(s)")
        if f.link_events or f.n_events:
            bits.append("dynamic events")
        if bits:
            fx = f", faults[{', '.join(bits)}]"
    return (f"{kind} {t.nx}x{t.ny}{ex}, {len(spec.channels)} channel(s), "
            f"routing={r.algorithm}(n_vcs={r.n_vcs}{extra}){fx}")


def analyze(spec: NocSpec, level: str = "full") -> AnalysisReport:
    """Full static-analysis report for one spec.  ``level="fast"``
    runs only the cheap protocol/credit checks (what NocSpec
    construction already enforces); ``"full"`` adds the route-table
    lint and the channel-dependency deadlock proof (lru-cached per
    (topology, routing))."""
    if level not in ("fast", "full"):
        raise ValueError(f"level must be 'fast' or 'full', got {level!r}")
    checks = list(check_protocol(spec))
    if level == "full":
        checks = list(analyze_routing(spec.topology, spec.routing,
                                      spec.faults)) + checks
    return AnalysisReport(subject=_subject(spec), checks=tuple(checks),
                          level=level)


def verify_spec(spec: NocSpec, verify: str = "fast") -> None:
    """The ``simulate(verify=...)`` gate: raise :class:`AnalysisError`
    when the requested level finds a FAIL.  ``"off"`` skips, ``"fast"``
    re-runs the construction-time cheap checks, ``"full"`` adds the
    CDG deadlock proof and rejects wedge-prone specs before a single
    cycle is simulated."""
    if verify == "off":
        return
    if verify not in ("fast", "full"):
        raise ValueError(
            f"verify must be 'off', 'fast' or 'full', got {verify!r}")
    report = analyze(spec, level=verify)
    if not report.ok:
        raise AnalysisError(report)


# --------------------------------------------------------------------- #
# CLI: python -m repro.noc.analyze
# --------------------------------------------------------------------- #
_PRESETS = {"narrow_wide": NocSpec.narrow_wide,
            "wide_only": NocSpec.wide_only,
            "multi_stream": NocSpec.multi_stream}


def _policy(args) -> RoutingPolicy:
    if args.routing == "valiant":
        return RoutingPolicy.valiant(args.n_vcs or 4, args.n_valiant)
    if args.routing == "o1turn":
        return RoutingPolicy.o1turn(args.n_vcs or 2)
    return RoutingPolicy.xy(args.n_vcs or 1)


@dataclass(frozen=True)
class _MatrixRow:
    name: str
    spec: NocSpec
    expect_fail: bool = False
    must_name: str = ""        # check expected to carry the FAIL
    note: str = field(default="")


def _preset_matrix() -> list[_MatrixRow]:
    """The committed preset/policy matrix the CI gate asserts: every
    shipped configuration passes, and the PR-5 VC-less minimal-wrap
    torus (the config that wedged under saturating bursts) is flagged
    with a concrete (link, VC) cycle."""
    mesh, torus = Mesh(4, 4), Torus(4, 4)
    rows = [
        _MatrixRow("narrow_wide mesh xy(1)", NocSpec.narrow_wide(4, 4)),
        _MatrixRow("wide_only mesh xy(1)", NocSpec.wide_only(4, 4)),
        _MatrixRow("multi_stream mesh xy(1)", NocSpec.multi_stream(4, 4)),
        _MatrixRow("narrow_wide express(2) xy(1)",
                   NocSpec.narrow_wide(4, 4,
                                       topology=Mesh(4, 4, express=(2,)))),
        _MatrixRow(
            "wide_only torus xy(1)  [PR-5 wedge]",
            NocSpec.wide_only(4, 4, topology=torus, burstlen=32,
                              max_wide_outstanding=16),
            expect_fail=True, must_name="cdg_acyclic",
            note="the saturating-burst wedge PR 5 caught in simulation"),
        _MatrixRow("narrow_wide torus xy(1)",
                   NocSpec.narrow_wide(4, 4, topology=torus),
                   expect_fail=True, must_name="cdg_acyclic"),
        _MatrixRow("narrow_wide torus xy(2)",
                   NocSpec.narrow_wide(4, 4, topology=torus,
                                       routing=RoutingPolicy.xy(2))),
        _MatrixRow("wide_only torus xy(2)",
                   NocSpec.wide_only(4, 4, topology=torus, burstlen=32,
                                     max_wide_outstanding=16,
                                     routing=RoutingPolicy.xy(2))),
        _MatrixRow("narrow_wide mesh o1turn(2)",
                   NocSpec.narrow_wide(4, 4,
                                       routing=RoutingPolicy.o1turn(2))),
        _MatrixRow("narrow_wide torus o1turn(4)",
                   NocSpec.narrow_wide(4, 4, topology=torus,
                                       routing=RoutingPolicy.o1turn(4))),
        _MatrixRow("narrow_wide mesh valiant(4)",
                   NocSpec.narrow_wide(4, 4,
                                       routing=RoutingPolicy.valiant(4))),
        _MatrixRow("narrow_wide mesh 7x7 xy(1)",
                   NocSpec.narrow_wide(7, 7)),
        # fault rows: every cut-out table set must re-pass the full
        # lint + CDG proof; an unroutable cut must FAIL with the
        # disconnecting coordinates
        _MatrixRow("narrow_wide mesh xy(2) dead-link (5,6)",
                   NocSpec.narrow_wide(
                       4, 4, routing=RoutingPolicy.xy(2),
                       faults=FaultModel(dead_links=((5, 6),))),
                   note="cut-out reroute re-proved deadlock-free"),
        _MatrixRow("narrow_wide torus xy(3) dead-node 5",
                   NocSpec.narrow_wide(
                       4, 4, topology=torus, routing=RoutingPolicy.xy(3),
                       faults=FaultModel(dead_nodes=(5,))),
                   note="node cut-out reroute re-proved deadlock-free"),
        _MatrixRow("narrow_wide mesh xy(2) corner cut  [unroutable]",
                   NocSpec.narrow_wide(
                       4, 4, routing=RoutingPolicy.xy(2),
                       faults=FaultModel(dead_links=((0, 1), (0, 4)))),
                   expect_fail=True, must_name="fault_reroute",
                   note="cut isolates router 0 — flagged with coords"),
    ]
    return rows


def _run_matrix(verbose: bool) -> int:
    rows = _preset_matrix()
    bad = 0
    for row in rows:
        rep = analyze(row.spec)
        flagged = not rep.ok
        as_expected = flagged == row.expect_fail
        if row.expect_fail and flagged and row.must_name:
            as_expected = rep[row.must_name].verdict == FAIL
            as_expected = as_expected and bool(rep[row.must_name].coords)
        status = "ok" if as_expected else "UNEXPECTED"
        want = "FAIL" if row.expect_fail else "PASS/WARN"
        print(f"{row.name:<40} {rep.verdict:<5} (expected {want:<9}) "
              f"{status}")
        if verbose or not as_expected:
            print(rep.render())
        if not as_expected:
            bad += 1
    if bad:
        print(f"\n{bad} matrix expectation(s) violated")
        return 1
    print(f"\nall {len(rows)} matrix expectations hold "
          "(wedge flagged with a concrete cycle; every committed "
          "preset/policy passes)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.noc.analyze",
        description="Static NoC spec verifier: channel-dependency "
                    "deadlock proofs, protocol/credit lint, and "
                    "route-table lint — no simulation needed.")
    ap.add_argument("--all-presets", action="store_true",
                    help="run the committed preset/policy matrix and "
                         "assert its expectations (the CI gate)")
    ap.add_argument("--preset", choices=sorted(_PRESETS),
                    default="narrow_wide")
    ap.add_argument("--topology", choices=("mesh", "torus"),
                    default="mesh")
    ap.add_argument("--express", type=int, nargs="*", default=(),
                    help="express link strides (mesh only)")
    ap.add_argument("--nx", type=int, default=4)
    ap.add_argument("--ny", type=int, default=4)
    ap.add_argument("--routing", choices=("xy", "o1turn", "valiant"),
                    default="xy")
    ap.add_argument("--n-vcs", type=int, default=0,
                    help="virtual channels (0: the algorithm's default)")
    ap.add_argument("--n-valiant", type=int, default=2)
    ap.add_argument("--resp-q-cap", type=int, default=256)
    ap.add_argument("--dead-link", type=int, nargs=2, action="append",
                    metavar=("A", "B"), default=[],
                    help="kill the duplex link between routers A and B "
                         "(repeatable); routes are regenerated around "
                         "the cut and re-proved deadlock-free")
    ap.add_argument("--dead-node", type=int, action="append",
                    metavar="N", default=[],
                    help="kill router N and all its links (repeatable)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print full per-check reports in matrix mode")
    args = ap.parse_args(argv)

    if args.all_presets:
        return _run_matrix(args.verbose)

    if args.topology == "torus":
        topo: Topology = Torus(args.nx, args.ny)
    else:
        topo = Mesh(args.nx, args.ny, express=tuple(args.express))
    faults = None
    if args.dead_link or args.dead_node:
        faults = FaultModel(
            dead_links=tuple((a, b) for a, b in args.dead_link),
            dead_nodes=tuple(args.dead_node))
    policy = _policy(args)
    if (faults is not None and not args.n_vcs
            and policy.algorithm == "xy"):
        # cut-out reroute needs the spare detour VC; default to the
        # smallest budget that admits it rather than rejecting
        policy = RoutingPolicy.xy(policy.required_vcs(topo) + 1)
    try:
        spec = _PRESETS[args.preset](
            args.nx, args.ny, topology=topo, resp_q_cap=args.resp_q_cap,
            routing=policy, faults=faults)
    except ValueError as e:                    # construction-time reject
        print(f"rejected at construction: {e}")
        return 1
    report = analyze(spec)
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
