"""Declarative NoC experiment specification.

A :class:`NocSpec` declares *what the network is* — a first-class
:class:`~repro.noc.topology.Topology` (XY mesh, torus, express-link
mesh), a :class:`~repro.noc.routing.RoutingPolicy` (routing algorithm
x virtual-channel count; the default ``RoutingPolicy.xy(n_vcs=1)`` is
the paper's plain VC-less XY configuration, bit-identical to the
pre-VC engine), an arbitrary list of physical channels (each its own
complete network instance of that topology), the traffic classes
riding on them, and a ``class_map`` assigning every AXI4 flow to a
channel.  Each class decomposes into the five AXI
channels (:data:`repro.core.flit.AXI_FLOWS`): reads are
``"<class>.ar"`` -> ``"<class>.r"``, writes are ``"<class>.aw"`` ->
``"<class>.w"`` -> ``"<class>.b"``.  The paper's mapping puts the
single-flit address/ack flows (AW / AR / B) on the narrow channels and
the data bursts (W / R) on the wide one.  Legacy two-flow maps
(``"<class>.req"`` / ``"<class>.rsp"``) are expanded automatically:
``req`` covers AR + AW, ``rsp`` covers R + B, and W rides the class's
R (data) channel.  The paper's two configurations are presets:

* :meth:`NocSpec.narrow_wide` — three physical networks (narrow_req /
  narrow_rsp / wide), paper §III-B Table I,
* :meth:`NocSpec.wide_only` — the Fig. 5 ablation where one network
  carries everything,

but any N-channel topology can be declared, e.g. the journal version's
end-to-end parallel multi-stream wide channels or PATRONoC-style
per-stream links.

Everything here is frozen/hashable: a ``NocSpec`` is the static cache
key for one jitted simulator (see ``engine.py``); the *dynamic* knobs
(service latency, outstanding limits, burst lengths, schedules) are
traced operands so sweeps vmap over them.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Mapping, Sequence

from repro.core.flit import AXI_FLOWS
from .faults import FaultModel
from .routing import RoutingPolicy
from .topology import Mesh, Topology, Torus  # noqa: F401  (re-exported)


@dataclass(frozen=True)
class TrafficClass:
    """One AXI4 traffic class (paper: narrow vs wide).

    ``burst_beats == 1`` marks a latency-critical class whose data
    bursts (R read data, W write data) are single flits; ``burst_beats
    > 1`` marks a bandwidth class whose bursts are atomic wormhole
    trains of that many beats.  ``max_outstanding`` bounds reads and
    writes *separately* (one ROB budget per direction, paper §III-A).

    ``service_lat`` / ``service_jitter`` give the class its own target
    service-latency *distribution*: the target NI answers a request
    after ``service_lat + U[-jitter, +jitter]`` cycles (offsets come
    from a seeded static table so runs are reproducible; both knobs are
    traced operands at simulate() time).  ``service_lat=None`` falls
    back to the spec-wide :attr:`NocSpec.service_lat` scalar, and
    ``service_jitter=0`` reproduces the fixed-latency model exactly.

    ``n_streams`` is the journal version's end-to-end AXI4 parallel
    multi-stream support: the class's transactions are spread over that
    many independent AXI ID streams, each with its own schedule pointer,
    its own slice of the ``max_outstanding`` credits (split as evenly as
    integer division allows, earlier streams get the remainder) and its
    own ROB/reorder slots — so a slow transaction on one stream never
    false-serializes traffic on another.  ``n_streams=1`` (the default)
    is the single-ID behaviour, bit-identical to the pre-stream engine.
    """
    name: str
    burst_beats: int = 1
    max_outstanding: int = 8       # per-direction ROB budget (all streams)
    payload_bits: int = 64         # per-beat payload (accounting only)
    service_lat: int | None = None   # None -> NocSpec.service_lat
    service_jitter: int = 0          # +/- uniform jitter, 0 = deterministic
    n_streams: int = 1               # independent AXI ID streams


@dataclass(frozen=True)
class PhysicalChannel:
    """One physical network instance (a complete router mesh; the
    spec-level :class:`~repro.noc.routing.RoutingPolicy` decides how
    many virtual channels each of its links carries)."""
    name: str
    depth: int = 2                 # input FIFO depth per router port
    width_bits: int = 603          # link width incl. header lines (accounting)


def _resolve_topology(nx: int, ny: int,
                      topology: "Topology | None") -> "Topology":
    """Preset helper: default to the paper's mesh; an explicit override
    must agree with the nx/ny arguments so a sweep can't silently
    simulate the wrong fabric."""
    if topology is None:
        return Mesh(nx, ny)
    if (topology.nx, topology.ny) != (nx, ny):
        raise ValueError(
            f"topology {topology!r} does not match nx={nx}, ny={ny}")
    return topology


@dataclass(frozen=True)
class NocSpec:
    """Static description of one NoC experiment configuration.

    ``topology`` is a first-class value (:class:`Mesh`, :class:`Torus`,
    or ``Mesh(..., express=...)`` for >5-port express-link routers) —
    every physical channel is one complete network instance of it.
    ``routing`` selects the routing algorithm and virtual-channel count
    every channel runs (:class:`~repro.noc.routing.RoutingPolicy`); the
    default single-VC XY policy reproduces the pre-VC engine
    bit-for-bit, while e.g. ``RoutingPolicy.xy(n_vcs=2)`` enables the
    dateline/escape-VC discipline that makes the torus deadlock-free.
    """
    topology: Topology = Mesh(4, 4)
    classes: tuple[TrafficClass, ...] = (
        TrafficClass("narrow", burst_beats=1, max_outstanding=8,
                     payload_bits=64),
        TrafficClass("wide", burst_beats=16, max_outstanding=8,
                     payload_bits=512),
    )
    channels: tuple[PhysicalChannel, ...] = (
        PhysicalChannel("req", depth=2, width_bits=119),
        PhysicalChannel("rsp", depth=2, width_bits=103),
        PhysicalChannel("wide", depth=2, width_bits=603),
    )
    # flow ("<class>.<ar|r|aw|w|b>") -> channel name, stored sorted.
    # Legacy "<class>.req"/"<class>.rsp" entries are expanded (req ->
    # AR+AW, rsp -> R+B, W rides the R data channel).  Default: the
    # paper's narrow_wide mapping — AW/AR/B narrow, W/R wide for the
    # wide class, everything narrow for the narrow class.
    class_map: tuple[tuple[str, str], ...] = (
        ("narrow.ar", "req"), ("narrow.aw", "req"), ("narrow.w", "req"),
        ("narrow.r", "rsp"), ("narrow.b", "rsp"),
        ("wide.ar", "req"), ("wide.aw", "req"), ("wide.b", "rsp"),
        ("wide.w", "wide"), ("wide.r", "wide"),
    )
    service_lat: int = 10          # target memory + NI latency (cycles)
    cycles: int = 4000
    # per-NI response reorder-ring capacity (entries per queue).  Sizes
    # the engine's (R, n_rq, resp_q_cap, 6) ring state, so small
    # studies can shrink it; must cover the worst-case R+B responses
    # pending at one NI (bounded by sum over classes of max_outstanding
    # x #sources targeting it — the engine does not check overflow at
    # runtime).  The per-class W rings are sized separately from the
    # classes' declared max_outstanding.
    resp_q_cap: int = 256
    # routing algorithm x VC count (kept after the scalar knobs so
    # older positional constructions stay valid).  Validated against
    # the topology below.
    routing: RoutingPolicy = RoutingPolicy()
    # fault-injection + NI robustness model (new last field, same
    # positional-compatibility rule).  None = the healthy fabric with
    # the fault machinery entirely compiled out (bit-identical to the
    # pre-fault engine); see repro.noc.faults.FaultModel.
    faults: FaultModel | None = None

    def __post_init__(self):
        if not isinstance(self.resp_q_cap, int) or isinstance(
                self.resp_q_cap, bool) or self.resp_q_cap < 2:
            raise ValueError(
                f"resp_q_cap must be an int >= 2, got {self.resp_q_cap!r}")
        if not (callable(getattr(self.topology, "tables", None))
                and getattr(self.topology, "__hash__", None)):
            raise TypeError(
                f"topology must be a hashable Topology (Mesh/Torus) with "
                f"static tables(), got {self.topology!r}")
        if not isinstance(self.routing, RoutingPolicy):
            raise TypeError(
                f"routing must be a RoutingPolicy, got {self.routing!r}")
        self.routing.validate_for(self.topology)
        if self.faults is not None:
            if not isinstance(self.faults, FaultModel):
                raise TypeError(
                    f"faults must be a FaultModel or None, got "
                    f"{self.faults!r}")
            R = self.topology.n_routers
            ids = ({n for n in self.faults.dead_nodes}
                   | {i for lk in self.faults.dead_links for i in lk}
                   | {i for ev in self.faults.link_events for i in ev[:2]})
            if ids and max(ids) >= R:
                raise ValueError(
                    f"fault references router {max(ids)}, but "
                    f"{self.topology!r} has only {R} routers")
            if self.faults.has_static and self.faults.reroute:
                # cheap static preconditions of the cut-out reroute;
                # the unroutable-cut case needs tables and is raised
                # (or reported by analyze) at compile time instead
                if self.routing.algorithm != "xy":
                    raise ValueError(
                        f"static fault reroute supports algorithm='xy' "
                        f"only, got {self.routing.algorithm!r}")
                need = self.routing.required_vcs(self.topology) + 1
                if self.routing.n_vcs < need:
                    raise ValueError(
                        f"static fault reroute on {self.topology!r} "
                        f"needs n_vcs >= {need} (base discipline + one "
                        f"dedicated detour VC), got {self.routing.n_vcs}")
            tc = self.faults.timeout_cycles
            if not isinstance(tc, int) and len(tc) != len(self.classes):
                raise ValueError(
                    f"per-class timeout_cycles has {len(tc)} entries for "
                    f"{len(self.classes)} classes")
        if isinstance(self.classes, Sequence) and not isinstance(
                self.classes, tuple):
            object.__setattr__(self, "classes", tuple(self.classes))
        if isinstance(self.channels, Sequence) and not isinstance(
                self.channels, tuple):
            object.__setattr__(self, "channels", tuple(self.channels))
        cm = self.class_map
        items = list(cm.items()) if isinstance(cm, Mapping) else list(cm)
        if len({k for k, _ in items}) != len(items):
            raise ValueError("class_map has duplicate flow entries")
        items = self._expand_legacy(items)
        # normalize (sort) regardless of input form so equivalent specs
        # hash equal and share one compiled simulator
        cm = tuple(sorted(items))
        object.__setattr__(self, "class_map", cm)
        names = {c.name for c in self.classes}
        chans = {c.name for c in self.channels}
        if len(names) != len(self.classes):
            raise ValueError("duplicate traffic class names")
        if len(chans) != len(self.channels):
            raise ValueError("duplicate channel names")
        for ch in self.channels:
            if ch.depth < 1:
                raise ValueError(
                    f"channel {ch.name!r} needs FIFO depth >= 1, got "
                    f"{ch.depth}")
        for cls in self.classes:
            if cls.service_lat is not None and cls.service_lat < 0:
                raise ValueError(
                    f"class {cls.name!r} service_lat must be >= 0")
            if cls.service_jitter < 0:
                raise ValueError(
                    f"class {cls.name!r} service_jitter must be >= 0")
            if not isinstance(cls.n_streams, int) or isinstance(
                    cls.n_streams, bool) or not (
                    1 <= cls.n_streams <= cls.max_outstanding):
                raise ValueError(
                    f"class {cls.name!r} n_streams must be an int in "
                    f"[1, max_outstanding={cls.max_outstanding}], got "
                    f"{cls.n_streams!r}")
        flows = dict(cm)
        for cls in self.classes:
            for d in AXI_FLOWS:
                flow = f"{cls.name}.{d}"
                if flow not in flows:
                    raise ValueError(f"class_map missing flow {flow!r}")
                if flows[flow] not in chans:
                    raise ValueError(
                        f"flow {flow!r} mapped to unknown channel "
                        f"{flows[flow]!r}")
        for flow in flows:
            cls_name, _, d = flow.partition(".")
            if cls_name not in names or d not in AXI_FLOWS:
                raise ValueError(f"class_map has unknown flow {flow!r}")
        # cheap static verification (repro.noc.analyze protocol/credit
        # checks; lazy import — analyze depends on this module): a FAIL,
        # e.g. a resp_q_cap that a single class's ROB budget can
        # overflow, rejects the spec at construction.  WARNs stay
        # advisory, and the expensive channel-dependency deadlock pass
        # waits for analyze()/simulate(verify="full").
        from .analyze import verify_spec
        verify_spec(self, "fast")

    @staticmethod
    def _expand_legacy(items: list[tuple[str, str]]) -> list[tuple[str, str]]:
        """Expand legacy ``"<cls>.req"``/``"<cls>.rsp"`` entries into the
        five AXI flows: req carries the address flows (AR, AW), rsp the
        response flows (R, B), and W rides the class's R data channel —
        W and R are the payload pair the paper puts on the wide link."""
        if not any(k.endswith((".req", ".rsp")) for k, _ in items):
            return items
        explicit = {k for k, _ in items
                    if not k.endswith((".req", ".rsp"))}
        out, rsp_ch = [], {}
        for k, ch in items:
            cls_name, _, d = k.partition(".")
            if d == "req":
                out += [(f"{cls_name}.{f}", ch) for f in ("ar", "aw")
                        if f"{cls_name}.{f}" not in explicit]
            elif d == "rsp":
                out += [(f"{cls_name}.{f}", ch) for f in ("r", "b")
                        if f"{cls_name}.{f}" not in explicit]
                rsp_ch[cls_name] = ch
            else:
                out.append((k, ch))
        have = {k for k, _ in out}
        for cls_name, ch in rsp_ch.items():
            if f"{cls_name}.w" not in have:
                out.append((f"{cls_name}.w", ch))
        return out

    # ------------------------------------------------------------------ #
    @property
    def nx(self) -> int:
        return self.topology.nx

    @property
    def ny(self) -> int:
        return self.topology.ny

    @property
    def n_routers(self) -> int:
        return self.topology.n_routers

    @property
    def flow_map(self) -> dict[str, str]:
        return dict(self.class_map)

    def class_index(self, name: str) -> int:
        for i, c in enumerate(self.classes):
            if c.name == name:
                return i
        raise KeyError(name)

    def get_class(self, name: str) -> TrafficClass:
        return self.classes[self.class_index(name)]

    def channel_index(self, name: str) -> int:
        for i, c in enumerate(self.channels):
            if c.name == name:
                return i
        raise KeyError(name)

    def flow_channel(self, cls_name: str, flow: str) -> int:
        """Channel index carrying ``cls_name``'s AXI ``flow``."""
        if flow not in AXI_FLOWS:
            raise KeyError(f"unknown AXI flow {flow!r}; have {AXI_FLOWS}")
        return self.channel_index(self.flow_map[f"{cls_name}.{flow}"])

    def req_channel(self, cls_name: str) -> int:
        """Legacy alias: the channel carrying the class's AR flow."""
        return self.flow_channel(cls_name, "ar")

    def rsp_channel(self, cls_name: str) -> int:
        """Legacy alias: the channel carrying the class's R flow."""
        return self.flow_channel(cls_name, "r")

    @property
    def burstlen(self) -> int:
        """Largest declared burst (legacy traffic generators key off it)."""
        return max(c.burst_beats for c in self.classes)

    def with_(self, **kw) -> "NocSpec":
        return replace(self, **kw)

    # ---------------------------------------------------------------- #
    # paper presets
    # ---------------------------------------------------------------- #
    @classmethod
    def narrow_wide(cls, nx: int = 4, ny: int = 4, *,
                    topology: Topology | None = None, depth: int = 2,
                    burstlen: int = 16, service_lat: int = 10,
                    cycles: int = 4000, max_narrow_outstanding: int = 8,
                    max_wide_outstanding: int = 8,
                    resp_q_cap: int = 256,
                    routing: RoutingPolicy | None = None,
                    faults: FaultModel | None = None) -> "NocSpec":
        """Paper §III-B: three independent physical networks, with the
        AXI flows mapped per the paper — single-flit address/ack flows
        (AR, AW, B) plus the narrow class's data on the narrow req/rsp
        pair, wide W/R data bursts on the wide channel.

        ``topology`` overrides the default XY mesh (e.g. ``Torus(nx,
        ny)`` or ``Mesh(nx, ny, express=(2,))``); ``routing``
        overrides the default single-VC XY policy (e.g.
        ``RoutingPolicy.xy(n_vcs=2)`` for a deadlock-free torus)."""
        return cls(
            topology=_resolve_topology(nx, ny, topology),
            classes=(
                TrafficClass("narrow", 1, max_narrow_outstanding, 64),
                TrafficClass("wide", burstlen, max_wide_outstanding, 512),
            ),
            channels=(
                PhysicalChannel("req", depth, 119),
                PhysicalChannel("rsp", depth, 103),
                PhysicalChannel("wide", depth, 603),
            ),
            class_map=(
                ("narrow.ar", "req"), ("narrow.aw", "req"),
                ("narrow.w", "req"),
                ("narrow.r", "rsp"), ("narrow.b", "rsp"),
                ("wide.ar", "req"), ("wide.aw", "req"),
                ("wide.b", "rsp"),
                ("wide.w", "wide"), ("wide.r", "wide")),
            service_lat=service_lat, cycles=cycles, resp_q_cap=resp_q_cap,
            routing=RoutingPolicy() if routing is None else routing,
            faults=faults)

    @classmethod
    def wide_only(cls, nx: int = 4, ny: int = 4, *,
                  topology: Topology | None = None, depth: int = 2,
                  burstlen: int = 16, service_lat: int = 10,
                  cycles: int = 4000, max_narrow_outstanding: int = 8,
                  max_wide_outstanding: int = 8,
                  resp_q_cap: int = 256,
                  routing: RoutingPolicy | None = None,
                  faults: FaultModel | None = None) -> "NocSpec":
        """Fig. 5 ablation: ONE network carries all five flows of every
        class; narrow flits burn full wide-link cycles and bursts hold
        links end-to-end."""
        return cls(
            topology=_resolve_topology(nx, ny, topology),
            classes=(
                TrafficClass("narrow", 1, max_narrow_outstanding, 64),
                TrafficClass("wide", burstlen, max_wide_outstanding, 512),
            ),
            channels=(PhysicalChannel("wide", depth, 603),),
            class_map=tuple((f"{c}.{f}", "wide")
                            for c in ("narrow", "wide")
                            for f in AXI_FLOWS),
            service_lat=service_lat, cycles=cycles, resp_q_cap=resp_q_cap,
            routing=RoutingPolicy() if routing is None else routing,
            faults=faults)

    @classmethod
    def multi_stream(cls, nx: int = 4, ny: int = 4, *, n_wide: int = 2,
                     topology: Topology | None = None,
                     depth: int = 2, burstlen: int = 16,
                     service_lat: int = 10, cycles: int = 4000,
                     resp_q_cap: int = 256,
                     routing: RoutingPolicy | None = None,
                     faults: FaultModel | None = None) -> "NocSpec":
        """Journal-version style: ``n_wide`` parallel wide stream channels
        (wide class i's W/R data bursts ride their own physical network)
        next to the shared narrow req/rsp pair carrying every class's
        AR/AW address flows and B acks."""
        classes = [TrafficClass("narrow", 1, 8, 64)]
        channels = [PhysicalChannel("req", depth, 119),
                    PhysicalChannel("rsp", depth, 103)]
        cmap = [("narrow.ar", "req"), ("narrow.aw", "req"),
                ("narrow.w", "req"),
                ("narrow.r", "rsp"), ("narrow.b", "rsp")]
        for i in range(n_wide):
            classes.append(TrafficClass(f"wide{i}", burstlen, 8, 512))
            channels.append(PhysicalChannel(f"wide{i}", depth, 603))
            cmap += [(f"wide{i}.ar", "req"), (f"wide{i}.aw", "req"),
                     (f"wide{i}.b", "rsp"),
                     (f"wide{i}.w", f"wide{i}"), (f"wide{i}.r", f"wide{i}")]
        return cls(topology=_resolve_topology(nx, ny, topology),
                   classes=tuple(classes), channels=tuple(channels),
                   class_map=tuple(sorted(cmap)),
                   service_lat=service_lat, cycles=cycles,
                   resp_q_cap=resp_q_cap,
                   routing=RoutingPolicy() if routing is None else routing,
                   faults=faults)
