"""Fault injection & graceful degradation for the NoC fabric.

A frozen :class:`FaultModel` lives on a :class:`~repro.noc.spec.NocSpec`
and declares two kinds of faults plus the NI's end-to-end robustness
knobs:

**Static faults** (``dead_links`` / ``dead_nodes``) are compiled into
*cut-out route tables*: :func:`cut_tables` regenerates the spec's route
table so no walk traverses a dead link or dead router, and proves the
result safe with the same machinery every healthy table goes through —
:func:`repro.noc.topology.run_table_checks` structural validation plus
the analyzer's exact channel-dependency-graph (CDG) deadlock proof.
The reroute scheme is Duato-style: the base escape-VC routes stay
untouched for every (source, dest) pair whose walk misses the cut,
while affected pairs detour along a BFS spanning tree of the surviving
graph riding a *dedicated top VC* (``n_vcs - 1``, which the base
compile provably never uses when ``n_vcs >= required_vcs + 1``).  Tree
hops within the detour VC are acyclic (up-edges strictly decrease BFS
level, down-edges strictly increase depth, and a walk never turns back
up), and the only cross-VC dependencies go detour -> base (a walk that
re-enters the clean region switches to the base table and, because the
clean region is suffix-closed, never switches back) — so the combined
CDG stays acyclic, which :func:`repro.noc.analyze.analyze_routing`
re-checks exactly rather than taking this argument on faith.

**Dynamic faults** (``link_events`` / :meth:`FaultModel.bernoulli`) are
``fail_at``/``heal_at`` cycle windows per physical link, carried as
traced operands through the engine and all three backends: a masked
link simply *drops its grants* — flits wait under backpressure, nothing
is lost — so a fabric without reroute wedges on a permanent cut (the
honest outcome) while a healed link lets traffic resume flit-for-flit
identically across backends.

**NI robustness**: ``timeout_cycles`` (per class, traced) arms a
per-transaction watchdog; a timed-out transaction is retried up to
``max_retries`` times with exponential backoff (``backoff_base << k``)
plus deterministic jitter drawn from the spec's PR-5 ``jitter_table``;
exhausted retries produce an AXI SLVERR-style error response that frees
the ROB credit so the simulation degrades gracefully instead of
wedging.  ``SimResult.faults`` reports the degradation stats.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .routing import RoutingPolicy, RouteTables
from .topology import Topology, validate_tables

__all__ = ["FaultModel", "UnroutableCutError", "cut_tables",
           "dynamic_events", "HEAL_NEVER"]

# sentinel for "never heals" — matches the engine's BIG time sentinel
HEAL_NEVER = 1 << 30


class UnroutableCutError(ValueError):
    """The static cut disconnects the fabric: some live router cannot
    reach the rest of the surviving graph.  ``coords`` names the first
    unreachable router (and the BFS root it cannot reach)."""

    def __init__(self, msg: str, coords: tuple = ()):
        super().__init__(msg)
        self.coords = coords


def _norm_link(a, b) -> tuple[int, int]:
    return (int(a), int(b)) if a <= b else (int(b), int(a))


@dataclass(frozen=True)
class FaultModel:
    """Frozen fault + robustness configuration of a NocSpec.

    ``dead_links``    — undirected ``(a, b)`` router pairs that are
                        permanently dead; with ``reroute=True`` (the
                        default) the route table is regenerated to
                        detour around them (see :func:`cut_tables`).
    ``dead_nodes``    — routers that are entirely dead: every attached
                        link dies, and traffic may not originate at or
                        target them (validated at simulate time).
    ``link_events``   — deterministic dynamic schedule: ``(a, b,
                        fail_at, heal_at)`` windows during which the
                        physical link drops all grants (blocking — no
                        flit loss). ``heal_at >= HEAL_NEVER`` never
                        heals.
    ``n_events`` /    — seeded Bernoulli mode: draw ``n_events`` random
    ``seed`` /          fail windows over the simulation horizon with
    ``mean_downtime``   geometric downtimes (see :meth:`bernoulli`).
    ``timeout_cycles``— per-transaction watchdog, scalar or per-class
                        tuple; 0 disables. Traced (overridable per
                        ``simulate`` call without recompiling).
    ``max_retries``   — retry budget per transaction before SLVERR.
    ``backoff_base``  — retry k waits ``backoff_base << k`` cycles plus
                        deterministic jitter from the spec's
                        ``jitter_table``.
    ``reroute``       — compile cut-out tables for the static faults;
                        ``False`` keeps the base tables (the cut is
                        only masked dynamically — the wedge baseline).
    """
    dead_links: tuple[tuple[int, int], ...] = ()
    dead_nodes: tuple[int, ...] = ()
    link_events: tuple[tuple[int, int, int, int], ...] = ()
    n_events: int = 0
    seed: int = 0
    mean_downtime: int = 64
    timeout_cycles: int | tuple[int, ...] = 0
    max_retries: int = 3
    backoff_base: int = 8
    reroute: bool = True

    def __post_init__(self):
        links = tuple(sorted({_norm_link(a, b)
                              for a, b in self.dead_links}))
        for a, b in links:
            if a == b or a < 0:
                raise ValueError(f"dead link ({a}, {b}) is not a link")
        object.__setattr__(self, "dead_links", links)
        nodes = tuple(sorted({int(n) for n in self.dead_nodes}))
        if any(n < 0 for n in nodes):
            raise ValueError(f"dead node ids must be >= 0, got {nodes}")
        object.__setattr__(self, "dead_nodes", nodes)
        evs = tuple((int(a), int(b), int(f), int(h))
                    for a, b, f, h in self.link_events)
        for a, b, f, h in evs:
            if a == b or a < 0 or b < 0:
                raise ValueError(f"link event ({a}, {b}) is not a link")
            if f < 0 or h <= f:
                raise ValueError(
                    f"link event needs 0 <= fail_at < heal_at, "
                    f"got fail_at={f} heal_at={h}")
        object.__setattr__(self, "link_events", evs)
        if self.n_events < 0:
            raise ValueError(f"n_events must be >= 0, got {self.n_events}")
        if self.n_events and self.mean_downtime < 1:
            raise ValueError(
                f"mean_downtime must be >= 1, got {self.mean_downtime}")
        tc = self.timeout_cycles
        tcs = (tc,) if isinstance(tc, int) else tuple(int(t) for t in tc)
        if any(t < 0 for t in tcs):
            raise ValueError(f"timeout_cycles must be >= 0, got {tc!r}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 1:
            raise ValueError(
                f"backoff_base must be >= 1, got {self.backoff_base}")

    # ------------------------------------------------------------------ #
    @classmethod
    def bernoulli(cls, n_events: int, seed: int = 0,
                  mean_downtime: int = 64, **kw) -> "FaultModel":
        """Seeded random dynamic faults: ``n_events`` fail windows drawn
        uniformly over the simulation horizon, on uniformly random wired
        links, with geometric downtimes of the given mean.  Fully
        deterministic given ``seed`` (drawn in numpy at build time, so
        the traced simulator sees them as ordinary operands)."""
        return cls(n_events=n_events, seed=seed,
                   mean_downtime=mean_downtime, **kw)

    # ------------------------------------------------------------------ #
    @property
    def has_static(self) -> bool:
        return bool(self.dead_links or self.dead_nodes)

    @property
    def has_dynamic(self) -> bool:
        return bool(self.link_events or self.n_events)

    def persistent_faults(self, horizon: int) -> tuple[tuple, ...]:
        """``(a, b, since)`` for every link still dead at ``horizon`` —
        what ``diagnose()`` names when an undrained sim has a fault."""
        out = [(a, b, 0) for a, b in self.dead_links]
        for n in self.dead_nodes:
            out.append((n, n, 0))
        for a, b, f, h in self.link_events:
            if f < horizon <= h:
                out.append((_norm_link(a, b) + (f,)))
        return tuple(out)


# --------------------------------------------------------------------- #
# static cut-out route regeneration
# --------------------------------------------------------------------- #
def _dead_link_set(topo: Topology, fm: FaultModel) -> set[tuple[int, int]]:
    """Normalized dead undirected links (incl. every link of a dead
    node), each validated to exist in the wired fabric."""
    nbr, _, _ = topo.tables()
    R, P = nbr.shape
    wired = {_norm_link(r, int(nbr[r, p]))
             for r in range(R) for p in range(P - 1) if nbr[r, p] >= 0}
    dead = set()
    for a, b in fm.dead_links:
        if b >= R:
            raise ValueError(
                f"dead link ({a}, {b}) out of range for {R} routers")
        if (a, b) not in wired:
            raise ValueError(
                f"dead link ({a}, {b}) is not a wired link of {topo!r}")
        dead.add((a, b))
    for n in fm.dead_nodes:
        if n >= R:
            raise ValueError(
                f"dead node {n} out of range for {R} routers")
        for p in range(P - 1):
            if nbr[n, p] >= 0:
                dead.add(_norm_link(n, int(nbr[n, p])))
    return dead


def _link_dead_mask(nbr: np.ndarray,
                    dead: set[tuple[int, int]]) -> np.ndarray:
    """(R, P) bool: output port (r, p) drives a dead link."""
    R, P = nbr.shape
    mask = np.zeros((R, P), bool)
    for r in range(R):
        for p in range(P - 1):
            t = int(nbr[r, p])
            if t >= 0 and _norm_link(r, t) in dead:
                mask[r, p] = True
    return mask


def cut_tables(topology: Topology, routing: RoutingPolicy,
               faults: FaultModel) -> RouteTables:
    """Compiled tables with the static cut routed around (cached).

    Unaffected (source, dest) pairs keep the base escape-VC route;
    affected pairs detour along a BFS spanning tree of the surviving
    graph on the dedicated top VC (see the module docstring for the
    deadlock argument).  Raises :class:`UnroutableCutError` when the cut
    disconnects the surviving fabric, and ``ValueError`` when the policy
    lacks the spare detour VC (static cuts need
    ``n_vcs >= required_vcs(topology) + 1``) or is not ``"xy"``.
    """
    if not (faults.has_static and faults.reroute):
        return routing.compile(topology)
    return _cut_tables(routing, topology, faults.dead_links,
                       faults.dead_nodes)


@functools.lru_cache(maxsize=64)
def _cut_tables(policy: RoutingPolicy, topo: Topology,
                dead_links: tuple, dead_nodes: tuple) -> RouteTables:
    if policy.algorithm != "xy":
        raise ValueError(
            f"static fault reroute supports algorithm='xy' only (the "
            f"detour rides a dedicated escape VC on the single XY "
            f"plane), got {policy.algorithm!r}")
    need = policy.required_vcs(topo) + 1
    if policy.n_vcs < need:
        raise ValueError(
            f"static fault reroute on {topo!r} needs n_vcs >= {need} "
            f"(base discipline + one dedicated detour VC), got "
            f"{policy.n_vcs}")
    fm = FaultModel(dead_links=dead_links, dead_nodes=dead_nodes)
    nbr, _, phys_route = topo.tables()
    R, P = nbr.shape
    V = policy.n_vcs
    dead = _dead_link_set(topo, fm)
    link_dead = _link_dead_mask(nbr, dead)
    alive = np.ones(R, bool)
    alive[list(fm.dead_nodes)] = False
    if alive.sum() < 2:
        raise UnroutableCutError(
            f"cut kills {len(fm.dead_nodes)} of {R} routers; fewer than "
            f"2 survive", coords=(int(fm.dead_nodes[0]),))

    # which (src, dest) base walks traverse the cut (pointer doubling;
    # suffix-closed: a clean walk's every suffix is clean, so a flit
    # that re-enters the clean region follows base routes to delivery)
    rr = np.arange(R)[:, None].repeat(R, axis=1)
    dd = rr.T
    off_diag = rr != dd
    sd = link_dead[rr, phys_route]                       # diag: local, False
    nxt = np.where(off_diag, nbr[rr, phys_route], rr)    # absorbing at dest
    bad = sd.copy()
    hop = nxt.copy()
    for _ in range(max(1, int(np.ceil(np.log2(max(2, R)))) + 1)):
        bad |= np.take_along_axis(bad, hop, axis=0)
        hop = np.take_along_axis(hop, hop, axis=0)

    # BFS spanning tree of the surviving graph (port-order, so the
    # tree — and therefore the regenerated table — is deterministic)
    root = int(np.flatnonzero(alive)[0])
    parent = np.full(R, -1, np.int64)
    level = np.full(R, -1, np.int64)
    level[root] = 0
    queue = [root]
    while queue:
        v = queue.pop(0)
        for p in range(P - 1):
            t = int(nbr[v, p])
            if t >= 0 and alive[t] and not link_dead[v, p] \
                    and level[t] < 0:
                parent[t] = v
                level[t] = level[v] + 1
                queue.append(t)
    unreached = alive & (level < 0)
    if unreached.any():
        u = int(np.flatnonzero(unreached)[0])
        raise UnroutableCutError(
            f"cut disconnects the fabric: router {u} cannot reach "
            f"router {root} with dead links {sorted(dead)} and dead "
            f"nodes {list(fm.dead_nodes)}", coords=(u, root))

    # tree next-hop toward each dest: parent(v) unless v is a proper
    # ancestor of d, then the child of v on d's root path
    tnext = np.repeat(parent[:, None], R, axis=1)
    for d in np.flatnonzero(alive):
        c, a = int(d), int(parent[d])
        while a >= 0:
            tnext[a, d] = c
            c, a = a, int(parent[a])
        tnext[d, d] = d

    # neighbor -> port map over live links (unique per pair: distinct
    # strides reach distinct routers)
    pmat = np.full((R, R), -1, np.int64)
    for p in range(P - 1):
        w = nbr[:, p]
        m = (w >= 0) & ~link_dead[:, p]
        pmat[np.flatnonzero(m), w[m]] = p

    base = policy.compile(topo)
    route_v = np.array(base.route)                       # writable copy
    affected = bad & off_diag & alive[:, None] & alive[None, :]
    srcs, dsts = np.nonzero(affected)
    if srcs.size:
        w = tnext[srcs, dsts]
        p = pmat[srcs, w]
        if (p < 0).any():
            i = int(np.flatnonzero(p < 0)[0])            # pragma: no cover
            raise AssertionError(
                f"tree hop {srcs[i]}->{w[i]} lost its live link")
        route_v[srcs, dsts] = p * V + (V - 1)            # detour top VC
    validate_tables(base.nbr, base.opp, route_v)
    route_v.setflags(write=False)
    return RouteTables(nbr=base.nbr, opp=base.opp, route=route_v,
                       vc_of_hop=base.vc_of_hop, n_vcs=base.n_vcs,
                       n_planes=base.n_planes,
                       n_base_ports=base.n_base_ports)


# --------------------------------------------------------------------- #
# dynamic fault events -> traced operands + static per-event masks
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=64)
def dynamic_events(topo: Topology, routing: RoutingPolicy,
                   faults: FaultModel, horizon: int):
    """``(ev_fail, ev_heal, masks)`` for one spec: ``ev_fail``/
    ``ev_heal`` are ``(E,) int32`` cycle bounds (traced operands);
    ``masks`` is the static ``(E, R, Pv) bool`` table of virtual output
    ports each event kills (both link directions, every VC — the fault
    is physical, so all planes/VCs share it).  Static dead links/nodes
    fold in as ``fail_at=0, heal_at=HEAL_NEVER`` events so masked-link
    accounting (``faulted_link_cycles``) covers them too; when there are
    no events at all, one never-active dummy keeps shapes static."""
    nbr, opp, _ = topo.tables()
    R, P = nbr.shape
    V = routing.n_vcs
    Pv = (P - 1) * V + 1
    events: list[tuple[tuple[int, int], int, int]] = []
    for a, b in _dead_link_set(topo, faults):
        events.append(((a, b), 0, HEAL_NEVER))
    for a, b, f, h in faults.link_events:
        lk = _norm_link(a, b)
        _dead_link_set(topo, FaultModel(dead_links=(lk,),
                                        reroute=False))  # existence check
        events.append((lk, f, h))
    if faults.n_events:
        rng = np.random.default_rng(
            np.uint32(0xFA17) + np.uint32(faults.seed))
        wired = sorted({_norm_link(r, int(nbr[r, p]))
                        for r in range(R) for p in range(P - 1)
                        if nbr[r, p] >= 0})
        for _ in range(faults.n_events):
            lk = wired[int(rng.integers(len(wired)))]
            f = int(rng.integers(max(1, horizon)))
            down = int(rng.geometric(1.0 / faults.mean_downtime))
            events.append((lk, f, f + max(1, down)))
    if not events:
        events.append(((0, 0), HEAL_NEVER, HEAL_NEVER + 1))

    E = len(events)
    ev_fail = np.array([f for _, f, _ in events], np.int32)
    ev_heal = np.array([h for _, _, h in events], np.int32)
    masks = np.zeros((E, R, Pv), bool)
    for e, ((a, b), _, _) in enumerate(events):
        if a == b:                                       # dummy event
            continue
        for r, t in ((a, b), (b, a)):
            for p in range(P - 1):
                if nbr[r, p] == t:
                    masks[e, r, p * V:(p + 1) * V] = True
    for arr in (ev_fail, ev_heal, masks):
        arr.setflags(write=False)
    return ev_fail, ev_heal, masks
