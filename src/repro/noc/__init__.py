"""repro.noc — declarative NoC experiment API.

    from repro.noc import NocSpec, Workload, simulate

    spec = NocSpec.narrow_wide(nx=4, ny=4, cycles=8000)
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 100, "wide": 200}, bidir=True)
    result = simulate(spec, wl)
    print(result.classes["narrow"].avg_lat)

Specs declare channel topology (any number of physical networks with a
class->channel map); workloads declare typed traffic patterns; sweeps
vmap over rates/seeds/latencies in one jit (`simulate_batch`, `sweep`).
The legacy ``repro.core.noc_sim.SimConfig``/``run_sim`` names remain as
deprecation shims over this API.
"""
from .api import (simulate, simulate_batch, simulate_schedules,  # noqa: F401
                  stack_schedules, sweep)
from .engine import build_topology, compiled_sim  # noqa: F401
from .result import ChannelStats, ClassStats, SimResult  # noqa: F401
from .spec import NocSpec, PhysicalChannel, TrafficClass  # noqa: F401
from .workload import (PATTERNS, Workload, from_legacy_traffic,  # noqa: F401
                       register_pattern)
