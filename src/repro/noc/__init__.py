"""repro.noc — declarative NoC experiment API.

    from repro.noc import Mesh, Torus, NocSpec, Workload, simulate

    spec = NocSpec.narrow_wide(nx=4, ny=4, cycles=8000)
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 100, "wide": 200}, bidir=True)
    result = simulate(spec, wl)                      # pure-jnp reference
    result = simulate(spec, wl, backend="pallas")    # Pallas router kernel
    print(result.classes["narrow"].avg_lat)

Specs declare a first-class topology (``Mesh(nx, ny)``, ``Torus(nx,
ny)``, ``Mesh(nx, ny, express=(2,))`` for >5-port express routers) and
channel layout (any number of physical networks with a class->channel
map); workloads declare typed traffic patterns; sweeps vmap over
rates/seeds/latencies in one jit (``simulate_batch``, ``sweep``).  The
router hot loop is a pluggable backend (``backends.list_backends()``)
behind the identical surface — every backend is flit-for-flit
equivalent.
"""
from .api import (simulate, simulate_batch, simulate_schedules,  # noqa: F401
                  stack_schedules, sweep)
from .backends import (get_backend, list_backends,  # noqa: F401
                       register_backend)
from .engine import (build_channel_plan, compiled_sim,  # noqa: F401
                     sim_cache_clear, sim_cache_stats)
from .result import ChannelStats, ClassStats, SimResult  # noqa: F401
from .spec import NocSpec, PhysicalChannel, TrafficClass  # noqa: F401
from .topology import Mesh, Topology, Torus, hop_table  # noqa: F401
from .workload import PATTERNS, Workload, register_pattern  # noqa: F401
