"""repro.noc — declarative NoC experiment API.

    from repro.noc import Mesh, Torus, NocSpec, Workload, simulate

    spec = NocSpec.narrow_wide(nx=4, ny=4, cycles=8000)
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 100, "wide": 200}, bidir=True,
                       write_frac={"wide": 0.5})    # half the wide txns
    result = simulate(spec, wl)                      # pure-jnp reference
    result = simulate(spec, wl, backend="pallas")    # Pallas router kernel
    print(result.classes["narrow"].avg_lat)          # reads (AR -> R)
    print(result.classes["wide"].w_avg_lat)          # writes (AW -> W -> B)

Specs declare a first-class topology (``Mesh(nx, ny)``, ``Torus(nx,
ny)``, ``Mesh(nx, ny, express=(2,))`` for >5-port express routers), a
channel layout (any number of physical networks), and the full AXI4
flow map — every class's AR/R/AW/W/B flows assigned to channels (the
paper maps address/ack flows narrow, data bursts wide).  A
``routing=RoutingPolicy(...)`` entry picks the routing algorithm and
virtual-channel count (XY, O1TURN, Valiant; escape-VC dateline
discipline makes the torus deadlock-free — see ``repro.noc.routing``).
Workloads
declare typed traffic patterns with per-class read/write mixes; sweeps
vmap over rates/seeds/latency distributions in one jit
(``simulate_batch``, ``sweep``).  The router hot loop is a pluggable,
flow-agnostic backend (``backends.list_backends()``) behind the
identical surface — every backend is flit-for-flit equivalent,
including on mixed read/write traffic.

Static verification (``repro.noc.analyze``): ``analyze(spec)`` proves
or refutes deadlock freedom from the compiled route tables (Dally
channel-dependency graph over (link, VC) channels), lints the AXI
flow->channel protocol order and ROB/credit budgets, and reports named
route-table checks; ``simulate(..., verify="full")`` rejects
deadlock-prone specs before stepping, and ``python -m
repro.noc.analyze --all-presets`` is the CI gate.
"""
from .api import (jitter_table, simulate, simulate_batch,  # noqa: F401
                  simulate_schedules, stack_schedules, sweep)
from .backends import (get_backend, list_backends,  # noqa: F401
                       register_backend)
from .engine import (FlowPlan, build_channel_plan,  # noqa: F401
                     build_flow_plan, compiled_sim, sim_cache_clear,
                     sim_cache_stats)
from .farm import (RowShard, farm_batch, merge_spec,  # noqa: F401
                   partition_spec)
from .faults import (FaultModel, UnroutableCutError,  # noqa: F401
                     cut_tables, dynamic_events)
from .result import (ChannelStats, ClassStats,  # noqa: F401
                     FaultStats, SimResult)
from .routing import RouteTables, RoutingPolicy  # noqa: F401
from .spec import NocSpec, PhysicalChannel, TrafficClass  # noqa: F401
from .topology import (Mesh, Topology, Torus, hop_table,  # noqa: F401
                       validate_tables)
from .traces import (EXPANDERS, expand_collective,  # noqa: F401
                     ledger_schedules, register_expander)
from .workload import PATTERNS, Workload, register_pattern  # noqa: F401

# repro.noc.analyze exports resolve lazily (PEP 562): the analyzer is
# only needed when a spec is constructed or verified, and keeping it
# out of the eager package import lets `python -m repro.noc.analyze`
# run as __main__ without a runpy double-import warning.  The name
# ``analyze`` resolves to the submodule (whose main entry point is
# ``analyze.analyze(spec)``), never a shadowing function.
_ANALYZE_EXPORTS = ("AnalysisError", "AnalysisReport", "CheckResult",
                    "analyze_routing", "check_protocol", "verify_spec")


def __getattr__(name: str):
    if name == "analyze" or name in _ANALYZE_EXPORTS:
        from importlib import import_module
        mod = import_module(".analyze", __name__)
        return mod if name == "analyze" else getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
