"""Structured simulation results.

Replaces the seed's ad-hoc result dicts (benchmarks / examples each
reshaping raw keys differently) with one typed :class:`SimResult`:
per-class latency/bandwidth stats, per-channel link activity + energy
(paper Fig. 6 pJ/B/hop model).

All arrays keep whatever leading batch dimensions the engine produced,
so a vmapped sweep returns ONE ``SimResult`` whose stats have a leading
sweep axis; ``point(i)`` slices out a single operating point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

from .spec import NocSpec


@dataclass(frozen=True)
class ClassStats:
    """Per-traffic-class metrics; arrays are (*batch, R)."""
    done: np.ndarray          # completed transactions per NI
    avg_lat: np.ndarray       # mean request->last-beat latency (cycles)
    max_lat: np.ndarray       # worst-case latency (cycles)
    beats_rx: np.ndarray      # response beats delivered per NI
    eff_bw: np.ndarray        # beats / active-span cycles (link utilization)


@dataclass(frozen=True)
class ChannelStats:
    """Per-physical-channel metrics; arrays are (*batch,)."""
    link_moves: np.ndarray    # link traversals over the run
    energy_pj: np.ndarray     # Fig. 6 model: moves * width_bytes * pJ/B/hop


@dataclass(frozen=True)
class SimResult:
    spec: NocSpec
    cycles: int
    classes: Mapping[str, ClassStats]
    channels: Mapping[str, ChannelStats]

    @classmethod
    def from_raw(cls, spec: NocSpec, raw: Mapping[str, Any]) -> "SimResult":
        from repro.core.noc_sim.energy import PAPER
        done = np.asarray(raw["done"])
        lat_sum = np.asarray(raw["lat_sum"])
        lat_max = np.asarray(raw["lat_max"])
        beats = np.asarray(raw["beats_rx"])
        first_t = np.asarray(raw["first_t"])
        last_t = np.asarray(raw["last_t"])
        moves = np.asarray(raw["link_moves"])

        classes = {}
        for i, tc in enumerate(spec.classes):
            d = done[..., i]
            span = np.maximum(
                last_t[..., i] - np.minimum(first_t[..., i], last_t[..., i]),
                1)
            classes[tc.name] = ClassStats(
                done=d,
                avg_lat=lat_sum[..., i] / np.maximum(d, 1),
                max_lat=lat_max[..., i],
                beats_rx=beats[..., i],
                eff_bw=beats[..., i] / span,
            )
        channels = {}
        for c, ch in enumerate(spec.channels):
            m = moves[..., c]
            channels[ch.name] = ChannelStats(
                link_moves=m,
                energy_pj=m * (ch.width_bits / 8.0) * PAPER.pj_per_byte_hop,
            )
        return cls(spec=spec, cycles=spec.cycles, classes=classes,
                   channels=channels)

    # ------------------------------------------------------------------ #
    @property
    def batch_shape(self) -> tuple[int, ...]:
        some = next(iter(self.classes.values()))
        return some.done.shape[:-1]

    def point(self, i: int) -> "SimResult":
        """Slice one operating point out of a batched (vmapped) result."""
        if not self.batch_shape:
            raise IndexError("result is not batched")
        classes = {k: ClassStats(**{f: getattr(v, f)[i]
                                    for f in ClassStats.__dataclass_fields__})
                   for k, v in self.classes.items()}
        channels = {k: ChannelStats(link_moves=v.link_moves[i],
                                    energy_pj=v.energy_pj[i])
                    for k, v in self.channels.items()}
        return SimResult(self.spec, self.cycles, classes, channels)

    @property
    def total_link_moves(self) -> np.ndarray:
        return np.sum(np.stack(
            [c.link_moves for c in self.channels.values()]), axis=0)

    @property
    def total_energy_pj(self) -> np.ndarray:
        return np.sum(np.stack(
            [c.energy_pj for c in self.channels.values()]), axis=0)

    def summary(self) -> dict[str, Any]:
        """Compact scalars (means over NIs with traffic) for reports."""
        out: dict[str, Any] = {"cycles": self.cycles}
        for name, st in self.classes.items():
            active = st.done > 0
            any_active = np.any(active, axis=-1)
            with np.errstate(invalid="ignore"):
                avg = np.where(
                    any_active,
                    np.sum(st.avg_lat * active, axis=-1)
                    / np.maximum(np.sum(active, axis=-1), 1), 0.0)
            out[f"{name}_done"] = np.sum(st.done, axis=-1)
            out[f"{name}_avg_lat"] = avg
            out[f"{name}_max_lat"] = np.max(st.max_lat, axis=-1)
            out[f"{name}_peak_eff_bw"] = np.max(st.eff_bw, axis=-1)
        out["total_link_moves"] = self.total_link_moves
        out["total_energy_pj"] = self.total_energy_pj
        return out
