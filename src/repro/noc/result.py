"""Structured simulation results.

Replaces the seed's ad-hoc result dicts (benchmarks / examples each
reshaping raw keys differently) with one typed :class:`SimResult`:
per-class *per-direction* latency/bandwidth stats (reads AR -> R,
writes AW -> W -> B), per-channel link activity + energy (paper Fig. 6
pJ/B/hop model — B acks traverse their mapped channel, so write-ack
energy shows up in that channel's ledger), per-channel *per-virtual-
channel* FIFO occupancy (mean + peak, shaped by the spec's
:class:`~repro.noc.routing.RoutingPolicy` ``n_vcs``) so escape-VC
deadlock freedom is observable rather than asserted, and fabric
liveness (``max_stall_cycles`` / ``drained``) for the deadlock
studies.

All arrays keep whatever leading batch dimensions the engine produced,
so a vmapped sweep returns ONE ``SimResult`` whose stats have a leading
sweep axis; ``point(i)`` slices out a single operating point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

from .spec import NocSpec


@dataclass(frozen=True)
class ClassStats:
    """Per-traffic-class metrics; arrays are (*batch, R).

    Read-direction fields keep their original names (``done`` ... are
    *read* transactions, measured at the issuing NI).  Write-direction
    fields carry a ``w_`` prefix: latency/done measured at the issuing
    NI (AW injection -> B arrival), W-beat counts and bandwidth span
    measured at the *receiving* NI (where the write data lands).

    The class-level arrays aggregate over the class's AXI ID streams
    (``TrafficClass.n_streams``); the ``stream_`` fields resolve the
    same completion metrics per stream, shaped (*batch, n_streams, R)
    — with the default single stream they are the class metrics with a
    length-1 stream axis.
    """
    done: np.ndarray          # completed read transactions per NI
    avg_lat: np.ndarray       # mean AR-inject -> last-R-beat latency
    max_lat: np.ndarray       # worst-case read latency (cycles)
    beats_rx: np.ndarray      # R beats delivered per NI
    eff_bw: np.ndarray        # R beats / active-span cycles
    w_done: np.ndarray        # completed write transactions per NI
    w_avg_lat: np.ndarray     # mean AW-inject -> B-arrival latency
    w_max_lat: np.ndarray     # worst-case write latency (cycles)
    w_beats_rx: np.ndarray    # W beats landing per (target) NI
    w_eff_bw: np.ndarray      # W beats / active-span cycles at target
    # per-AXI-ID-stream completion stats, (*batch, n_streams, R)
    stream_done: np.ndarray
    stream_avg_lat: np.ndarray
    stream_max_lat: np.ndarray
    stream_last_t: np.ndarray      # last R beat per stream (makespan)
    stream_w_done: np.ndarray
    stream_w_avg_lat: np.ndarray
    stream_w_max_lat: np.ndarray
    stream_w_last_t: np.ndarray    # last W beat landing per stream


@dataclass(frozen=True)
class ChannelStats:
    """Per-physical-channel metrics; scalar-like arrays are (*batch,),
    VC-resolved arrays (*batch, n_vcs) — one column per virtual channel
    of the spec's routing policy (a single column under the default
    single-VC policy, where it equals total FIFO occupancy)."""
    link_moves: np.ndarray    # link traversals over the run
    energy_pj: np.ndarray     # Fig. 6 model: moves * width_bytes * pJ/B/hop
    vc_occupancy: np.ndarray       # mean flits resident per VC per cycle
    vc_peak_occupancy: np.ndarray  # peak flits resident per VC


@dataclass(frozen=True)
class FaultStats:
    """Degradation metrics, present only when the spec carries a
    :class:`~repro.noc.faults.FaultModel`.  Per-class mappings aggregate
    over NIs and AXI ID streams; scalar-like arrays are (*batch,).

    ``goodput_under_fault`` is data beats delivered per cycle *while at
    least one link was down* — the graceful-degradation headline: a
    rerouted fabric keeps it well above zero, a non-rerouted cut drives
    it to zero as the wedge forms."""
    faulted_link_cycles: np.ndarray   # sum over cycles of #dead links
    fault_cycles: np.ndarray          # cycles with >= 1 link down
    retries: Mapping[str, np.ndarray]
    timeouts: Mapping[str, np.ndarray]
    slverr: Mapping[str, np.ndarray]          # retry budgets exhausted
    delivered_despite_fault: Mapping[str, np.ndarray]
    beats_under_fault: Mapping[str, np.ndarray]
    goodput_under_fault: Mapping[str, np.ndarray]   # beats / fault cycle
    undone: Mapping[str, np.ndarray]  # not-completed txns at horizon


@dataclass(frozen=True)
class SimResult:
    spec: NocSpec
    cycles: int
    classes: Mapping[str, ClassStats]
    channels: Mapping[str, ChannelStats]
    # liveness: longest streak of cycles with transactions in flight but
    # ZERO fabric activity (no injection, delivery, or link move), and
    # whether every scheduled transaction completed.  A single-VC torus
    # under saturating wormhole bursts can wedge: that shows up as
    # drained=False with max_stall_cycles ~ the remaining horizon (and
    # VC0 occupancy pinned at its peak), while an escape-VC routing
    # policy (``RoutingPolicy.xy(n_vcs=2)``) keeps it draining.
    max_stall_cycles: np.ndarray = np.int32(0)   # (*batch,)
    drained: np.ndarray = np.bool_(True)         # (*batch,)
    faults: FaultStats | None = None             # spec.faults runs only

    @classmethod
    def from_raw(cls, spec: NocSpec, raw: Mapping[str, Any]) -> "SimResult":
        from repro.core.noc_sim.energy import PAPER

        def span(first_t, last_t):
            return np.maximum(last_t - np.minimum(first_t, last_t), 1)

        # raw arrays are lane-resolved (*batch, R, n_lanes), class-major
        # — slice each class's stream block, aggregate for the class
        # view (sums / maxes / span mins are exact identities at
        # n_streams=1) and keep the per-stream slice alongside
        classes = {}
        off = 0
        for tc in spec.classes:
            S = tc.n_streams
            g = {k: np.asarray(raw[k])[..., off:off + S] for k in
                 ("done", "lat_sum", "lat_max", "beats_rx", "first_t",
                  "last_t", "w_done", "w_lat_sum", "w_lat_max",
                  "w_beats_rx", "w_first_t", "w_last_t")}
            off += S
            a = {  # class aggregate over the stream axis
                "done": g["done"].sum(-1),
                "lat_sum": g["lat_sum"].sum(-1),
                "lat_max": g["lat_max"].max(-1),
                "beats_rx": g["beats_rx"].sum(-1),
                "first_t": g["first_t"].min(-1),
                "last_t": g["last_t"].max(-1),
                "w_done": g["w_done"].sum(-1),
                "w_lat_sum": g["w_lat_sum"].sum(-1),
                "w_lat_max": g["w_lat_max"].max(-1),
                "w_beats_rx": g["w_beats_rx"].sum(-1),
                "w_first_t": g["w_first_t"].min(-1),
                "w_last_t": g["w_last_t"].max(-1),
            }
            st = {k: np.moveaxis(v, -1, -2) for k, v in g.items()}
            classes[tc.name] = ClassStats(
                done=a["done"],
                avg_lat=a["lat_sum"] / np.maximum(a["done"], 1),
                max_lat=a["lat_max"],
                beats_rx=a["beats_rx"],
                eff_bw=a["beats_rx"] / span(a["first_t"], a["last_t"]),
                w_done=a["w_done"],
                w_avg_lat=a["w_lat_sum"] / np.maximum(a["w_done"], 1),
                w_max_lat=a["w_lat_max"],
                w_beats_rx=a["w_beats_rx"],
                w_eff_bw=a["w_beats_rx"] / span(a["w_first_t"],
                                                a["w_last_t"]),
                stream_done=st["done"],
                stream_avg_lat=st["lat_sum"] / np.maximum(st["done"], 1),
                stream_max_lat=st["lat_max"],
                stream_last_t=st["last_t"],
                stream_w_done=st["w_done"],
                stream_w_avg_lat=st["w_lat_sum"]
                / np.maximum(st["w_done"], 1),
                stream_w_max_lat=st["w_lat_max"],
                stream_w_last_t=st["w_last_t"],
            )
        moves = np.asarray(raw["link_moves"])
        occ_sum = np.asarray(raw["vc_occ_sum"])       # (*batch, n_ch, V)
        occ_max = np.asarray(raw["vc_occ_max"])
        channels = {}
        for c, ch in enumerate(spec.channels):
            m = moves[..., c]
            channels[ch.name] = ChannelStats(
                link_moves=m,
                energy_pj=m * (ch.width_bits / 8.0) * PAPER.pj_per_byte_hop,
                vc_occupancy=occ_sum[..., c, :] / float(spec.cycles),
                vc_peak_occupancy=occ_max[..., c, :],
            )
        faults = None
        if "retries" in raw:
            fc = np.asarray(raw["fault_cycles"])

            def per_cls(key):
                # lane-resolved (*batch, R, n_lanes) -> per-class totals
                a, out, off = np.asarray(raw[key]), {}, 0
                for tc in spec.classes:
                    out[tc.name] = a[..., off:off + tc.n_streams].sum(
                        axis=(-2, -1))
                    off += tc.n_streams
                return out

            beats = per_cls("beats_under_fault")
            faults = FaultStats(
                faulted_link_cycles=np.asarray(raw["faulted_link_cycles"]),
                fault_cycles=fc,
                retries=per_cls("retries"),
                timeouts=per_cls("timeouts"),
                slverr=per_cls("slverr"),
                delivered_despite_fault=per_cls("delivered_despite_fault"),
                beats_under_fault=beats,
                goodput_under_fault={
                    k: v / np.maximum(fc, 1) for k, v in beats.items()},
                undone=per_cls("undone"))
        return cls(spec=spec, cycles=spec.cycles, classes=classes,
                   channels=channels,
                   max_stall_cycles=np.asarray(raw["max_stall_cycles"]),
                   drained=np.asarray(raw["drained"]), faults=faults)

    # ------------------------------------------------------------------ #
    @property
    def batch_shape(self) -> tuple[int, ...]:
        some = next(iter(self.classes.values()))
        return some.done.shape[:-1]

    def point(self, i: int) -> "SimResult":
        """Slice one operating point out of a batched (vmapped) result."""
        if not self.batch_shape:
            raise IndexError("result is not batched")
        classes = {k: ClassStats(**{f: getattr(v, f)[i]
                                    for f in ClassStats.__dataclass_fields__})
                   for k, v in self.classes.items()}
        channels = {k: ChannelStats(
            **{f: getattr(v, f)[i]
               for f in ChannelStats.__dataclass_fields__})
                    for k, v in self.channels.items()}
        faults = None
        if self.faults is not None:
            def fslice(v):
                return ({k: np.asarray(a)[i] for k, a in v.items()}
                        if isinstance(v, Mapping) else np.asarray(v)[i])
            faults = FaultStats(
                **{f: fslice(getattr(self.faults, f))
                   for f in FaultStats.__dataclass_fields__})
        return SimResult(self.spec, self.cycles, classes, channels,
                         max_stall_cycles=np.asarray(
                             self.max_stall_cycles)[i],
                         drained=np.asarray(self.drained)[i],
                         faults=faults)

    @property
    def total_link_moves(self) -> np.ndarray:
        return np.sum(np.stack(
            [c.link_moves for c in self.channels.values()]), axis=0)

    @property
    def total_energy_pj(self) -> np.ndarray:
        return np.sum(np.stack(
            [c.energy_pj for c in self.channels.values()]), axis=0)

    def summary(self) -> dict[str, Any]:
        """Compact scalars (means over NIs with traffic) for reports."""
        out: dict[str, Any] = {"cycles": self.cycles}

        def active_mean(per_ni, active):
            any_active = np.any(active, axis=-1)
            with np.errstate(invalid="ignore"):
                return np.where(
                    any_active,
                    np.sum(per_ni * active, axis=-1)
                    / np.maximum(np.sum(active, axis=-1), 1), 0.0)

        for name, st in self.classes.items():
            out[f"{name}_done"] = np.sum(st.done, axis=-1)
            out[f"{name}_avg_lat"] = active_mean(st.avg_lat, st.done > 0)
            out[f"{name}_max_lat"] = np.max(st.max_lat, axis=-1)
            out[f"{name}_peak_eff_bw"] = np.max(st.eff_bw, axis=-1)
            out[f"{name}_w_done"] = np.sum(st.w_done, axis=-1)
            out[f"{name}_w_avg_lat"] = active_mean(st.w_avg_lat,
                                                   st.w_done > 0)
            out[f"{name}_w_max_lat"] = np.max(st.w_max_lat, axis=-1)
            out[f"{name}_w_peak_eff_bw"] = np.max(st.w_eff_bw, axis=-1)
        for name, chs in self.channels.items():
            out[f"{name}_vc_occupancy"] = chs.vc_occupancy
            out[f"{name}_vc_peak_occupancy"] = chs.vc_peak_occupancy
        out["total_link_moves"] = self.total_link_moves
        out["total_energy_pj"] = self.total_energy_pj
        out["max_stall_cycles"] = self.max_stall_cycles
        out["drained"] = self.drained
        if self.faults is not None:
            out["fault_cycles"] = self.faults.fault_cycles
            out["faulted_link_cycles"] = self.faults.faulted_link_cycles
            for name in self.classes:
                out[f"{name}_retries"] = self.faults.retries[name]
                out[f"{name}_timeouts"] = self.faults.timeouts[name]
                out[f"{name}_slverr"] = self.faults.slverr[name]
                out[f"{name}_goodput_under_fault"] = \
                    self.faults.goodput_under_fault[name]
        if not np.all(self.drained):
            out["diagnosis"] = self.diagnose()
        return out

    def diagnose(self) -> str:
        """One-line verdict for an undrained run, distinguishing three
        causes:

        * **fault stall** — the spec's FaultModel leaves a link/router
          dead at the horizon: names the component, when it died, and
          the first starved class (the fabric isn't deadlocked; the
          cut simply severed routes or reroute was disabled);
        * **true deadlock** — the analyzer's channel-dependency proof
          fails: names the cyclic (link, VC) wait;
        * **congestion** — analyzer passes, no persistent fault: the
          run likely just ran out of horizon.

        Lazy import — :mod:`repro.noc.analyze` already depends on this
        package — and lru-cached per (topology, routing), so repeated
        summaries of one wedged sweep pay the proof once."""
        fm = self.spec.faults
        if fm is not None:
            dead = fm.persistent_faults(self.cycles)
            if dead:
                a, b, since = dead[0]
                what = (f"router {a}" if a == b else f"link ({a}, {b})")
                msg = f"fault stall: {what} dead since cycle {since}"
                if self.faults is not None:
                    starved = [n for n, u in self.faults.undone.items()
                               if np.any(np.asarray(u) > 0)]
                    if starved:
                        msg += f"; first starved class: {starved[0]!r}"
                if not fm.reroute:
                    msg += " (reroute disabled)"
                return msg
        from .analyze import analyze
        report = analyze(self.spec)
        if report.ok:
            return ("analyzer passed — likely congestion, not deadlock "
                    "(try more cycles or lower load)")
        return "static analysis: " + report.summary_line()
