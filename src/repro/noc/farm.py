"""Device-parallel simulation farm: shard sweeps and giant meshes.

Two tiers, both behind the unchanged ``simulate``/``sweep`` surface
(:mod:`repro.noc.api`), both plain ``jax.shard_map`` over the local
device mesh (CPU hosts get devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``):

**Tier (a) — spec-grid sharding** (``sweep(points, devices=N)``).
A sweep group is already one vmapped jit over stacked per-point
operands; the farm wraps that same vmapped simulator in a ``shard_map``
whose ``specs`` axis splits the batch across devices.  The frozen
:class:`~repro.noc.spec.NocSpec` partitions into

* the **static half** (:func:`partition_spec` -> a depth-normalized,
  hashable spec) which keys the compilation and is *closed over* —
  it never crosses the shard_map boundary, exactly like the engine's
  static/traced split, and
* the **dynamic half** (schedules, per-channel FIFO depths, per-class
  knob vectors, the jitter table) which rides through as traced
  operands, the schedules and depths sharded on ``specs`` and the
  group-constant knobs replicated.

Uneven grids are padded by repeating the last point (the pad lanes are
sliced off the gathered result before it becomes a
:class:`~repro.noc.result.SimResult`), so every group size works on
every device count.  Per-point results are bit-identical to the
single-device vmapped sweep: the per-point program is unchanged integer
arithmetic — sharding only changes *where* each lane runs.

**Tier (b) — spatial row-sharding** (``simulate(spec, wl,
shard=RowShard(n))``).  One big fabric's router rows split into ``n``
contiguous strips of ``ny / n`` mesh rows; each device advances its
strip's routers + NIs locally and the only cross-shard traffic is the
per-cycle **halo exchange** of boundary-row link state
(:func:`repro.dist.backend.halo_permute` neighbor ``ppermute``):

* downstream input-FIFO occupancy of the facing boundary rows (the
  drain decision's backpressure input), exchanged *before* phase A,
* the boundary rows' drain decisions + output registers (the neighbor
  push's payload), exchanged *after* phase A,

because those two gathers are the complete cross-row coupling of the
synchronous fabric step — everything else in
:func:`~repro.core.noc_sim.router.make_fabric_step` is row-local.
Local tables come from one ``lax.dynamic_slice`` of the global route
tables at ``axis_index * local_R``; neighbor/feeder row ids remap into
the ``[north halo | local | south halo]`` extended index space with a
single mod-``R_g`` affine (torus wrap falls out of the modulus; mesh
edges read ``ppermute``'s zero fill, which the ``nbr >= 0`` masks
already ignore).  Liveness and occupancy scalars are ``lax.psum``-ed
per cycle (see :class:`~repro.noc.engine.ShardInfo`), so the sharded
run is **flit-for-flit identical** to the single-device engine — the
equivalence tests compare entire ``SimResult`` trees.

Compiled farm simulators live in their own partitions of the engine's
stats-instrumented cache (``"farm[N]:backend"`` / ``"rowshard[N]"``),
so repeated sharded sweeps at a fixed device count never silently
recompile (``bench_sweep_scaling`` asserts the miss count).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh as _DeviceMesh, PartitionSpec as P

from repro.core.noc_sim.router import (F_BEAT, F_DEST, N_FIELDS, NO_PORT,
                                       NetState, arbiter_jnp,
                                       feeder_tables)
from repro.dist.backend import halo_permute
from .api import (_check_dead_traffic, _depths, _dyn_scalars, _fault_ops,
                  _strip_depths, jitter_table, stack_schedules)
from .backends import _resolve_tables, _stacked_init
from .engine import (BIG, ShardInfo, SimState, _cache_get, _cache_put,
                     _depth_normalized, build_flow_plan, compiled_sim,
                     init_ni, make_step)
from .result import SimResult
from .spec import NocSpec
from .topology import Mesh, Torus

__all__ = ["RowShard", "partition_spec", "merge_spec", "farm_batch",
           "compiled_farm_sweep", "compiled_rowshard_sim"]

ROW_AXIS = "rows"          # tier (b) shard_map axis name
SPEC_AXIS = "specs"        # tier (a) shard_map axis name


# --------------------------------------------------------------------- #
# static / dynamic NocSpec partition (tier a)
# --------------------------------------------------------------------- #
def partition_spec(spec: NocSpec) -> tuple[NocSpec, dict[str, np.ndarray]]:
    """Split a frozen spec into the **static half** (a hashable
    depth-normalized spec that keys the compilation and is closed over
    by the shard_mapped simulator) and the **dynamic half** (the traced
    knob arrays that cross the shard_map boundary as operands: per-
    channel FIFO ``depths``, the per-class ``service_lat`` /
    ``max_outstanding`` / ``burst_beats`` vectors, and the seeded
    ``jitter`` table).

    The static half still *declares* ``max_outstanding`` etc. — those
    values size state arrays (W rings, ROB-bounded pending tables)
    statically — but the values the engine compares against at runtime
    are the dynamic vectors, which is why a whole sweep group shares
    one compilation.  :func:`merge_spec` is the exact inverse:
    ``merge_spec(*partition_spec(s)) == s`` for every spec (tested by
    hypothesis round-trip)."""
    static = _strip_depths(spec)
    sl, mo, bb = _dyn_scalars(spec, None, None, None)
    dyn = {
        "depths": _depths(spec),
        "service_lat": sl,
        "max_outstanding": mo,
        "burst_beats": bb,
        "jitter": jitter_table(spec),
    }
    return static, dyn


def merge_spec(static: NocSpec, dyn: Mapping[str, np.ndarray]) -> NocSpec:
    """Reassemble the original spec from a :func:`partition_spec` pair
    (the depth vector is the only spec field the static half
    normalizes away; every other dynamic entry shadows a value the
    static spec still declares)."""
    depths = np.asarray(dyn["depths"], np.int64)
    if depths.shape != (len(static.channels),):
        raise ValueError(
            f"depths shape {depths.shape} != ({len(static.channels)},)")
    return static.with_(channels=tuple(
        replace(ch, depth=int(d))
        for ch, d in zip(static.channels, depths)))


# --------------------------------------------------------------------- #
# device mesh plumbing
# --------------------------------------------------------------------- #
def _device_mesh(n: int, axis: str) -> _DeviceMesh:
    avail = jax.devices()
    if n < 1:
        raise ValueError(f"need at least 1 device, got {n}")
    if n > len(avail):
        raise ValueError(
            f"requested {n} devices but only {len(avail)} are visible; "
            f"on a CPU-only host launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(must be set before jax is imported)")
    return _DeviceMesh(np.array(avail[:n]), (axis,))


# --------------------------------------------------------------------- #
# tier (a): spec-grid sharding
# --------------------------------------------------------------------- #
def compiled_farm_sweep(spec: NocSpec, T: int, devices: int,
                        backend: str = "jnp", *,
                        max_depth: int | None = None):
    """The shard_mapped analogue of ``vmap(compiled_sim(...))``: same
    operand signature with a leading batch axis on schedules + depths
    (batch size divisible by ``devices``), batch split across the
    ``specs`` device axis.  Cached in partition ``"farm[N]:backend"``
    keyed by the depth-normalized spec — a repeat sweep at the same
    device count is a cache hit, never a recompile."""
    key_spec, _ = _depth_normalized(spec, max_depth)
    partition = f"farm[{devices}]:{backend}"
    key = (key_spec, T)
    fn = _cache_get(partition, key)
    if fn is not None:
        return fn
    inner = compiled_sim(spec, T, backend, max_depth=max_depth)
    n_fops = 5 if spec.faults is not None else 0
    mesh = _device_mesh(devices, SPEC_AXIS)
    vmapped = jax.vmap(inner, in_axes=(0, 0, 0, None, None, None, None, 0,
                                       *((None,) * n_fops)))
    in_specs = ((P(SPEC_AXIS),) * 3 + (P(),) * 4 + (P(SPEC_AXIS),)
                + (P(),) * n_fops)
    fn = jax.jit(shard_map(vmapped, mesh=mesh, in_specs=in_specs,
                           out_specs=P(SPEC_AXIS), check_rep=False))
    return _cache_put(partition, key, fn)


def farm_batch(specs: Sequence[NocSpec], wls, devices: int,
               backend: str = "jnp") -> SimResult:
    """Run one sweep group (specs sharing a static half, possibly
    differing in FIFO depths) sharded across ``devices`` — the farm
    counterpart of :func:`repro.noc.api._batch_depth_sweep`.  Pads the
    group up to a device multiple by repeating the last point and
    slices the pad off the gathered raw, so results keep the exact
    batched shape of the single-device path."""
    base = specs[0]
    per_point = [wl.schedules(s) for s, wl in zip(specs, wls)]
    T = max(max(np.asarray(t).reshape(base.n_routers, -1).shape[1]
                for t, *_ in sched.values()) for sched in per_point)
    stacked = [stack_schedules(s, sched, T=T)
               for s, sched in zip(specs, per_point)]
    times = np.stack([t for t, _, _ in stacked])       # (n, n_lanes, R, T)
    dests = np.stack([d for _, d, _ in stacked])
    writes = np.stack([w for _, _, w in stacked])
    sl, mo, bb = _dyn_scalars(base, None, None, None)
    jt = jitter_table(base)
    fops = _fault_ops(base)
    for i in range(len(specs)):
        _check_dead_traffic(base, times[i], dests[i])
    depths = np.stack([_depths(s) for s in specs])     # (n, n_ch)

    n = len(specs)
    n_pad = -(-n // devices) * devices
    if n_pad != n:
        reps = n_pad - n
        pad = functools.partial(np.concatenate, axis=0)
        times = pad([times, np.repeat(times[-1:], reps, axis=0)])
        dests = pad([dests, np.repeat(dests[-1:], reps, axis=0)])
        writes = pad([writes, np.repeat(writes[-1:], reps, axis=0)])
        depths = pad([depths, np.repeat(depths[-1:], reps, axis=0)])

    fn = compiled_farm_sweep(base, T, devices, backend,
                             max_depth=int(depths.max()))
    raw = fn(jnp.asarray(times), jnp.asarray(dests), jnp.asarray(writes),
             jnp.asarray(sl), jnp.asarray(mo), jnp.asarray(bb),
             jnp.asarray(jt), jnp.asarray(depths),
             *(jnp.asarray(x) for x in fops))
    raw = {k: np.asarray(v)[:n] for k, v in raw.items()}
    return SimResult.from_raw(base, raw)


# --------------------------------------------------------------------- #
# tier (b): spatial row-sharding with halo exchange
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class RowShard:
    """Split the fabric's router rows (``topology.ny`` mesh rows) into
    ``n`` contiguous strips, one device each.  Pass as
    ``simulate(spec, wl, shard=RowShard(n))``; requires a plain
    ``Mesh``/``Torus`` (no express links — their stride links would
    couple non-adjacent shards), ``ny % n == 0``, the ``jnp`` backend
    and a fault-free spec."""
    n: int

    def __post_init__(self):
        if not isinstance(self.n, int) or isinstance(self.n, bool) \
                or self.n < 1:
            raise ValueError(f"RowShard.n must be a positive int, "
                             f"got {self.n!r}")


def _check_rowshard(spec: NocSpec, shard: RowShard, backend: str) -> None:
    if backend != "jnp":
        raise ValueError(
            f"row-sharded simulation runs on the 'jnp' backend only "
            f"(got {backend!r}); the fused kernel path is single-device")
    topo = spec.topology
    if not isinstance(topo, Mesh) or getattr(topo, "express", ()):
        raise ValueError(
            "RowShard needs a plain Mesh/Torus topology (express links "
            "couple non-adjacent row strips)")
    if spec.faults is not None:
        raise NotImplementedError(
            "row-sharded simulation does not support FaultModel specs")
    if topo.ny % shard.n:
        raise ValueError(
            f"RowShard({shard.n}) needs ny divisible by the shard "
            f"count; got ny={topo.ny}")


def compiled_rowshard_sim(spec: NocSpec, T: int, shard: RowShard,
                          backend: str = "jnp"):
    """One jitted row-sharded simulator per (depth-normalized spec,
    horizon, shard count), cached in partition ``"rowshard[N]:jnp"``.
    Same operand signature and raw-result keys as
    :func:`~repro.noc.engine.compiled_sim` (fault-free form)."""
    _check_rowshard(spec, shard, backend)
    key_spec, d_max = _depth_normalized(spec, None)
    partition = f"rowshard[{shard.n}]:{backend}"
    key = (key_spec, T)
    fn = _cache_get(partition, key)
    if fn is not None:
        return fn
    return _cache_put(partition, key,
                      _build_rowshard_sim(key_spec, T, shard.n, d_max))


def _build_rowshard_sim(spec: NocSpec, T: int, n_shards: int, d_max: int):
    """Build the shard_mapped simulator: each shard advances ``R_l =
    R_g / n`` contiguous router rows with a locally-sliced copy of the
    global tables, exchanging boundary-row state via
    :func:`~repro.dist.backend.halo_permute` twice per cycle."""
    plan = build_flow_plan(spec)
    nbr, opp, route, n_vcs = _resolve_tables(spec.topology, spec.routing)
    src_r, src_o = feeder_tables(nbr, opp)
    R_g, Pn = nbr.shape
    nx = spec.topology.nx
    R_l = R_g // n_shards
    wrap = isinstance(spec.topology, Torus)
    n_ch = plan.n_ch
    n_vcs_pol = spec.routing.n_vcs
    sh = ShardInfo(ROW_AXIS, n_shards, R_l, R_g)
    mesh = _device_mesh(n_shards, ROW_AXIS)
    # extended row index space per shard: [north halo (nx rows) |
    # local (R_l rows) | south halo (nx rows)]
    R_ext = R_l + 2 * nx
    PORT_L = Pn - 1
    n_phys = (Pn - 1) // n_vcs

    # global tables as replicated jnp constants; each shard slices its
    # own R_l-row window at trace time (hoisted out of the cycle scan)
    nbr_g = jnp.asarray(nbr, jnp.int32)
    opp_g = jnp.asarray(opp, jnp.int32)
    route_g = jnp.asarray(route, jnp.int32)
    srcr_g = jnp.asarray(src_r, jnp.int32)
    srco_g = jnp.asarray(src_o, jnp.int32)

    def _local_tables():
        base = lax.axis_index(ROW_AXIS) * R_l

        def sl(a):
            return lax.dynamic_slice_in_dim(a, base, R_l, axis=0)

        nbr_l, opp_l, route_l = sl(nbr_g), sl(opp_g), sl(route_g)
        srcr_l, srco_l = sl(srcr_g), sl(srco_g)

        # every neighbor/feeder of a local row lies within one boundary
        # strip, so its extended index is one affine: north halo rows
        # land in [0, nx), local in [nx, nx + R_l), south in
        # [nx + R_l, R_ext).  Torus wrap links need the mod (with n=1 a
        # wrapped neighbor then resolves into the identity self-halo);
        # a mesh has no wrap links, and must NOT mod — with n=1 the
        # affine of a local bottom-strip row exceeds R_g and the mod
        # would alias it into the zero-filled north halo
        def ext(g):
            off = g - base + nx
            return off % R_g if wrap else off

        nbr_ext = jnp.where(nbr_l >= 0, ext(nbr_l), -1)
        has_feed = srcr_l >= 0
        src_flat = jnp.where(has_feed, ext(srcr_l) * Pn + srco_l, 0)
        return nbr_ext, opp_l, route_l, has_feed, src_flat

    def _with_halo(x):
        """(R_l, ...) local rows -> (R_ext, ...) with both boundary
        strips exchanged (mesh edges receive ppermute's zero fill,
        masked off by the nbr/feeder >= 0 guards)."""
        north = halo_permute(x[-nx:], ROW_AXIS, n_shards, shift=1,
                             wrap=wrap)
        south = halo_permute(x[:nx], ROW_AXIS, n_shards, shift=-1,
                             wrap=wrap)
        return jnp.concatenate([north, x, south], axis=0)

    def _make_net_step(nbr_ext, opp_l, route_l, has_feed, src_flat):
        """The row-local analogue of
        :func:`~repro.core.noc_sim.router.make_fabric_step`: identical
        phase structure, with the two cross-row gathers (downstream
        occupancy, neighbor push) reading the halo-extended arrays."""
        r_idx = jnp.arange(R_l)

        def serialize_drain(ready):
            if n_vcs == 1:
                return ready
            e = ready[:, :Pn - 1].reshape(R_l, n_phys, n_vcs)
            rank = jnp.where(e, jnp.arange(n_vcs)[None, None, :], -1)
            win = e & (rank == jnp.max(rank, axis=2, keepdims=True))
            return jnp.concatenate(
                [win.reshape(R_l, Pn - 1), ready[:, Pn - 1:]], axis=1)

        def one(state: NetState, inject_valid, inject_flit, depth):
            heads = state.fifo[:, :, 0, :]
            head_valid = state.count > 0

            # phase A: drain — backpressure reads the *halo-extended*
            # cycle-start occupancy (registered, like the local gather)
            count_ext = _with_halo(state.count)            # (R_ext, P)
            ds_count = count_ext[jnp.clip(nbr_ext, 0, R_ext - 1), opp_l]
            can_drain = jnp.where(
                jnp.arange(Pn)[None, :] == PORT_L, True,
                (nbr_ext >= 0) & (ds_count < depth))
            drain = serialize_drain(state.oreg_v & can_drain)

            deliver_valid = drain[:, PORT_L]
            deliver_flit = state.oreg[:, PORT_L, :]

            # neighbor push: the feeder gather reads halo-extended
            # drain decisions + output registers
            drain_ext = _with_halo(drain)                  # (R_ext, P)
            oreg_ext = _with_halo(state.oreg)              # (R_ext, P, F)
            recv_valid = has_feed & drain_ext.reshape(-1)[src_flat]
            recv_flit = jnp.where(
                recv_valid[:, :, None],
                oreg_ext.reshape(-1, N_FIELDS)[src_flat], 0)

            local_ready = state.count[:, PORT_L] < depth
            inj_ok = inject_valid & local_ready
            recv_valid = recv_valid.at[:, PORT_L].set(inj_ok)
            recv_flit = recv_flit.at[:, PORT_L].set(
                jnp.where(inj_ok[:, None], inject_flit, 0))

            # phase B: arbitration (row-local; dest ids are global, the
            # local route-table slice maps them to output ports)
            oreg_free = (~state.oreg_v) | drain
            out_port = route_l[r_idx[:, None], heads[:, :, F_DEST]]
            out_port = jnp.where(head_valid, out_port, NO_PORT)
            winner, pop, new_ptr, new_lock = arbiter_jnp(
                out_port, heads[:, :, F_BEAT], state.rr_ptr, oreg_free,
                state.lock_in)

            any_grant = winner >= 0
            flit_to_oreg = heads[r_idx[:, None], jnp.clip(winner, 0)]
            new_oreg_v = (state.oreg_v & ~drain) | any_grant
            new_oreg = jnp.where(any_grant[:, :, None], flit_to_oreg,
                                 state.oreg)

            D = state.fifo.shape[2]
            shifted = jnp.concatenate(
                [state.fifo[:, :, 1:, :],
                 jnp.zeros_like(state.fifo[:, :, :1, :])], axis=2)
            fifo = jnp.where(pop[:, :, None, None], shifted, state.fifo)
            count = state.count - pop.astype(jnp.int32)

            slot = jnp.clip(count, 0, D - 1)
            write = recv_valid & (count < depth)
            onehot_slot = jax.nn.one_hot(slot, D, dtype=jnp.bool_)
            sel = write[:, :, None] & onehot_slot
            fifo = jnp.where(sel[..., None], recv_flit[:, :, None, :],
                             fifo)
            count = count + write.astype(jnp.int32)

            new_state = NetState(fifo=fifo, count=count, rr_ptr=new_ptr,
                                 oreg=new_oreg, oreg_v=new_oreg_v,
                                 lock_in=new_lock)
            link_moves = jnp.sum(drain.astype(jnp.int32)
                                 * (jnp.arange(Pn)[None, :] != PORT_L))
            return (new_state, inj_ok, deliver_valid, deliver_flit,
                    link_moves)

        return jax.vmap(one, in_axes=(0, 0, 0, 0))

    # per-CLASS -> per-lane knob expansion, mirrored from _build_sim
    multi_stream = any(c.n_streams > 1 for c in spec.classes)
    cls_of = np.asarray(plan.cls_of_lane, np.int32)
    s_of = np.asarray(plan.stream_of_lane, np.int32)
    S_of = np.asarray([spec.classes[ci].n_streams
                       for ci in plan.cls_of_lane], np.int32)

    def to_lanes(service_lat, max_out, burst_beats, jitter):
        if not multi_stream:
            return service_lat, max_out, burst_beats, jitter
        mo_c = max_out[cls_of]
        mo = mo_c // S_of + (s_of < mo_c % S_of)
        return (service_lat[cls_of], mo, burst_beats[cls_of],
                jitter[cls_of])

    def sharded(times, dests, writes, service_lat, max_out, burst_beats,
                jitter, depths):
        # local shapes: times/dests/writes (n_lanes, R_l, T)
        net_step = _make_net_step(*_local_tables())
        step = make_step(spec, plan, T, net_step, shard=sh)
        state = SimState(_stacked_init(R_l, Pn)(n_ch, d_max),
                         init_ni(R_l, plan, spec.resp_q_cap),
                         jnp.int32(0), jnp.zeros((n_ch,), jnp.int32),
                         jnp.int32(0), jnp.int32(0),
                         jnp.zeros((n_ch, n_vcs_pol), jnp.int32),
                         jnp.zeros((n_ch, n_vcs_pol), jnp.int32), ())
        service_lat, max_out, burst_beats, jitter = to_lanes(
            service_lat, max_out, burst_beats, jitter)
        times_l = jnp.moveaxis(times, 0, 1)            # (R_l, n_lanes, T)
        dyn = {"times": times_l,
               "dests": jnp.moveaxis(dests, 0, 1),
               "writes": jnp.moveaxis(writes, 0, 1),
               "service_lat": service_lat, "max_out": max_out,
               "burst_beats": burst_beats, "jitter": jitter,
               "depths": jnp.asarray(depths, jnp.int32)}
        final, _ = lax.scan(functools.partial(step, dyn), state, None,
                            length=spec.cycles)
        ni = final.ni
        n_sched = jnp.sum(times_l < BIG, axis=2)
        drained = (jnp.all(ni.ptr >= n_sched) & jnp.all(ni.out_r == 0)
                   & jnp.all(ni.out_w == 0))
        # every leaf leaves with a leading gather axis: per-row arrays
        # concatenate back into global row order (shards are contiguous
        # strips); per-shard leaves stack to (n_shards, ...) and are
        # reduced host-side in run()
        return {
            "done": ni.done, "lat_sum": ni.lat_sum,
            "lat_max": ni.lat_max, "beats_rx": ni.beats_rx,
            "first_t": ni.first_t, "last_t": ni.last_t,
            "w_done": ni.w_done, "w_lat_sum": ni.w_lat_sum,
            "w_lat_max": ni.w_lat_max, "w_beats_rx": ni.w_beats_rx,
            "w_first_t": ni.w_first_t, "w_last_t": ni.w_last_t,
            "link_moves": final.moves[None],            # local partials
            "max_stall_cycles": final.max_stall[None],  # psum-replicated
            "drained": drained[None],                   # local verdicts
            "vc_occ_sum": final.vc_occ_sum[None],       # psum-replicated
            "vc_occ_max": final.vc_occ_max[None],
        }

    in_specs = ((P(None, ROW_AXIS),) * 3 + (P(),) * 5)
    smfn = jax.jit(shard_map(sharded, mesh=mesh, in_specs=in_specs,
                             out_specs=P(ROW_AXIS), check_rep=False))

    def run(times, dests, writes, service_lat, max_out, burst_beats,
            jitter, depths):
        raw = smfn(jnp.asarray(times), jnp.asarray(dests),
                   jnp.asarray(writes), jnp.asarray(service_lat),
                   jnp.asarray(max_out), jnp.asarray(burst_beats),
                   jnp.asarray(jitter), jnp.asarray(depths, jnp.int32))
        raw = {k: np.asarray(v) for k, v in raw.items()}
        # fold the per-shard leaves back to the single-device raw shape
        raw["link_moves"] = raw["link_moves"].sum(axis=0,
                                                  dtype=np.int32)
        raw["max_stall_cycles"] = raw["max_stall_cycles"][0]
        raw["drained"] = np.bool_(raw["drained"].all())
        raw["vc_occ_sum"] = raw["vc_occ_sum"][0]
        raw["vc_occ_max"] = raw["vc_occ_max"][0]
        return raw

    return run
