"""Replay ``repro.dist`` collective ledgers as NoC traffic.

This is the bridge between the repo's two halves: the traffic
*producer* (``repro.dist`` train/prefill/decode steps, whose
:class:`~repro.core.channels.Backend` seam records every cross-device
byte in a trace-time :class:`~repro.core.channels.Ledger`) and the
traffic *consumer* (the ``repro.noc`` cycle simulator).  A ledger entry
``(phase, op, axes, nbytes, traffic_class)`` is expanded into the link-
level transfers its collective actually performs on a group of ranks
(:data:`EXPANDERS`), the ranks are mapped onto mesh tiles, and the
transfers become timed per-class ``(times, dests, writes, streams)``
schedules — so "what does Llama-3 decode do to a 7x7 wide NoC" is
``simulate(spec, Workload.from_ledger(art.ledger, spec))``.

Ledger byte conventions (what the dist backend logs, reproduced here):

=================  =====================================================
op                 logged ``nbytes``
=================  =====================================================
``all_gather``     bytes *received* per rank, ``chunk * (n-1)``
``reduce_scatter`` bytes *sent* per rank over the ring, ``full*(n-1)/n``
``psum``/``pmax``  the full reduced tensor (all-reduce)
``ring_rs_ag``     the full tensor of the bucketed ring all-reduce
``all_to_all``     bytes *sent* per rank to the others, ``full*(n-1)/n``
other              treated as a point-to-point send of ``nbytes``
=================  =====================================================

Expansion algorithms: ``"ring"`` (default — ``n-1`` neighbor rounds for
AG/RS/A2A, ``2(n-1)`` for all-reduce = RS+AG) or
``"recursive_doubling"`` (``log2 n`` pairwise-exchange rounds; group
sizes must be powers of two).  Rounds serialize — round ``r+1``'s
transfers start after round ``r``'s longest sender has issued all its
bursts plus a latency slack — and ledger entries serialize after one
another (the trace is the step's sequential program order), with an
optional ``compute_ns`` gap between entries converted through
``cycle_time_ns``.

Rank -> tile mapping: ``mapping=None`` treats the whole mesh as one
group for every entry (all R tiles participate in each collective);
``mapping={"data": 2, "model": 4}`` lays the 8 ranks out row-major on
tiles 0..7, and an entry over ``("model",)`` runs 2 concurrent
4-rank groups (one per data index) — the axes a collective names select
which mesh dimensions it spans, exactly like ``shard_map``.

Multi-stream replay: each entry's transactions all ride ONE AXI ID
stream of their class, chosen round-robin per class
(``entry_counter % n_streams``) — consecutive collectives of a class
land on different AXI IDs, so with ``TrafficClass(n_streams>1)`` a slow
bulk collective no longer false-serializes the next one in the ROB
(the journal version's parallel multi-stream case).
"""
from __future__ import annotations

import math
from typing import Callable, Mapping, Sequence

import numpy as np

from .spec import NocSpec
from .workload import BIG, register_pattern

__all__ = ["EXPANDERS", "register_expander", "expand_collective",
           "ledger_schedules", "ledger_replay"]

# op name -> expander(n, nbytes, algorithm) -> rounds, each round a list
# of (src_local, dst_local, move_bytes) link moves within an n-rank group
EXPANDERS: dict[str, Callable] = {}


def register_expander(*ops: str):
    def deco(fn):
        for op in ops:
            EXPANDERS[op] = fn
        return fn
    return deco


def _chunk(nbytes: int, parts: int) -> int:
    return max(1, -(-int(nbytes) // parts))


def _check_pow2(n: int, op: str) -> int:
    k = n.bit_length() - 1
    if (1 << k) != n:
        raise ValueError(
            f"recursive_doubling expansion of {op!r} needs a power-of-two "
            f"group, got n={n}; use algorithm='ring'")
    return k


@register_expander("all_gather")
def _ag(n: int, nbytes: int, algorithm: str):
    # logged nbytes = chunk * (n-1) received per rank
    if algorithm == "ring":
        c = _chunk(nbytes, n - 1)
        return [[(i, (i + 1) % n, c) for i in range(n)]
                for _ in range(n - 1)]
    k = _check_pow2(n, "all_gather")
    c = _chunk(nbytes, n - 1)
    return [[(i, i ^ (1 << r), c * (1 << r)) for i in range(n)]
            for r in range(k)]


@register_expander("reduce_scatter")
def _rs(n: int, nbytes: int, algorithm: str):
    # logged nbytes = full * (n-1)/n sent per rank over the ring
    if algorithm == "ring":
        c = _chunk(nbytes, n - 1)
        return [[(i, (i + 1) % n, c) for i in range(n)]
                for _ in range(n - 1)]
    k = _check_pow2(n, "reduce_scatter")
    full = int(nbytes) * n // max(n - 1, 1)
    return [[(i, i ^ (1 << r), _chunk(full, 2 << r)) for i in range(n)]
            for r in range(k)]


@register_expander("psum", "pmax", "ring_rs_ag", "all_reduce")
def _ar(n: int, nbytes: int, algorithm: str):
    # logged nbytes = the full reduced tensor; ring all-reduce is
    # RS (n-1 rounds) then AG (n-1 rounds) of full/n chunks
    if algorithm == "ring":
        c = _chunk(nbytes, n)
        return [[(i, (i + 1) % n, c) for i in range(n)]
                for _ in range(2 * (n - 1))]
    k = _check_pow2(n, "all_reduce")
    return [[(i, i ^ (1 << r), int(nbytes)) for i in range(n)]
            for r in range(k)]


@register_expander("all_to_all")
def _a2a(n: int, nbytes: int, algorithm: str):
    # logged nbytes = full * (n-1)/n sent per rank; full exchange in
    # n-1 src-staggered rounds (rank i's round-r partner is i+1+r)
    c = _chunk(nbytes, n - 1)
    return [[(i, (i + 1 + r) % n, c) for i in range(n)]
            for r in range(n - 1)]


def _p2p(n: int, nbytes: int, algorithm: str):
    # fallback for ops without a registered expander (ppermute, pipeline
    # edges, halo sends): one neighbor hop of the logged bytes
    return [[(i, (i + 1) % n, int(nbytes)) for i in range(n)]]


def expand_collective(op: str, n: int, nbytes: int,
                      algorithm: str = "ring"):
    """Link moves of one collective over an ``n``-rank group: a list of
    rounds, each a list of ``(src_local, dst_local, move_bytes)``.
    Unregistered ops fall back to a point-to-point neighbor send."""
    if n <= 1 or nbytes <= 0:
        return []
    if algorithm not in ("ring", "recursive_doubling"):
        raise ValueError(
            f"unknown algorithm {algorithm!r}; have 'ring', "
            f"'recursive_doubling'")
    return EXPANDERS.get(op, _p2p)(int(n), int(nbytes), algorithm)


# --------------------------------------------------------------------- #
# rank -> tile mapping
# --------------------------------------------------------------------- #
def _norm_mapping(spec: NocSpec, mapping) -> tuple[tuple[str, int], ...]:
    if mapping is None:
        return ()
    items = (tuple(mapping.items()) if isinstance(mapping, Mapping)
             else tuple((str(a), int(s)) for a, s in mapping))
    if len({a for a, _ in items}) != len(items):
        raise ValueError(f"mapping has duplicate axes: {items}")
    total = math.prod(s for _, s in items) if items else 1
    if any(s < 1 for _, s in items) or total > spec.n_routers:
        raise ValueError(
            f"mapping {items} needs {total} tiles but the "
            f"{spec.nx}x{spec.ny} mesh has {spec.n_routers}")
    return items


def _groups(spec: NocSpec, mapping: tuple[tuple[str, int], ...],
            axes: tuple[str, ...]) -> list[list[int]]:
    """Tile groups one collective over ``axes`` runs on: ranks laid out
    row-major over the mapping's axis order, one group per combination
    of the non-collective axes."""
    if not mapping:
        return [list(range(spec.n_routers))]
    names = [a for a, _ in mapping]
    sizes = [s for _, s in mapping]
    for a in axes:
        if a not in names:
            raise ValueError(
                f"collective axis {a!r} not in mapping axes {names}; "
                f"pass mapping={{...}} covering every ledger axis")
    coll = [names.index(a) for a in axes]
    fixed = [i for i in range(len(names)) if i not in coll]
    grid = np.arange(math.prod(sizes)).reshape(sizes)
    # move collective axes last, flatten the fixed axes into groups
    perm = fixed + coll
    g = np.transpose(grid, perm).reshape(
        -1, math.prod(sizes[i] for i in coll) if coll else 1)
    return [list(map(int, row)) for row in g if len(row) > 1]


# --------------------------------------------------------------------- #
# schedule synthesis
# --------------------------------------------------------------------- #
def ledger_schedules(spec: NocSpec, entries: Sequence[tuple], *,
                     cycle_time_ns: float = 1.0, mapping=None,
                     algorithm: str = "ring", scale: float = 1.0,
                     as_writes: bool = True, compute_ns: float = 0.0,
                     start: int = 10, round_slack: int | None = None
                     ) -> dict[str, tuple]:
    """Convert ledger entries ``(phase, op, axes, nbytes, cls)`` into
    per-class ``(times, dests, writes, streams)`` schedule 4-tuples.

    ``scale`` multiplies every entry's bytes (shrink production-sized
    tensors to simulable burst counts); ``as_writes`` issues the
    transfers as AXI writes (AW/W/B — the DMA-push shape of PATRONoC
    traffic) instead of reads; ``compute_ns / cycle_time_ns`` cycles of
    compute separate consecutive entries; ``round_slack`` (default:
    class service latency + mesh diameter) pads each round for the
    in-flight tail before the next round's dependent transfers begin."""
    if cycle_time_ns <= 0:
        raise ValueError(f"cycle_time_ns must be > 0, got {cycle_time_ns}")
    if scale <= 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    mapping = _norm_mapping(spec, mapping)
    R = spec.n_routers
    rows: dict[str, list[list[tuple[int, int, int, int]]]] = {
        c.name: [[] for _ in range(R)] for c in spec.classes}
    counters = {c.name: 0 for c in spec.classes}
    gap_cycles = max(0, int(round(float(compute_ns) / cycle_time_ns)))
    now = int(start)
    for e in entries:
        phase, op, axes, nbytes, cls_name = e[0], e[1], tuple(e[2]), \
            int(e[3]), e[4]
        spec.class_index(cls_name)      # typed against declared classes
        cls = spec.get_class(cls_name)
        nbytes = max(1, int(round(nbytes * scale))) if nbytes > 0 else 0
        groups = _groups(spec, mapping, axes)
        if not groups or nbytes <= 0:
            continue
        stream = counters[cls_name] % cls.n_streams
        counters[cls_name] += 1
        burst_bytes = max(1, cls.burst_beats * cls.payload_bits // 8)
        gap = cls.burst_beats
        sl = (spec.service_lat if cls.service_lat is None
              else cls.service_lat)
        slack = (sl + spec.nx + spec.ny if round_slack is None
                 else int(round_slack))
        # every group of this entry has the same size, so one expansion
        # serves all of them (groups differ only in their tile sets)
        rounds = expand_collective(op, len(groups[0]), nbytes, algorithm)
        wr = 1 if as_writes else 0
        for moves in rounds:
            round_txns = 0
            for src_l, dst_l, mbytes in moves:
                txns = -(-int(mbytes) // burst_bytes)
                round_txns = max(round_txns, txns)
                for g in groups:
                    src, dst = g[src_l], g[dst_l]
                    if src == dst:
                        continue
                    r = rows[cls_name][src]
                    for j in range(txns):
                        r.append((now + j * gap, dst, wr, stream))
            now += round_txns * gap + slack
        now += gap_cycles
    out = {}
    for c in spec.classes:
        rr = rows[c.name]
        T = max(1, max(len(r) for r in rr))
        t = np.full((R, T), BIG, np.int32)
        d = np.zeros((R, T), np.int32)
        w = np.zeros((R, T), np.int32)
        s = np.zeros((R, T), np.int32)
        for src, r in enumerate(rr):
            r.sort(key=lambda m: m[0])
            for j, (tt, dd, ww, ss) in enumerate(r):
                t[src, j], d[src, j], w[src, j], s[src, j] = tt, dd, ww, ss
        out[c.name] = (t, d, w, s)
    return out


@register_pattern("ledger_replay")
def ledger_replay(spec: NocSpec, *, entries: Sequence[tuple] = (),
                  cycle_time_ns: float = 1.0, mapping=(),
                  algorithm: str = "ring", scale: float = 1.0,
                  as_writes: bool = True, compute_ns: float = 0.0,
                  start: int = 10, round_slack: int | None = None) -> dict:
    """The :class:`~repro.noc.workload.Workload` pattern behind
    :meth:`Workload.from_ledger` — parameters as frozen tuples so replay
    workloads hash/sweep like any other pattern."""
    return ledger_schedules(
        spec, entries, cycle_time_ns=cycle_time_ns,
        mapping=tuple(mapping) or None, algorithm=algorithm, scale=scale,
        as_writes=as_writes, compute_ns=compute_ns, start=start,
        round_slack=round_slack)
