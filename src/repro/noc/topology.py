"""First-class physical fabric topologies.

A :class:`Topology` describes the router graph one physical channel is
instantiated on: how many routers, how many ports per router, which
router each output port links to, and which output port a flit bound
for ``dest`` takes at every router.  Everything is reduced to three
static tables consumed by the cycle engine
(:func:`repro.core.noc_sim.router.make_fabric_step`):

* ``nbr[r, p]``   — neighbor router reached by output port ``p`` of
  router ``r`` (``-1``: no link; the local/NI port is always the last
  port index),
* ``opp[r, p]``   — the *input* port on that neighbor the link feeds,
* ``route[r, d]`` — the output port a flit for destination ``d`` takes
  at router ``r`` (deterministic, so AXI-style in-order delivery holds
  per source/destination pair).

Topologies are frozen/hashable — they live inside a
:class:`~repro.noc.spec.NocSpec` and key the cached jitted simulator.

Provided fabrics:

* :class:`Mesh`  — the paper's 2D mesh with XY dimension-ordered
  routing; ``express=(s, ...)`` adds express links of stride ``s`` in
  both dimensions (>5-port routers), with greedy largest-stride-first
  dimension-ordered routing (never overshoots, still deterministic),
* :class:`Torus` — 2D torus with minimal-wrap dimension-ordered
  routing (ties break to the positive direction).  Under the default
  VC-less routing policy the wrap links can deadlock under sustained
  wormhole bursts, like any real VC-less torus; give the spec a
  ``RoutingPolicy`` with ``n_vcs >= 2`` (:mod:`repro.noc.routing`) to
  run the dateline/escape-VC discipline that makes the torus
  deadlock-free.

:func:`validate_tables` is the reusable structural check (termination,
duplex links, local-port-last) every table set goes through — the base
topologies here and the expanded multi-plane/VC table sets
:mod:`repro.noc.routing` generates.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Union

import numpy as np

# direction order within each stride group (matches the seed's
# N,E,S,W,Local port convention; Local is always the last port)
_DIRS = ((0, -1), (1, 0), (0, 1), (-1, 0))      # N, E, S, W as (dx, dy)
_OPP_DIR = (2, 3, 0, 1)                         # N<->S, E<->W


def _check_dims(nx: int, ny: int) -> None:
    if nx < 1 or ny < 1:
        raise ValueError(f"mesh dims must be >= 1, got {nx}x{ny}")
    if nx * ny < 2:
        raise ValueError("topology needs at least 2 routers")


@dataclass(frozen=True)
class Mesh:
    """2D mesh, XY routing; ``express`` strides add >5-port routers."""
    nx: int
    ny: int
    express: tuple[int, ...] = ()

    def __post_init__(self):
        _check_dims(self.nx, self.ny)
        ex = tuple(self.express)
        object.__setattr__(self, "express", ex)
        for s in ex:
            if not 2 <= s < max(self.nx, self.ny):
                raise ValueError(
                    f"express stride {s} invalid for {self.nx}x{self.ny} "
                    f"mesh (need 2 <= stride < max dim)")
        if len(set(ex)) != len(ex):
            raise ValueError("duplicate express strides")

    @property
    def n_routers(self) -> int:
        return self.nx * self.ny

    @property
    def strides(self) -> tuple[int, ...]:
        """Link strides, base mesh first then ascending express."""
        return (1, *sorted(self.express))

    @property
    def n_ports(self) -> int:
        return 4 * len(self.strides) + 1

    def tables(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return _mesh_tables(self)

    def hops(self) -> np.ndarray:
        return hop_table(self)


@dataclass(frozen=True)
class Torus(Mesh):
    """2D torus: wrap-around links, minimal-wrap dimension-ordered
    routing. Express links are not supported on the torus."""

    def __post_init__(self):
        super().__post_init__()
        if self.express:
            raise ValueError("Torus does not support express links")

    def tables(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return _torus_tables(self)


Topology = Union[Mesh, Torus]


def _port(stride_idx: int, d: int) -> int:
    return 4 * stride_idx + d


@functools.lru_cache(maxsize=64)
def _mesh_tables(topo: Mesh):
    nx, ny, strides = topo.nx, topo.ny, topo.strides
    R, P = topo.n_routers, topo.n_ports
    x = np.arange(R) % nx
    y = np.arange(R) // nx
    nbr = np.full((R, P), -1, np.int64)
    opp = np.full((R, P), P - 1, np.int64)
    for si, s in enumerate(strides):
        for d, (dx, dy) in enumerate(_DIRS):
            tx, ty = x + dx * s, y + dy * s
            ok = (0 <= tx) & (tx < nx) & (0 <= ty) & (ty < ny)
            p = _port(si, d)
            nbr[ok, p] = (ty * nx + tx)[ok]
            opp[ok, p] = _port(si, _OPP_DIR[d])

    # dimension-ordered: largest stride <= remaining distance first
    # (never overshoots, so it also never leaves the mesh); strides is
    # sorted ascending, so searchsorted finds that stride per pair
    sarr = np.asarray(strides)
    dxm = x[None, :] - x[:, None]                    # (src, dest)
    dym = y[None, :] - y[:, None]
    si_x = np.maximum(np.searchsorted(sarr, np.abs(dxm), "right") - 1, 0)
    si_y = np.maximum(np.searchsorted(sarr, np.abs(dym), "right") - 1, 0)
    px = 4 * si_x + np.where(dxm > 0, 1, 3)          # E / W
    py = 4 * si_y + np.where(dym > 0, 2, 0)          # S / N (E->S, W->N)
    route = np.where(dxm != 0, px,
                     np.where(dym != 0, py, P - 1))  # default: local port
    return _freeze_tables(nbr, opp, route)


def _wrap_delta(a: np.ndarray, b: np.ndarray, size: int) -> np.ndarray:
    """Signed minimal wrap distance a -> b on a ring (ties positive)."""
    d = (b - a) % size
    return np.where(d <= size - d, d, d - size)


@functools.lru_cache(maxsize=64)
def _torus_tables(topo: Torus):
    nx, ny = topo.nx, topo.ny
    R, P = topo.n_routers, topo.n_ports
    x = np.arange(R) % nx
    y = np.arange(R) // nx
    nbr = np.full((R, P), -1, np.int64)
    opp = np.full((R, P), P - 1, np.int64)
    for d, (dx, dy) in enumerate(_DIRS):
        # dims of size 1 have no ring; leave those ports unwired
        if (dx and nx == 1) or (dy and ny == 1):
            continue
        tx, ty = (x + dx) % nx, (y + dy) % ny
        nbr[:, d] = ty * nx + tx
        opp[:, d] = _OPP_DIR[d]

    # wrap deltas have only nx*nx / ny*ny distinct values: compute the
    # small per-coordinate tables once and gather, instead of running
    # int64 modulo over the full (R, R) matrices
    # the deltas only feed sign/zero tests, so int16 is exact
    wx = _wrap_delta(np.arange(nx)[:, None], np.arange(nx)[None, :],
                     nx).astype(np.int16)
    wy = _wrap_delta(np.arange(ny)[:, None], np.arange(ny)[None, :],
                     ny).astype(np.int16)
    dxm = wx[x[:, None], x[None, :]]                 # (src, dest)
    dym = wy[y[:, None], y[None, :]]
    px = np.where(dxm > 0, 1, 3)                     # E / W
    py = np.where(dym > 0, 2, 0)                     # S / N
    route = np.where(dxm != 0, px,
                     np.where(dym != 0, py, P - 1))
    return _freeze_tables(nbr, opp, route)


def _freeze_tables(nbr: np.ndarray, opp: np.ndarray, route: np.ndarray):
    """Validate then mark read-only: the tables are cached and shared
    with every caller, so a mutation would corrupt all later sims."""
    validate_tables(nbr, opp, route)
    for a in (nbr, opp, route):
        a.setflags(write=False)
    return nbr, opp, route


# ordered names of the structural table checks run_table_checks runs;
# repro.noc.analyze reports each as its own named lint check
TABLE_CHECKS = ("no_port_sentinel", "local_port", "duplex_links",
                "route_structure", "route_termination")


def run_table_checks(nbr: np.ndarray, opp: np.ndarray,
                     route: np.ndarray):
    """Named, individually-reportable structural checks over one fabric
    table set — the checks :func:`validate_tables` has always enforced,
    exposed per-name so :mod:`repro.noc.analyze` can report them in an
    ``AnalysisReport`` instead of a single opaque raise.

    Accepts any table set shaped like the fabric's contract — the base
    topologies' ``(R, R)`` route tables and the multi-plane/VC-expanded
    ``(R, n_planes*R)`` sets :mod:`repro.noc.routing` generates, where
    column ``j`` addresses destination router ``j % R``.

    Returns ``(results, hops)``: ``results`` is a list of ``(name,
    error-message-or-None, coords)`` tuples in :data:`TABLE_CHECKS`
    order, stopping after the first failing check (later checks would
    index with the very values the failed one proved invalid); ``hops``
    is the ``(R, n_dest)`` route-walk hop-count table, or ``None`` when
    any check failed.  A route table whose column count is not a
    multiple of ``R`` is malformed input, not a lintable property, and
    raises immediately.
    """
    R, P = nbr.shape
    n_dest = route.shape[1]
    if n_dest % R:
        raise ValueError(
            f"route table has {n_dest} destination columns, not a "
            f"multiple of {R} routers")
    results: list[tuple[str, str | None, tuple]] = []

    def fail(name: str, msg: str, coords: tuple = ()):
        results.append((name, msg, coords))
        return results, None

    if P >= 99:
        return fail("no_port_sentinel",
                    f"{P} ports collides with the NO-ROUTE sentinel (99)")
    results.append(("no_port_sentinel", None, ()))

    if np.any(nbr[:, P - 1] >= 0):
        r = int(np.argwhere(nbr[:, P - 1] >= 0)[0][0])
        return fail("local_port",
                    "local port (last index) must not carry a link",
                    (r, P - 1))
    results.append(("local_port", None, ()))

    t = nbr[:, :P - 1]
    wired = t >= 0
    back = nbr[np.where(wired, t, 0), opp[:, :P - 1]]
    nondup = wired & (back != np.arange(R)[:, None])
    if np.any(nondup):
        r, p = map(int, np.argwhere(nondup)[0])
        return fail("duplex_links", f"link {r}:{p} is not duplex", (r, p))
    results.append(("duplex_links", None, ()))

    # broadcast views, never materialized: (R, n_dest) row / dest-router
    # indices (n_dest can be n_planes*R for VC-expanded tables)
    rr = np.broadcast_to(np.arange(R, dtype=np.int32)[:, None], (R, n_dest))
    dd = np.broadcast_to(np.arange(n_dest, dtype=np.int32)[None, :] % R,
                         (R, n_dest))
    off_diag = rr != dd
    oob = (route < 0) | (route > P - 1)
    if np.any(oob):
        r, d = map(int, np.argwhere(oob)[0])
        return fail("route_structure",
                    f"route entry {r}:{d} is not a port index "
                    f"(got {int(route[r, d])}, have {P} ports)", (r, d))
    is_local = route == P - 1
    bad = ~is_local & ~off_diag
    if np.any(bad):
        r, d = map(int, np.argwhere(bad)[0])
        return fail("route_structure",
                    "route to self must use the local port", (r, d))
    bad = is_local & off_diag
    if np.any(bad):
        r, d = map(int, np.argwhere(bad)[0])
        return fail("route_structure",
                    "route reaches the local port before the "
                    "destination router", (r, d))
    step0 = nbr[rr, np.where(off_diag, route, 0)]   # first hop per pair
    missing = off_diag & (step0 < 0)
    if np.any(missing):
        r, d = map(int, np.argwhere(missing)[0])
        return fail("route_structure", "route uses a missing link", (r, d))
    results.append(("route_structure", None, ()))

    # pointer doubling over the one-hop successor map (absorbing at the
    # destination): after k squarings ``cur`` has advanced 2^k hops, so
    # ceil(log2(R)) rounds cover every terminating walk (a terminating
    # walk never revisits a router, hence takes < R hops) in O(log R)
    # passes instead of one pass per hop.  ``hops`` accumulates exact
    # walk lengths because the absorbed destination contributes zero.
    # the walk runs dest-major (transposed): column j's successor map
    # only indexes within column j, so after the transpose every
    # pointer-doubling gather stays inside one contiguous row instead
    # of striding the whole matrix
    curT = np.where(off_diag, step0, rr).astype(np.int32).T
    curT = np.ascontiguousarray(curT)                 # (n_dest, R)
    ddT, hopsT = dd.T, off_diag.T.astype(np.int32, order="C")
    for _ in range(int(np.ceil(np.log2(max(2, R)))) + 1):
        if np.array_equal(curT, ddT):
            break
        hopsT = hopsT + np.take_along_axis(hopsT, curT, axis=1)
        curT = np.take_along_axis(curT, curT, axis=1)
    hops = hopsT.T.astype(np.int64, order="C")
    if np.any(curT != ddT):
        r, d = map(int, np.argwhere((curT != ddT).T)[0])
        return fail("route_termination", "routing does not terminate",
                    (r, d))
    results.append(("route_termination", None, ()))
    return results, hops


def validate_tables(nbr: np.ndarray, opp: np.ndarray,
                    route: np.ndarray) -> np.ndarray:
    """Structural invariants every fabric table set must satisfy (real
    raises, not asserts — these guard simulation correctness under
    ``-O`` too: a port index reaching the arbiter's NO-ROUTE sentinel
    would make valid heads silently never granted).  The checks
    themselves live in :func:`run_table_checks`; this wrapper raises
    ``ValueError`` on the first failure and returns the ``(R, n_dest)``
    hop-count table on success (which also proves every route
    terminates — no livelock)."""
    results, hops = run_table_checks(nbr, opp, route)
    for _name, err, _coords in results:
        if err:
            raise ValueError(err)
    return hops


@functools.lru_cache(maxsize=64)
def hop_table(topo: Topology) -> np.ndarray:
    """(R, R) hop counts along each deterministic route (0 on the
    diagonal). Also proves every route terminates (no livelock)."""
    nbr, opp, route = topo.tables()
    hops = validate_tables(nbr, opp, route)
    hops.setflags(write=False)           # cached + shared with callers
    return hops
