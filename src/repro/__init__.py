"""FlooNoC-JAX: a multi-pod JAX training/serving framework built on
FlooNoC's narrow-wide, endpoint-ordered, dimension-routed NoC principles."""
__version__ = "0.1.0"
