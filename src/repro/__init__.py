"""FlooNoC-JAX: a multi-pod JAX training/serving framework built on
FlooNoC's narrow-wide, endpoint-ordered, dimension-routed NoC principles."""
from . import _jax_compat  # noqa: F401  (backfills renamed JAX entry points)

__version__ = "0.1.0"
