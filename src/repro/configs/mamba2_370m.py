"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality). [arXiv:2405.21060; unverified]
d_inner = 2*d_model, 32 SSD heads of dim 64, conv width 4.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    d_inner=2048,
    conv_width=4,
    ssd_chunk=256,
    mlp_act="swiglu",
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
)
