"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
[arXiv:2212.04356; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    num_encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu",
    norm="layernorm",
    pos_emb="learned",
    context_len=4096,          # stub audio-frame context (matched to shape.seq_len)
    tie_embeddings=True,
)
