"""Config system: frozen dataclasses for model / shape / mesh / run configs.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` with the exact published numbers. Reduced ("smoke") variants
are derived via :meth:`ModelConfig.smoke` for CPU tests; the full configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters.

    ``family`` selects the model builder in ``models/registry.py``:
      dense | moe | ssm | hybrid | vlm | audio
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- attention flavour ---
    sliding_window: int = 0            # 0 = full attention
    global_layers: tuple[int, ...] = ()  # layers that stay full-attn when SWA
    cross_attn_layers: tuple[int, ...] = ()  # VLM image cross-attention layers
    num_encoder_layers: int = 0        # enc-dec (audio) encoder depth
    context_len: int = 0               # stub-frontend context length (vlm/audio)

    # --- misc ---
    mlp_act: str = "swiglu"            # swiglu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    pos_emb: str = "rope"              # rope | learned | none
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid-SWA archs)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_enc_dec(self) -> bool:
        return self.num_encoder_layers > 0

    def param_count(self) -> int:
        """Total parameter count (closed form, matches the model builders)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n_embed = V * d * (1 if self.tie_embeddings else 2)
        per_layer = self._params_per_layer()
        enc = self.num_encoder_layers * self._params_per_layer(encoder=True)
        return n_embed + L * per_layer + enc + d  # + final norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n_embed = V * d * (1 if self.tie_embeddings else 2)
        attn = self._attn_params()
        ff_active = self._ff_params() * (
            (self.top_k + (1 if self.shared_expert else 0)) / max(self.num_experts, 1)
        ) * self.num_experts / (self.top_k + (1 if self.shared_expert else 0)) \
            if False else self._ff_params()  # per-expert params
        active_ff = ff_active * (self.top_k + (1 if self.shared_expert else 0))
        router = d * self.num_experts
        norms = 2 * d
        return n_embed + L * (attn + active_ff + router + norms) + d

    # -- internals ------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ff_params(self) -> int:
        d = self.d_model
        if self.d_ff == 0:
            return 0
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * d * self.d_ff

    def _ssm_params(self) -> int:
        if self.ssm_state == 0:
            return 0
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        in_proj = d * (2 * di + 2 * ns + nh)   # x, z, B, C, dt
        conv = (di + 2 * ns) * self.conv_width
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * nh  # + A_log, D

    def _params_per_layer(self, encoder: bool = False) -> int:
        norms = 2 * self.d_model
        if self.family == "ssm":
            return self._ssm_params() + norms
        attn = self._attn_params()
        ff = self._ff_params()
        if self.family == "moe" and not encoder:
            ff = ff * self.num_experts + (self._ff_params() if self.shared_expert else 0)
            ff += self.d_model * self.num_experts  # router
        if self.family == "hybrid":
            return attn + self._ssm_params() + ff + norms + self.d_model
        if self.family == "vlm" and not encoder:
            # cross-attn layers add one extra attention + norm
            frac = len(self.cross_attn_layers) / max(self.num_layers, 1)
            return int(attn + ff + norms + frac * (self._attn_params() + self.d_model))
        if self.is_enc_dec and not encoder:
            return 2 * attn + ff + 3 * self.d_model  # self + cross attn
        return attn + ff + norms

    # ------------------------------------------------------------------
    def smoke(self, **overrides: Any) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        base = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_head_dim=32 if self.ssm_heads else 64,
            d_inner=128 if self.d_inner else 0,
            ssd_chunk=32,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            global_layers=tuple(g for g in self.global_layers if g < 2),
            cross_attn_layers=(1,) if self.cross_attn_layers else (),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            context_len=min(self.context_len, 32) if self.context_len else 0,
            name=self.name + "-smoke",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh. Axes are ordered (pod?, data, model)."""

    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.pod, self.data, self.model) if self.pod > 1 else (self.data, self.model)

    @property
    def num_devices(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp_axes(self) -> tuple[str, ...]:
        """Axes that carry data parallelism (batch + grad reduction)."""
        return ("pod", "data") if self.pod > 1 else ("data",)


@dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs for one run / dry-run cell."""

    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)

    backend: str = "floo"          # floo | xla  (collective backend)
    use_sp: bool = True            # sequence parallelism for norms/residuals
    microbatches: int = 1          # gradient accumulation steps
    remat: str = "layer"           # none | layer | full
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_dtype: str = "bfloat16"   # dtype for cross-replica grad reduction
    optimizer: str = "adamw"
    opt_state_bits: int = 32       # 32 or 8 (block-quantized m/v)
    grad_compression: str = "none" # none | int8-pod (error-feedback int8 on pod axis)
    wide_flit_bytes: int = 65536   # narrow/wide traffic classification threshold
    collective_chunks: int = 1     # chunked/windowed wide transfers (NI window)
    bidir_rings: bool = False      # use both ring directions (duplex links)
    overlap_matmul: bool = False   # wormhole-pipelined collective matmuls
    param_sharding: str = "fsdp"   # fsdp | replicated (over the data axis)
    flat_dp: bool = False          # collapse TP: whole mesh is DP + FSDP
                                   # (small archs; see EXPERIMENTS §Perf)

    @property
    def tp_size(self) -> int:
        """Effective tensor-parallel degree (model axis role)."""
        return 1 if self.flat_dp else self.mesh.model

    @property
    def dp_axes_eff(self) -> tuple[str, ...]:
        """Axes carrying batch shards (includes 'model' under flat_dp)."""
        return self.mesh.dp_axes + (("model",) if self.flat_dp else ())

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        """Axes FSDP-sharding the parameters (dim-ordered for ring gathers)."""
        if self.param_sharding != "fsdp":
            return ()
        return ("model", "data") if self.flat_dp else ("data",)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    seed: int = 0

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def pretty(cfg: Any) -> str:
    if dataclasses.is_dataclass(cfg):
        d: Mapping[str, Any] = dataclasses.asdict(cfg)
        return "\n".join(f"  {k}: {v}" for k, v in d.items())
    return str(cfg)
