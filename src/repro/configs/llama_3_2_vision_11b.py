"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attn image layers every 5th layer (8 of 40).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (B, context_len, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_layers=(3, 8, 13, 18, 23, 28, 33, 38),
    context_len=1024,          # stub image-patch tokens
    mlp_act="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=500_000.0,
)
