"""Assigned input-shape cells (same four for every LM-family arch).

``long_500k`` lowers only for sub-quadratic archs (SSM / hybrid); the pure
full-attention archs record a documented SKIP (see DESIGN.md §5).
"""
from __future__ import annotations

from .base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; reason if not."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for full-attention arch"
    return True, ""


def cells(model: ModelConfig) -> list[ShapeConfig]:
    return [s for s in SHAPES.values() if applicable(model, s)[0]]
