"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.

[hf:xai-org/grok-1; unverified]
Trained/served with 8-bit optimizer states in this framework so one v5e pod
fits the optimizer (see DESIGN.md §7).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    top_k=2,
    capacity_factor=1.25,
    mlp_act="swiglu",  # grok-1 uses a gated 3-matrix MLP; yields ~314B total
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=10_000.0,
    logit_softcap=30.0,
)
