"""Architecture registry: ``--arch <id>`` ids map to ModelConfigs here."""
from __future__ import annotations

from .base import MeshConfig, ModelConfig, RunConfig, ShapeConfig
from .shapes import SHAPES, applicable, cells

from .llama_3_2_vision_11b import CONFIG as LLAMA_3_2_VISION_11B
from .mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from .llama3_2_1b import CONFIG as LLAMA3_2_1B
from .starcoder2_15b import CONFIG as STARCODER2_15B
from .llama3_2_3b import CONFIG as LLAMA3_2_3B
from .whisper_tiny import CONFIG as WHISPER_TINY
from .mamba2_370m import CONFIG as MAMBA2_370M
from .llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT_17B_A16E
from .grok_1_314b import CONFIG as GROK_1_314B
from .hymba_1_5b import CONFIG as HYMBA_1_5B

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        LLAMA_3_2_VISION_11B,
        MISTRAL_NEMO_12B,
        LLAMA3_2_1B,
        STARCODER2_15B,
        LLAMA3_2_3B,
        WHISPER_TINY,
        MAMBA2_370M,
        LLAMA4_SCOUT_17B_A16E,
        GROK_1_314B,
        HYMBA_1_5B,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "MeshConfig",
    "ModelConfig",
    "RunConfig",
    "ShapeConfig",
    "applicable",
    "cells",
    "get_arch",
    "get_shape",
]
