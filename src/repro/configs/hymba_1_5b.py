"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attn+mamba heads within each block.

[arXiv:2411.13676; hf]
Sliding-window attention everywhere except 3 global layers (first/middle/last),
making the arch sub-quadratic and eligible for long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    d_inner=1600,
    conv_width=4,
    ssd_chunk=256,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    mlp_act="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=10_000.0,
)
