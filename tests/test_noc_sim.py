"""NoC simulator: paper-claim validation at test scale."""
import numpy as np
import pytest

from repro.core.noc_sim import (PAPER, PAPER_CLAIMS, SimConfig, fig5_traffic,
                                run_sim)


def test_zero_load_latency_matches_paper():
    cfg = SimConfig(nx=2, ny=1, cycles=200, narrow_wide=True, service_lat=10)
    tr = fig5_traffic(cfg, num_narrow=1, num_wide=0, narrow_rate=0.01,
                      src=0, dst=1)
    m = run_sim(cfg, tr)
    assert int(m["narrow_done"][0]) == 1
    assert float(m["narrow_avg_lat"][0]) == \
        PAPER_CLAIMS["zero_load_round_trip_cycles"]


def test_all_transactions_complete():
    cfg = SimConfig(nx=4, ny=4, cycles=6000)
    tr = fig5_traffic(cfg, num_narrow=100, num_wide=32, wide_rate=1.0,
                      narrow_rate=0.05, src=0, dst=15)
    m = run_sim(cfg, tr)
    assert int(m["narrow_done"][0]) == 100
    assert int(m["wide_done"][0]) == 32
    assert int(m["wide_beats_rx"][0]) == 32 * cfg.burstlen


def test_narrow_wide_isolation():
    """Fig 5a core claim: narrow latency flat under wide interference."""
    lat = {}
    for rate in (0.0, 1.0):
        cfg = SimConfig(nx=4, ny=4, cycles=8000, narrow_wide=True,
                        service_lat=10)
        tr = fig5_traffic(cfg, num_narrow=100, num_wide=128 if rate else 0,
                          wide_rate=rate, narrow_rate=0.05, src=0, dst=15,
                          bidir=True)
        lat[rate] = float(run_sim(cfg, tr)["narrow_avg_lat"][0])
    assert lat[1.0] / lat[0.0] < 1.1, lat


def test_wide_only_degrades():
    """Fig 5a ablation: shared link degrades narrow latency >= 2x."""
    lat = {}
    for rate in (0.0, 1.0):
        cfg = SimConfig(nx=4, ny=4, cycles=8000, narrow_wide=False,
                        service_lat=10)
        tr = fig5_traffic(cfg, num_narrow=100, num_wide=128 if rate else 0,
                          wide_rate=rate, narrow_rate=0.05, src=0, dst=15,
                          bidir=True)
        lat[rate] = float(run_sim(cfg, tr)["narrow_avg_lat"][0])
    assert lat[1.0] / lat[0.0] > 2.0, lat


def test_wide_bandwidth_robust_with_separation():
    utils = []
    for nrate in (0.0, 1.0):
        cfg = SimConfig(nx=4, ny=4, cycles=6000, narrow_wide=True,
                        service_lat=10)
        tr = fig5_traffic(cfg, num_narrow=2000 if nrate else 0, num_wide=128,
                          wide_rate=1.0, narrow_rate=nrate, src=0, dst=5)
        utils.append(float(run_sim(cfg, tr)["wide_eff_bw"][0]))
    assert utils[1] >= 0.85 * utils[0], utils
    assert utils[1] >= PAPER_CLAIMS["eff_bandwidth_utilization"], utils


def test_rob_flow_control_limits_outstanding():
    """End-to-end flow control: wide txns never exceed the ROB budget."""
    cfg = SimConfig(nx=2, ny=2, cycles=2000, max_wide_outstanding=2)
    tr = fig5_traffic(cfg, num_narrow=0, num_wide=64, wide_rate=1.0,
                      src=0, dst=3)
    m = run_sim(cfg, tr)
    assert int(m["wide_done"][0]) == 64     # all complete despite tiny ROB


def test_analytic_model_matches_paper_numbers():
    assert abs(PAPER.wide_link_gbps() - 629) < 2
    assert abs(PAPER.wide_link_duplex_tbps() - 1.26) < 0.01
    assert abs(PAPER.mesh_boundary_bandwidth_tbs(7, 7) - 4.4) < 0.1
    assert abs(PAPER.noc_area_fraction() - 0.10) < 0.001
    assert abs(PAPER.energy_pj(1024, 1) - 198) < 5
