"""NoC simulator: paper-claim validation at test scale.

Seed-era claims, expressed through the declarative ``repro.noc`` API
(the legacy config/runner surface these tests used to drive was
migrated off and deleted).
"""
from repro.core.noc_sim import PAPER, PAPER_CLAIMS
from repro.noc import NocSpec, Workload, simulate


def _fig5(rates, counts, **kw):
    return Workload.make("fig5", rates=rates, counts=counts, **kw)


def test_zero_load_latency_matches_paper():
    spec = NocSpec.narrow_wide(2, 1, cycles=200)
    m = simulate(spec, _fig5({"narrow": 0.01}, {"narrow": 1}, src=0, dst=1))
    assert int(m.classes["narrow"].done[0]) == 1
    assert float(m.classes["narrow"].avg_lat[0]) == \
        PAPER_CLAIMS["zero_load_round_trip_cycles"]


def test_all_transactions_complete():
    spec = NocSpec.narrow_wide(4, 4, cycles=6000)
    m = simulate(spec, _fig5({"narrow": 0.05, "wide": 1.0},
                             {"narrow": 100, "wide": 32}, src=0, dst=15))
    assert int(m.classes["narrow"].done[0]) == 100
    assert int(m.classes["wide"].done[0]) == 32
    assert int(m.classes["wide"].beats_rx[0]) == 32 * spec.burstlen


def test_narrow_wide_isolation():
    """Fig 5a core claim: narrow latency flat under wide interference."""
    lat = {}
    for rate in (0.0, 1.0):
        spec = NocSpec.narrow_wide(4, 4, cycles=8000)
        m = simulate(spec, _fig5(
            {"narrow": 0.05, "wide": rate},
            {"narrow": 100, "wide": 128 if rate else 0},
            src=0, dst=15, bidir=True))
        lat[rate] = float(m.classes["narrow"].avg_lat[0])
    assert lat[1.0] / lat[0.0] < 1.1, lat


def test_wide_only_degrades():
    """Fig 5a ablation: shared link degrades narrow latency >= 2x."""
    lat = {}
    for rate in (0.0, 1.0):
        spec = NocSpec.wide_only(4, 4, cycles=8000)
        m = simulate(spec, _fig5(
            {"narrow": 0.05, "wide": rate},
            {"narrow": 100, "wide": 128 if rate else 0},
            src=0, dst=15, bidir=True))
        lat[rate] = float(m.classes["narrow"].avg_lat[0])
    assert lat[1.0] / lat[0.0] > 2.0, lat


def test_wide_bandwidth_robust_with_separation():
    utils = []
    for nrate in (0.0, 1.0):
        spec = NocSpec.narrow_wide(4, 4, cycles=6000)
        m = simulate(spec, _fig5(
            {"narrow": nrate, "wide": 1.0},
            {"narrow": 2000 if nrate else 0, "wide": 128}, src=0, dst=5))
        utils.append(float(m.classes["wide"].eff_bw[0]))
    assert utils[1] >= 0.85 * utils[0], utils
    assert utils[1] >= PAPER_CLAIMS["eff_bandwidth_utilization"], utils


def test_rob_flow_control_limits_outstanding():
    """End-to-end flow control: wide txns never exceed the ROB budget."""
    spec = NocSpec.narrow_wide(2, 2, cycles=2000, max_wide_outstanding=2)
    m = simulate(spec, _fig5({"wide": 1.0}, {"wide": 64}, src=0, dst=3))
    assert int(m.classes["wide"].done[0]) == 64  # all complete, tiny ROB


def test_analytic_model_matches_paper_numbers():
    assert abs(PAPER.wide_link_gbps() - 629) < 2
    assert abs(PAPER.wide_link_duplex_tbps() - 1.26) < 0.01
    assert abs(PAPER.mesh_boundary_bandwidth_tbs(7, 7) - 4.4) < 0.1
    assert abs(PAPER.noc_area_fraction() - 0.10) < 0.001
    assert abs(PAPER.energy_pj(1024, 1) - 198) < 5
