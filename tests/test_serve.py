"""Serving engine: generation shapes, greedy determinism, EOS handling."""
import numpy as np
import pytest

from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig

from repro.serve import Engine


@pytest.fixture(scope="module")
def engine():
    mcfg = get_arch("llama3.2-1b").smoke(num_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256)
    cfg = RunConfig(model=mcfg, shape=ShapeConfig("s", 32, 4, "prefill"),
                    mesh=MeshConfig(1, 1, 1))
    e = Engine(cfg, max_len=64)
    e.init_params()
    return e


def test_generate_shapes_and_determinism(engine):
    prompts = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % 256
    a = engine.generate(prompts, max_new_tokens=6, greedy=True)
    b = engine.generate(prompts, max_new_tokens=6, greedy=True)
    assert a.tokens.shape == (2, 6)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.min() >= 0 and a.tokens.max() < 256


def test_sampled_generation_runs(engine):
    prompts = np.ones((2, 8), np.int32)
    out = engine.generate(prompts, max_new_tokens=4, greedy=False,
                          temperature=0.7, seed=3)
    assert out.tokens.shape == (2, 4)


def test_decode_matches_teacher_forcing(engine):
    """Greedy continuation must re-produce prefill's next-token argmax."""
    prompts = (np.arange(2 * 12, dtype=np.int32).reshape(2, 12) * 7) % 256
    out = engine.generate(prompts, max_new_tokens=3, greedy=True)
    ext = np.concatenate([prompts, out.tokens[:, :1]], axis=1)
    out2 = engine.generate(ext, max_new_tokens=2, greedy=True)
    np.testing.assert_array_equal(out.tokens[:, 1:3], out2.tokens[:, :2])
