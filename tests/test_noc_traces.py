"""Traffic replay (ledger -> NoC) and per-stream AXI IDs (PR 7).

Covers the two halves of the trace subsystem: collective expansion /
schedule synthesis (``repro.noc.traces``), and the multi-stream lane
machinery it feeds (``TrafficClass.n_streams``) — including the
acceptance end-to-end: a REAL ``build_decode_step`` ledger replayed on
a 7x7 mesh in one ``Workload.from_ledger`` call, the
false-serialization regression (two AXI ID streams drain a blocked
write queue measurably earlier than one at equal total credits), and
flit-for-flit backend equivalence on streamed traffic.
"""
import dataclasses

import numpy as np
import pytest

from conftest import given, settings, st
from repro.core.channels import Ledger, LedgerEntry
from repro.noc import (NocSpec, Torus, Workload, build_flow_plan,
                       expand_collective, ledger_schedules, simulate,
                       simulate_schedules, stack_schedules)
from repro.noc.workload import BIG


def _streamed(spec: NocSpec, **n_streams: int) -> NocSpec:
    """Copy of ``spec`` with per-class ``n_streams`` overridden."""
    return spec.with_(classes=tuple(
        dataclasses.replace(c, n_streams=n_streams.get(c.name, c.n_streams))
        for c in spec.classes))


def _empty_row(R):
    return (np.full((R, 1), BIG, np.int32), np.zeros((R, 1), np.int32),
            np.zeros((R, 1), np.int32))


# --------------------------------------------------------------------- #
# collective expanders
# --------------------------------------------------------------------- #
def test_ring_expanders_round_counts_and_bytes():
    # all_gather logs chunk*(n-1) received per rank: n-1 neighbor rounds
    rounds = expand_collective("all_gather", 4, 3000, "ring")
    assert len(rounds) == 3
    for moves in rounds:
        assert sorted((s, d) for s, d, _ in moves) == \
            [(0, 1), (1, 2), (2, 3), (3, 0)]
        assert all(b == 1000 for _, _, b in moves)
    # every rank receives the logged bytes in total
    rx = {i: 0 for i in range(4)}
    for moves in rounds:
        for _, d, b in moves:
            rx[d] += b
    assert all(v == 3000 for v in rx.values())

    # all-reduce = RS + AG over full/n chunks: 2(n-1) rounds
    rounds = expand_collective("psum", 4, 4000, "ring")
    assert len(rounds) == 6
    assert all(b == 1000 for moves in rounds for _, _, b in moves)

    # all_to_all: n-1 staggered rounds, each rank meets every other once
    rounds = expand_collective("all_to_all", 5, 4000, "ring")
    assert len(rounds) == 4
    partners = {i: set() for i in range(5)}
    for moves in rounds:
        for s, d, _ in moves:
            assert s != d
            partners[s].add(d)
    assert all(p == set(range(5)) - {i} for i, p in partners.items())


def test_recursive_doubling_rounds_and_pow2_guard():
    rounds = expand_collective("psum", 8, 512, "recursive_doubling")
    assert len(rounds) == 3                       # log2(8) exchanges
    for r, moves in enumerate(rounds):
        for s, d, b in moves:
            assert d == s ^ (1 << r) and b == 512
    with pytest.raises(ValueError, match="power-of-two"):
        expand_collective("all_gather", 6, 512, "recursive_doubling")


def test_expander_edge_cases():
    assert expand_collective("psum", 1, 4096) == []       # degenerate group
    assert expand_collective("psum", 4, 0) == []          # zero bytes
    # unregistered ops fall back to one point-to-point neighbor round
    rounds = expand_collective("ppermute", 3, 700)
    assert rounds == [[(0, 1, 700), (1, 2, 700), (2, 0, 700)]]
    with pytest.raises(ValueError, match="unknown algorithm"):
        expand_collective("psum", 4, 64, "butterfly")


# --------------------------------------------------------------------- #
# ledger -> schedules: mapping, streams, validation
# --------------------------------------------------------------------- #
def test_mapping_groups_confine_collectives():
    """mapping={'data':2,'model':2}: a ('model',) collective runs as two
    concurrent 2-rank groups {0,1} and {2,3}; other tiles stay idle."""
    spec = NocSpec.narrow_wide(4, 4)
    sch = ledger_schedules(
        spec, [("fwd", "all_gather", ("model",), 3000, "wide")],
        mapping={"data": 2, "model": 2})
    t, d, w, s = sch["wide"]
    active = np.unique(np.nonzero(t < BIG)[0])
    np.testing.assert_array_equal(active, [0, 1, 2, 3])
    pair = {0: 1, 1: 0, 2: 3, 3: 2}               # ring on a 2-rank group
    for src in active:
        np.testing.assert_array_equal(d[src][t[src] < BIG], pair[src])
    assert np.all(w[t < BIG] == 1)                # as_writes default


def test_mapping_validation():
    spec = NocSpec.narrow_wide(2, 2)
    with pytest.raises(ValueError, match="not in mapping axes"):
        ledger_schedules(spec, [("fwd", "psum", ("pod",), 64, "narrow")],
                         mapping={"data": 2, "model": 2})
    with pytest.raises(ValueError, match="needs .* tiles"):
        ledger_schedules(spec, [], mapping={"data": 8, "model": 2})
    with pytest.raises(KeyError):
        ledger_schedules(spec, [("fwd", "psum", (), 64, "hbm")])


def test_ledger_entries_round_robin_streams():
    """Consecutive same-class entries alternate AXI ID streams."""
    spec = _streamed(NocSpec.narrow_wide(2, 2), wide=2)
    entries = [("fwd", "ppermute", (), 100, "wide"),
               ("fwd", "ppermute", (), 100, "wide"),
               ("fwd", "ppermute", (), 100, "wide")]
    t, d, w, s = ledger_schedules(spec, entries)["wide"]
    # each entry = 1 txn/src (100 B < one burst); columns are time-sorted
    assert t.shape == (4, 3)
    np.testing.assert_array_equal(s[:, 0], 0)
    np.testing.assert_array_equal(s[:, 1], 1)
    np.testing.assert_array_equal(s[:, 2], 0)
    assert np.all(np.diff(t, axis=1) > 0)         # entries serialize


def test_ledger_schedules_compute_gap_and_scale():
    spec = NocSpec.narrow_wide(2, 2)
    e = [("fwd", "ppermute", (), 2048, "wide"),
         ("fwd", "ppermute", (), 2048, "wide")]
    base = ledger_schedules(spec, e)["wide"][0]
    gapped = ledger_schedules(spec, e, compute_ns=100.0,
                              cycle_time_ns=2.0)["wide"][0]
    # entry 2's first burst (col 2) slips by 100 ns / 2 ns-per-cycle
    assert gapped[0, 2] - base[0, 2] == 50
    scaled = ledger_schedules(spec, e, scale=0.25)["wide"][0]
    assert (scaled[0] < BIG).sum() < (base[0] < BIG).sum()  # fewer bursts
    with pytest.raises(ValueError, match="scale"):
        ledger_schedules(spec, e, scale=0.0)


# --------------------------------------------------------------------- #
# Ledger JSON round-trip (satellite: commit-and-replay)
# --------------------------------------------------------------------- #
_entry_st = st.builds(
    LedgerEntry,
    st.sampled_from(["fwd", "bwd", "opt"]),
    st.sampled_from(["psum", "pmax", "all_gather", "reduce_scatter",
                     "all_to_all", "ring_rs_ag", "sendrecv"]),
    st.lists(st.sampled_from(["data", "model", "pod"]), max_size=3),
    st.integers(min_value=0, max_value=1 << 42),
    st.sampled_from(["narrow", "wide"]),
    st.text(max_size=16),
)


@given(st.lists(_entry_st, max_size=8), st.sampled_from(["fwd", "bwd"]))
@settings(max_examples=60, deadline=None)
def test_ledger_json_roundtrip(entries, phase):
    led = Ledger(entries=[dataclasses.replace(e, axes=tuple(e.axes))
                          for e in entries], phase=phase)
    back = Ledger.from_json(led.to_json())
    assert back == led
    assert all(isinstance(e.axes, tuple) for e in back.entries)


# --------------------------------------------------------------------- #
# stack_schedules: 3- vs 4-tuple compatibility
# --------------------------------------------------------------------- #
def test_stack_schedules_deals_three_tuples_round_robin():
    spec = _streamed(NocSpec.narrow_wide(2, 2), wide=2)
    R = spec.n_routers
    t = np.full((R, 4), BIG, np.int32)
    t[0] = [10, 20, 30, 40]
    d = np.full((R, 4), 3, np.int32)
    sched = {"wide": (t, d), "narrow": _empty_row(R)}
    times, dests, writes = stack_schedules(spec, sched)
    assert times.shape[0] == 3                    # narrow + 2 wide lanes
    np.testing.assert_array_equal(times[1, 0, :2], [10, 30])  # stream 0
    np.testing.assert_array_equal(times[2, 0, :2], [20, 40])  # stream 1


def test_stack_schedules_explicit_streams_and_validation():
    spec = _streamed(NocSpec.narrow_wide(2, 2), wide=2)
    R = spec.n_routers
    t = np.full((R, 3), BIG, np.int32)
    t[1] = [5, 6, 7]
    d = np.zeros((R, 3), np.int32)
    w = np.zeros((R, 3), np.int32)
    s = np.zeros((R, 3), np.int32)
    s[1] = [1, 1, 0]
    times, _, _ = stack_schedules(
        spec, {"wide": (t, d, w, s), "narrow": _empty_row(R)})
    np.testing.assert_array_equal(times[1, 1, :1], [7])       # stream 0
    np.testing.assert_array_equal(times[2, 1, :2], [5, 6])    # stream 1
    s[1, 0] = 2                                   # out of range for S=2
    with pytest.raises(ValueError, match="stream ids"):
        stack_schedules(spec, {"wide": (t, d, w, s),
                               "narrow": _empty_row(R)})


def test_flow_plan_lane_expansion():
    spec = _streamed(NocSpec.narrow_wide(4, 4), wide=2)
    plan = build_flow_plan(spec)
    assert plan.n_cls == 3                        # lanes, class-major
    assert plan.cls_of_lane == (0, 1, 1)
    assert plan.stream_of_lane == (0, 0, 1)
    # single-stream spec keeps the pre-stream plan exactly
    p1 = build_flow_plan(NocSpec.narrow_wide(4, 4))
    assert p1.n_cls == 2 and p1.stream_of_lane == (0, 0)


def test_spec_rejects_bad_n_streams():
    for bad in (0, -1, 9, True, 2.0):
        with pytest.raises((ValueError, TypeError)):
            _streamed(NocSpec.narrow_wide(2, 2), wide=bad)


# --------------------------------------------------------------------- #
# n_streams=1 bit-identity and per-stream stats
# --------------------------------------------------------------------- #
def _mixed_sched(R):
    rng = np.random.default_rng(11)
    T = 6
    t = np.sort(rng.integers(5, 60, (R, T)).astype(np.int32), axis=1)
    d = rng.integers(0, R, (R, T)).astype(np.int32)
    d = np.where(d == np.arange(R)[:, None], (d + 1) % R, d)
    w = rng.integers(0, 2, (R, T)).astype(np.int32)
    s = rng.integers(0, 2, (R, T)).astype(np.int32)
    return t, d, w, s


def test_single_stream_ignores_stream_column():
    """On an n_streams=1 class the stream column collapses onto the one
    AXI ID: the 4-tuple runs bit-identical to the 3-tuple."""
    spec = NocSpec.narrow_wide(4, 4, cycles=1500)
    R = spec.n_routers
    t, d, w, s = _mixed_sched(R)
    a = simulate_schedules(spec, {"wide": (t, d, w, s),
                                  "narrow": _empty_row(R)})
    b = simulate_schedules(spec, {"wide": (t, d, w),
                                  "narrow": _empty_row(R)})
    _assert_results_equal(a, b)
    assert a.classes["wide"].stream_done.shape == (1, R)


def test_per_stream_stats_partition_class_totals():
    spec = _streamed(NocSpec.narrow_wide(4, 4, cycles=2000), wide=2)
    R = spec.n_routers
    res = simulate_schedules(spec, {"wide": _mixed_sched(R),
                                    "narrow": _empty_row(R)})
    c = res.classes["wide"]
    assert c.stream_done.shape == (2, R)
    np.testing.assert_array_equal(c.stream_done.sum(0), c.done)
    np.testing.assert_array_equal(c.stream_w_done.sum(0), c.w_done)
    np.testing.assert_array_equal(c.stream_max_lat.max(0), c.max_lat)
    np.testing.assert_array_equal(c.stream_w_max_lat.max(0), c.w_max_lat)
    assert bool(res.drained)


# --------------------------------------------------------------------- #
# the false-serialization regression (acceptance)
# --------------------------------------------------------------------- #
def _hol_blocking_result(n_streams: int):
    """One NI issues 30 reads to a far hotspot (slow: response
    serialization at the target), then 20 writes to a near neighbor.
    With one AXI ID the shared in-order issue pointer stalls the writes
    behind the read ROB; with two IDs the writes drain on their own
    credits while the reads are still in flight."""
    spec = _streamed(NocSpec.narrow_wide(4, 4, cycles=3000),
                     wide=n_streams)
    R = spec.n_routers
    T = 50
    t = np.full((R, T), BIG, np.int32)
    d = np.zeros((R, T), np.int32)
    w = np.zeros((R, T), np.int32)
    s = np.zeros((R, T), np.int32)
    t[0, :30], d[0, :30], w[0, :30], s[0, :30] = 10, 15, 0, 0   # reads
    t[0, 30:], d[0, 30:], w[0, 30:], s[0, 30:] = 11, 1, 1, 1    # writes
    return simulate_schedules(spec, {"wide": (t, d, w, s),
                                     "narrow": _empty_row(R)})


def test_two_streams_beat_one_at_equal_total_credits():
    one = _hol_blocking_result(1).classes["wide"]
    two = _hol_blocking_result(2).classes["wide"]
    # both runs drain the same transactions
    np.testing.assert_array_equal(one.done, two.done)
    np.testing.assert_array_equal(one.w_done, two.w_done)
    assert int(one.done.sum()) == 30 and int(one.w_done.sum()) == 20
    # the read stream is untouched by the split ...
    assert int(one.stream_last_t.max()) == int(two.stream_last_t.max())
    # ... but the writes land dramatically earlier on their own AXI ID
    w1 = int(one.stream_w_last_t.max())
    w2 = int(two.stream_w_last_t.max())
    assert w2 < 0.6 * w1, (w1, w2)


# --------------------------------------------------------------------- #
# backend equivalence on streamed traffic (acceptance)
# --------------------------------------------------------------------- #
def _assert_results_equal(a, b):
    for cname in a.classes:
        for f in ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw",
                  "w_done", "w_avg_lat", "w_max_lat", "w_beats_rx",
                  "w_eff_bw", "stream_done", "stream_avg_lat",
                  "stream_max_lat", "stream_last_t", "stream_w_done",
                  "stream_w_avg_lat", "stream_w_max_lat",
                  "stream_w_last_t"):
            np.testing.assert_array_equal(
                getattr(a.classes[cname], f), getattr(b.classes[cname], f),
                err_msg=f"{cname}.{f}")
    for ch in a.channels:
        np.testing.assert_array_equal(a.channels[ch].link_moves,
                                      b.channels[ch].link_moves)
    np.testing.assert_array_equal(a.max_stall_cycles, b.max_stall_cycles)
    np.testing.assert_array_equal(a.drained, b.drained)


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("case", ["mesh", "torus"])
def test_backends_agree_on_streamed_traffic(case, backend):
    """Stream identity rides the fabric-opaque flit kind: every backend
    stays flit-for-flit identical on mixed multi-stream traffic."""
    if case == "mesh":
        spec = _streamed(NocSpec.narrow_wide(4, 4, cycles=1500),
                         narrow=2, wide=2)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.3, "wide": 0.8},
                           counts={"narrow": 10, "wide": 5}, seed=3,
                           write_frac=0.5)
    else:
        spec = _streamed(NocSpec.wide_only(3, 3, topology=Torus(3, 3),
                                           cycles=1200), wide=2)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.2, "wide": 0.5},
                           counts={"narrow": 8, "wide": 4}, seed=5,
                           write_frac=0.6)
    ref = simulate(spec, wl)
    assert ref.classes["wide"].stream_done.shape[0] == 2
    _assert_results_equal(ref, simulate(spec, wl, backend=backend))


# --------------------------------------------------------------------- #
# end-to-end: a real decode step's ledger on a 7x7 mesh (acceptance)
# --------------------------------------------------------------------- #
_DECODE_REPLAY = """
import jax, numpy as np
from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.models import build_model
from repro.dist import step as step_lib
from repro.noc import NocSpec, Workload, simulate
from repro.noc.workload import BIG

mcfg = get_arch("llama3.2-1b").smoke()
mesh_cfg = MeshConfig(data=2, model=2, pod=1)
cfg = RunConfig(model=mcfg, shape=ShapeConfig("p", 32, 4, "prefill"),
                mesh=mesh_cfg)
mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names,
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
model = build_model(mcfg, cfg)
art = step_lib.build_decode_step(model, ShapeConfig("d", 64, 4, "decode"),
                                 mesh)
art.fn.lower(*art.in_sds)         # trace time populates the ledger
assert len(art.ledger.entries) > 0
assert any(e.op == "all_gather" and "model" in e.axes
           for e in art.ledger.entries), art.ledger.summary()

spec = NocSpec.narrow_wide(7, 7)
wl = Workload.from_ledger(art.ledger, spec)     # the one-call experiment
res = simulate(spec, wl)
for name, c in res.classes.items():
    assert int(c.w_done.sum()) > 0, name        # real traffic landed
    print("CLASS", name, int(c.done.sum()), int(c.w_done.sum()))

# schedule checksum so the parent can verify commit-and-replay parity
for name, (t, d, w, s) in sorted(wl.schedules(spec).items()):
    v = t < BIG
    print("SUM", name, int(v.sum()), int(t[v].sum()), int(d[v].sum()),
          int(s[v].sum()))

# the job's own 2x2 rank grid mapped onto a corner of the mesh
r2 = simulate(spec, Workload.from_ledger(
    art.ledger, spec, mapping={"data": 2, "model": 2}))
assert all(int(c.done.sum() + c.w_done.sum()) > 0
           for c in r2.classes.values())
print("LEDGER_JSON", art.ledger.to_json())
"""


def test_decode_ledger_replays_on_7x7_mesh(subproc):
    """ISSUE 7 acceptance: Workload.from_ledger(artifact.ledger, spec)
    runs end-to-end — real build_decode_step trace to SimResult on a
    7x7 mesh — and the committed-JSON replay reproduces the exact same
    schedules without re-tracing the step."""
    out = subproc(_DECODE_REPLAY, n_devices=4)
    lines = dict()
    sums = {}
    for ln in out.splitlines():
        if ln.startswith("SUM "):
            _, name, *vals = ln.split()
            sums[name] = tuple(int(v) for v in vals)
        elif ln.startswith("LEDGER_JSON "):
            lines["json"] = ln[len("LEDGER_JSON "):]
    assert sums and "json" in lines, out

    # replay from the committed JSON, no jax tracing in this process
    led = Ledger.from_json(lines["json"])
    assert len(led.entries) > 0
    spec = NocSpec.narrow_wide(7, 7)
    sch = Workload.from_ledger(led, spec).schedules(spec)
    for name, (t, d, w, s) in sch.items():
        v = t < BIG
        assert sums[name] == (int(v.sum()), int(t[v].sum()),
                              int(d[v].sum()), int(s[v].sum()))


def test_from_ledger_workloads_hash_and_compare():
    """Replay workloads are frozen like any pattern: equal ledgers give
    equal (hashable, sweepable) workloads."""
    led = Ledger()
    led.log("all_gather", ("model",), 4096, "wide")
    led.log("psum", ("data", "model"), 256, "narrow")
    led2 = Ledger.from_json(led.to_json())
    spec = NocSpec.narrow_wide(4, 4)
    a = Workload.from_ledger(led, spec)
    b = Workload.from_ledger(led2, spec)
    assert a == b and hash(a) == hash(b)
    assert a != Workload.from_ledger(led, spec, scale=0.5)
