"""Per-arch smoke tests: reduced configs, one train step on CPU, finite loss,
and prefill/decode consistency for representative archs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig

from repro.dist import params as params_lib, step as step_lib
from repro.models import build_model

MESH = None


def mesh():
    global MESH
    if MESH is None:
        MESH = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return MESH


def make_batch(mcfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, mcfg.vocab_size,
                                          jnp.int32),
             "labels": jax.random.randint(key, (B, S), 0, mcfg.vocab_size,
                                          jnp.int32)}
    if mcfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, mcfg.context_len, mcfg.d_model), jnp.bfloat16)
    if mcfg.is_enc_dec:
        batch["frames"] = jax.random.normal(key, (B, S, mcfg.d_model),
                                            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    mcfg = ARCHS[arch].smoke()
    S, B = 32, 2
    shape = ShapeConfig("t", S, B, "train")
    cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1))
    model = build_model(mcfg, cfg)
    art = step_lib.build_train_step(model, shape, mesh())
    key = jax.random.key(0)
    params = params_lib.materialize_sharded(art.param_specs, key, mesh())
    opt = params_lib.materialize_sharded(art.opt_specs, key, mesh())
    batch = make_batch(mcfg, B, S, jax.random.key(7))
    p2, o2, m = art.fn(params, opt, jnp.int32(0), batch)
    assert np.isfinite(float(m["loss"])), m
    assert float(m["loss"]) > 0
    # output shapes match input specs
    for (a, b) in zip(jax.tree.leaves(p2), jax.tree.leaves(params_lib.tree_sds(
            art.param_specs))):
        assert a.shape == b.shape and a.dtype == b.dtype


def _pad_cache(caches, S_new):
    def pad(seg):
        out = {}
        for k, v in seg.items():
            if k == "attn":
                out[k] = tuple(jnp.pad(
                    a, ((0, 0), (0, 0), (0, S_new - a.shape[2]), (0, 0),
                        (0, 0))) for a in v)
            else:
                out[k] = v
        return out
    return {n: pad(s) for n, s in caches.items()}


DECODE_ARCHS = ["llama3.2-1b", "starcoder2-15b", "mamba2-370m",
                "hymba-1.5b", "llama4-scout-17b-a16e",
                "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(S), token_S) must equal prefill(S+1) last logits."""
    mcfg = ARCHS[arch].smoke()
    S, B = 32, 2
    cfg = RunConfig(model=mcfg, shape=ShapeConfig("p", S, B, "prefill"),
                    mesh=MeshConfig(1, 1, 1))
    model = build_model(mcfg, cfg)
    pre = step_lib.build_prefill_step(model, ShapeConfig("p", S, B, "prefill"),
                                      mesh())
    dec = step_lib.build_decode_step(
        model, ShapeConfig("d", S + 1, B, "decode"), mesh(), split_kv=False)
    key = jax.random.key(3)
    params = params_lib.materialize_sharded(pre.param_specs, key, mesh())
    toks = jax.random.randint(key, (B, S + 1), 0, mcfg.vocab_size, jnp.int32)
    pb = {"tokens": toks[:, :S]}
    if mcfg.family == "vlm":
        pb["image_embeds"] = jax.random.normal(
            key, (B, mcfg.context_len, mcfg.d_model), jnp.bfloat16)
    logits_p, caches = pre.fn(params, pb)
    caches = _pad_cache(caches, S + 1)
    logits_d, _ = dec.fn(params, caches, toks[:, S:S + 1], jnp.int32(S))

    pre2 = step_lib.build_prefill_step(
        model, ShapeConfig("p2", S + 1, B, "prefill"), mesh())
    pb2 = dict(pb, tokens=toks)
    logits_ref, _ = pre2.fn(params, pb2)
    a = np.asarray(logits_d, np.float32)
    b = np.asarray(logits_ref, np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
    assert rel < 0.05, rel
