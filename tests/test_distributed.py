"""Multi-device SPMD correctness (subprocess with 8 host devices).

The invariant throughout: ANY mesh factorization must produce the same loss
and the same global gradient norm as the single-device run — this is what
makes the sharding rules + collective schedules trustworthy at 256/512
chips where we can only dry-run.
"""

COMMON = """
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.models import build_model
from repro.dist import step as step_lib, params as params_lib

def run(mesh_cfg, arch="llama3.2-1b", smoke_kw=None, **kw):
    mcfg = get_arch(arch).smoke(**(smoke_kw or {}))
    shape = ShapeConfig("t", 32, 4, "train")
    cfg = RunConfig(model=mcfg, shape=shape, mesh=mesh_cfg, **kw)
    mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(mesh_cfg.shape))
    model = build_model(mcfg, cfg)
    art = step_lib.build_train_step(model, shape, mesh)
    key = jax.random.key(0)
    params = params_lib.materialize_sharded(art.param_specs, key, mesh)
    opt = params_lib.materialize_sharded(art.opt_specs, key, mesh)
    kb = jax.random.key(7)
    batch = {"tokens": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32),
             "labels": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32)}
    if mcfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(kb, (4, mcfg.context_len, mcfg.d_model), jnp.bfloat16)
    if mcfg.is_enc_dec:
        batch["frames"] = jax.random.normal(kb, (4, 32, mcfg.d_model), jnp.bfloat16)
    _, _, m = art.fn(params, opt, jnp.int32(0), batch)
    return float(m["loss"]), float(m["grad_norm"])

def check(arch="llama3.2-1b", meshes=None, tol=2e-2, gtol=7e-2, smoke_kw=None, **kw):
    base_l, base_g = run(MeshConfig(1, 1, 1), arch, smoke_kw)
    for mc in meshes:
        l, g = run(mc, arch, smoke_kw, **kw)
        assert abs(l - base_l) < tol, (arch, mc, l, base_l)
        assert abs(g - base_g) / max(base_g, 1e-6) < gtol, (arch, mc, g, base_g)
    print("PASS", arch)
"""


def test_dense_all_axes(subproc):
    subproc(COMMON + """
check("llama3.2-1b", meshes=[MeshConfig(2,1,1), MeshConfig(1,2,1),
                             MeshConfig(2,2,1), MeshConfig(2,2,2)])
""")


def test_moe_ep(subproc):
    subproc(COMMON + """
check("llama4-scout-17b-a16e", meshes=[MeshConfig(2,2,1)], gtol=0.1)
""")


def test_moe_tp_path(subproc):
    # 3 experts on a 2-wide model axis forces the TP-MoE path
    subproc(COMMON + """
check("grok-1-314b", meshes=[MeshConfig(2,2,1)], gtol=0.1,
      smoke_kw={"num_experts": 3, "top_k": 2})
""")


def test_ssm_and_hybrid(subproc):
    subproc(COMMON + """
check("mamba2-370m", meshes=[MeshConfig(2,2,1)], gtol=0.1)
check("hymba-1.5b", meshes=[MeshConfig(2,2,1)], gtol=0.1)
""")


def test_xla_backend_parity(subproc):
    subproc(COMMON + """
l1, g1 = run(MeshConfig(2,2,1), backend="floo")
l2, g2 = run(MeshConfig(2,2,1), backend="xla")
assert abs(l1 - l2) < 1e-2, (l1, l2)
assert abs(g1 - g2) / max(g1, 1e-6) < 5e-2, (g1, g2)
print("PASS parity")
""")


def test_bidir_and_compression(subproc):
    subproc(COMMON + """
base_l, base_g = run(MeshConfig(1,1,1))
l, g = run(MeshConfig(2, 2, 2), bidir_rings=True)
assert abs(l - base_l) < 2e-2
l2, g2 = run(MeshConfig(2, 2, 2), grad_compression="int8-pod")
assert abs(l2 - base_l) < 3e-2              # int8 grads: loss unchanged
assert abs(g2 - base_g)/base_g < 0.15       # grad norm approx (quantized)
print("PASS bidir+compression")
""")


def test_decode_split_kv_parity(subproc):
    """split-KV decode over the data axis == batch-sharded decode."""
    subproc(COMMON + """
from jax.sharding import NamedSharding
arch = "hymba-1.5b"
mcfg = get_arch(arch).smoke()
S, B = 32, 1
key = jax.random.key(3)
toks = jax.random.randint(key, (B, S+1), 0, mcfg.vocab_size, jnp.int32)

# single-device reference: prefill(S) -> caches, and prefill(S+1) last logits
mesh1_cfg = MeshConfig(1, 1, 1)
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg1 = RunConfig(model=mcfg, shape=ShapeConfig("p", S, B, "prefill"), mesh=mesh1_cfg)
model1 = build_model(mcfg, cfg1)
pre1 = step_lib.build_prefill_step(model1, ShapeConfig("p", S, B, "prefill"), mesh1)
params1 = params_lib.materialize_sharded(pre1.param_specs, key, mesh1)
_, caches = pre1.fn(params1, {"tokens": toks[:, :S]})
pre1b = step_lib.build_prefill_step(model1, ShapeConfig("p2", S+1, B, "prefill"), mesh1)
logits_ref, _ = pre1b.fn(params1, {"tokens": toks})

# split-KV decode on (data=2, model=2): cache seq sharded over data
mesh_cfg = MeshConfig(data=2, model=2, pod=1)
mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names,
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = RunConfig(model=mcfg, shape=ShapeConfig("p", S, B, "prefill"), mesh=mesh_cfg)
model = build_model(mcfg, cfg)
dshape = ShapeConfig("d", S + 32, B, "decode")
dec_split = step_lib.build_decode_step(model, dshape, mesh, split_kv=True)
params = params_lib.materialize_sharded(dec_split.param_specs, key, mesh)
sds, specs = model.cache_specs(dshape, split_kv=True)

def to_split(pref, sds_tree, spec_tree):
    out = {}
    for name, seg in pref.items():
        o = {}
        for k, v in seg.items():
            if k == "attn":
                tgt, sp = sds_tree[name][k], spec_tree[name][k]
                # single-device n_kv may differ (dedup): slice/pad head dim2
                def fit(a, t, s):
                    a = jnp.pad(a, ((0,0),(0,0),(0, t.shape[2]-a.shape[2]),
                                    (0,0),(0,0)))
                    if a.shape[3] != t.shape[3]:
                        reps = t.shape[3] // a.shape[3]
                        a = jnp.tile(a, (1,1,1,reps,1))
                    return jax.device_put(a, NamedSharding(mesh, s))
                o[k] = tuple(fit(a, t, s) for a, t, s in zip(v, tgt, sp))
            else:
                sp = spec_tree[name][k]
                o[k] = jax.tree.map(
                    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                    v, sp)
        out[name] = o
    return out

# NOTE: single-device caches store n_kv=hkv heads; the 2-way-TP layout
# stores plan.n_kv_loc per rank with rank-dependent head selection, so a
# faithful transfer requires the per-rank gather. At smoke scale
# (model=2, hkv=2) the layouts coincide: n_kv_loc=1 per rank == heads
# split across ranks == hkv stacked.
caches_split = to_split(caches, sds, specs)
logits_d, _ = dec_split.fn(params, caches_split, toks[:, S:S+1], jnp.int32(S))
a = np.asarray(jnp.reshape(logits_d, -1), np.float32)
b = np.asarray(jnp.reshape(logits_ref, -1), np.float32)
rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
assert rel < 0.06, rel
print("PASS split_kv", rel)
""")
