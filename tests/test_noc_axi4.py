"""AXI4 flow-model tests: write transactions (AW/W/B) through every
layer, golden bit-identity for read-only presets, per-class service
latency distributions, and stall/deadlock observability.

The goldens were captured from the pre-AXI4 read-only engine (commit
4fcff85) on fixed workloads: the five-flow refactor must leave every
read-only preset flit-for-flit identical — W rings and AW/B flows that
never carry traffic must not perturb arbitration, ring order, or
round-robin state.
"""
import numpy as np
import pytest

from conftest import given, settings, st

from repro.core.flit import (AXI_FLOWS, N_FLOWS, flow_kind, kind_class,
                             kind_flow)
from repro.noc import (Mesh, NocSpec, Torus, TrafficClass, Workload,
                       build_flow_plan, hop_table, simulate,
                       simulate_batch)
from repro.noc.workload import _freeze, _mix_writes, _thaw

BIG = 1 << 30


# --------------------------------------------------------------------- #
# flow vocabulary
# --------------------------------------------------------------------- #
def test_flow_kind_round_trips():
    for i in range(4):
        for f in AXI_FLOWS:
            k = flow_kind(i, f)
            assert kind_class(k) == i and kind_flow(k) == f
    # class 0's read kinds keep the legacy req/rsp tag values
    assert flow_kind(0, "ar") == 0 and flow_kind(0, "r") == 1
    assert N_FLOWS == 5


def test_paper_flow_mapping():
    """ISSUE/paper mapping: AW/AR/B on the narrow channels, W/R on the
    wide one (wide class); the narrow class rides narrow end-to-end."""
    spec = NocSpec.narrow_wide()
    ch = {f: spec.channels[spec.flow_channel("wide", f)].name
          for f in AXI_FLOWS}
    assert ch == {"ar": "req", "aw": "req", "b": "rsp",
                  "w": "wide", "r": "wide"}
    nch = {f: spec.channels[spec.flow_channel("narrow", f)].name
           for f in AXI_FLOWS}
    assert nch == {"ar": "req", "aw": "req", "w": "req",
                   "r": "rsp", "b": "rsp"}


def test_legacy_class_map_expands():
    """Two-flow maps keep working: req -> AR+AW, rsp -> R+B, W joins R
    on the class's data channel."""
    spec = NocSpec(class_map=(("narrow.req", "req"), ("narrow.rsp", "rsp"),
                              ("wide.req", "req"), ("wide.rsp", "wide")))
    assert spec.flow_map["narrow.aw"] == "req"
    assert spec.flow_map["narrow.b"] == "rsp"
    assert spec.flow_map["narrow.w"] == "rsp"     # data channel
    assert spec.flow_map["wide.w"] == "wide"
    assert spec.flow_map["wide.b"] == "wide"
    # explicit five-flow entries win over the expansion default
    spec2 = NocSpec(class_map=(("narrow.req", "req"), ("narrow.rsp", "rsp"),
                               ("narrow.w", "req"),
                               ("wide.req", "req"), ("wide.rsp", "wide"),
                               ("wide.b", "rsp")))
    assert spec2.flow_map["narrow.w"] == "req"
    assert spec2.flow_map["wide.b"] == "rsp"


def test_flow_plan_rings():
    """Response rings stay channel-keyed in the read-only order; every
    class gets its own W ring appended."""
    plan = build_flow_plan(NocSpec.narrow_wide())
    assert plan.n_rq == 2 and plan.n_q == 4       # [rsp, wide] + 2 W rings
    assert plan.rq_of_r == (0, 1)
    assert plan.rq_of_b == (0, 0)                 # both B flows on rsp ring
    wo = build_flow_plan(NocSpec.wide_only())
    assert wo.n_rq == 1 and wo.n_q == 3
    assert wo.rr_classes[0] == (0, 1)             # RR slots: ring + 2 classes


# --------------------------------------------------------------------- #
# golden bit-identity: read-only presets vs the pre-AXI4 engine
# --------------------------------------------------------------------- #
def _spec_of(tag, cycles=2500):
    return {
        "narrow_wide": lambda: NocSpec.narrow_wide(4, 4, cycles=cycles),
        "wide_only": lambda: NocSpec.wide_only(4, 4, cycles=cycles),
        "torus": lambda: NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                                             cycles=cycles),
        "express": lambda: NocSpec.narrow_wide(
            6, 1, topology=Mesh(6, 1, express=(2,)), cycles=cycles),
    }[tag]()


# (tag, workload kind) -> per-class (done, lat_sum, max_lat, beats_rx)
# + per-channel link moves, captured from the read-only engine
GOLDENS = {
    ("narrow_wide", "fig5"): (
        {"narrow": (80, 3040, 38, 80), "wide": (48, 2586, 54, 768)},
        {"req": 768, "rsp": 480, "wide": 4608}),
    ("wide_only", "fig5"): (
        {"narrow": (80, 6502, 138, 80), "wide": (48, 4766, 138, 768)},
        {"wide": 5856}),
    ("torus", "fig5"): (
        {"narrow": (80, 1760, 22, 80), "wide": (48, 1818, 38, 768)},
        {"req": 256, "rsp": 160, "wide": 1536}),
    ("express", "fig5"): (
        {"narrow": (80, 2080, 26, 80), "wide": (48, 2010, 42, 768)},
        {"req": 384, "rsp": 240, "wide": 2304}),
    ("narrow_wide", "ur"): (
        {"narrow": (192, 4834, 40, 192), "wide": (80, 5881, 167, 1280)},
        {"req": 713, "rsp": 498, "wide": 3440}),
    ("wide_only", "ur"): (
        {"narrow": (192, 13901, 221, 192), "wide": (80, 8796, 232, 1280)},
        {"wide": 4651}),
    ("torus", "ur"): (
        {"narrow": (192, 4323, 33, 192), "wide": (80, 5747, 160, 1280)},
        {"req": 561, "rsp": 380, "wide": 2896}),
    ("express", "ur"): (
        {"narrow": (72, 1492, 28, 72), "wide": (30, 1371, 106, 480)},
        {"req": 159, "rsp": 114, "wide": 720}),
}


@pytest.mark.parametrize("tag,wkind", sorted(GOLDENS))
def test_read_only_presets_match_goldens(tag, wkind):
    spec = _spec_of(tag)
    if wkind == "fig5":
        wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                           counts={"narrow": 40, "wide": 24},
                           src=0, dst=spec.n_routers - 1, bidir=True)
    else:
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.3, "wide": 0.8},
                           counts={"narrow": 12, "wide": 5}, seed=7)
    r = simulate(spec, wl)
    want_cls, want_moves = GOLDENS[(tag, wkind)]
    for cname, (done, lat_sum, max_lat, beats) in want_cls.items():
        st_ = r.classes[cname]
        got = (int(st_.done.sum()),
               int(round(float((st_.avg_lat
                                * np.maximum(st_.done, 1)).sum()))),
               int(st_.max_lat.max()), int(st_.beats_rx.sum()))
        assert got == (done, lat_sum, max_lat, beats), (cname, got)
        # read-only: the write direction never activates
        assert int(st_.w_done.sum()) == 0
        assert int(st_.w_beats_rx.sum()) == 0
    assert {ch: int(c.link_moves) for ch, c in r.channels.items()} \
        == want_moves


# --------------------------------------------------------------------- #
# write path end-to-end
# --------------------------------------------------------------------- #
def test_pure_write_fig5_completes_with_analytic_flit_counts():
    """Every write completes; AW/W/B flit counts x hop distance match
    the per-channel link-move ledger exactly (narrow W rides req, wide
    W rides wide, every B rides rsp — the paper mapping)."""
    spec = NocSpec.narrow_wide(4, 4, cycles=3000)
    n_n, n_w = 20, 10
    wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                       counts={"narrow": n_n, "wide": n_w},
                       src=0, dst=15, write_frac=1.0)
    r = simulate(spec, wl)
    hops = int(hop_table(spec.topology)[0, 15])
    bl = spec.get_class("wide").burst_beats
    for cname, n in (("narrow", n_n), ("wide", n_w)):
        st_ = r.classes[cname]
        assert int(st_.done.sum()) == 0               # no reads issued
        assert int(st_.w_done.sum()) == n
    assert int(r.classes["narrow"].w_beats_rx.sum()) == n_n
    assert int(r.classes["wide"].w_beats_rx.sum()) == n_w * bl
    # req: narrow AW + narrow W (single-beat) + wide AW
    assert int(r.channels["req"].link_moves) == (2 * n_n + n_w) * hops
    # rsp: one B ack per write
    assert int(r.channels["rsp"].link_moves) == (n_n + n_w) * hops
    # wide: the wide W bursts
    assert int(r.channels["wide"].link_moves) == n_w * bl * hops
    assert bool(r.drained)


@pytest.mark.parametrize("pattern,kw", [
    ("fig5", dict(rates={"narrow": 0.3, "wide": 1.0},
                  counts={"narrow": 12, "wide": 6}, src=0, dst=15)),
    ("transpose", dict(rates={"narrow": 0.3, "wide": 1.0},
                       counts={"narrow": 4, "wide": 2})),
    ("all_to_all", dict(rates={"narrow": 0.3, "wide": 1.0},
                        rounds={"narrow": 1, "wide": 1})),
    ("uniform_random", dict(rates={"narrow": 0.3, "wide": 1.0},
                            counts={"narrow": 8, "wide": 4}, seed=11)),
    ("hotspot", dict(rates={"narrow": 0.3, "wide": 1.0},
                     counts={"narrow": 8, "wide": 4}, seed=11)),
])
@pytest.mark.parametrize("wf", [1.0, 0.5])
def test_pattern_flit_counts_under_write_mix(pattern, kw, wf):
    """Analytic transaction/beat conservation for every pattern under a
    pure-write and a 50/50 mix: scheduled = reads + writes, R beats =
    reads x burst, W beats = writes x burst, every txn completes."""
    spec = NocSpec.narrow_wide(4, 4, cycles=12000)
    wl = Workload.make(pattern, write_frac=wf, **kw)
    sched = wl.schedules(spec)
    r = simulate(spec, wl)
    assert bool(r.drained), (pattern, wf)
    for i, tc in enumerate(spec.classes):
        times, _, writes = sched[tc.name]
        live = times < BIG
        n_total = int(live.sum())
        n_writes = int((writes * live).sum())
        st_ = r.classes[tc.name]
        assert int(st_.done.sum()) == n_total - n_writes, (pattern, wf)
        assert int(st_.w_done.sum()) == n_writes, (pattern, wf)
        assert int(st_.beats_rx.sum()) == \
            (n_total - n_writes) * tc.burst_beats
        assert int(st_.w_beats_rx.sum()) == n_writes * tc.burst_beats
        if wf == 1.0 and n_total:
            assert n_writes == n_total
        elif n_total and pattern in ("fig5", "transpose", "all_to_all"):
            # deterministic interleave: half, up to one rounding txn
            # per NI (odd per-NI counts, e.g. all_to_all's R-1 sweeps)
            assert abs(2 * n_writes - n_total) <= spec.n_routers, \
                (pattern, n_writes, n_total)
        elif n_total:
            # seeded random draw: a loose binomial sanity band
            assert 0.25 * n_total < n_writes < 0.75 * n_total


def test_write_rob_flow_control_limits_outstanding():
    """The write ROB budget gates AW injection: even a tiny budget
    drains a long write stream (end-to-end flow control, paper §III-A),
    and reads keep their own independent credits."""
    spec = NocSpec.narrow_wide(2, 2, cycles=4000, max_wide_outstanding=2)
    wl = Workload.make("fig5", rates={"wide": 1.0}, counts={"wide": 48},
                       src=0, dst=3, write_frac=0.5)
    r = simulate(spec, wl)
    assert int(r.classes["wide"].done[0]) == 24
    assert int(r.classes["wide"].w_done[0]) == 24
    assert bool(r.drained)


def test_write_frac_validation_and_mix():
    with pytest.raises(ValueError, match="write_frac"):
        Workload.make("fig5", counts={"narrow": 4},
                      rates={"narrow": 1.0},
                      write_frac=1.5).schedules(NocSpec.narrow_wide(2, 2))
    with pytest.raises(KeyError):
        Workload.make("fig5", write_frac={"bogus": 0.5}).schedules(
            NocSpec.narrow_wide(2, 2))
    assert _mix_writes(8, 0.0).sum() == 0
    assert _mix_writes(8, 1.0).sum() == 8
    assert _mix_writes(8, 0.5).sum() == 4
    assert _mix_writes(100, 0.25).sum() == 25


def test_write_frac_never_reshuffles_schedules():
    """Review regression: the random patterns draw write flags from an
    independent per-class rng stream, so turning the mix knob for one
    class leaves EVERY class's times/dests bit-identical — a mix sweep
    varies only the direction of transactions, never the traffic."""
    spec = NocSpec.narrow_wide(4, 4, cycles=100)
    for pattern in ("uniform_random", "hotspot"):
        kw = dict(rates={"narrow": 0.3, "wide": 0.8},
                  counts={"narrow": 10, "wide": 5}, seed=0)
        base = Workload.make(pattern, **kw).schedules(spec)
        mixed = Workload.make(pattern, write_frac={"narrow": 0.5},
                              **kw).schedules(spec)
        for cls in ("narrow", "wide"):
            np.testing.assert_array_equal(base[cls][0], mixed[cls][0],
                                          err_msg=f"{pattern}:{cls} times")
            np.testing.assert_array_equal(base[cls][1], mixed[cls][1],
                                          err_msg=f"{pattern}:{cls} dests")
        assert np.any(mixed["narrow"][2] > 0)
        assert not np.any(mixed["wide"][2] > 0)


def test_wide_only_carries_writes_too():
    """The shared-link ablation serializes W bursts, B acks, and reads
    on one physical channel and still drains."""
    spec = NocSpec.wide_only(3, 3, cycles=6000)
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.3, "wide": 0.8},
                       counts={"narrow": 10, "wide": 5}, seed=2,
                       write_frac={"narrow": 0.5, "wide": 0.5})
    r = simulate(spec, wl)
    assert bool(r.drained)
    assert int(r.classes["wide"].w_done.sum()) > 0
    assert int(r.classes["narrow"].w_done.sum()) > 0
    assert len(r.channels) == 1


# --------------------------------------------------------------------- #
# backend equivalence on mixed read/write traffic (acceptance)
# --------------------------------------------------------------------- #
def _assert_results_equal(a, b):
    for cname in a.classes:
        for f in ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw",
                  "w_done", "w_avg_lat", "w_max_lat", "w_beats_rx",
                  "w_eff_bw"):
            np.testing.assert_array_equal(
                getattr(a.classes[cname], f), getattr(b.classes[cname], f),
                err_msg=f"{cname}.{f}")
    for ch in a.channels:
        np.testing.assert_array_equal(a.channels[ch].link_moves,
                                      b.channels[ch].link_moves)
    np.testing.assert_array_equal(a.max_stall_cycles, b.max_stall_cycles)
    np.testing.assert_array_equal(a.drained, b.drained)


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("case", ["mesh", "torus"])
def test_backends_agree_on_mixed_write_traffic(case, backend):
    """All three backends are flit-for-flit identical on mixed
    read/write workloads — the fabric is flow-agnostic, so the AXI4
    refactor must not open any backend-specific divergence."""
    if case == "mesh":
        spec = NocSpec.narrow_wide(4, 4, cycles=1500)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.3, "wide": 0.8},
                           counts={"narrow": 10, "wide": 5}, seed=3,
                           write_frac=0.5)
    else:
        spec = NocSpec.wide_only(3, 3, topology=Torus(3, 3), cycles=1200)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.2, "wide": 0.5},
                           counts={"narrow": 8, "wide": 4}, seed=5,
                           write_frac=0.6)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, backend=backend))


def test_batched_write_sweep_matches_singles():
    """write_frac sweeps vmap like any other workload axis."""
    spec = NocSpec.narrow_wide(3, 3, cycles=2500)
    wls = [Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                         counts={"narrow": 10, "wide": 5},
                         src=0, dst=8, write_frac=wf)
           for wf in (0.0, 0.5, 1.0)]
    batched = simulate_batch(spec, wls)
    for i, wl in enumerate(wls):
        single = simulate(spec, wl)
        _assert_results_equal(batched.point(i), single)
    # the mix shifts work between directions, conserving transactions
    done = batched.classes["wide"].done.sum(axis=-1)
    w_done = batched.classes["wide"].w_done.sum(axis=-1)
    np.testing.assert_array_equal(done + w_done, [5, 5, 5])
    np.testing.assert_array_equal(w_done, [0, 2, 5])


# --------------------------------------------------------------------- #
# per-class service-latency distributions (satellite)
# --------------------------------------------------------------------- #
def test_jitter_zero_reproduces_exactly():
    spec = NocSpec.narrow_wide(4, 4, cycles=2500)
    wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                       counts={"narrow": 40, "wide": 24}, src=0, dst=15,
                       write_frac=0.5)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, service_jitter=0))


def test_per_class_service_lat_vector():
    spec = NocSpec.narrow_wide(2, 2, cycles=1500)
    wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 0.5},
                       counts={"narrow": 10, "wide": 5}, src=0, dst=3)
    lo = simulate(spec, wl, service_lat=[5, 40])
    hi = simulate(spec, wl, service_lat=[40, 5])
    assert float(lo.classes["narrow"].avg_lat[0]) < \
        float(hi.classes["narrow"].avg_lat[0])
    assert float(lo.classes["wide"].avg_lat[0]) > \
        float(hi.classes["wide"].avg_lat[0])


def test_spec_declared_distribution_and_seeded_table():
    """TrafficClass (mean, jitter) feeds the engine; the jitter table is
    seeded so equal seeds reproduce and different seeds differ."""
    classes = (TrafficClass("narrow", 1, 8, 64, service_lat=8,
                            service_jitter=6),
               TrafficClass("wide", 16, 8, 512))
    spec = NocSpec.narrow_wide(3, 3, cycles=2500).with_(classes=classes)
    wl = Workload.make("uniform_random", rates={"narrow": 0.4},
                       counts={"narrow": 30}, seed=1)
    a = simulate(spec, wl)
    b = simulate(spec, wl)
    _assert_results_equal(a, b)                    # deterministic
    c = simulate(spec, wl, jitter_seed=9)
    assert not np.array_equal(a.classes["narrow"].avg_lat,
                              c.classes["narrow"].avg_lat)
    # jitter widens the observed latency spread vs the fixed-mean run
    flat = simulate(spec, wl, service_jitter=0)
    assert float(a.classes["narrow"].max_lat.max()) >= \
        float(flat.classes["narrow"].max_lat.max())
    assert int(a.classes["narrow"].done.sum()) == 30 * (spec.n_routers)


def test_jitter_decorrelates_across_sources():
    """Per-request offsets are keyed by (issuing NI, txn id): two NIs
    issuing the same txn sequence to one target must not see identical
    latency trajectories under jitter (review regression)."""
    spec = NocSpec.narrow_wide(4, 1, cycles=3000)
    # NIs 0 and 1 both send the same schedule to NI 3, one extra hop
    # apart; under jitter their per-txn service draws must differ
    sched = {
        "narrow": (np.where(np.arange(4)[:, None] < 2,
                            10 + 40 * np.arange(30)[None, :], BIG),
                   np.full((4, 30), 3, np.int32)),
        "wide": (np.full((4, 1), BIG, np.int32),
                 np.zeros((4, 1), np.int32))}
    from repro.noc import simulate_schedules
    r = simulate_schedules(spec, sched, service_lat=10, service_jitter=8)
    lat0 = r.classes["narrow"].avg_lat[0]
    lat1 = r.classes["narrow"].avg_lat[1]
    # NI 1 is one hop closer (4 router cycles less round trip); equal
    # jitter draws would make the latency gap exactly 4 — it must not be
    assert abs((float(lat0) - float(lat1)) - 4.0) > 1e-6, (lat0, lat1)


def test_batch_per_class_vector_when_n_equals_n_cls():
    """N == n_cls ambiguity: a 1-D per-class knob keeps its per-class
    meaning (review regression — it must NOT become a per-point
    sweep), matching what per-point runs with the same vector do."""
    spec = NocSpec.narrow_wide(2, 2, cycles=1500)
    wl = Workload.make("fig5", rates={"narrow": 0.3, "wide": 1.0},
                       counts={"narrow": 8, "wide": 8}, src=0, dst=3)
    mo = [1, 4]                      # per-class: narrow=1, wide=4
    batched = simulate_batch(spec, [wl, wl], max_outstanding=mo)
    single = simulate(spec, wl, max_outstanding=mo)
    for i in range(2):
        _assert_results_equal(batched.point(i), single)
    # service_lat keeps its historical per-POINT meaning instead
    sl_batched = simulate_batch(spec, [wl, wl], service_lat=[5, 30])
    for i, sl in enumerate((5, 30)):
        _assert_results_equal(sl_batched.point(i),
                              simulate(spec, wl, service_lat=sl))


def test_service_lat_jitter_sweep_vmaps():
    """Latency-distribution knobs batch like every other operand."""
    spec = NocSpec.narrow_wide(2, 2, cycles=1200)
    wl = Workload.make("fig5", rates={"narrow": 0.2},
                       counts={"narrow": 8}, src=0, dst=3)
    jits = [0, 3, 9]
    batched = simulate_batch(spec, [wl] * 3,
                             service_jitter=np.asarray(jits))
    for i, j in enumerate(jits):
        single = simulate(spec, wl, service_jitter=j)
        _assert_results_equal(batched.point(i), single)


# --------------------------------------------------------------------- #
# stall / deadlock observability (satellite)
# --------------------------------------------------------------------- #
def test_light_load_drains_with_small_stall():
    spec = NocSpec.narrow_wide(3, 3, cycles=2000)
    wl = Workload.make("fig5", rates={"narrow": 0.1, "wide": 0.5},
                       counts={"narrow": 10, "wide": 4}, src=0, dst=8,
                       write_frac=0.5)
    r = simulate(spec, wl)
    assert bool(r.drained)
    # quiet stretches are bounded by service latency + scheduling gaps,
    # nowhere near the horizon
    assert int(r.max_stall_cycles) < 100


def test_torus_saturating_bursts_deadlock_is_observable():
    """Regression for the ROADMAP liveness caveat: deterministic
    minimal-wrap routing on a VC-less torus — like the real VC-less
    tori the paper's no-VC design space excludes — can deadlock under
    saturating wormhole bursts, because wrap-around links close cyclic
    channel-dependency chains that the mesh's dimension-ordered routing
    provably cannot form.  The engine must *surface* the wedge
    (drained=False, max_stall ~ the remaining horizon), not hang or
    silently undercount; the same load on the mesh keeps moving every
    cycle."""
    wl = Workload.make("all_to_all", rates={"wide": 1.0},
                       rounds={"wide": 4}, write_frac=0.5)
    mk = lambda topo: NocSpec.wide_only(          # noqa: E731
        4, 4, topology=topo, burstlen=32, cycles=2500,
        max_wide_outstanding=16)
    r_torus = simulate(mk(Torus(4, 4)), wl)
    r_mesh = simulate(mk(None), wl)
    assert not bool(r_torus.drained)
    assert int(r_torus.max_stall_cycles) > 2500 // 2   # wedged for good
    assert int(r_mesh.max_stall_cycles) <= 5           # continuous progress
    assert int(r_mesh.classes["wide"].w_done.sum()) > \
        int(r_torus.classes["wide"].w_done.sum())


# --------------------------------------------------------------------- #
# Workload frozen-params round-trip (satellite property test)
# --------------------------------------------------------------------- #
_scalars = st.one_of(st.integers(-1000, 1000), st.floats(
    allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=8), st.booleans())
_nested = st.recursive(
    _scalars,
    lambda inner: st.one_of(
        st.lists(inner, max_size=4),
        st.dictionaries(st.text(max_size=6), inner, max_size=4)),
    max_leaves=12)


@settings(max_examples=60, deadline=None)
@given(params=st.dictionaries(st.text(min_size=1, max_size=8), _nested,
                              max_size=5))
def test_freeze_thaw_round_trips_nested_mappings(params):
    """_freeze/_thaw are exact inverses over arbitrarily nested
    mappings/sequences (lists normalize to tuples), and frozen params
    are hashable — the property Workload's cache-key role depends on."""
    frozen = tuple(sorted((k, _freeze(v)) for k, v in params.items()))
    hash(frozen)                                   # must be hashable

    def norm(v):
        if isinstance(v, dict):
            return {k: norm(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return tuple(norm(x) for x in v)
        return v

    thawed = {k: _thaw(v) for k, v in frozen}
    assert thawed == {k: norm(v) for k, v in params.items()}


def test_workload_kwargs_round_trip_nested():
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.5, "wide": 1.0},
                       counts={"narrow": 3},
                       write_frac={"narrow": 0.25})
    kw = wl.kwargs
    assert kw["rates"] == {"narrow": 0.5, "wide": 1.0}
    assert kw["write_frac"] == {"narrow": 0.25}
    hash(wl)
