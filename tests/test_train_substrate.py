"""Training substrate: optimizer math, checkpoints (atomic/async/elastic),
data determinism, straggler policies, end-to-end loss decrease + resume."""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig

from repro.train import optimizer as opt_mod
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.elastic import choose_mesh, degraded_meshes
from repro.train.straggler import SimulatedCluster, StepTimer


def test_sync_axes_rule():
    from jax.sharding import PartitionSpec as P
    mesh = MeshConfig(data=16, model=16, pod=2)
    assert opt_mod.sync_axes_for(P(None, "model"), mesh) == ("pod", "data")
    assert opt_mod.sync_axes_for(P("data", "model"), mesh) == ("pod",)
    assert opt_mod.sync_axes_for(P(), mesh) == ("pod", "data", "model")
    assert opt_mod.sync_axes_for(P(("data", "model")), mesh) == ("pod",)


def test_adamw_matches_reference():
    """Single-device AdamW step == hand-rolled numpy Adam."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    from jax.sharding import PartitionSpec as P
    cfg = RunConfig(model=get_arch("llama3.2-1b").smoke(),
                    shape=ShapeConfig("t", 8, 1, "train"),
                    mesh=MeshConfig(1, 1, 1))
    acfg = opt_mod.AdamWConfig(lr=1e-2, warmup=0, weight_decay=0.0,
                               clip_norm=1e9)
    p = {"w": jnp.ones((4, 4)) * 2.0}
    g = {"w": jnp.full((4, 4), 0.5)}
    s = {"w": {"m": jnp.zeros((4, 4)), "v": jnp.zeros((4, 4))}}
    pspecs = {"w": P()}

    def step(p, g, s):
        from repro.dist.backend import Backend
        bk = Backend(cfg)
        return opt_mod.adamw_update(p, g, s, jnp.int32(0), cfg, acfg,
                                    pspecs, bk)
    out_p, out_s, stats = jax.jit(
        lambda p, g, s: jax.shard_map(
            step, mesh=mesh, in_specs=(P(), P(), P()),
            out_specs=(P(), P(), P()), check_vma=False)(p, g, s))(p, g, s)

    m = 0.1 * 0.5
    v = 0.05 * 0.25
    upd = (m / 0.1) / (np.sqrt(v / 0.05) + acfg.eps)
    # lr at step0 with warmup=0 -> full cosine start = lr
    want = 2.0 - 1e-2 * upd
    np.testing.assert_allclose(np.asarray(out_p["w"]), want, rtol=1e-5)


def test_8bit_optimizer_tracks_fp32():
    """8-bit m/v training stays close to fp32 on a toy quadratic."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.backend import Backend
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    target = jnp.ones((8, 256)) * 3.0

    def run(bits):
        cfg = RunConfig(model=get_arch("llama3.2-1b").smoke(),
                        shape=ShapeConfig("t", 8, 1, "train"),
                        mesh=MeshConfig(1, 1, 1), opt_state_bits=bits)
        acfg = opt_mod.AdamWConfig(lr=5e-2, warmup=0, weight_decay=0.0,
                                   clip_norm=1e9)
        p = {"w": jnp.zeros((8, 256))}
        from repro.dist.params import ParamSpec
        sp = opt_mod.opt_state_specs({"w": ParamSpec((8, 256), pspec=P())},
                                     cfg)
        s = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), sp,
                         is_leaf=lambda x: hasattr(x, "materialize"))
        pspecs = {"w": P()}

        @jax.jit
        def stepfn(p, s, i):
            def inner(p, s):
                bk = Backend(cfg)
                g = jax.grad(lambda q: jnp.mean((q["w"] - target) ** 2))(p)
                return opt_mod.adamw_update(p, g, s, i, cfg, acfg, pspecs, bk)
            return jax.shard_map(inner, mesh=mesh, in_specs=(P(), P()),
                                 out_specs=(P(), P(), P()),
                                 check_vma=False)(p, s)
        for i in range(60):
            p, s, _ = stepfn(p, s, jnp.int32(i))
        return float(jnp.mean(jnp.abs(p["w"] - 3.0)))

    err32 = run(32)
    err8 = run(8)
    assert err8 < 0.5, err8
    assert err8 < err32 * 10 + 0.3


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    from jax.sharding import PartitionSpec as P
    from repro.train.checkpoint import CheckpointManager
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,))}}
    specs = {"a": P(), "b": {"c": P()}}
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, tree, specs, block=True)
    assert mgr.steps() == [10]
    assert not list(Path(tmp_path).glob("*.tmp"))
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = mgr.restore(10, like, mesh, specs)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # gc keeps last 2
    mgr.save(20, tree, specs, block=True)
    mgr.save(30, tree, specs, block=True)
    assert mgr.steps() == [20, 30]


def test_checkpoint_elastic_reshard(subproc):
    """Save on (data=2, model=2), restore on (data=4, model=2)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.train.checkpoint import CheckpointManager
import tempfile
d = tempfile.mkdtemp()
mesh1 = jax.make_mesh((2, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh1, P("data", "model")))
mgr = CheckpointManager(d)
mgr.save(1, {"x": x}, {"x": P("data", "model")}, block=True)
mesh2 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
out = mgr.restore(1, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                  mesh2, {"x": P("data", "model")})
np.testing.assert_array_equal(np.asarray(out["x"]),
                              np.arange(64.0).reshape(8, 8))
assert len(out["x"].addressable_shards) == 8
print("PASS elastic")
""")


def test_data_determinism_and_prefetch():
    ds = SyntheticLM(1000, 16, 4, seed=3)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 1000
    pf = Prefetcher(iter(ds), depth=2)
    first = next(pf)
    np.testing.assert_array_equal(first["tokens"], ds.batch_at(0)["tokens"])
    pf.close()


def test_straggler_policies_improve_p99():
    rep = SimulatedCluster(n_hosts=512, seed=1).report(steps=500)
    assert rep["rebalance"]["p99"] <= rep["none"]["p99"]
    assert rep["quarantine"]["p99"] < rep["none"]["p99"] * 0.8


def test_step_timer_flags_outlier(monkeypatch):
    # drive a fake clock instead of time.sleep: the real-sleep version
    # flaked under load (a 1 ms sleep stretched by the scheduler trips
    # the z-test); the z-score logic is what's under test, not the OS
    from repro.train import straggler as straggler_mod
    clock = {"t": 0.0}
    monkeypatch.setattr(straggler_mod.time, "perf_counter",
                        lambda: clock["t"])

    def step(dt):
        t.start(); clock["t"] += dt; t.stop()

    t = StepTimer(warmup=5, z_threshold=2.0)
    for i in range(30):
        step(0.001 + (1e-5 if i % 2 else -1e-5))   # steady, tiny wobble
    assert not t.flagged
    step(0.05)                                      # 50x outlier
    assert t.flagged


def test_elastic_mesh_choices():
    m = choose_mesh(512, model=16)
    assert (m.pod, m.data, m.model) == (2, 16, 16)
    m = choose_mesh(256, model=16)
    assert (m.pod, m.data, m.model) == (1, 16, 16)
    seq = degraded_meshes(MeshConfig(data=16, model=16, pod=1), 2)
    assert [x.data for x in seq] == [16, 15, 14]


def test_train_loop_decreases_and_resumes(tmp_path):
    from repro.train.loop import train
    mcfg = get_arch("llama3.2-1b").smoke(num_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256)
    shape = ShapeConfig("t", 32, 4, "train")
    cfg = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1),
                    learning_rate=3e-3)
    r1 = train(cfg, num_steps=12, ckpt_dir=tmp_path, ckpt_every=6,
               log_every=0)
    assert r1.final_loss < r1.losses[0]
    # resume from step 12 and continue
    r2 = train(cfg, num_steps=16, ckpt_dir=tmp_path, ckpt_every=0,
               log_every=0)
    assert r2.resumed_from == 12
    assert r2.steps == 4
