"""Direct unit coverage for the repro.dist substrate.

The integration suites (test_distributed / test_models_smoke /
test_train_substrate / test_serve) exercise repro.dist through the
models; these tests pin the package's own contracts: blockwise-int8
round trips, sharded materialization (determinism, init rules,
placement), spec-tree projections, and the compressed all-reduce
against the exact one on a real multi-device mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compression, params as params_lib
from repro.dist.backend import Backend
from repro.dist.params import ParamSpec


# ---------------------------------------------------------------------------
# blockwise int8
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [32, 128, 256])
@pytest.mark.parametrize("shape", [(512,), (4, 256), (2, 3, 256)])
def test_quantize_blockwise_roundtrip(shape, block):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, shape).astype(np.float32))
    q, s = compression.quantize_blockwise(x, block)
    assert q.dtype == jnp.int8
    assert s.shape == shape[:-1] + (shape[-1] // block,)
    y = compression.dequantize_blockwise(q, s, block)
    # per-block max-abs scaling bounds the element error at scale/2
    xb = np.asarray(x).reshape(-1, block)
    yb = np.asarray(y).reshape(-1, block)
    bound = np.abs(xb).max(axis=1) / 127.0 * 0.5 + 1e-7
    assert (np.abs(xb - yb).max(axis=1) <= bound).all()


def test_quantize_blockwise_zero_block_exact():
    x = jnp.zeros((256,), jnp.float32)
    q, s = compression.quantize_blockwise(x, 128)
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(
        np.asarray(compression.dequantize_blockwise(q, s, 128)), 0.0)


def test_compressed_all_reduce_matches_exact(subproc):
    """int8 all-reduce over a 2-rank axis stays within the quant bound
    of the exact psum (and is bitwise identical across ranks)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist import compression

mesh = jax.make_mesh((2,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))

def f(x):
    exact = jax.lax.psum(x, ("pod",))
    approx = compression.compressed_all_reduce(x, [("pod", 2)])
    return exact, approx

x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 1000)),
                jnp.float32)
exact, approx = jax.jit(jax.shard_map(
    f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
    check_vma=False))(x)
err = np.abs(np.asarray(exact) - np.asarray(approx)).max()
scale = np.abs(np.asarray(x)).max() / 127 * 2   # 2 contributions
assert err <= scale + 1e-6, (err, scale)
# both ranks computed the same sum (order-independent wire format)
np.testing.assert_array_equal(np.asarray(approx)[0], np.asarray(approx)[1])
print("PASS compressed_ar", err)
""", n_devices=2)


# ---------------------------------------------------------------------------
# ParamSpec / materialize_sharded
# ---------------------------------------------------------------------------
def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_tree_projections():
    tree = {"a": ParamSpec((4, 8), jnp.float32, P(None, "model")),
            "b": {"c": ParamSpec((3,), jnp.bfloat16, P(), init="zeros")}}
    sds = params_lib.tree_sds(tree)
    assert sds["a"].shape == (4, 8) and sds["b"]["c"].dtype == jnp.bfloat16
    ps = params_lib.tree_pspecs(tree)
    assert ps["a"] == P(None, "model") and ps["b"]["c"] == P()
    assert params_lib.is_spec(tree["a"]) and not params_lib.is_spec(sds["a"])


def test_materialize_init_rules_and_placement():
    mesh = _mesh11()
    tree = {
        "zeros": ParamSpec((16,), jnp.float32, P(), init="zeros"),
        "ones": ParamSpec((16,), jnp.float32, P(), init="ones"),
        "normal": ParamSpec((256, 64), jnp.float32, P(), init="normal"),
        "scaled": ParamSpec((256, 64), jnp.float32, P(None, "model"),
                            init="scaled", fan_in_axes=(0,)),
    }
    out = params_lib.materialize_sharded(tree, jax.random.key(0), mesh)
    np.testing.assert_array_equal(np.asarray(out["zeros"]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["ones"]), 1.0)
    # fixed-std embedding init
    assert abs(float(jnp.std(out["normal"])) - 0.02) < 0.002
    # fan-in scaled: std ~ 1/sqrt(256) (truncation shrinks it slightly)
    std = float(jnp.std(out["scaled"]))
    assert 0.5 / np.sqrt(256) < std <= 1.1 / np.sqrt(256)
    for k, spec in tree.items():
        assert out[k].sharding == NamedSharding(mesh, spec.pspec), k
        assert out[k].dtype == spec.dtype


def test_materialize_deterministic_and_leafwise_independent():
    mesh = _mesh11()
    tree = {"a": ParamSpec((32, 32), jnp.float32, P(), init="scaled",
                           fan_in_axes=(0,)),
            "b": ParamSpec((32, 32), jnp.float32, P(), init="scaled",
                           fan_in_axes=(0,))}
    o1 = params_lib.materialize_sharded(tree, jax.random.key(7), mesh)
    o2 = params_lib.materialize_sharded(tree, jax.random.key(7), mesh)
    np.testing.assert_array_equal(np.asarray(o1["a"]), np.asarray(o2["a"]))
    # distinct leaves draw from distinct folded keys
    assert not np.array_equal(np.asarray(o1["a"]), np.asarray(o1["b"]))
    # different base key -> different draw
    o3 = params_lib.materialize_sharded(tree, jax.random.key(8), mesh)
    assert not np.array_equal(np.asarray(o1["a"]), np.asarray(o3["a"]))


def test_materialize_mesh_independent(subproc):
    """Same spec tree + key must materialize bit-identical GLOBAL values
    on any mesh factorization (the cross-mesh equivalence bedrock)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist import params as params_lib
from repro.dist.params import ParamSpec

tree = {"w": ParamSpec((8, 64), jnp.float32, P("data", "model"),
                       init="scaled", fan_in_axes=(0,))}
vals = []
for shape, names in (((1, 1), ("data", "model")),
                     ((2, 2), ("data", "model"))):
    mesh = jax.make_mesh(shape, names,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    out = params_lib.materialize_sharded(tree, jax.random.key(3), mesh)
    vals.append(np.asarray(jax.device_get(out["w"])))
np.testing.assert_array_equal(vals[0], vals[1])
print("PASS mesh_independent")
""", n_devices=4)


# ---------------------------------------------------------------------------
# Backend statics (no mesh needed)
# ---------------------------------------------------------------------------
def test_backend_statics_and_flat_dp():
    from repro.configs import get_arch, ShapeConfig
    from repro.configs.base import MeshConfig, RunConfig
    mcfg = get_arch("llama3.2-1b").smoke()
    shape = ShapeConfig("t", 32, 4, "train")
    cfg = RunConfig(model=mcfg, shape=shape,
                    mesh=MeshConfig(data=4, model=2, pod=2))
    bk = Backend(cfg)
    assert bk.is_floo and bk.model == 2
    assert bk.axis_size("data") == 4 and bk.axis_size("pod") == 2
    assert bk.axis_size("nope") == 1
    flat = Backend(cfg.replace(flat_dp=True, backend="xla"))
    assert flat.model == 1 and not flat.is_floo
    # TP collectives degenerate to identity under flat_dp
    x = jnp.ones((4, 4))
    assert flat.psum_model(x) is x and flat.pmax_model(x) is x
    assert flat.seq_ag(x, dim=0) is x and flat.seq_rs(x, dim=0) is x
    assert int(flat.axis_index("model")) == 0
