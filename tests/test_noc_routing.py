"""Tests for repro.noc.routing — RoutingPolicy, virtual channels, and
deadlock-free cyclic fabrics.

Covers: policy validation (VC budgets, topology compatibility), the
compiled table structure (VC fold, dateline/escape-VC bits, multi-plane
route divergence, validate_tables on generated sets), bit-identity of
the default single-VC XY policy with the pre-VC engine, flit-for-flit
three-backend agreement with n_vcs >= 2 on mesh + torus mixed
read/write traffic, per-VC occupancy reporting, and the PR-5
saturating-burst torus regression flipped from wedged to drained by the
escape-VC discipline (the VC-less config is kept wedging alongside as
the contrast).
"""
import numpy as np
import pytest

from repro.noc import (Mesh, NocSpec, RoutingPolicy, Torus, Workload,
                       hop_table, simulate, validate_tables)


# --------------------------------------------------------------------- #
# policy construction + validation
# --------------------------------------------------------------------- #
def test_default_policy_is_single_vc_xy():
    pol = RoutingPolicy()
    assert pol == RoutingPolicy.xy(n_vcs=1)
    assert pol.algorithm == "xy" and pol.n_vcs == 1 and pol.n_planes == 1


@pytest.mark.parametrize("bad", [
    dict(algorithm="zigzag"),
    dict(n_vcs=0),
    dict(n_vcs=-1),
    dict(algorithm="valiant", n_valiant=0),
])
def test_bad_policy_params_raise(bad):
    with pytest.raises((ValueError, TypeError)):
        RoutingPolicy(**bad)


@pytest.mark.parametrize("pol,topo,ok", [
    (RoutingPolicy.xy(1), Mesh(4, 4), True),
    (RoutingPolicy.xy(1), Torus(4, 4), True),      # allowed, documented wedge
    (RoutingPolicy.xy(2), Torus(4, 4), True),
    (RoutingPolicy.o1turn(1), Mesh(4, 4), False),  # needs a VC per plane
    (RoutingPolicy.o1turn(2), Mesh(4, 4), True),
    (RoutingPolicy.o1turn(2), Torus(4, 4), False),  # dateline doubles it
    (RoutingPolicy.o1turn(4), Torus(4, 4), True),
    (RoutingPolicy.valiant(4), Mesh(4, 4), True),
    (RoutingPolicy.valiant(2), Mesh(4, 4), False),
    (RoutingPolicy.valiant(4), Torus(4, 4), False),  # mesh-only
    (RoutingPolicy.o1turn(2), Mesh(6, 1, express=(2,)), False),  # xy only
    (RoutingPolicy.xy(2), Mesh(6, 1, express=(2,)), True),
])
def test_policy_topology_compatibility(pol, topo, ok):
    if ok:
        pol.validate_for(topo)
        pol.compile(topo)
    else:
        with pytest.raises(ValueError):
            pol.validate_for(topo)


def test_spec_validates_routing_against_topology():
    with pytest.raises(ValueError):
        NocSpec.narrow_wide(4, 4, routing=RoutingPolicy.o1turn(1))
    with pytest.raises(TypeError):
        NocSpec.narrow_wide(4, 4, routing="xy")
    # valid combos construct and stay hashable (cache key material)
    spec = NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                               routing=RoutingPolicy.xy(2))
    assert hash(spec) == hash(spec.with_())


# --------------------------------------------------------------------- #
# compiled table structure
# --------------------------------------------------------------------- #
def test_default_policy_tables_bit_identical_to_topology():
    for topo in (Mesh(4, 4), Torus(4, 4), Mesh(6, 1, express=(2,))):
        rt = RoutingPolicy.xy(1).compile(topo)
        nbr, opp, route = topo.tables()
        assert np.array_equal(rt.nbr, nbr)
        assert np.array_equal(rt.opp, opp)
        assert np.array_equal(rt.route, route)
        assert rt.n_vcs == 1 and rt.n_planes == 1


def test_vc_fold_shapes_and_validation():
    topo = Torus(4, 4)
    P = topo.n_ports
    rt = RoutingPolicy.xy(2).compile(topo)
    assert rt.nbr.shape == (16, (P - 1) * 2 + 1)
    assert rt.route.shape == (16, 16)
    # generated sets pass the same structural checks as base topologies
    hops = validate_tables(rt.nbr, rt.opp, rt.route)
    assert np.array_equal(hops, hop_table(topo))   # same physical paths


def test_mesh_xy_never_uses_escape_vc():
    rt = RoutingPolicy.xy(2).compile(Mesh(4, 4))
    assert (rt.vc_of_hop == 0).all()   # acyclic mesh: VC bits stay 0


def test_torus_dateline_bits_are_monotone_along_routes():
    """Walk every (src, dest) route on the torus: within one
    dimension's ring, once a flit is bumped to the escape VC it stays
    there until the dimension is done (the dateline discipline that
    breaks the ring cycle — the bit may reset at the X->Y turn, since
    dimension-ordered routing already breaks cross-dimension cycles),
    and every wrap-link hop lands in VC 1."""
    topo = Torus(4, 4)
    nbr, _, route = topo.tables()
    rt = RoutingPolicy.xy(2).compile(topo)
    vc = rt.vc_of_hop[0]
    nx = topo.nx
    used_escape = used_vc0 = False
    for s in range(16):
        for d in range(16):
            cur, prev_vc, prev_dim, hops = s, 0, None, 0
            while cur != d:
                b = int(vc[cur, d])
                nxt = int(nbr[cur, route[cur, d]])
                dim = "x" if cur % nx != nxt % nx else "y"
                if dim == prev_dim:
                    assert b >= prev_vc, (s, d, cur)  # never back to VC0
                dx = abs(cur % nx - nxt % nx)
                dy = abs(cur // nx - nxt // nx)
                if dx > 1 or dy > 1:                  # wrap link crossed
                    assert b == 1, (s, d, cur)
                    used_escape = True
                used_vc0 |= (b == 0)
                prev_vc, prev_dim, cur = b, dim, nxt
                hops += 1
                assert hops <= 16
    assert used_escape and used_vc0       # both VCs genuinely exercised


def test_o1turn_planes_diverge():
    """Plane 0 is XY, plane 1 is YX: for any off-axis pair the first
    hops differ, and both planes deliver (validate_tables terminates)."""
    topo = Mesh(4, 4)
    rt = RoutingPolicy.o1turn(2).compile(topo)
    R = 16
    # virtual destination column d of plane k is k*R + d
    p0 = rt.route[0, 5] // rt.n_vcs        # router 0 -> (1,1), plane XY
    p1 = rt.route[0, 16 + 5] // rt.n_vcs   # same pair, plane YX
    assert p0 != p1                        # E first vs S first
    assert rt.route.shape == (R, 2 * R)


def test_valiant_routes_terminate_and_detour():
    topo = Mesh(4, 4)
    rt = RoutingPolicy.valiant(4).compile(topo)
    hops = validate_tables(rt.nbr, rt.opp, rt.route)
    base = hop_table(topo)
    K = rt.n_planes
    assert K == 2
    # valiant detours: at least some pairs take strictly more hops than
    # minimal XY, none fewer
    longer = 0
    for k in range(K):
        hk = hops[:, k * 16:(k + 1) * 16]
        assert (hk >= base).all()
        longer += int((hk > base).sum())
    assert longer > 0


# --------------------------------------------------------------------- #
# engine integration
# --------------------------------------------------------------------- #
def _mixed_wl():
    return Workload.make("uniform_random",
                         rates={"narrow": 0.3, "wide": 0.8},
                         counts={"narrow": 8, "wide": 4}, seed=7,
                         write_frac=0.5)


def _assert_results_equal(a, b):
    for k, ca in a.classes.items():
        cb = b.classes[k]
        for f in ca.__dataclass_fields__:
            assert np.array_equal(getattr(ca, f), getattr(cb, f)), (k, f)
    for k, ca in a.channels.items():
        cb = b.channels[k]
        for f in ca.__dataclass_fields__:
            assert np.array_equal(getattr(ca, f), getattr(cb, f)), (k, f)
    assert np.array_equal(a.max_stall_cycles, b.max_stall_cycles)
    assert np.array_equal(a.drained, b.drained)


@pytest.mark.parametrize("topo,pol", [
    (Torus(4, 4), RoutingPolicy.xy(2)),
    (Mesh(4, 4), RoutingPolicy.o1turn(2)),
    (Torus(4, 4), RoutingPolicy.o1turn(4)),
    (Mesh(4, 4), RoutingPolicy.valiant(4)),
])
def test_backends_flit_for_flit_equal_with_vcs(topo, pol):
    spec = NocSpec.narrow_wide(4, 4, topology=topo, cycles=1500,
                               routing=pol)
    wl = _mixed_wl()
    ref = simulate(spec, wl, backend="jnp")
    assert bool(ref.drained)
    for backend in ("pallas", "pallas_fused"):
        _assert_results_equal(ref, simulate(spec, wl, backend=backend))


def test_single_vc_policy_matches_default_spec_exactly():
    """RoutingPolicy.xy(1) is the default: same spec value, same cached
    simulator, and (golden-checked elsewhere) the pre-VC numbers."""
    wl = _mixed_wl()
    a = simulate(NocSpec.narrow_wide(4, 4, cycles=1200), wl)
    b = simulate(NocSpec.narrow_wide(4, 4, cycles=1200,
                                     routing=RoutingPolicy.xy(1)), wl)
    _assert_results_equal(a, b)


def test_per_vc_occupancy_reported():
    spec = NocSpec.narrow_wide(4, 4, topology=Torus(4, 4), cycles=1500,
                               routing=RoutingPolicy.xy(2))
    r = simulate(spec, _mixed_wl())
    for ch in ("req", "rsp", "wide"):
        st = r.channels[ch]
        assert st.vc_occupancy.shape == (2,)
        assert st.vc_peak_occupancy.shape == (2,)
    # 4x4 torus dateline: traffic demonstrably reaches the escape VC
    assert float(r.channels["wide"].vc_occupancy[1]) > 0
    assert "wide_vc_occupancy" in r.summary()


def test_multi_plane_policies_drain_and_spread():
    """O1TURN on the mesh drains and genuinely uses both planes (both
    VC groups see occupancy)."""
    spec = NocSpec.narrow_wide(4, 4, cycles=1500,
                               routing=RoutingPolicy.o1turn(2))
    r = simulate(spec, _mixed_wl())
    assert bool(r.drained)
    occ = r.channels["wide"].vc_occupancy
    assert occ.shape == (2,) and (occ > 0).all()


# --------------------------------------------------------------------- #
# the deadlock-freedom regression (gating)
# --------------------------------------------------------------------- #
def test_torus_saturating_bursts_escape_vc_flips_wedge_to_drained():
    """PR-5's saturating-burst wormhole config on the minimal-wrap
    torus: VC-less it wedges (drained=False, stall ~ horizon), and the
    identical spec with the 2-VC escape/dateline policy drains with no
    meaningful stall.  This is the PR-6 acceptance regression."""
    wl = Workload.make("all_to_all", rates={"wide": 1.0},
                       rounds={"wide": 2}, write_frac=0.5)

    def mk(**kw):
        return NocSpec.wide_only(4, 4, topology=Torus(4, 4), burstlen=32,
                                 cycles=3500, max_wide_outstanding=16, **kw)

    wedged = simulate(mk(), wl)
    assert not bool(wedged.drained)
    assert int(wedged.max_stall_cycles) > 1750
    # the wedge is visible per-VC: the single VC is pinned near-full
    assert float(wedged.channels["wide"].vc_occupancy[0]) > 10

    fixed = simulate(mk(routing=RoutingPolicy.xy(n_vcs=2)), wl)
    assert bool(fixed.drained)
    assert int(fixed.max_stall_cycles) < 100
