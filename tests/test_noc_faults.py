"""Fault-injection & graceful-degradation tests.

Covers the full robustness surface: zero-fault bit-identity (a spec
with an empty/dynamic-only FaultModel must not perturb healthy
behavior), static dead-link/node cut-out reroute (drains with bounded
latency inflation where the unrerouted cut wedges), NI
timeout/retry/backoff and AXI SLVERR semantics, backend equivalence
under flapping links, the three-way ``diagnose()`` triage, and the
property that every fault-regenerated route table re-passes the
structural lint and the CDG deadlock proof.
"""
import numpy as np
import pytest

from conftest import given, settings, st

from repro.noc import (FaultModel, Mesh, NocSpec, Torus,
                       UnroutableCutError, Workload, cut_tables,
                       simulate, simulate_batch)
from repro.noc.routing import RoutingPolicy
from repro.noc.topology import validate_tables


def _wl(seed=7, n_narrow=12, n_wide=5):
    return Workload.make("uniform_random",
                         rates={"narrow": 0.3, "wide": 0.8},
                         counts={"narrow": n_narrow, "wide": n_wide},
                         seed=seed)


def _stats_tuple(r):
    out = []
    for name, st_ in sorted(r.classes.items()):
        out.append((name, int(st_.done.sum()),
                    float(st_.avg_lat.sum()), int(st_.max_lat.max()),
                    int(st_.beats_rx.sum()), int(st_.w_done.sum()),
                    int(st_.w_beats_rx.sum())))
    return tuple(out)


# --------------------------------------------------------------------- #
# zero-fault bit-identity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
def test_empty_fault_model_is_bit_identical(backend):
    """FaultModel() with no faults at all: same flits, same stats as
    the faults=None spec on every backend — the fault machinery must
    be invisible when inactive."""
    wl = _wl()
    base = simulate(NocSpec.narrow_wide(4, 4, cycles=4000), wl,
                    backend=backend)
    faulted = simulate(
        NocSpec.narrow_wide(4, 4, cycles=4000, faults=FaultModel()), wl,
        backend=backend)
    assert _stats_tuple(base) == _stats_tuple(faulted)
    assert bool(base.drained) and bool(faulted.drained)
    assert base.faults is None and faulted.faults is not None
    fs = faulted.faults
    assert int(fs.fault_cycles) == 0 and int(fs.faulted_link_cycles) == 0
    for m in (fs.retries, fs.timeouts, fs.slverr):
        assert all(int(np.sum(v)) == 0 for v in m.values())


def test_dynamic_only_fault_model_keeps_route_tables():
    """Dynamic events never re-route: the compiled tables are the base
    policy's (masked links stall in place instead)."""
    topo, pol = Torus(4, 4), RoutingPolicy.xy(2)
    fm = FaultModel(link_events=((1, 2, 100, 200),))
    rt = cut_tables(topo, pol, fm)
    base = pol.compile(topo)
    assert np.array_equal(rt.route, base.route)


# --------------------------------------------------------------------- #
# the acceptance story: kill a torus X-link mid-burst
# --------------------------------------------------------------------- #
def _torus_spec(faults=None, cycles=8000):
    return NocSpec.narrow_wide(4, 4, topology=Torus(4, 4), cycles=cycles,
                               routing=RoutingPolicy.xy(3), faults=faults)


def test_dead_link_reroutes_drains_with_bounded_inflation():
    wl = _wl()
    healthy = simulate(_torus_spec(), wl)
    cut = simulate(_torus_spec(FaultModel(dead_links=((1, 2),))), wl)
    assert bool(healthy.drained) and bool(cut.drained)
    # graceful: worst-case latency stays under 2x the healthy fabric
    h = max(int(s.max_lat.max()) for s in healthy.classes.values())
    c = max(int(s.max_lat.max()) for s in cut.classes.values())
    assert c < 2 * h, (c, h)
    fs = cut.faults
    assert int(fs.fault_cycles) > 0
    assert sum(int(v) for v in fs.delivered_despite_fault.values()) > 0
    assert sum(float(v) for v in fs.goodput_under_fault.values()) > 0
    # nothing left behind, no errors surfaced
    assert all(int(v) == 0 for v in fs.undone.values())
    assert all(int(v) == 0 for v in fs.slverr.values())


def test_same_cut_without_reroute_wedges_and_diagnose_names_link():
    wl = _wl()
    r = simulate(_torus_spec(
        FaultModel(dead_links=((1, 2),), reroute=False)), wl)
    assert not bool(r.drained)
    msg = r.diagnose()
    assert "fault stall: link (1, 2) dead since cycle 0" in msg
    assert "(reroute disabled)" in msg
    assert any(int(v) > 0 for v in r.faults.undone.values())
    # goodput collapses relative to the rerouted fabric
    rr = simulate(_torus_spec(FaultModel(dead_links=((1, 2),))), wl)
    assert (sum(float(v) for v in rr.faults.goodput_under_fault.values())
            > sum(float(v) for v in r.faults.goodput_under_fault.values()))


def _avoid_dead_node(spec, wl, dead):
    """Per-class schedules with the dead node silenced as a source and
    removed as a destination (dests also steered off self-traffic)."""
    R = spec.n_routers
    src = np.arange(R)[:, None]
    out = {}
    for name, entry in wl.schedules(spec).items():
        t = np.array(entry[0], np.int32).reshape(R, -1)
        d = np.array(entry[1], np.int32).reshape(R, -1)
        w = (np.array(entry[2], np.int32).reshape(R, -1)
             if len(entry) > 2 else np.zeros_like(t))
        while ((d == dead) | (d == src)).any():
            d = np.where((d == dead) | (d == src), (d + 1) % R, d)
        t[dead, :] = 1 << 30
        out[name] = (t, d, w)
    return out


def test_dead_node_reroute_drains_around_router():
    """Kill a whole router: surviving pairs still drain (traffic may
    not source at or target the dead node)."""
    from repro.noc import simulate_schedules
    spec = _torus_spec(FaultModel(dead_nodes=(5,)))
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.3, "wide": 0.6},
                       counts={"narrow": 8, "wide": 4}, seed=3)
    r = simulate_schedules(spec, _avoid_dead_node(spec, wl, 5))
    assert bool(r.drained)
    assert all(int(v) == 0 for v in r.faults.undone.values())


def test_traffic_at_dead_node_is_rejected():
    spec = _torus_spec(FaultModel(dead_nodes=(5,)))
    wl = _wl()
    with pytest.raises(ValueError, match="dead node"):
        simulate(spec, wl)


# --------------------------------------------------------------------- #
# NI timeout / retry / backoff / SLVERR
# --------------------------------------------------------------------- #
def test_transient_outage_retries_then_drains():
    """A link that dies and heals: watchdogs fire, retries reinject,
    everything completes with zero SLVERR."""
    fm = FaultModel(link_events=((0, 1, 50, 800),), timeout_cycles=150,
                    max_retries=6, backoff_base=8)
    wl = _wl(seed=11)
    r = simulate(NocSpec.narrow_wide(
        4, 4, cycles=12000, routing=RoutingPolicy.xy(2), faults=fm), wl)
    assert bool(r.drained)
    fs = r.faults
    assert sum(int(v) for v in fs.timeouts.values()) > 0
    assert sum(int(v) for v in fs.retries.values()) > 0
    assert all(int(v) == 0 for v in fs.slverr.values())
    assert all(int(v) == 0 for v in fs.undone.values())


def test_exhausted_retries_raise_slverr_and_free_credits():
    """Outage longer than the whole retry budget: transactions complete
    with SLVERR (AXI error response), credits are freed, and the run
    still drains once the link heals."""
    fm = FaultModel(link_events=((0, 1, 50, 3000),), timeout_cycles=100,
                    max_retries=1, backoff_base=4)
    wl = _wl(seed=5)
    r = simulate(NocSpec.narrow_wide(
        4, 4, cycles=9000, routing=RoutingPolicy.xy(2), faults=fm), wl)
    assert bool(r.drained)
    fs = r.faults
    assert sum(int(v) for v in fs.slverr.values()) > 0
    assert all(int(v) == 0 for v in fs.undone.values())


def test_runtime_overrides_require_fault_model():
    spec = NocSpec.narrow_wide(4, 4)
    with pytest.raises(ValueError, match="FaultModel"):
        simulate(spec, _wl(), timeout_cycles=100)


def test_per_class_timeout_length_validated():
    with pytest.raises(ValueError, match="timeout_cycles"):
        NocSpec.narrow_wide(4, 4, faults=FaultModel(
            timeout_cycles=(100, 200, 300)))


# --------------------------------------------------------------------- #
# backend equivalence under dynamic faults
# --------------------------------------------------------------------- #
def test_flapping_link_backends_flit_for_flit():
    fm = FaultModel(link_events=((1, 2, 100, 260), (5, 6, 300, 420),
                                 (1, 2, 700, 840)),
                    timeout_cycles=2000, max_retries=2)
    spec = NocSpec.narrow_wide(4, 4, cycles=6000,
                               routing=RoutingPolicy.xy(2), faults=fm)
    wl = _wl(seed=13)
    runs = {b: simulate(spec, wl, backend=b)
            for b in ("jnp", "pallas", "pallas_fused")}
    ref = runs["jnp"]
    for b, r in runs.items():
        assert _stats_tuple(r) == _stats_tuple(ref), b
        assert bool(r.drained) == bool(ref.drained), b
        assert int(r.faults.fault_cycles) == int(ref.faults.fault_cycles)
        assert (int(r.faults.faulted_link_cycles)
                == int(ref.faults.faulted_link_cycles))
        for name in ref.classes:
            assert (int(np.sum(r.faults.retries[name]))
                    == int(np.sum(ref.faults.retries[name]))), (b, name)
    assert int(ref.faults.fault_cycles) > 0


def test_bernoulli_fault_model_is_deterministic_per_seed():
    fm1 = FaultModel.bernoulli(n_events=3, seed=42, mean_downtime=80.0)
    fm2 = FaultModel.bernoulli(n_events=3, seed=42, mean_downtime=80.0)
    spec = NocSpec.narrow_wide(4, 4, cycles=6000,
                               routing=RoutingPolicy.xy(2), faults=fm1)
    spec2 = NocSpec.narrow_wide(4, 4, cycles=6000,
                                routing=RoutingPolicy.xy(2), faults=fm2)
    wl = _wl(seed=2)
    a, b = simulate(spec, wl), simulate(spec2, wl)
    assert _stats_tuple(a) == _stats_tuple(b)


def test_batch_faulted_matches_single_point():
    fm = FaultModel(link_events=((1, 2, 100, 300),), timeout_cycles=500)
    spec = NocSpec.narrow_wide(4, 4, cycles=6000,
                               routing=RoutingPolicy.xy(2), faults=fm)
    wl = _wl(seed=9)
    single = simulate(spec, wl)
    batch = simulate_batch(spec, [wl, wl])
    p0 = batch.point(0)
    assert _stats_tuple(p0) == _stats_tuple(single)
    assert (int(np.sum(p0.faults.retries["narrow"]))
            == int(np.sum(single.faults.retries["narrow"])))


# --------------------------------------------------------------------- #
# diagnose(): fault stall vs true deadlock vs congestion
# --------------------------------------------------------------------- #
def test_diagnose_distinguishes_three_causes():
    wl = _wl()
    # 1) persistent fault, reroute off -> names the dead link
    stall = simulate(_torus_spec(
        FaultModel(dead_links=((1, 2),), reroute=False)), wl)
    assert stall.diagnose().startswith("fault stall: link (1, 2)")

    # 2) analyzer-refutable config -> static analysis verdict
    wedge = NocSpec.wide_only(4, 4, topology=Torus(4, 4), burstlen=32,
                              max_wide_outstanding=16, cycles=400)
    wedge_wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                             counts={"narrow": 4, "wide": 64},
                             src=0, dst=15, bidir=True)
    r = simulate(wedge, wedge_wl)
    assert r.diagnose().startswith("static analysis:")
    assert "cdg_acyclic" in r.diagnose()

    # 3) healthy spec, short horizon -> congestion, not deadlock
    short = simulate(NocSpec.narrow_wide(4, 4, cycles=80), wl)
    assert not bool(short.drained)
    assert short.diagnose().startswith("analyzer passed")
    assert "congestion" in short.diagnose()


def test_diagnose_names_dead_router():
    from repro.noc import simulate_schedules
    spec = _torus_spec(FaultModel(dead_nodes=(5,), reroute=False),
                       cycles=2000)
    r = simulate_schedules(spec, _avoid_dead_node(spec, _wl(seed=3), 5))
    if not bool(r.drained):
        assert r.diagnose().startswith("fault stall: router 5")


# --------------------------------------------------------------------- #
# regenerated tables re-pass the proofs (incl. property test)
# --------------------------------------------------------------------- #
def test_unroutable_cut_raises_with_coords():
    with pytest.raises(UnroutableCutError) as ei:
        cut_tables(Mesh(2, 2), RoutingPolicy.xy(2),
                   FaultModel(dead_links=((0, 1), (0, 2))))
    assert ei.value.coords == (1, 0)


def test_analyze_reports_unroutable_cut():
    from repro.noc.analyze import analyze_routing
    checks = analyze_routing(
        Mesh(2, 2), RoutingPolicy.xy(2),
        FaultModel(dead_links=((0, 1), (0, 2))))
    assert len(checks) == 1
    c = checks[0]
    assert c.name == "fault_reroute" and c.verdict == "FAIL"
    assert c.coords == (1, 0) and "disconnects" in c.detail


def test_cut_tables_pass_full_lint_and_cdg():
    from repro.noc.analyze import analyze_routing
    for topo, pol in ((Mesh(4, 4), RoutingPolicy.xy(2)),
                      (Torus(4, 4), RoutingPolicy.xy(3))):
        checks = analyze_routing(topo, pol,
                                 FaultModel(dead_links=((1, 2),),
                                            dead_nodes=(9,)))
        bad = [c for c in checks if c.verdict == "FAIL"]
        assert not bad, bad
        assert any(c.name == "fault_reroute" for c in checks)
        assert any(c.name == "cdg_acyclic" for c in checks)


def test_reroute_needs_spare_vc():
    with pytest.raises(ValueError, match="n_vcs >= 2"):
        NocSpec.narrow_wide(4, 4, faults=FaultModel(dead_links=((5, 6),)))


@pytest.mark.parametrize("torus", [False, True])
def test_every_single_link_cut_reproves_on_3x3(torus):
    """Exhaustive (no hypothesis needed): every possible single-link
    cut of a 3x3 mesh/torus regenerates tables that pass the full
    structural lint and the CDG deadlock proof."""
    from repro.noc.analyze import analyze_routing
    topo = Torus(3, 3) if torus else Mesh(3, 3)
    pol = RoutingPolicy.xy(3 if torus else 2)
    nbr, _, _ = topo.tables()
    R, P = nbr.shape
    links = sorted({(min(r, int(nbr[r, p])), max(r, int(nbr[r, p])))
                    for r in range(R) for p in range(P - 1)
                    if nbr[r, p] >= 0})
    for lk in links:
        checks = analyze_routing(topo, pol,
                                 FaultModel(dead_links=(lk,)))
        bad = [c for c in checks if c.verdict == "FAIL"]
        assert not bad, (lk, bad)


@settings(max_examples=12, deadline=None)
@given(nx=st.integers(2, 4), ny=st.integers(2, 4), torus=st.booleans(),
       kill_node=st.booleans(), pick=st.integers(0, 10 ** 6))
def test_random_single_cut_tables_reprove_deadlock_free(
        nx, ny, torus, kill_node, pick):
    """Any single dead link or dead router on any small mesh/torus:
    either the cut disconnects the fabric (UnroutableCutError with
    coordinates) or the regenerated tables pass every structural check
    AND the CDG deadlock proof, and a short simulation drains."""
    from repro.noc.analyze import analyze_routing
    topo = Torus(nx, ny) if torus else Mesh(nx, ny)
    pol = RoutingPolicy.xy(3 if torus else 2)
    nbr, _, _ = topo.tables()
    R, P = nbr.shape
    if kill_node:
        fm = FaultModel(dead_nodes=(pick % R,))
    else:
        links = sorted({(min(r, int(nbr[r, p])), max(r, int(nbr[r, p])))
                        for r in range(R) for p in range(P - 1)
                        if nbr[r, p] >= 0})
        fm = FaultModel(dead_links=(links[pick % len(links)],))
    try:
        rt = cut_tables(topo, pol, fm)
    except UnroutableCutError as e:
        assert e.coords
        checks = analyze_routing(topo, pol, fm)
        assert checks[0].name == "fault_reroute"
        assert checks[0].verdict == "FAIL"
        return
    validate_tables(rt.nbr, rt.opp, rt.route)       # raises on failure
    checks = analyze_routing(topo, pol, fm)
    assert not [c for c in checks if c.verdict == "FAIL"]

    from repro.noc import simulate_schedules
    spec = NocSpec.narrow_wide(nx, ny, topology=topo, routing=pol,
                               cycles=6000, faults=fm)
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.2, "wide": 0.5},
                       counts={"narrow": 4, "wide": 2}, seed=1)
    if fm.dead_nodes:
        sched = _avoid_dead_node(spec, wl, fm.dead_nodes[0])
    else:
        sched = wl.schedules(spec)
    r = simulate_schedules(spec, sched)
    assert bool(r.drained), r.diagnose()
