"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gemm import expert_gemm_pallas, expert_gemm_ref
from repro.kernels.noc_router import router_arbiter_pallas, router_arbiter_ref
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_pallas

TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (1, 128, 128, 4, 2, 64),
    (2, 256, 256, 4, 4, 64),
    (1, 128, 256, 8, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0),
])
def test_flash_attention(B, Sq, Sk, Hq, Hkv, D, dtype, causal, window, softcap):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = _rand(k1, (B, Sq, Hq, D), dtype)
    k = _rand(k2, (B, Sk, Hkv, D), dtype)
    v = _rand(k3, (B, Sk, Hkv, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=64, block_k=64,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **TOL)


@pytest.mark.parametrize("rows,d", [(64, 128), (256, 256), (17, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    k1, k2 = jax.random.split(jax.random.key(1))
    x = _rand(k1, (rows, d), dtype)
    w = _rand(k2, (d,), jnp.float32)
    if rows % 64:
        pytest.skip("pallas path requires row-aligned blocks; ref covers")
    out = rmsnorm_pallas(x, w, block_rows=64, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **TOL)


@pytest.mark.parametrize("B,S,H,P,G,N,Q", [
    (1, 256, 2, 32, 1, 32, 64),
    (2, 128, 4, 16, 2, 16, 64),
    (1, 512, 2, 64, 1, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd(B, S, H, P, G, N, Q, dtype):
    keys = jax.random.split(jax.random.key(2), 5)
    x = _rand(keys[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (B, S, H), jnp.float32))
    A_log = _rand(keys[2], (H,), jnp.float32) * 0.1
    Bm = _rand(keys[3], (B, S, G, N), dtype) * 0.3
    Cm = _rand(keys[4], (B, S, G, N), dtype) * 0.3
    D = jnp.ones((H,), jnp.float32)
    y = ssd_pallas(x, dt, A_log, Bm, Cm, D, chunk=Q, interpret=True)
    want = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=Q)
    np.testing.assert_allclose(y.astype(np.float32),
                               want.astype(np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssd_final_state():
    B, S, H, P, G, N, Q = 1, 256, 2, 32, 1, 32, 64
    keys = jax.random.split(jax.random.key(3), 5)
    x = _rand(keys[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (B, S, H), jnp.float32))
    A_log = _rand(keys[2], (H,), jnp.float32) * 0.1
    Bm = _rand(keys[3], (B, S, G, N), jnp.float32) * 0.3
    Cm = _rand(keys[4], (B, S, G, N), jnp.float32) * 0.3
    D = jnp.ones((H,), jnp.float32)
    y, h = ssd_pallas(x, dt, A_log, Bm, Cm, D, chunk=Q,
                      return_final_state=True, interpret=True)
    yr, hr = ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=Q,
                         return_final_state=True)
    # ref state layout (B,H,P,N) matches kernel output
    np.testing.assert_allclose(h, hr, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("E,C,d,f", [(4, 64, 128, 256), (2, 128, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm(E, C, d, f, dtype):
    k1, k2 = jax.random.split(jax.random.key(4))
    x = _rand(k1, (E, C, d), dtype)
    w = _rand(k2, (E, d, f), dtype)
    out = expert_gemm_pallas(x, w, block_c=64, block_f=128, interpret=True)
    want = expert_gemm_ref(x, w)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), **TOL)


def _rand_arbiter_state(rng, R, P, lock_frac=0.2):
    """Random routed head state (the arbiter's post-route-lookup view)."""
    out_port = np.where(rng.random((R, P)) < 0.7,
                        rng.integers(0, P, size=(R, P)), 99).astype(np.int32)
    beat = rng.integers(1, 5, size=(R, P)).astype(np.int32)
    ptr = rng.integers(0, P, size=(R, P)).astype(np.int32)
    free = rng.integers(0, 2, size=(R, P)).astype(np.int32)
    lock = np.where(rng.random((R, P)) < lock_frac,
                    rng.integers(0, P, size=(R, P)), -1).astype(np.int32)
    return out_port, beat, ptr, free, lock


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("R,P,lock_frac", [
    (16, 5, 0.2),    # paper 5-port router, block-aligned
    (12, 5, 0.2),    # 3x4 mesh: R not divisible by the default block
    (16, 9, 0.2),    # express-link radix (Mesh(express=(2,)))
    (16, 5, 0.8),    # lock-heavy: the seed kernel's rr_ptr parity bug
    (13, 5, 0.5),    # prime R: used to degrade to block_r=1; now padded
    (7, 5, 0.5),     # odd R below the default block
    (23, 9, 0.8),    # prime R x express radix x lock-heavy
])
def test_router_arbiter(seed, R, P, lock_frac):
    """Random router states: kernel == engine arbiter (exact int match).

    The lock-heavy cases are a regression for the seed kernel, which
    advanced the round-robin pointer on wormhole-locked grants while
    the engine held it — breaking flit-level backend parity.  The
    prime/odd-R cases regression-test the neutral-row padding that
    replaced `_pick_block`'s degenerate fallback to 1-row tiles."""
    rng = np.random.default_rng(seed)
    args = [jnp.asarray(a) for a in _rand_arbiter_state(rng, R, P,
                                                        lock_frac)]
    got = router_arbiter_pallas(*args, interpret=True)
    want = router_arbiter_ref(*args)
    for g, w, name in zip(got, want, ("winner", "pop", "ptr", "lock")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_router_arbiter_holds_ptr_under_lock():
    """Directed case: a locked output grants its locked input but must
    NOT advance the round-robin pointer (engine semantics)."""
    R, P = 1, 5
    out_port = np.full((R, P), 99, np.int32)
    out_port[0, 2] = 0                       # input 2 requests output 0
    beat = np.full((R, P), 3, np.int32)      # mid-burst
    ptr = np.zeros((R, P), np.int32)
    free = np.ones((R, P), np.int32)
    lock = np.full((R, P), -1, np.int32)
    lock[0, 0] = 2                           # output 0 locked to input 2
    winner, pop, nptr, nlock = [
        np.asarray(x) for x in router_arbiter_pallas(
            jnp.asarray(out_port), jnp.asarray(beat), jnp.asarray(ptr),
            jnp.asarray(free), jnp.asarray(lock), interpret=True)]
    assert winner[0, 0] == 2 and pop[0, 2] == 1
    assert nptr[0, 0] == 0                   # held, not advanced
    assert nlock[0, 0] == 2                  # burst continues
