"""Pipeline parallelism + flat_dp equivalence (subprocess, 8 devices)."""


def test_flat_dp_equivalence(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.models import build_model
from repro.dist import step as step_lib, params as params_lib

def run(mesh_cfg, flat_dp=False):
    mcfg = get_arch("llama3.2-1b").smoke()
    shape = ShapeConfig("t", 32, 4, "train")
    cfg = RunConfig(model=mcfg, shape=shape, mesh=mesh_cfg, flat_dp=flat_dp)
    mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(mesh_cfg.shape))
    model = build_model(mcfg, cfg)
    art = step_lib.build_train_step(model, shape, mesh)
    params = params_lib.materialize_sharded(art.param_specs, jax.random.key(0), mesh)
    opt = params_lib.materialize_sharded(art.opt_specs, jax.random.key(0), mesh)
    kb = jax.random.key(7)
    batch = {"tokens": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32),
             "labels": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32)}
    _, _, m = art.fn(params, opt, jnp.int32(0), batch)
    return float(m["loss"]), float(m["grad_norm"])

l0, g0 = run(MeshConfig(1, 1, 1))
l1, g1 = run(MeshConfig(data=2, model=2), flat_dp=True)
assert abs(l0 - l1) < 2e-2, (l0, l1)
assert abs(g0 - g1) / g0 < 7e-2, (g0, g1)
print("PASS flat_dp", l0, l1)
""", n_devices=4)


def test_pipeline_equivalence(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_arch, ShapeConfig
from repro.configs.base import MeshConfig, RunConfig
from repro.models import build_model
from repro.dist import params as params_lib, pipeline, step as step_lib

mcfg = get_arch("llama3.2-1b").smoke()
shape = ShapeConfig("t", 32, 4, "train")
kb = jax.random.key(7)
batch = {"tokens": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32),
         "labels": jax.random.randint(kb, (4, 32), 0, mcfg.vocab_size, jnp.int32)}

cfg0 = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(1, 1, 1))
mesh0 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
m0 = build_model(mcfg, cfg0)
a0 = step_lib.build_train_step(m0, shape, mesh0)
p0 = params_lib.materialize_sharded(a0.param_specs, jax.random.key(0), mesh0)
o0 = params_lib.materialize_sharded(a0.opt_specs, jax.random.key(0), mesh0)
_, _, r0 = a0.fn(p0, o0, jnp.int32(0), batch)

cfg1 = RunConfig(model=mcfg, shape=shape, mesh=MeshConfig(data=2, model=2, pod=2),
                 microbatches=2)
mesh1 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 3)
m1 = build_model(mcfg, cfg1)
a1 = pipeline.build_pipeline_train_step(m1, shape, mesh1)
p1 = params_lib.materialize_sharded(a1.param_specs, jax.random.key(0), mesh1)
o1 = params_lib.materialize_sharded(a1.opt_specs, jax.random.key(0), mesh1)
_, _, r1 = a1.fn(p1, o1, jnp.int32(0), batch)

l0, l1 = float(r0["loss"]), float(r1["loss"])
g0, g1 = float(r0["grad_norm"]), float(r1["grad_norm"])
assert abs(l0 - l1) < 3e-2, (l0, l1)
assert abs(g0 - g1) / g0 < 0.1, (g0, g1)
print("PASS pipeline", l0, l1)
""")
