"""Static-analysis verifier tests (repro.noc.analyze).

Ground truth pinned here: the analyzer must flag PR-5's VC-less
minimal-wrap torus with a concrete (link, VC) channel-dependency
cycle, must pass xy(n_vcs=2) / o1turn / valiant and every committed
preset, and its verdict must agree with simulated liveness (the
hypothesis property test at the bottom: analyzer deadlock-free =>
the sim drains).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from conftest import given, settings, st  # noqa: E402

from repro.noc import (Mesh, NocSpec, RoutingPolicy, Torus,  # noqa: E402
                       Workload, simulate, sweep)
from repro.noc import analyze as anz  # noqa: E402
from repro.noc.analyze import (AnalysisError, analyze,  # noqa: E402
                               analyze_routing, check_protocol,
                               verify_spec)
from repro.noc.engine import sim_cache_stats  # noqa: E402
from repro.noc.topology import run_table_checks  # noqa: E402


def wedge_spec(cycles=600):
    """PR-5's saturating-burst torus wedge configuration."""
    return NocSpec.wide_only(4, 4, topology=Torus(4, 4), burstlen=32,
                             cycles=cycles, max_wide_outstanding=16)


def wedge_workload():
    return Workload.make("all_to_all", rates={"wide": 1.0},
                         rounds={"wide": 2}, write_frac=0.5)


# --------------------------------------------------------------------- #
# routing family: the channel-dependency deadlock proof
# --------------------------------------------------------------------- #
def test_wedge_flagged_with_concrete_cycle():
    report = analyze(wedge_spec())
    assert not report.ok and report.verdict == "FAIL"
    c = report["cdg_acyclic"]
    assert c.verdict == "FAIL" and c.family == "routing"
    # offending coords: a CONNECTED cycle of ((u, v), vc) links
    assert len(c.coords) >= 2
    for (link, vc) in c.coords:
        u, v = link
        assert 0 <= u < 16 and 0 <= v < 16 and vc == 0
    for (link, _), (nxt, _) in zip(c.coords,
                                   c.coords[1:] + c.coords[:1]):
        assert link[1] == nxt[0], "cycle links must chain head-to-tail"
    assert "n_vcs=2" in c.suggestion


def test_escape_vc_tables_prove_acyclic():
    # the dateline tables remove the wrap cycle from the CDG itself —
    # a link-level analysis (ignoring VCs) would wrongly flag this
    checks = analyze_routing(Torus(4, 4), RoutingPolicy.xy(2))
    cdg = next(c for c in checks if c.name == "cdg_acyclic")
    assert cdg.verdict == "PASS"


@pytest.mark.parametrize("topo,policy", [
    (Mesh(4, 4), RoutingPolicy.xy(1)),
    (Mesh(4, 4), RoutingPolicy.xy(2)),
    (Mesh(4, 4, express=(2,)), RoutingPolicy.xy(1)),
    (Torus(4, 4), RoutingPolicy.xy(2)),
    (Torus(3, 5), RoutingPolicy.xy(2)),
    (Mesh(4, 4), RoutingPolicy.o1turn(2)),
    (Torus(4, 4), RoutingPolicy.o1turn(4)),
    (Mesh(4, 4), RoutingPolicy.valiant(4)),
    (Mesh(5, 3), RoutingPolicy.valiant(6, 3)),
], ids=str)
def test_deadlock_free_matrix(topo, policy):
    checks = analyze_routing(topo, policy)
    assert all(c.verdict == "PASS" for c in checks), [
        (c.name, c.detail) for c in checks if c.verdict != "PASS"]


def test_vcless_torus_cycle_is_a_real_ring():
    # every link in the reported cycle is a unit-stride torus link
    checks = analyze_routing(Torus(4, 4), RoutingPolicy.xy(1))
    cdg = next(c for c in checks if c.name == "cdg_acyclic")
    assert cdg.verdict == "FAIL"
    nbr = Torus(4, 4).tables()[0]
    for (u, v), _vc in cdg.coords:
        assert v in nbr[u], f"{u}->{v} is not a wired link"


# --------------------------------------------------------------------- #
# the verify= gate
# --------------------------------------------------------------------- #
def test_verify_full_rejects_wedge_before_stepping():
    spec = wedge_spec(cycles=613)      # unique horizon -> unique jit key
    before = sim_cache_stats()["misses"]
    with pytest.raises(AnalysisError) as ei:
        simulate(spec, wedge_workload(), verify="full")
    assert sim_cache_stats()["misses"] == before, \
        "verify='full' must reject before compiling/stepping"
    assert "cdg_acyclic" in str(ei.value)
    assert ei.value.report["cdg_acyclic"].coords


def test_verify_default_and_off_still_simulate_the_wedge():
    # the wedge is a *documented* configuration — default (fast) and
    # off verification must keep simulating it so the dynamic
    # regression can observe drained=False
    r = simulate(wedge_spec(), wedge_workload())
    assert not np.all(r.drained)
    r2 = simulate(wedge_spec(), wedge_workload(), verify="off")
    assert bool(np.all(r.drained == r2.drained))


def test_verify_full_passes_fixed_policy_and_sweep_gate():
    spec = wedge_spec(cycles=3500).with_(routing=RoutingPolicy.xy(2))
    wl = wedge_workload()
    r = simulate(spec, wl, verify="full")
    assert bool(np.all(r.drained))
    with pytest.raises(AnalysisError):
        sweep([(wedge_spec(), wl)], verify="full")
    with pytest.raises(ValueError, match="verify must be"):
        simulate(spec, wl, verify="paranoid")


def test_undrained_summary_carries_diagnosis():
    r = simulate(wedge_spec(), wedge_workload())
    s = r.summary()
    assert not np.all(r.drained)
    assert "cdg_acyclic" in s["diagnosis"]
    # congestion (not deadlock): analyzer passed -> says so
    mesh = NocSpec.narrow_wide(4, 4, cycles=60)
    rm = simulate(mesh, Workload.make(
        "all_to_all", rates={"wide": 1.0}, rounds={"wide": 4},
        write_frac=0.5))
    assert not np.all(rm.drained)
    assert "congestion" in rm.summary()["diagnosis"]
    # drained runs carry no diagnosis key
    ok = simulate(mesh.with_(cycles=4000), Workload.make(
        "uniform_random", rates={"narrow": 0.05, "wide": 0.05},
        counts={"narrow": 5, "wide": 5}))
    assert bool(np.all(ok.drained))
    assert "diagnosis" not in ok.summary()


# --------------------------------------------------------------------- #
# protocol family
# --------------------------------------------------------------------- #
def test_construction_rejects_overflowable_resp_q_cap():
    with pytest.raises(AnalysisError) as ei:
        NocSpec.narrow_wide(4, 4, resp_q_cap=4)   # < max_outstanding=8
    chk = ei.value.report["credit_conservation"]
    assert chk.verdict == "FAIL"
    assert chk.coords and chk.coords[0] in ("req", "rsp", "wide")
    assert "resp_q_cap>=8" in chk.suggestion
    # a cap covering the worst single (class, flow) budget constructs,
    # reporting the single-source aggregate as an advisory WARN
    spec = NocSpec.narrow_wide(2, 2, resp_q_cap=16)
    chk = analyze(spec)["credit_conservation"]
    assert chk.verdict == "WARN"


def test_message_order_verdicts():
    # wide_only: AR/AW share the single channel with R/B at one VC
    chk = analyze(NocSpec.wide_only(4, 4))["message_order"]
    assert chk.verdict == "WARN"
    assert any("narrow" == cls for cls, _ in chk.coords)
    # narrow_wide: responses own their channels (W sharing R's wide
    # channel is the paper's design and stays PASS)
    assert analyze(NocSpec.narrow_wide(4, 4))["message_order"] \
        .verdict == "PASS"
    assert analyze(NocSpec.multi_stream(4, 4))["message_order"] \
        .verdict == "PASS"
    # VC separation clears the shared-channel WARN
    chk = analyze(NocSpec.wide_only(
        4, 4, routing=RoutingPolicy.xy(2)))["message_order"]
    assert chk.verdict == "PASS"


# --------------------------------------------------------------------- #
# lint family: named checks + offending coordinates
# --------------------------------------------------------------------- #
def _tables(topo):
    nbr, opp, route = (a.copy() for a in topo.tables())
    return nbr, opp, route


def _failing(results):
    return next((r for r in results if r[1]), None)


def test_lint_local_port_coords():
    nbr, opp, route = _tables(Mesh(3, 3))
    nbr[2, -1] = 0                        # local port must stay linkless
    results, hops = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    assert name == "local_port" and hops is None
    assert "local port" in err and coords == (2, nbr.shape[1] - 1)


def test_lint_duplex_coords():
    nbr, opp, route = _tables(Mesh(3, 3))
    r, p = 4, int(np.argmax(nbr[4] >= 0))
    other = int(nbr[r, p])                # 4's old neighbor on that link
    q = int(np.argmax(nbr[other] == r))   # ...and its port back to 4
    nbr[r, p] = (nbr[r, p] + 1) % 9       # rewire one link one-way
    results, _ = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    # either endpoint of the now-asymmetric link is a valid offense
    assert name == "duplex_links" and coords in ((r, p), (other, q))
    assert "is not duplex" in err


def test_lint_route_structure_coords():
    nbr, opp, route = _tables(Mesh(3, 3))
    route[0, 0] = 1                       # self-route must use local port
    results, _ = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    assert name == "route_structure" and coords == (0, 0)
    assert "local port" in err

    nbr, opp, route = _tables(Mesh(3, 3))
    route[0, 8] = nbr.shape[1] - 1        # local port before destination
    results, _ = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    assert name == "route_structure" and coords == (0, 8)

    nbr, opp, route = _tables(Mesh(3, 3))
    route[0, 8] = 0                       # N port of router 0 is unwired
    results, _ = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    assert name == "route_structure" and coords == (0, 8)
    assert "missing link" in err


def test_lint_termination_coords():
    nbr, opp, route = _tables(Mesh(2, 2))
    route[0, 3], route[1, 3] = 1, 3       # 0 <-> 1 ping-pong toward 3
    results, hops = run_table_checks(nbr, opp, route)
    name, err, coords = _failing(results)
    assert name == "route_termination" and hops is None
    assert err == "routing does not terminate" and coords[1] == 3


def test_lint_all_pass_on_compiled_tables():
    rt = RoutingPolicy.o1turn(4).compile(Torus(4, 4))
    results, hops = run_table_checks(rt.nbr, rt.opp, rt.route)
    assert [r[0] for r in results] == [
        "no_port_sentinel", "local_port", "duplex_links",
        "route_structure", "route_termination"]
    assert all(err is None for _, err, _ in results)
    assert hops is not None and hops.shape == (16, 32)


def test_dateline_monotonicity_coords():
    # break the one-way escape transition by hand: router 0's E hop
    # toward dest 1 drops back to VC0 after the wrap delivered into VC1
    rt = RoutingPolicy.xy(2).compile(Torus(4, 4))
    V = rt.n_vcs
    route = rt.route.copy()
    q = route[0, 1]
    assert q % V == 1                     # post-wrap hop rides escape VC
    route[0, 1] = (q // V) * V            # force it back to VC0
    bad = rt._replace(route=route)
    chk = anz._dateline_check(Torus(4, 4), bad)
    assert chk.verdict == "FAIL"
    plane, src, dest, router = chk.coords
    assert (plane, dest, router) == (0, 1, 0) and src != 0
    # and the untouched tables are monotone
    assert anz._dateline_check(Torus(4, 4), rt).verdict == "PASS"


def test_minimality_reports_stretch_for_detour_planes():
    checks = analyze_routing(Mesh(4, 4), RoutingPolicy.valiant(4))
    m = next(c for c in checks if c.name == "route_minimality")
    assert m.verdict == "PASS" and "stretch" in m.detail


# --------------------------------------------------------------------- #
# report plumbing + CLI
# --------------------------------------------------------------------- #
def test_report_is_machine_readable():
    report = analyze(wedge_spec())
    assert report.failures and report.failures[0].name == "cdg_acyclic"
    assert report.level == "full"
    line = report.summary_line()
    assert "FAIL" in line and "fix:" in line
    txt = report.render()
    assert "verdict: FAIL" in txt and "cdg_acyclic" in txt
    with pytest.raises(KeyError):
        report["no_such_check"]
    fast = analyze(wedge_spec(), level="fast")
    assert fast.ok and {c.family for c in fast.checks} == {"protocol"}
    with pytest.raises(ValueError, match="level"):
        analyze(wedge_spec(), level="everything")


def test_cli_matrix_and_single_spec(capsys):
    assert anz.main(["--all-presets"]) == 0
    out = capsys.readouterr().out
    n_rows = len(anz._preset_matrix())
    assert "wedge" in out
    assert f"all {n_rows} matrix expectations hold" in out
    assert anz.main(["--preset", "wide_only", "--topology", "torus"]) == 1
    assert "cdg_acyclic" in capsys.readouterr().out
    assert anz.main(["--preset", "narrow_wide", "--topology", "torus",
                     "--routing", "xy", "--n-vcs", "2"]) == 0
    assert "verdict: PASS" in capsys.readouterr().out


# --------------------------------------------------------------------- #
# property: analyzer verdict agrees with simulated liveness
# --------------------------------------------------------------------- #
_POLICIES = [RoutingPolicy.xy(1), RoutingPolicy.xy(2),
             RoutingPolicy.o1turn(2), RoutingPolicy.o1turn(4),
             RoutingPolicy.valiant(4)]


@settings(max_examples=10, deadline=None)
@given(nx=st.integers(2, 4), ny=st.integers(2, 4),
       torus=st.booleans(), policy=st.sampled_from(_POLICIES),
       rounds=st.integers(1, 2), seed=st.integers(0, 3))
def test_analyzer_deadlock_free_implies_sim_drains(nx, ny, torus, policy,
                                                   rounds, seed):
    """One-sided agreement: whenever the analyzer proves a (topology,
    policy) deadlock-free, a saturating wormhole workload on the
    shared-channel ablation must drain.  (The converse is not a
    theorem: a cyclic CDG needs enough load to close the wait loop.)"""
    topo = Torus(nx, ny) if torus else Mesh(nx, ny)
    try:
        spec = NocSpec.wide_only(nx, ny, topology=topo, burstlen=8,
                                 cycles=3000, routing=policy)
    except ValueError:
        return                           # invalid (policy, topology) pair
    report = analyze(spec)
    if not report.ok:
        return                           # analyzer says deadlock-possible
    wl = Workload.make("all_to_all", rates={"wide": 1.0},
                       rounds={"wide": rounds}, write_frac=0.5, seed=seed)
    r = simulate(spec, wl, verify="full")
    assert bool(np.all(r.drained)), (
        f"analyzer PASSed {report.subject} but the sim wedged "
        f"(stall={int(np.max(r.max_stall_cycles))})")


def test_wedge_liveness_agrees_both_ways():
    """The documented wedge: analyzer FAIL <-> sim wedges; the escape-VC
    fix: analyzer PASS <-> sim drains (same spec, same load)."""
    wl = wedge_workload()
    bad = wedge_spec(cycles=3500)
    assert not analyze(bad).ok
    r = simulate(bad, wl)
    assert not np.all(r.drained)
    good = bad.with_(routing=RoutingPolicy.xy(2))
    assert analyze(good).ok
    assert bool(np.all(simulate(good, wl, verify="full").drained))
