"""Unit + property tests for the FlooNoC core layer (flit/channels/ni/routing)."""
import jax
import jax.numpy as jnp
import numpy as np

# hypothesis-or-skip shim shared by every test module (dev extra)
from conftest import given, settings, st  # noqa: E402

from repro.core import channels, flit  # noqa: E402
from repro.core.collectives import _merge, _split  # noqa: E402

from repro.dist.compression import (dequantize_blockwise,  # noqa: E402
                                    quantize_blockwise)
from repro.models.layers import HeadPlan  # noqa: E402


# ---------------------------------------------------------------------------
# flit packing (property: pack/unpack is the identity for any float tree)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 7), st.integers(1, 5)),
                min_size=1, max_size=6),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_flit_roundtrip(shapes, dtype):
    leaves = [jnp.arange(a * b, dtype=jnp.float32).reshape(a, b).astype(dtype)
              for a, b in shapes]
    tree = {"leaves": leaves, "scalar": jnp.float32(3.5)}
    payload, header = flit.pack(tree)
    out = flit.unpack(payload, header)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_flit_header_is_static():
    payload, header = flit.pack([jnp.zeros((4, 4)), jnp.zeros((3,))])
    assert header.nbytes == (16 + 3) * 4
    assert len(payload) == 1     # one dtype group -> one wide word


# ---------------------------------------------------------------------------
# classification / bucketing
# ---------------------------------------------------------------------------
def test_classify_threshold():
    big = jnp.zeros((1 << 15,))          # 128 KiB fp32
    small = jnp.zeros((16,))
    cls = channels.classify([big, small], 65536)
    assert cls == [channels.WIDE, channels.NARROW]


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 1 << 18), min_size=1, max_size=30))
def test_bucketize_covers_all(sizes):
    leaves = [jnp.zeros((n,)) for n in sizes]
    buckets = channels.bucketize(leaves, 1 << 20)
    seen = sorted(i for b in buckets for i in b)
    assert seen == list(range(len(leaves)))


# ---------------------------------------------------------------------------
# split/merge (ring chunk plumbing)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(0, 2))
def test_split_merge_semantics(n, c, dim):
    """_split yields dim-chunks (moved to front); _merge concatenates
    stacked shards back along dim — the ring RS/AG layout contracts."""
    shape = [2, 3, 4]
    shape[dim] = n * c
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    xs = _split(x, n, dim)
    assert xs.shape[0] == n
    for k in range(n):
        want = jnp.moveaxis(
            jax.lax.slice_in_dim(x, k * c, (k + 1) * c, axis=dim), dim, 0)
        np.testing.assert_array_equal(np.asarray(xs[k]), np.asarray(want))
    # AG layout: stacked per-device shards (n, ...) concat along dim
    shards = jnp.stack([jax.lax.slice_in_dim(x, k * c, (k + 1) * c, axis=dim)
                        for k in range(n)])
    y = _merge(shards, dim)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# blockwise int8 quantization (property: bounded relative error)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.floats(0.01, 100.0))
def test_quant_error_bound(nblocks, scale):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, scale, nblocks * 256).astype(np.float32))
    q, s = quantize_blockwise(x, 256)
    y = dequantize_blockwise(q, s, 256)
    err = np.max(np.abs(np.asarray(x - y)))
    bound = np.max(np.abs(np.asarray(x))) / 127 * 1.01 + 1e-9
    assert err <= bound


# ---------------------------------------------------------------------------
# HeadPlan (property: every real q head maps to a stored kv head)
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16), st.sampled_from([1, 2, 4, 8, 16]))
def test_head_plan_covers(hq, hkv, model):
    if hq % hkv:
        hkv = max(1, hq // max(1, hq // hkv))
        if hq % hkv:
            return
    plan = HeadPlan.build(hq, hkv, 64, model)
    assert plan.hq_pad % model == 0
    for r in range(model):
        ridx = jnp.int32(r)
        kv_ids = np.asarray(plan.local_kv_ids(ridx))
        q2kv = np.asarray(plan.q_to_local_kv(ridx))
        qs = np.asarray(plan.local_q_ids(ridx))
        mask = np.asarray(plan.q_mask(ridx))
        assert np.all(kv_ids >= 0) and np.all(kv_ids < hkv)
        for j, qg in enumerate(qs):
            if mask[j] > 0:          # real head
                want = min(qg, hq - 1) // max(1, hq // hkv)
                assert kv_ids[q2kv[j]] == want, (qg, want, kv_ids, q2kv)


# ---------------------------------------------------------------------------
# NI windowed transactions
# ---------------------------------------------------------------------------
def test_windowed_transactions_results():
    from repro.core.ni import windowed_transactions
    thunks = [lambda i=i: jnp.full((4,), i, jnp.float32) for i in range(6)]
    outs = windowed_transactions(thunks, window=2)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), np.full((4,), i))


def test_windowed_rw_transactions_independent_directions():
    """The AXI AR/AW split analogue: read and write streams each come
    back complete and value-exact under independent (even asymmetric,
    uneven-length) windows."""
    from repro.core.ni import TransactionWindow, windowed_rw_transactions
    r_thunks = [lambda i=i: jnp.full((3,), i, jnp.float32)
                for i in range(5)]
    w_thunks = [lambda i=i: jnp.full((3,), 100 + i, jnp.float32)
                for i in range(3)]
    reads, writes = windowed_rw_transactions(
        r_thunks, w_thunks, window=2, write_window=1)
    assert len(reads) == 5 and len(writes) == 3
    for i, o in enumerate(reads):
        np.testing.assert_array_equal(np.asarray(o), np.full((3,), i))
    for i, o in enumerate(writes):
        np.testing.assert_array_equal(np.asarray(o), np.full((3,), 100 + i))
    tw = TransactionWindow(chunks=4, window=2, write_window=2)
    assert tw.rob_bytes_per_flit_rw(1024) == 2 * tw.rob_bytes_per_flit(1024)
