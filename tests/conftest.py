"""Test helpers. NOTE: no XLA_FLAGS here — the main pytest process must see
ONE device; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with n host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_devices
