"""Test helpers. NOTE: no XLA_FLAGS here — the main pytest process must see
ONE device; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

# hypothesis is an optional dev dependency (the `dev` extra in
# pyproject.toml): without it, property tests skip but plain tests
# still run.  Test modules import this one shim instead of each
# carrying their own copy: `from conftest import given, settings, st`.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="property tests need the `hypothesis` dev extra "
                   "(pip install -e .[dev])")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None
    st = _NoStrategies()


def run_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with n host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_devices
