"""Roofline HLO parser: trip-count scaling and collective byte accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.roofline import (analyze_hlo_text, _group_size, _link_bytes,
                                   _type_bytes)


def test_type_bytes():
    assert _type_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
    assert _type_bytes("bf16[2,3]") == 12
    assert _type_bytes("(f32[4], s32[2])") == 16 + 8
    assert _type_bytes("f32[]") == 0 or _type_bytes("f32[]") == 4  # scalar


def test_link_bytes_model():
    rest = "replica_groups=[16,16]<=[256]"
    assert _group_size(rest) == 16
    assert _link_bytes("all-gather", 100.0, rest) == 1500.0
    assert abs(_link_bytes("all-reduce", 100.0, rest) - 187.5) < 1e-9
    assert _link_bytes("collective-permute", 100.0, "") == 100.0


def test_scan_trip_count_scaling():
    """Parsed dot FLOPs must scale with the scan length (cost_analysis
    famously does not)."""
    def make(L):
        def step(c, x):
            return c @ x, ()

        def f(c, xs):
            return jax.lax.scan(step, c, xs)[0]

        N = 64
        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((N, N), jnp.float32),
            jax.ShapeDtypeStruct((L, N, N), jnp.float32))
        return lowered.compile()

    costs4 = analyze_hlo_text(make(4).as_text())
    costs8 = analyze_hlo_text(make(8).as_text())
    analytic8 = 2 * 64**3 * 8
    assert costs8.dot_flops == pytest.approx(analytic8, rel=0.01)
    assert costs8.dot_flops == pytest.approx(2 * costs4.dot_flops, rel=0.01)
    assert 8 in costs8.while_trips.values()


def test_collective_bytes_in_scan(subproc):
    subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.roofline import analyze_hlo_text

mesh = jax.make_mesh((4,), ("i",), axis_types=(jax.sharding.AxisType.Auto,))

def step(c, _):
    c = jax.lax.ppermute(c, "i", [(j, (j + 1) % 4) for j in range(4)])
    return c, ()

def f(c):
    return jax.lax.scan(step, c, None, length=6)[0]

sh = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
comp = jax.jit(sh).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
costs = analyze_hlo_text(comp.as_text())
want = 128 * 128 * 4 * 6          # one permute of the buffer x 6 trips
got = costs.collective_bytes.get("collective-permute", 0)
assert abs(got - want) / want < 0.01, (got, want)
print("PASS", got)
""", n_devices=4)
