"""Tests for the declarative repro.noc experiment API.

Covers: spec/workload validation, paper-preset invariants (Fig. 5a/5b
through the new surface), vmapped-sweep == Python-loop equivalence,
the uniform_random self-traffic regression, N-channel topologies, and
the NocSpec -> ChannelPolicy derivation shared with the collectives.
"""
import numpy as np
import pytest

# hypothesis-or-skip shim shared by every test module (dev extra)
from conftest import given, settings, st

from repro.noc import (Mesh, NocSpec, PhysicalChannel, Torus,  # noqa: F401
                       TrafficClass, Workload, build_channel_plan, hop_table,
                       sim_cache_clear, sim_cache_stats, simulate,
                       simulate_batch, sweep)


# --------------------------------------------------------------------- #
# spec validation / topology derivation
# --------------------------------------------------------------------- #
def test_spec_validates_class_map():
    with pytest.raises(ValueError, match="missing flow"):
        NocSpec(class_map=(("narrow.req", "req"), ("narrow.rsp", "rsp"),
                           ("wide.req", "req")))
    with pytest.raises(ValueError, match="unknown channel"):
        NocSpec(class_map=(("narrow.req", "nope"), ("narrow.rsp", "rsp"),
                           ("wide.req", "req"), ("wide.rsp", "wide")))


def test_channel_plan_presets():
    nw = build_channel_plan(NocSpec.narrow_wide())
    assert nw.n_ch == 3 and nw.n_q == 2
    assert nw.reqs_on == ((0, 1), (), ())        # shared req, narrow first
    assert nw.queues_on == ((), (0,), (1,))      # dedicated rsp networks
    wo = build_channel_plan(NocSpec.wide_only())
    assert wo.n_ch == 1 and wo.n_q == 1          # shared-FIFO ablation
    assert wo.queue_of_class == (0, 0)
    ms = build_channel_plan(NocSpec.multi_stream(n_wide=3))
    assert ms.n_ch == 5 and ms.n_q == 4


def test_workload_typed_against_classes():
    spec = NocSpec.narrow_wide(2, 2, cycles=100)
    with pytest.raises(KeyError):
        Workload.make("nonexistent_pattern")
    wl = Workload.make("fig5", rates={"bogus_class": 1.0},
                       counts={"bogus_class": 1})
    with pytest.raises(KeyError):
        wl.schedules(spec)


# --------------------------------------------------------------------- #
# paper invariants through the new API
# --------------------------------------------------------------------- #
def _fig5_wl(rate, n_wide, bidir=True):
    return Workload.make("fig5", rates={"narrow": 0.05, "wide": rate},
                         counts={"narrow": 100, "wide": n_wide},
                         src=0, dst=15, bidir=bidir)


def test_zero_load_latency():
    spec = NocSpec.narrow_wide(2, 1, cycles=200)
    r = simulate(spec, Workload.make("fig5", rates={"narrow": 0.01},
                                     counts={"narrow": 1}, src=0, dst=1))
    assert int(r.classes["narrow"].done[0]) == 1
    assert float(r.classes["narrow"].avg_lat[0]) == 18   # paper VI-A


def test_narrow_wide_isolation_vs_wide_only_degradation():
    """Fig. 5a through the new API: dedicated channels keep narrow
    latency flat; the shared wide-only link degrades max latency >=2x."""
    stats = {}
    for preset in (NocSpec.narrow_wide, NocSpec.wide_only):
        spec = preset(4, 4, cycles=8000)
        r = simulate_batch(spec, [_fig5_wl(0.0, 0), _fig5_wl(1.0, 128)])
        base = float(r.classes["narrow"].avg_lat[0, 0])
        stats[preset.__name__] = (
            float(r.classes["narrow"].avg_lat[1, 0]) / base,
            float(r.classes["narrow"].max_lat[1, 0]) / base)
    avg_nw, _ = stats["narrow_wide"]
    avg_wo, max_wo = stats["wide_only"]
    assert avg_nw < 1.1, stats
    assert avg_wo > 2.0, stats
    assert max_wo >= 2.0, stats


def test_wide_bandwidth_follows_fig5b_trend():
    """Fig. 5b: with separation, wide bandwidth under narrow
    interference stays within 15% of the clean run."""
    spec = NocSpec.narrow_wide(4, 4, cycles=6000)
    wls = [Workload.make("fig5", rates={"narrow": nr, "wide": 1.0},
                         counts={"narrow": 2000 if nr else 0, "wide": 128},
                         src=0, dst=5)
           for nr in (0.0, 1.0)]
    r = simulate_batch(spec, wls)
    clean = float(r.classes["wide"].eff_bw[0, 0])
    loaded = float(r.classes["wide"].eff_bw[1, 0])
    assert loaded >= 0.85 * clean, (clean, loaded)


# --------------------------------------------------------------------- #
# vmapped sweep == Python loop (the API's core promise)
# --------------------------------------------------------------------- #
def test_vmapped_sweep_matches_individual_runs():
    spec = NocSpec.narrow_wide(4, 4, cycles=2000)
    rates = [0.25, 0.5, 0.75, 1.0]
    wls = [Workload.make("fig5", rates={"narrow": 0.05, "wide": r},
                         counts={"narrow": 40, "wide": 24}, src=0, dst=15)
           for r in rates]
    batched = simulate_batch(spec, wls)
    assert batched.batch_shape == (len(rates),)
    for i, wl in enumerate(wls):
        single = simulate(spec, wl)
        for cname in ("narrow", "wide"):
            b, s = batched.point(i).classes[cname], single.classes[cname]
            np.testing.assert_array_equal(b.done, s.done)
            np.testing.assert_allclose(b.avg_lat, s.avg_lat)
            np.testing.assert_array_equal(b.beats_rx, s.beats_rx)
        np.testing.assert_array_equal(batched.point(i).total_link_moves,
                                      single.total_link_moves)


def test_scalar_field_sweep_vmaps():
    """service_lat is a traced operand: sweeping it batches in one jit
    and matches per-point runs."""
    spec = NocSpec.narrow_wide(2, 2, cycles=600)
    wl = Workload.make("fig5", rates={"narrow": 0.1}, counts={"narrow": 10},
                       src=0, dst=3)
    lats = [5, 10, 20]
    batched = simulate_batch(spec, [wl] * len(lats), service_lat=lats)
    for i, sl in enumerate(lats):
        single = simulate(spec, wl, service_lat=sl)
        np.testing.assert_allclose(
            batched.point(i).classes["narrow"].avg_lat,
            single.classes["narrow"].avg_lat)
    # more service latency -> strictly more round-trip latency
    l = [float(np.max(batched.classes["narrow"].avg_lat[i])) for i in
         range(len(lats))]
    assert l[0] < l[1] < l[2], l


def test_sweep_groups_static_specs():
    pts = [(NocSpec.narrow_wide(2, 2, depth=d, cycles=400),
            Workload.make("fig5", rates={"narrow": 0.1},
                          counts={"narrow": 5}))
           for d in (2, 3, 2)]
    res = sweep(pts)
    assert [int(r.classes["narrow"].done.sum()) for r in res] == [5, 5, 5]
    assert all(not r.batch_shape for r in res)


# --------------------------------------------------------------------- #
# workload patterns
# --------------------------------------------------------------------- #
def test_uniform_random_never_self():
    """Regression: the old remap (d + 1 + src) % R with d drawn from
    [0, R) produced dest == src whenever d == R-1."""
    spec = NocSpec.narrow_wide(4, 4, cycles=100)
    for seed in range(8):
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.5, "wide": 0.5},
                           counts={"narrow": 200, "wide": 50}, seed=seed)
        for name, (times, dests, _) in wl.schedules(spec).items():
            live = times < (1 << 30)
            srcs = np.broadcast_to(
                np.arange(spec.n_routers)[:, None], dests.shape)
            assert not np.any((dests == srcs) & live), (name, seed)


def test_patterns_produce_valid_schedules():
    spec = NocSpec.narrow_wide(4, 4, cycles=100)
    wls = [
        Workload.make("hotspot", rates={"narrow": 0.2}, counts={"narrow": 5}),
        Workload.make("transpose", rates={"wide": 0.5}, counts={"wide": 2}),
        Workload.make("all_to_all", rates={"narrow": 0.2},
                      rounds={"narrow": 1}),
    ]
    for wl in wls:
        sched = wl.schedules(spec)
        assert set(sched) == {"narrow", "wide"}
        for times, dests, writes in sched.values():
            assert times.shape == dests.shape == writes.shape
            assert np.all((dests >= 0) & (dests < spec.n_routers))
            assert np.all(writes == 0)       # read-only by default
            assert np.all(np.diff(
                np.where(times < (1 << 30), times, np.int64(1 << 30)),
                axis=1) >= 0)  # sorted per NI


def test_all_to_all_covers_every_pair():
    spec = NocSpec.narrow_wide(3, 3, cycles=100)
    wl = Workload.make("all_to_all", rates={"narrow": 1.0},
                       rounds={"narrow": 1})
    times, dests, _ = wl.schedules(spec)["narrow"]
    R = spec.n_routers
    for s in range(R):
        live = times[s] < (1 << 30)
        assert set(dests[s][live].tolist()) == set(range(R)) - {s}


# --------------------------------------------------------------------- #
# N-channel topologies beyond the paper's two
# --------------------------------------------------------------------- #
def test_multi_stream_completes_and_isolates():
    spec = NocSpec.multi_stream(3, 3, n_wide=2, cycles=4000)
    wl = Workload.make("fig5",
                       rates={"narrow": 0.1, "wide0": 1.0, "wide1": 1.0},
                       counts={"narrow": 20, "wide0": 8, "wide1": 8},
                       src=0, dst=8)
    r = simulate(spec, wl)
    assert int(r.classes["narrow"].done[0]) == 20
    assert int(r.classes["wide0"].done[0]) == 8
    assert int(r.classes["wide1"].done[0]) == 8
    # both streams deliver full bursts
    bl = spec.get_class("wide0").burst_beats
    assert int(r.classes["wide0"].beats_rx[0]) == 8 * bl
    # 4 physical networks (req, rsp, wide0, wide1) tracked independently
    assert len(r.channels) == 4
    assert float(r.channels["req"].energy_pj) > 0


# --------------------------------------------------------------------- #
# NocSpec -> ChannelPolicy (shared vocabulary with collectives)
# --------------------------------------------------------------------- #
def test_channel_policy_from_spec():
    from repro.core.channels import ChannelPolicy
    dual = ChannelPolicy.from_spec(NocSpec.narrow_wide())
    assert [(c.name, c.transport, c.channel) for c in dual.classes] == \
        [("narrow", "psum", "rsp"), ("wide", "ring", "wide")]
    single = ChannelPolicy.from_spec(NocSpec.wide_only())
    assert len({c.channel for c in single.classes}) == 1
    ms = ChannelPolicy.from_spec(NocSpec.multi_stream(n_wide=2))
    assert [c.channel for c in ms.classes] == ["rsp", "wide0", "wide1"]
    assert ms.classes[1].min_bytes < ms.classes[2].min_bytes


# --------------------------------------------------------------------- #
# first-class Topology (mesh / torus / express)
# --------------------------------------------------------------------- #
def test_topology_validation():
    with pytest.raises(ValueError, match="at least 2 routers"):
        Mesh(1, 1)
    with pytest.raises(ValueError, match="express stride"):
        Mesh(4, 4, express=(5,))
    with pytest.raises(ValueError, match="express"):
        Torus(4, 4, express=(2,))
    with pytest.raises(TypeError, match="topology"):
        NocSpec(topology="4x4")
    with pytest.raises(ValueError, match="does not match"):
        NocSpec.narrow_wide(8, 8, topology=Torus(4, 4))
    assert Mesh(4, 4, express=(2,)).n_ports == 9   # 5-port + 4 express
    assert Torus(4, 4).n_ports == 5


@pytest.mark.parametrize("nx,ny", [(4, 4), (5, 3), (2, 2)])
def test_topology_torus_hops_leq_mesh(nx, ny):
    """Wrap-around links never lengthen a deterministic route."""
    hm, ht = hop_table(Mesh(nx, ny)), hop_table(Torus(nx, ny))
    assert np.all(ht <= hm)
    if max(nx, ny) >= 4:
        assert ht.max() < hm.max()     # corners actually get closer


def test_topology_express_hops_and_ports():
    """Express strides shorten routes without breaking duplex links."""
    hm = hop_table(Mesh(8, 8))
    he = hop_table(Mesh(8, 8, express=(2,)))
    assert np.all(he <= hm)
    assert he.max() < hm.max()


def test_topology_express_reduces_latency_at_equal_load():
    """Same injected workload, same channel layout: express links cut
    average narrow latency."""
    wl = Workload.make("fig5", rates={"narrow": 0.2},
                       counts={"narrow": 30}, src=0, dst=7)
    lats = {}
    for tag, topo in (("mesh", Mesh(8, 1)), ("express", Mesh(8, 1,
                                                             express=(3,)))):
        spec = NocSpec.narrow_wide(8, 1, topology=topo, cycles=1500)
        r = simulate(spec, wl)
        assert int(r.classes["narrow"].done[0]) == 30
        lats[tag] = float(r.classes["narrow"].avg_lat[0])
    assert lats["express"] < lats["mesh"], lats


def test_topology_torus_end_to_end():
    """Torus spec runs the full engine with per-class metrics; the
    wrap route beats the mesh on corner-to-corner traffic."""
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 20, "wide": 8}, src=0, dst=15)
    res = {}
    for tag, topo in (("mesh", Mesh(4, 4)), ("torus", Torus(4, 4))):
        spec = NocSpec.narrow_wide(4, 4, topology=topo, cycles=3000)
        r = simulate(spec, wl)
        assert int(r.classes["narrow"].done[0]) == 20
        assert int(r.classes["wide"].beats_rx[0]) == 8 * spec.burstlen
        assert float(r.channels["wide"].energy_pj) > 0
        res[tag] = r
    assert (float(res["torus"].classes["narrow"].avg_lat[0])
            < float(res["mesh"].classes["narrow"].avg_lat[0]))
    # fewer hops -> fewer link traversals for identical traffic
    assert (int(res["torus"].total_link_moves)
            < int(res["mesh"].total_link_moves))


def test_topology_is_static_cache_key():
    """Same spec fields + different topology must not share a compiled
    simulator (specs compare unequal, so the lru_cache keys differ even
    where dataclass field-hashes collide across Mesh/Torus)."""
    a = NocSpec.narrow_wide(4, 4)
    assert a != NocSpec.narrow_wide(4, 4, topology=Torus(4, 4))
    assert a != NocSpec.narrow_wide(4, 4, topology=Mesh(4, 4, express=(2,)))
    assert a == NocSpec.narrow_wide(4, 4, topology=Mesh(4, 4))


# --------------------------------------------------------------------- #
# pluggable backends behind the same simulate() surface
# --------------------------------------------------------------------- #
def test_backend_registry():
    from repro.noc import get_backend, list_backends
    assert {"jnp", "pallas", "pallas_fused"} <= set(list_backends())
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("fpga")


def _assert_results_equal(a, b):
    for cname in a.classes:
        for f in ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw"):
            np.testing.assert_array_equal(
                getattr(a.classes[cname], f), getattr(b.classes[cname], f),
                err_msg=f"{cname}.{f}")
    for ch in a.channels:
        np.testing.assert_array_equal(a.channels[ch].link_moves,
                                      b.channels[ch].link_moves)


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("preset", [NocSpec.narrow_wide, NocSpec.wide_only])
def test_backend_kernels_match_jnp_on_paper_presets(preset, backend):
    """simulate(spec, wl, backend=...) is flit-for-flit identical to the
    jnp reference on both paper presets (fig5 workload), under
    interference load that exercises wormhole locks and round-robin
    state — for both the arbiter-only kernel and the fused full-cycle
    kernel."""
    spec = preset(4, 4, cycles=2000)
    wl = Workload.make("fig5", rates={"narrow": 0.05, "wide": 1.0},
                       counts={"narrow": 40, "wide": 24},
                       src=0, dst=15, bidir=True)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, backend=backend))


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_backend_kernels_match_jnp_on_torus(backend):
    """Backend equivalence is not mesh-specific: the kernels see only
    routed ports / static tables, so the torus agrees too."""
    spec = NocSpec.wide_only(3, 3, topology=Torus(3, 3), cycles=1200)
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.2, "wide": 0.5},
                       counts={"narrow": 20, "wide": 6}, seed=3)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, backend=backend))


def test_backend_fused_matches_jnp_on_express():
    """>5-port express-link routers through the fused kernel: the port
    count is a static parameter, not a baked-in 5."""
    topo = Mesh(6, 1, express=(2,))
    spec = NocSpec.narrow_wide(6, 1, topology=topo, cycles=1200)
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.3, "wide": 0.5},
                       counts={"narrow": 15, "wide": 4}, seed=5)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, backend="pallas_fused"))


def test_backend_batch_and_sweep_accept_backend():
    spec = NocSpec.narrow_wide(2, 2, cycles=400)
    wl = Workload.make("fig5", rates={"narrow": 0.1},
                       counts={"narrow": 5}, src=0, dst=3)
    b = simulate_batch(spec, [wl, wl], backend="pallas")
    s = simulate(spec, wl)
    np.testing.assert_array_equal(b.point(0).classes["narrow"].done,
                                  s.classes["narrow"].done)
    (r,) = sweep([(spec, wl)], backend="pallas")
    np.testing.assert_array_equal(r.classes["narrow"].done,
                                  s.classes["narrow"].done)


def test_backend_fused_batches():
    """The fused kernel composes with vmapped sweeps (the batching rule
    adds a grid dim over the stacked state)."""
    spec = NocSpec.wide_only(2, 2, cycles=400)
    wl = Workload.make("fig5", rates={"narrow": 0.1, "wide": 1.0},
                       counts={"narrow": 5, "wide": 3}, src=0, dst=3)
    b = simulate_batch(spec, [wl, wl], backend="pallas_fused")
    s = simulate(spec, wl)
    for i in range(2):
        np.testing.assert_array_equal(b.point(i).classes["wide"].done,
                                      s.classes["wide"].done)


# --------------------------------------------------------------------- #
# fused hot loop: property test (random fabrics, lock-heavy traffic)
# --------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(nx=st.integers(2, 4), ny=st.integers(1, 3),
       torus=st.booleans(), wide_only=st.booleans(),
       seed=st.integers(0, 99),
       burst=st.sampled_from([1, 2, 4, 8, 16]),
       n_narrow=st.integers(0, 25), n_wide=st.integers(0, 10),
       cycles=st.integers(50, 400))
def test_fused_backend_property(nx, ny, torus, wide_only, seed, burst,
                                n_narrow, n_wide, cycles):
    """Random topology/seed/burst streams: the fused kernel is
    flit-for-flit equal to the jnp reference over full random-length
    runs, including wormhole-lock-heavy traffic (wide_only + long
    bursts shares every flow on one link, so grants lock constantly)."""
    topo = Torus(nx, ny) if torus else Mesh(nx, ny)
    preset = NocSpec.wide_only if wide_only else NocSpec.narrow_wide
    spec = preset(nx, ny, topology=topo, burstlen=burst, cycles=cycles)
    wl = Workload.make("uniform_random",
                       rates={"narrow": 0.5, "wide": 1.0},
                       counts={"narrow": n_narrow, "wide": n_wide},
                       seed=seed)
    _assert_results_equal(simulate(spec, wl),
                          simulate(spec, wl, backend="pallas_fused"))


# --------------------------------------------------------------------- #
# one-compilation sweeps + compiled-sim cache behavior
# --------------------------------------------------------------------- #
def test_depth_sweep_single_compilation():
    """A FIFO-depth sweep across >= 4 depths runs through exactly ONE
    compiled_sim build (depth is a traced operand masked against the
    group max), and every point matches its natively-compiled run."""
    wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                       counts={"narrow": 10, "wide": 4}, src=0, dst=3)
    pts = [(NocSpec.narrow_wide(2, 2, depth=d, cycles=500), wl)
           for d in (2, 3, 4, 6)]
    sim_cache_clear()
    res = sweep(pts)
    stats = sim_cache_stats()
    assert stats["misses"] == 1, stats
    assert stats["evictions"] == 0, stats
    for (spec, _), r in zip(pts, res):
        single = simulate(spec, wl)
        _assert_results_equal(r, single)
        assert r.spec == spec     # each point keeps its OWN depths
    # deeper FIFOs never hurt: the sweep is a real ablation, not noise
    done = [int(r.classes["wide"].done.sum()) for r in res]
    assert done == sorted(done), done


def test_sim_cache_never_thrashes_on_large_grids():
    """A 70-spec grid compiles each spec exactly once; a second pass is
    all hits (the old lru_cache(maxsize=64) silently evicted jitted
    sims mid-sweep for grids this size)."""
    from repro.noc import compiled_sim
    specs = [NocSpec.narrow_wide(2, 2, cycles=100 + 10 * i)
             for i in range(70)]
    sim_cache_clear()
    for s in specs:
        compiled_sim(s, 8)
    first = sim_cache_stats()
    assert first["misses"] == 70 and first["evictions"] == 0, first
    for s in specs:
        compiled_sim(s, 8)
    second = sim_cache_stats()
    assert second["misses"] == 70, second
    assert second["hits"] >= 70, second
    assert second["evictions"] == 0, second


def test_resp_q_cap_sizes_ring_and_validates():
    with pytest.raises(ValueError, match="resp_q_cap"):
        NocSpec.narrow_wide(2, 2, resp_q_cap=1)
    spec_small = NocSpec.narrow_wide(2, 2, cycles=800, resp_q_cap=16)
    spec_big = NocSpec.narrow_wide(2, 2, cycles=800)
    wl = Workload.make("fig5", rates={"narrow": 0.2, "wide": 1.0},
                       counts={"narrow": 10, "wide": 4}, src=0, dst=3)
    # a ring that covers the in-flight responses behaves identically
    _assert_results_equal(simulate(spec_small, wl), simulate(spec_big, wl))
