"""Tests for the device-parallel simulation farm (``repro.noc.farm``).

Tier (a): ``sweep(devices=n)`` shard_maps the spec grid across the
device mesh — asserted bit-identical to the vmapped single-device path,
including uneven grids that exercise the pad-and-slice masking.
Tier (b): ``simulate(..., shard=RowShard(n))`` spatially shards a
mesh's router rows with per-cycle halo exchange — asserted
flit-for-flit identical to the unsharded engine on mesh AND torus with
mixed read/write traffic.

Also covers the satellite work riding this PR: the vectorized
route-table compile path (byte-identity against a straightforward
reference expansion on 32x32 fabrics), the farm compile cache, and the
fused kernel's VMEM budget check.

Multi-device cases run in-process when the interpreter already sees
several host devices (the CI farm lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and skip on a
single-device run; one subprocess test keeps tier-1 coverage of the
halo exchange even without the lane.
"""
import numpy as np
import pytest

from conftest import given, settings, st

import jax

from repro.noc import (Mesh, NocSpec, RoutingPolicy, RowShard, Torus,
                       Workload, farm_batch, merge_spec, partition_spec,
                       sim_cache_clear, sim_cache_stats, simulate, sweep)

CLASS_FIELDS = ("done", "avg_lat", "max_lat", "beats_rx", "eff_bw",
                "w_done", "w_avg_lat", "w_max_lat", "w_beats_rx",
                "w_eff_bw")


def assert_results_equal(a, b, ctx=""):
    """Bit-exact SimResult comparison: every class stat, per-channel
    link moves + VC occupancy, and the liveness scalars."""
    assert set(a.classes) == set(b.classes), ctx
    for cname in a.classes:
        for f in CLASS_FIELDS:
            np.testing.assert_array_equal(
                getattr(a.classes[cname], f), getattr(b.classes[cname], f),
                err_msg=f"{ctx}:{cname}.{f}")
    for ch in a.channels:
        np.testing.assert_array_equal(
            a.channels[ch].link_moves, b.channels[ch].link_moves,
            err_msg=f"{ctx}:{ch}.link_moves")
        np.testing.assert_array_equal(
            a.channels[ch].vc_occupancy, b.channels[ch].vc_occupancy,
            err_msg=f"{ctx}:{ch}.vc_occupancy")
    np.testing.assert_array_equal(np.asarray(a.drained),
                                  np.asarray(b.drained), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(a.max_stall_cycles),
                                  np.asarray(b.max_stall_cycles),
                                  err_msg=ctx)


# --------------------------------------------------------------------- #
# static / dynamic partition round trip
# --------------------------------------------------------------------- #
def _spec_variants():
    rng = np.random.default_rng(7)
    out = []
    for preset in (NocSpec.narrow_wide, NocSpec.wide_only):
        for _ in range(6):
            out.append(preset(
                int(rng.integers(2, 5)), int(rng.integers(1, 5)),
                depth=int(rng.integers(1, 7)),
                burstlen=int(rng.choice([4, 16, 32])),
                service_lat=int(rng.integers(1, 20)),
                cycles=int(rng.integers(100, 500)),
                max_wide_outstanding=int(rng.integers(1, 9))))
    out.append(NocSpec.multi_stream(3, 3, n_wide=2, cycles=300))
    out.append(NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                                   routing=RoutingPolicy.xy(2), cycles=200))
    out.append(NocSpec.narrow_wide(6, 2, topology=Mesh(6, 2, express=(2,)),
                                   cycles=200))
    return out


def test_partition_merge_round_trip_variants():
    for spec in _spec_variants():
        static, dyn = partition_spec(spec)
        assert hash(static) is not None       # the compile-cache key
        back = merge_spec(static, dyn)
        assert back == spec, spec
        # the static half is depth-normalized: any two depth variants
        # of one spec share it (that is what makes a sweep one compile)
        other = merge_spec(static, {**dyn,
                                    "depths": dyn["depths"] * 0 + 1})
        assert partition_spec(other)[0] == static


@settings(max_examples=40, deadline=None)
@given(nx=st.integers(2, 5), ny=st.integers(1, 4),
       depth=st.integers(1, 8), burstlen=st.sampled_from([4, 16, 32]),
       service_lat=st.integers(1, 24), wide=st.booleans())
def test_partition_merge_round_trip_property(nx, ny, depth, burstlen,
                                             service_lat, wide):
    preset = NocSpec.wide_only if wide else NocSpec.narrow_wide
    spec = preset(nx, ny, depth=depth, burstlen=burstlen,
                  service_lat=service_lat, cycles=200)
    static, dyn = partition_spec(spec)
    assert merge_spec(static, dyn) == spec


def test_merge_spec_rejects_bad_depths():
    static, dyn = partition_spec(NocSpec.narrow_wide(2, 2, cycles=100))
    with pytest.raises(ValueError, match="depths shape"):
        merge_spec(static, {**dyn, "depths": np.ones(17, np.int64)})


# --------------------------------------------------------------------- #
# tier (a): sharded sweep == vmapped sweep
# --------------------------------------------------------------------- #
def _sweep_points(n=6, cycles=400):
    pts = []
    for i in range(n):
        spec = NocSpec.narrow_wide(4, 4, depth=(2, 3, 4)[i % 3],
                                   cycles=cycles)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.1, "wide": 0.5},
                           counts={"narrow": 3, "wide": 2}, seed=i)
        pts.append((spec, wl))
    return pts


def test_sweep_devices1_bit_identical():
    pts = _sweep_points()
    ref = sweep(pts)
    farm = sweep(pts, devices=1)
    assert len(ref) == len(farm) == len(pts)
    for i, (r, f) in enumerate(zip(ref, farm)):
        assert_results_equal(r, f, ctx=f"point{i}")


def test_farm_sweep_caches_per_device_count():
    pts = _sweep_points(n=4)
    sim_cache_clear()
    sweep(pts, devices=1)
    misses = sim_cache_stats()["misses"]
    assert misses == 2      # inner engine build + farm shard_map wrapper
    sweep(pts, devices=1)   # repeat sweep: pure cache hit
    assert sim_cache_stats()["misses"] == misses
    assert "farm[1]:jnp" in sim_cache_stats()["partitions"]


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (CI farm lane)")
def test_sweep_multi_device_bit_identical_with_padding():
    # 5 points on 2 devices: pads to 6, slices back — masking must be
    # invisible in every stat
    pts = _sweep_points(n=5)
    ref = sweep(pts)
    farm = sweep(pts, devices=2)
    for i, (r, f) in enumerate(zip(ref, farm)):
        assert_results_equal(r, f, ctx=f"point{i}")


def test_farm_batch_rejects_missing_devices():
    pts = _sweep_points(n=4)
    n = jax.device_count() + 1
    with pytest.raises(ValueError, match="host_platform_device_count"):
        farm_batch([s for s, _ in pts], [w for _, w in pts], devices=n)


# --------------------------------------------------------------------- #
# tier (b): row-sharded simulate == single-device simulate
# --------------------------------------------------------------------- #
def _mixed_wl(seed=3):
    return Workload.make("uniform_random",
                         rates={"narrow": 0.2, "wide": 0.7},
                         counts={"narrow": 4, "wide": 3},
                         seed=seed, write_frac=0.5)


def _mesh_spec(cycles=500):
    return NocSpec.narrow_wide(4, 4, cycles=cycles)


def _torus_spec(cycles=500):
    return NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                               routing=RoutingPolicy.xy(2), cycles=cycles)


@pytest.mark.parametrize("mk", [_mesh_spec, _torus_spec],
                         ids=["mesh", "torus_vc"])
def test_rowshard1_flit_identical(mk):
    spec, wl = mk(), _mixed_wl()
    ref = simulate(spec, wl)
    sharded = simulate(spec, wl, shard=RowShard(1))
    assert_results_equal(ref, sharded, ctx="rowshard1")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (CI farm lane)")
@pytest.mark.parametrize("mk", [_mesh_spec, _torus_spec],
                         ids=["mesh", "torus_vc"])
def test_rowshard2_flit_identical(mk):
    spec, wl = mk(), _mixed_wl(seed=5)
    ref = simulate(spec, wl)
    sharded = simulate(spec, wl, shard=RowShard(2))
    assert_results_equal(ref, sharded, ctx="rowshard2")


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices (CI farm lane)")
def test_rowshard4_flit_identical_torus():
    spec, wl = _torus_spec(), _mixed_wl(seed=11)
    ref = simulate(spec, wl)
    sharded = simulate(spec, wl, shard=RowShard(4))
    assert_results_equal(ref, sharded, ctx="rowshard4")


def test_rowshard2_flit_identical_subprocess(subproc):
    """Tier-1 coverage of the real halo exchange (2 shards, wrap and
    no-wrap) even when the main process sees one device."""
    subproc("""
        import numpy as np
        from repro.noc import (NocSpec, RoutingPolicy, RowShard, Torus,
                               Workload, simulate)
        wl = Workload.make("uniform_random",
                           rates={"narrow": 0.2, "wide": 0.7},
                           counts={"narrow": 4, "wide": 3},
                           seed=5, write_frac=0.5)
        for spec in (NocSpec.narrow_wide(4, 4, cycles=400),
                     NocSpec.narrow_wide(4, 4, topology=Torus(4, 4),
                                         routing=RoutingPolicy.xy(2),
                                         cycles=400)):
            ref = simulate(spec, wl)
            sh = simulate(spec, wl, shard=RowShard(2))
            for c in ref.classes:
                for f in ("done", "avg_lat", "max_lat", "beats_rx",
                          "w_done", "w_avg_lat", "w_beats_rx"):
                    np.testing.assert_array_equal(
                        getattr(ref.classes[c], f),
                        getattr(sh.classes[c], f), err_msg=f"{c}.{f}")
            for ch in ref.channels:
                np.testing.assert_array_equal(
                    ref.channels[ch].link_moves,
                    sh.channels[ch].link_moves)
            assert bool(ref.drained) == bool(sh.drained)
        print("rowshard2 ok")
    """, n_devices=2)


def test_rowshard_validation():
    spec = _mesh_spec()
    with pytest.raises(ValueError, match="positive int"):
        RowShard(0)
    with pytest.raises(ValueError, match="positive int"):
        RowShard(True)
    with pytest.raises(ValueError, match="divisible"):
        simulate(spec, _mixed_wl(), shard=RowShard(3))
    with pytest.raises(ValueError, match="jnp"):
        simulate(spec, _mixed_wl(), shard=RowShard(1), backend="pallas")
    from repro.noc import FaultModel
    faulty = NocSpec.narrow_wide(4, 4, cycles=200,
                                 routing=RoutingPolicy.xy(3),
                                 topology=Torus(4, 4),
                                 faults=FaultModel(dead_links=((1, 2),)))
    with pytest.raises(NotImplementedError):
        simulate(faulty, _mixed_wl(), shard=RowShard(1))


# --------------------------------------------------------------------- #
# satellite: vectorized route-table compile path (byte identity)
# --------------------------------------------------------------------- #
def _reference_expand(policy, topo):
    """The straightforward per-(port, VC) loop expansion the vectorized
    ``routing._compile`` replaced — kept here as the oracle."""
    from repro.noc.routing import _plane_tables
    nbr, opp, _ = topo.tables()
    R, P = nbr.shape
    V, K = policy.n_vcs, policy.n_planes
    v_pp = policy.vcs_per_plane(topo)
    planes, bits = _plane_tables(policy, topo)
    vc_of_hop = np.stack([np.minimum(k * v_pp + b, V - 1)
                          for k, b in enumerate(bits)])
    dest_ids = np.arange(R)
    for k in range(K):
        vc_of_hop[k, dest_ids, dest_ids] = 0
    Pv = (P - 1) * V + 1
    nbr_v = np.full((R, Pv), -1, np.int64)
    opp_v = np.full((R, Pv), Pv - 1, np.int64)
    for p in range(P - 1):
        for v in range(V):
            q = p * V + v
            nbr_v[:, q] = nbr[:, p]
            opp_v[:, q] = np.where(nbr[:, p] >= 0,
                                   opp[:, p] * V + v, Pv - 1)
    route_v = np.full((R, K * R), Pv - 1, np.int64)
    off_diag = dest_ids[:, None] != dest_ids[None, :]
    for k in range(K):
        virt = planes[k] * V + vc_of_hop[k]
        block = route_v[:, k * R:(k + 1) * R]
        block[off_diag] = virt[off_diag]
    return nbr_v, opp_v, route_v, vc_of_hop


@pytest.mark.parametrize("topo,policy", [
    (Mesh(32, 32), RoutingPolicy.xy(2)),
    (Torus(32, 32), RoutingPolicy.xy(2)),
    (Mesh(32, 32), RoutingPolicy.o1turn(2)),
    (Torus(32, 32), RoutingPolicy.o1turn(4)),
    (Mesh(16, 16), RoutingPolicy.valiant(4, 2)),
    (Mesh(16, 16, express=(2, 4)), RoutingPolicy.xy(3)),
], ids=["mesh32_xy2", "torus32_xy2", "mesh32_o1turn", "torus32_o1turn4",
        "mesh16_valiant", "mesh16_express_xy3"])
def test_route_tables_byte_identical_to_reference(topo, policy):
    rt = policy.compile(topo)
    nbr_r, opp_r, route_r, vch_r = _reference_expand(policy, topo)
    for got, ref in ((rt.nbr, nbr_r), (rt.opp, opp_r),
                     (rt.route, route_r), (rt.vc_of_hop, vch_r)):
        assert got.dtype == ref.dtype
        assert got.tobytes() == ref.tobytes()


def test_feeder_tables_byte_identical_to_reference():
    from repro.core.noc_sim.router import feeder_tables
    for topo in (Mesh(32, 32), Torus(32, 32), Mesh(8, 8, express=(2,))):
        nbr, opp, _ = topo.tables()
        R, P = nbr.shape
        src_r = np.full((R, P), -1, np.int64)
        src_o = np.full((R, P), -1, np.int64)
        for t in range(R):
            for o in range(P - 1):
                if nbr[t, o] < 0:
                    continue
                r, p = int(nbr[t, o]), int(opp[t, o])
                assert src_r[r, p] < 0
                src_r[r, p], src_o[r, p] = t, o
        got_r, got_o = feeder_tables(nbr, opp)
        assert got_r.tobytes() == src_r.tobytes()
        assert got_o.tobytes() == src_o.tobytes()


def test_feeder_tables_duplicate_error_message():
    from repro.core.noc_sim.router import feeder_tables
    # router 1's ports 0 and 1 both claim input port 0 of router 0;
    # the t-major first-offender semantics of the old loop must hold
    nbr = np.array([[1, -1, -1], [0, 0, -1]])
    opp = np.array([[0, 2, 2], [0, 0, 2]])
    with pytest.raises(ValueError,
                       match=r"input port 0:0 is fed by two links "
                             r"\(1:0 and 1:1\)"):
        feeder_tables(nbr, opp)


def test_hop_table_analytic():
    n = 8
    h = Torus(n, n).hops()
    exp = np.empty((n * n, n * n), np.int64)
    for s in range(n * n):
        for d in range(n * n):
            dx = abs(s % n - d % n)
            dy = abs(s // n - d // n)
            exp[s, d] = min(dx, n - dx) + min(dy, n - dy)
    np.testing.assert_array_equal(h, exp)
    hm = Mesh(n, n).hops()
    for s, d in ((0, 63), (7, 56), (9, 9)):
        assert hm[s, d] == abs(s % n - d % n) + abs(s // n - d // n)


# --------------------------------------------------------------------- #
# satellite: fused-kernel VMEM budget check
# --------------------------------------------------------------------- #
def test_vmem_budget_raises_with_estimate():
    import jax.numpy as jnp
    from repro.kernels.noc_router import fused_fabric_step_pallas
    N, P, D, F = 4096, 5, 8, 6

    def z(*s):
        return jnp.zeros(s, jnp.int32)

    args = (z(N, P, D, F), z(N, P), z(N, P), z(N, P, F), z(N, P),
            z(N, P), z(N), z(N, F), jnp.full((N,), D, jnp.int32),
            z(N, P), z(N, P), z(N, N), z(N, P))
    with pytest.raises(ValueError, match=r"bytes of VMEM .*RowShard"):
        fused_fabric_step_pallas(*args, interpret=False)
    # tightening the budget trips the check on any size; interpret mode
    # never engages it (a small fabric still runs)
    n = 8
    small = (z(n, P, D, F), z(n, P), z(n, P), z(n, P, F), z(n, P),
             z(n, P), z(n), z(n, F), jnp.full((n,), D, jnp.int32),
             z(n, P), z(n, P), z(n, n), z(n, P))
    with pytest.raises(ValueError, match="VMEM"):
        fused_fabric_step_pallas(*small, interpret=False,
                                 vmem_budget_bytes=64)
    out = fused_fabric_step_pallas(*small, interpret=True)
    assert out[0].shape == (n, P, D, F)
